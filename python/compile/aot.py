"""AOT pipeline: lower the L2 model + L1 kernels to HLO text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model config (fixed shapes; the coordinator pads):

    init_<cfg>        (seed i32)                             -> params...
    fwd_<cfg>         (params..., tokens i32[B,T])           -> logits
    loss_<cfg>        (params..., tokens i32[B,T+1])         -> loss
    train_step_<cfg>  (params..., mu..., nu..., step, tokens, lr)
                                                             -> params', mu', nu', loss
    prefill_<cfg>     (params..., state..., tokens i32[B,Tp])-> logits[B,V], state'...
    decode_step_<cfg> (params..., state..., tokens i32[B])   -> logits[B,V], state'...

plus kernel-only microbench artifacts lowered through the *Pallas* kernels
(kernel_<mixer>_n<N>_d<D>), proving the L1 -> HLO -> Rust path.

``artifacts/manifest.json`` records every artifact's input/output specs,
parameter/state tree-flatten order, and the model config — the Rust
``runtime::artifact`` module parses it.

Usage: ``python -m compile.aot --out-dir ../artifacts [--only NAME]``
(the Makefile drives this; it is incremental at the Makefile level).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ahla, hla2, hla3, linear_attn
from .model import HlaConfig

# ---------------------------------------------------------------------------
# config registry
# ---------------------------------------------------------------------------

# name -> {cfg, train_bt, decode_b, prefill_t, kinds}
CONFIGS: dict[str, dict] = {}


def _register(cfg: HlaConfig, *, train_bt=(8, 256), decode_b=8, prefill_t=64, kinds=None):
    CONFIGS[cfg.name] = {
        "cfg": cfg,
        "train_bt": train_bt,
        "decode_b": decode_b,
        "prefill_t": prefill_t,
        "kinds": kinds or ("init", "fwd", "loss", "train_step", "prefill", "decode_step"),
    }


_register(
    HlaConfig(name="micro", d_model=64, n_layers=2, n_heads=2, chunk=16),
    train_bt=(2, 32),
    decode_b=2,
    prefill_t=16,
)
_register(HlaConfig(name="tiny", d_model=256, n_layers=4, n_heads=4, chunk=64))
_register(
    HlaConfig(name="tiny-linear", mixer="linear", d_model=256, n_layers=4, n_heads=4, chunk=64)
)
_register(
    HlaConfig(name="micro-ahla", mixer="ahla", d_model=64, n_layers=2, n_heads=2, chunk=16),
    train_bt=(2, 32),
    decode_b=2,
    prefill_t=16,
)
_register(
    HlaConfig(
        name="micro-hla3", mixer="hla3", d_model=64, n_layers=2, n_heads=2, chunk=16, gamma=1.0
    ),
    train_bt=(2, 32),
    decode_b=2,
    prefill_t=16,
)
_register(
    HlaConfig(name="micro-linear", mixer="linear", d_model=64, n_layers=2, n_heads=2, chunk=16),
    train_bt=(2, 32),
    decode_b=2,
    prefill_t=16,
)
_register(
    HlaConfig(name="micro-mq", d_model=64, n_layers=2, n_heads=2, chunk=16, multi_query=True),
    train_bt=(2, 32),
    decode_b=2,
    prefill_t=16,
    kinds=("init", "fwd", "decode_step"),
)

# kernel microbench shapes: (mixer, n, d)
KERNEL_SHAPES = [
    ("hla2", 1024, 64),
    ("ahla", 1024, 64),
    ("hla3", 1024, 64),
    ("linear", 1024, 64),
    ("hla2", 4096, 64),
]


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (xla_extension-0.5.1-safe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def _flatten_specs(tree):
    return [_spec(leaf) for leaf in jax.tree_util.tree_leaves(tree)]


def _emit(out_dir, name, fn, example_args, manifest, kind, cfg_name, extra=None):
    """Lower ``fn`` at ``example_args`` and write HLO text + manifest entry."""
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    out_shapes = jax.eval_shape(fn, *example_args)
    entry = {
        "file": f"{name}.hlo.txt",
        "kind": kind,
        "config": cfg_name,
        "inputs": _flatten_specs(example_args),
        "outputs": _flatten_specs(out_shapes),
    }
    if extra:
        entry.update(extra)
    manifest["artifacts"][name] = entry
    print(
        f"  wrote {name}.hlo.txt ({len(text) / 1e6:.2f} MB, "
        f"{len(entry['inputs'])} in / {len(entry['outputs'])} out)"
    )


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tree_sds(tree):
    return jax.tree_util.tree_map(lambda x: _sds(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# per-config emission
# ---------------------------------------------------------------------------


def emit_config(out_dir, name, entry, manifest, only=None):
    cfg: HlaConfig = entry["cfg"]
    bt, t = entry["train_bt"]
    db, pt = entry["decode_b"], entry["prefill_t"]
    kinds = entry["kinds"]

    params_shape = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    n_params = len(jax.tree_util.tree_leaves(params_shape))
    state_shape = jax.eval_shape(lambda: model.state_init(cfg, db))
    n_state = len(jax.tree_util.tree_leaves(state_shape))
    state_paths = [
        (jax.tree_util.keystr(p), list(l.shape))
        for p, l in jax.tree_util.tree_flatten_with_path(state_shape)[0]
    ]

    manifest["configs"][cfg.name] = {
        **dataclasses.asdict(cfg),
        "head_dim": cfg.head_dim,
        "d_ffn": cfg.d_ffn,
        "kv_heads": cfg.kv_heads,
        "n_params": int(cfg.n_params()),
        "n_param_tensors": n_params,
        "n_state_tensors": n_state,
        "param_paths": model.param_paths(cfg),
        "state_paths": state_paths,
        "train_batch": bt,
        "train_seq": t,
        "decode_batch": db,
        "prefill_len": pt,
    }

    def want(k):
        return k in kinds and (only is None or only == k)

    pflat, ptree = jax.tree_util.tree_flatten(_tree_sds(params_shape))
    sflat, stree = jax.tree_util.tree_flatten(_tree_sds(state_shape))

    def unflatten_p(args):
        return jax.tree_util.tree_unflatten(ptree, args)

    def unflatten_s(args):
        return jax.tree_util.tree_unflatten(stree, args)

    if want("init"):

        def init_fn(seed):
            p = model.init_params(jax.random.PRNGKey(seed), cfg)
            return tuple(jax.tree_util.tree_leaves(p))

        _emit(out_dir, f"init_{name}", init_fn, (_sds((), jnp.int32),), manifest, "init", name)

    if want("fwd"):

        def fwd_fn(*args):
            p = unflatten_p(args[:n_params])
            return (model.forward(cfg, p, args[n_params]),)

        _emit(
            out_dir,
            f"fwd_{name}",
            fwd_fn,
            (*pflat, _sds((bt, t), jnp.int32)),
            manifest,
            "fwd",
            name,
        )

    if want("loss"):

        def loss_fn(*args):
            p = unflatten_p(args[:n_params])
            return (model.loss_fn(cfg, p, args[n_params]),)

        _emit(
            out_dir,
            f"loss_{name}",
            loss_fn,
            (*pflat, _sds((bt, t + 1), jnp.int32)),
            manifest,
            "loss",
            name,
        )

    if want("train_step"):

        def ts_fn(*args):
            p = unflatten_p(args[:n_params])
            mu = unflatten_p(args[n_params : 2 * n_params])
            nu = unflatten_p(args[2 * n_params : 3 * n_params])
            step, tokens, lr = args[3 * n_params :]
            p2, mu2, nu2, loss = model.train_step(cfg, p, mu, nu, step, tokens, lr)
            return (
                *jax.tree_util.tree_leaves(p2),
                *jax.tree_util.tree_leaves(mu2),
                *jax.tree_util.tree_leaves(nu2),
                loss,
            )

        _emit(
            out_dir,
            f"train_step_{name}",
            ts_fn,
            (*pflat, *pflat, *pflat, _sds(()), _sds((bt, t + 1), jnp.int32), _sds(())),
            manifest,
            "train_step",
            name,
        )

    if want("prefill"):

        def prefill_fn(*args):
            p = unflatten_p(args[:n_params])
            s = unflatten_s(args[n_params : n_params + n_state])
            logits, s2 = model.prefill(cfg, p, s, args[n_params + n_state])
            return (logits, *jax.tree_util.tree_leaves(s2))

        _emit(
            out_dir,
            f"prefill_{name}",
            prefill_fn,
            (*pflat, *sflat, _sds((db, pt), jnp.int32)),
            manifest,
            "prefill",
            name,
        )

    if want("decode_step"):

        def dec_fn(*args):
            p = unflatten_p(args[:n_params])
            s = unflatten_s(args[n_params : n_params + n_state])
            logits, s2 = model.decode_step(cfg, p, s, args[n_params + n_state])
            return (logits, *jax.tree_util.tree_leaves(s2))

        _emit(
            out_dir,
            f"decode_step_{name}",
            dec_fn,
            (*pflat, *sflat, _sds((db,), jnp.int32)),
            manifest,
            "decode_step",
            name,
        )

        # occupancy-adaptive bucketing (rust coordinator): the same
        # decode step at every power-of-two batch width below decode_b.
        # Params are batch-independent; only the state leaves and the
        # token vector narrow.  The Rust side discovers these by token
        # shape (runtime/bucket.rs) and repacks lane state exactly
        # between widths, so narrow buckets serve low occupancy without
        # paying the full-width step.
        w = 1
        while w < db:
            state_shape_w = jax.eval_shape(lambda w=w: model.state_init(cfg, w))
            sflat_w, stree_w = jax.tree_util.tree_flatten(_tree_sds(state_shape_w))

            def dec_fn_w(*args, stree_w=stree_w):
                p = unflatten_p(args[:n_params])
                s = jax.tree_util.tree_unflatten(stree_w, args[n_params : n_params + n_state])
                logits, s2 = model.decode_step(cfg, p, s, args[n_params + n_state])
                return (logits, *jax.tree_util.tree_leaves(s2))

            _emit(
                out_dir,
                f"decode_step_{name}_b{w}",
                dec_fn_w,
                (*pflat, *sflat_w, _sds((w,), jnp.int32)),
                manifest,
                "decode_step",
                name,
            )
            w *= 2


def emit_kernels(out_dir, manifest, only=None):
    """Kernel-only artifacts through the Pallas path (interpret=True)."""
    fns = {
        "hla2": lambda q, k, v: (hla2.hla2_pallas(q, k, v, chunk=64, gamma=0.99, norm_mode="abs"),),
        "ahla": lambda q, k, v: (ahla.ahla_pallas(q, k, v, chunk=64, gamma=0.99, norm_mode="abs"),),
        "hla3": lambda q, k, v: (hla3.hla3_pallas(q, k, v, chunk=64, gamma=1.0, norm_mode="abs"),),
        "linear": lambda q, k, v: (
            linear_attn.linear_attn_pallas(q, k, v, chunk=64, gamma=0.99, norm_mode="abs"),
        ),
    }
    for mixer, n, d in KERNEL_SHAPES:
        name = f"kernel_{mixer}_n{n}_d{d}"
        if only is not None and only != name:
            continue
        spec = _sds((n, d))
        _emit(
            out_dir,
            name,
            fns[mixer],
            (spec, spec, spec),
            manifest,
            "kernel",
            mixer,
            extra={"n": n, "d": d},
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    ap.add_argument("--only", default=None, help="restrict to one config (or 'kernels')")
    ap.add_argument("--kind", default=None, help="restrict to one artifact kind")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"configs": {}, "artifacts": {}}
    for name, entry in CONFIGS.items():
        if args.only is not None and args.only not in (name, "all"):
            continue
        print(f"config {name}: {entry['cfg'].n_params() / 1e6:.2f}M params, mixer={entry['cfg'].mixer}")
        emit_config(out_dir, name, entry, manifest, only=args.kind)
    if args.only in (None, "all", "kernels"):
        emit_kernels(out_dir, manifest)

    mpath = os.path.join(out_dir, "manifest.json")
    if args.only is not None and os.path.exists(mpath):
        old = json.load(open(mpath))
        old["configs"].update(manifest["configs"])
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
