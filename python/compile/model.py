"""L2: HLA transformer in JAX — the paper's mixer as a drop-in attention
replacement (Section 5.2) inside a standard pre-norm decoder block.

Only the attention sublayer changes per Section 5.2: RMSNorm -> mixer ->
residual, RMSNorm -> SwiGLU FFN -> residual, tied LM head.  The mixer is
selected by ``HlaConfig.mixer``:

    hla2      masked second-order HLA (Theorem 3.1), chunked
    ahla      asymmetric second-order HLA (Theorem 6.1), chunked
    hla3      canonical third-order HLA, chunked
    linear    first-order linear attention baseline
    softmax   quadratic softmax attention baseline (Section 2.1)

Everything in this module is build-time only: ``aot.py`` lowers the jitted
functions to HLO text that the Rust runtime loads; Python never runs on the
request path.

Training-path functions (``loss_fn``, ``train_step``) use the
differentiable ``*_chunked`` implementations; streaming-path functions
(``prefill``, ``decode_step``) use the same chunk math plus the per-token
``*_step`` updates from ``kernels.ref``, so serving state composes exactly
with training activations (test_model.py asserts decode == forward).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import chunk_math, ref
from .kernels.ahla import ahla_chunked
from .kernels.hla2 import hla2_chunked
from .kernels.hla3 import hla3_chunked
from .kernels.linear_attn import linear_attn_chunked

MIXERS = ("hla2", "ahla", "hla3", "linear", "softmax")


@dataclasses.dataclass(frozen=True)
class HlaConfig:
    """Model + operator configuration (burned into the AOT artifacts)."""

    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    ffn_mult: float = 2.6667
    mixer: str = "hla2"
    chunk: int = 64
    gamma: float = 0.99
    lam: float = 0.0
    norm_mode: str = "abs"
    eps: float = 1e-6
    multi_query: bool = False
    name: str = "tiny"

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ffn(self) -> int:
        # round to a multiple of 32 for tidy matmuls
        return max(32, int(self.d_model * self.ffn_mult) // 32 * 32)

    @property
    def kv_heads(self) -> int:
        """Multi-query sharing (Section 5.2): one K/V head shared."""
        return 1 if self.multi_query else self.n_heads

    def n_params(self) -> int:
        d, f = self.d_model, self.d_ffn
        per_layer = (
            2 * d
            + d * self.n_heads * self.head_dim * 2  # wq, wo
            + d * self.kv_heads * self.head_dim * 2  # wk, wv
            + 3 * d * f
        )
        return self.vocab * d + d + self.n_layers * per_layer


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_params(key, cfg: HlaConfig):
    """Scaled-normal init; embedding doubles as the (tied) LM head."""
    d, dh, hq, hkv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.kv_heads
    f = cfg.d_ffn

    def dense(key, fan_in, shape):
        return jax.random.normal(key, shape, jnp.float32) * (fan_in**-0.5)

    keys = jax.random.split(key, 2 + cfg.n_layers)
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "norm_f": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + li], 8)
        params["layers"].append(
            {
                "norm1": jnp.ones((d,), jnp.float32),
                "wq": dense(ks[0], d, (d, hq * dh)),
                "wk": dense(ks[1], d, (d, hkv * dh)),
                "wv": dense(ks[2], d, (d, hkv * dh)),
                "wo": dense(ks[3], hq * dh, (hq * dh, d)),
                "norm2": jnp.ones((d,), jnp.float32),
                "w_gate": dense(ks[4], d, (d, f)),
                "w_up": dense(ks[5], d, (d, f)),
                "w_down": dense(ks[6], f, (f, d)),
            }
        )
    return params


def param_paths(cfg: HlaConfig):
    """Flattened parameter names + shapes in tree_flatten order (manifest)."""
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    return [(jax.tree_util.keystr(path), list(leaf.shape)) for path, leaf in leaves]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def _mixer_seq(cfg: HlaConfig, q, k, v):
    """Single-head sequence mixer [T, dh] -> [T, dh] (training path)."""
    kw = dict(norm_mode=cfg.norm_mode, eps=cfg.eps)
    if cfg.mixer == "hla2":
        return hla2_chunked(q, k, v, chunk=cfg.chunk, gamma=cfg.gamma, lam=cfg.lam, **kw)
    if cfg.mixer == "ahla":
        return ahla_chunked(q, k, v, chunk=cfg.chunk, gamma=cfg.gamma, **kw)
    if cfg.mixer == "hla3":
        return hla3_chunked(q, k, v, chunk=cfg.chunk, gamma=cfg.gamma, **kw)
    if cfg.mixer == "linear":
        return linear_attn_chunked(q, k, v, chunk=cfg.chunk, gamma=cfg.gamma, **kw)
    if cfg.mixer == "softmax":
        return ref.softmax_attention(q, k, v, scale=1.0)  # q,k pre-scaled
    raise ValueError(f"unknown mixer {cfg.mixer!r}")


def _project_heads(cfg: HlaConfig, lp, x):
    """x [T, D] -> per-head q, k, v [H, T, dh], with 1/sqrt(dh) q/k scaling
    and multi-query K/V broadcast when enabled."""
    t = x.shape[0]
    dh = cfg.head_dim
    scale = dh**-0.5
    q = (x @ lp["wq"]).reshape(t, cfg.n_heads, dh).transpose(1, 0, 2) * scale
    k = (x @ lp["wk"]).reshape(t, cfg.kv_heads, dh).transpose(1, 0, 2) * scale
    v = (x @ lp["wv"]).reshape(t, cfg.kv_heads, dh).transpose(1, 0, 2)
    if cfg.multi_query and cfg.n_heads > 1:
        k = jnp.broadcast_to(k, (cfg.n_heads, t, dh))
        v = jnp.broadcast_to(v, (cfg.n_heads, t, dh))
    return q, k, v


def mixer_apply(cfg: HlaConfig, lp, x):
    """HLA mixer sublayer on a single sequence x [T, D]."""
    q, k, v = _project_heads(cfg, lp, x)
    o = jax.vmap(lambda qh, kh, vh: _mixer_seq(cfg, qh, kh, vh))(q, k, v)
    o = o.transpose(1, 0, 2).reshape(x.shape[0], cfg.n_heads * cfg.head_dim)
    return o @ lp["wo"]


def ffn_apply(lp, x):
    return (jax.nn.silu(x @ lp["w_gate"]) * (x @ lp["w_up"])) @ lp["w_down"]


def block_apply(cfg: HlaConfig, lp, x):
    x = x + mixer_apply(cfg, lp, rmsnorm(x, lp["norm1"]))
    x = x + ffn_apply(lp, rmsnorm(x, lp["norm2"]))
    return x


def forward(cfg: HlaConfig, params, tokens):
    """tokens [B, T] int32 -> logits [B, T, V] (tied LM head)."""

    def one(seq):
        x = params["embed"][seq]
        for lp in params["layers"]:
            x = block_apply(cfg, lp, x)
        x = rmsnorm(x, params["norm_f"])
        return x @ params["embed"].T

    return jax.vmap(one)(tokens)


def loss_fn(cfg: HlaConfig, params, tokens):
    """Next-token cross entropy; tokens [B, T+1]."""
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# training (Adam)
# ---------------------------------------------------------------------------


def adam_init(params):
    return (
        jax.tree_util.tree_map(jnp.zeros_like, params),
        jax.tree_util.tree_map(jnp.zeros_like, params),
    )


def train_step(cfg: HlaConfig, params, mu, nu, step, tokens, lr):
    """One Adam step; ``lr`` and ``step`` are traced scalars so the Rust
    driver owns the schedule.  Returns (params', mu', nu', loss)."""
    b1, b2, eps = 0.9, 0.95, 1e-8
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    step = step + 1.0
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, mu, grads)
    nu = jax.tree_util.tree_map(lambda n, g: b2 * n + (1 - b2) * g * g, nu, grads)
    bias1 = 1.0 - b1**step
    bias2 = 1.0 - b2**step
    params = jax.tree_util.tree_map(
        lambda p, m, n: p - lr * (m / bias1) / (jnp.sqrt(n / bias2) + eps), params, mu, nu
    )
    return params, mu, nu, loss


# ---------------------------------------------------------------------------
# streaming inference: recurrent state, prefill, decode_step
# ---------------------------------------------------------------------------

STATE_COMPONENTS = {
    "hla2": ("s", "c", "m", "g", "h"),
    "ahla": ("p", "m", "e", "n"),
    "hla3": ("s", "p", "m", "f", "eta"),
    "linear": ("p", "m"),
}


def state_init(cfg: HlaConfig, batch: int):
    """Zero recurrent state, stacked [L, B, H, ...] per component.

    Component sets per mixer (dh = head_dim = dv):
      hla2:   s [dh,dh], c [dh,dv], m [dh], g [dh,dv], h [dh]   (Thm 3.1)
      ahla:   p [dh,dv], m [dh], e [dh,dv], n [dh]              (Thm 6.1)
      hla3:   s [dh,dh], p [dh,dv], m [dh], f [dh,dv], eta [dh] (canonical)
      linear: p [dh,dv], m [dh]
    """
    lbh = (cfg.n_layers, batch, cfg.n_heads)
    dh = cfg.head_dim
    z = lambda *shape: jnp.zeros(lbh + shape, jnp.float32)
    mat = {"s": (dh, dh), "c": (dh, dh), "p": (dh, dh), "g": (dh, dh), "e": (dh, dh), "f": (dh, dh)}
    if cfg.mixer not in STATE_COMPONENTS:
        raise ValueError(f"mixer {cfg.mixer!r} has no constant-size streaming state")
    return {c: z(*mat.get(c, (dh,))) for c in STATE_COMPONENTS[cfg.mixer]}


def _state_tuple(cfg: HlaConfig, st):
    if cfg.mixer == "hla2":
        return ref.Hla2State(st["s"], st["c"], st["m"], st["g"], st["h"])
    if cfg.mixer == "ahla":
        return ref.AhlaState(st["p"], st["m"], st["e"], st["n"])
    if cfg.mixer == "hla3":
        return ref.Hla3State(st["s"], st["p"], st["m"], st["f"], st["eta"])
    return (st["p"], st["m"])


def _state_dict(cfg: HlaConfig, tup):
    comps = STATE_COMPONENTS[cfg.mixer]
    return dict(zip(comps, tuple(tup)))


def _mixer_step(cfg: HlaConfig, st, qt, kt, vt):
    """One streaming token for one head: (out [dv], new state tuple)."""
    if cfg.mixer == "hla2":
        new = ref.hla2_step(st, qt, kt, vt, gamma=cfg.gamma)
        out = ref.hla2_out(new, qt, norm_mode=cfg.norm_mode, eps=cfg.eps, lam=cfg.lam)
        return out, new
    if cfg.mixer == "ahla":
        new = ref.ahla_step(st, qt, kt, vt, gamma=cfg.gamma)
        num, den = qt @ new.e, qt @ new.n
    elif cfg.mixer == "hla3":
        new = ref.hla3_step(st, qt, kt, vt, gamma=cfg.gamma)
        num, den = qt @ new.f, qt @ new.eta
    else:  # linear
        p, m = st
        p = cfg.gamma * p + jnp.outer(kt, vt)
        m = cfg.gamma * m + kt
        new = (p, m)
        num, den = qt @ p, qt @ m
    out = ref.apply_normalization(num[None, :], den[None], cfg.norm_mode, cfg.eps)[0]
    return out, new


def decode_step(cfg: HlaConfig, params, state, tokens):
    """One decode step: tokens [B] int32 -> (logits [B, V], state').

    This is the O(1)-per-token serving path: constant-size state, no
    KV-cache, per-token cost independent of context length (bench E2/E8).
    """
    comps = STATE_COMPONENTS[cfg.mixer]
    x = params["embed"][tokens]  # [B, D]
    b = x.shape[0]
    dh = cfg.head_dim
    scale = dh**-0.5
    new_state = {c: [] for c in comps}
    for li, lp in enumerate(params["layers"]):
        h = rmsnorm(x, lp["norm1"])
        q = (h @ lp["wq"]).reshape(b, cfg.n_heads, dh) * scale
        k = (h @ lp["wk"]).reshape(b, cfg.kv_heads, dh) * scale
        v = (h @ lp["wv"]).reshape(b, cfg.kv_heads, dh)
        if cfg.multi_query and cfg.n_heads > 1:
            k = jnp.broadcast_to(k, (b, cfg.n_heads, dh))
            v = jnp.broadcast_to(v, (b, cfg.n_heads, dh))
        st_l = _state_tuple(cfg, {c: state[c][li] for c in comps})
        out, new = jax.vmap(jax.vmap(lambda s, a, bb, c: _mixer_step(cfg, s, a, bb, c)))(
            st_l, q, k, v
        )  # vmapped over B then H
        o = out.reshape(b, cfg.n_heads * dh) @ lp["wo"]
        x = x + o
        x = x + ffn_apply(lp, rmsnorm(x, lp["norm2"]))
        nd = _state_dict(cfg, new)
        for c in comps:
            new_state[c].append(nd[c])
    x = rmsnorm(x, params["norm_f"])
    logits = x @ params["embed"].T
    return logits, {c: jnp.stack(v) for c, v in new_state.items()}


def _mixer_prefill(cfg: HlaConfig, carry_tuple, q, k, v):
    """Chunked prefill for one head; returns (outputs, carry')."""
    kw = dict(chunk=cfg.chunk, norm_mode=cfg.norm_mode, eps=cfg.eps, return_carry=True)
    if cfg.mixer == "hla2":
        return hla2_chunked(
            q, k, v, gamma=cfg.gamma, lam=cfg.lam, carry=chunk_math.Hla2Carry(*carry_tuple), **kw
        )
    if cfg.mixer == "ahla":
        return ahla_chunked(
            q, k, v, gamma=cfg.gamma, carry=chunk_math.AhlaCarry(*carry_tuple), **kw
        )
    if cfg.mixer == "hla3":
        return hla3_chunked(
            q, k, v, gamma=cfg.gamma, carry=chunk_math.Hla3Carry(*carry_tuple), **kw
        )
    return linear_attn_chunked(q, k, v, gamma=cfg.gamma, carry=tuple(carry_tuple), **kw)


def prefill(cfg: HlaConfig, params, state, tokens):
    """Chunked prompt ingestion: tokens [B, Tp] -> (logits_last [B, V], state').

    The chunk carry *is* the decode state (same summaries), so prefill and
    decode compose exactly — asserted by test_model.py.  Tp must be a
    multiple of cfg.chunk (the coordinator pads prompts).
    """
    comps = list(STATE_COMPONENTS[cfg.mixer])

    def one(seq, *st_comps):
        x = params["embed"][seq]
        new_layers = {c: [] for c in comps}
        for li, lp in enumerate(params["layers"]):
            h = rmsnorm(x, lp["norm1"])
            q, k, v = _project_heads(cfg, lp, h)

            def pre(qh, kh, vh, *carry):
                return _mixer_prefill(cfg, carry, qh, kh, vh)

            carr = [st_comps[ci][li] for ci in range(len(comps))]
            out, new = jax.vmap(pre)(q, k, v, *carr)
            o = out.transpose(1, 0, 2).reshape(x.shape[0], -1) @ lp["wo"]
            x = x + o
            x = x + ffn_apply(lp, rmsnorm(x, lp["norm2"]))
            nd = _state_dict(cfg, new)
            for c in comps:
                new_layers[c].append(nd[c])
        x = rmsnorm(x, params["norm_f"])
        logits = x[-1] @ params["embed"].T
        return (logits, *[jnp.stack(new_layers[c]) for c in comps])

    # state is [L, B, H, ...] -> vmap over the batch axis
    st_b = [jnp.moveaxis(state[c], 1, 0) for c in comps]
    res = jax.vmap(one)(tokens, *st_b)
    logits = res[0]
    new_state = {c: jnp.moveaxis(res[1 + ci], 0, 1) for ci, c in enumerate(comps)}
    return logits, new_state
