"""Chunkwise HLA math shared by the Pallas kernels and the jnp training path.

Each ``*_chunk`` function processes one chunk of ``w`` tokens given the
carry-in prefix state and returns ``(outputs, carry_out)``.  The math is the
closed-form inter/intra-chunk decomposition of the paper's Section 4
(second order), Section 6.2 (AHLA) and Section 7.3 (third order), derived in
DESIGN.md.  The same functions are

* called inside the Pallas kernel bodies (``hla2.py`` etc.) on VMEM tiles, and
* driven by ``jax.lax.scan`` over chunks for the differentiable L2 model path

so the kernel and the training graph share one implementation of the math.

Decay convention is monoid-consistent (see ``ref.py`` docstring): carries are
attenuated by ``gamma**w`` across a chunk and cross terms use the attenuated
carry.  The inter-chunk cross term composes with the *plain* (undecayed)
segment moments — e.g. ``G_new = g^w G0 + (Kc^T Kc)(g^w C0) + G_loc`` — which
is what the serial recurrence implies (DESIGN.md errata #2/#3: the paper's
printed decayed operators attenuate the cross moment a second time).

Within a chunk, local position p runs 1..w.  Notation (all per chunk):

    gp[p]   = gamma**p            carry attenuation seen by token p
    wp[p]   = gamma**(w-p)        token p's attenuation at chunk end
    Gam[t,j]= gamma**(t-j) (j<=t) intra-chunk pairwise decay ("Gamma" mask)

Shapes: qc, kc: [w, d]; vc: [w, dv].  Single head; callers vmap.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from . import ref

__all__ = [
    "Hla2Carry",
    "AhlaCarry",
    "Hla3Carry",
    "hla2_carry_init",
    "ahla_carry_init",
    "hla3_carry_init",
    "hla2_chunk",
    "ahla_chunk",
    "hla3_chunk",
    "linear_chunk",
    "decay_factors",
]


def decay_factors(w: int, gamma, dtype=jnp.float32):
    """(gp, wp, Gam) decay tensors for a chunk of width w."""
    gamma = jnp.asarray(gamma, dtype)
    p = jnp.arange(1, w + 1, dtype=dtype)
    gp = gamma**p
    wp = gamma ** (w - p)
    t = jnp.arange(w, dtype=dtype)
    expo = t[:, None] - t[None, :]
    gam = jnp.where(expo >= 0, gamma**expo, 0.0)
    return gp, wp, gam


# ---------------------------------------------------------------------------
# second order (masked), Theorem 3.1 + Section 4
# ---------------------------------------------------------------------------


class Hla2Carry(NamedTuple):
    s: jnp.ndarray  # [d, d]
    c: jnp.ndarray  # [d, dv]
    m: jnp.ndarray  # [d]
    g: jnp.ndarray  # [d, dv]
    h: jnp.ndarray  # [d]


def hla2_carry_init(d: int, dv: int, dtype=jnp.float32) -> Hla2Carry:
    z = jnp.zeros
    return Hla2Carry(
        z((d, d), dtype), z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype)
    )


def hla2_chunk(
    carry: Hla2Carry,
    qc,
    kc,
    vc,
    *,
    gamma=1.0,
    lam=0.0,
    masked=True,
    norm_mode="none",
    eps=1e-6,
):
    """One chunk of masked second-order HLA.

    Output decomposition for token t (local index 1..w), derived in
    DESIGN.md from the monoid-consistent serial recurrence.  The carry's
    S0C0 part attenuates as g^{2t} (both indices in the past) while the G0
    correction attenuates as g^t; for g != 1 an additional mixed term
    ``g^t q_t^T (u_t - u~_t) C0`` appears, where ``u_t`` is the *decayed*
    local key moment applied to q_t and ``u~_t`` the plain one (they cancel
    at g == 1, recovering the familiar three-part split):

      past x past:   g^{2t} q_t^T S0 C0  -  g^t q_t^T G0
      past-key mix:  g^t ((Qc S0 Qc^T) . Gam) Vc
      local-key mix: g^t (u_t - u~_t) C0
      intra-chunk:   (((Gam.W) W^T) . Gam) Vc,   W = tril(Qc Kc^T)
    """
    w = qc.shape[0]
    dt = qc.dtype
    gp, wp, gam = decay_factors(w, gamma, dt)
    tril = ref.causal_mask(w, dt)
    stril = ref.strict_causal_mask(w, dt)
    gw = jnp.asarray(gamma, dt) ** w
    ones = jnp.ones((w,), dt)
    gp2 = gp * gp

    s0, c0, m0, g0, h0 = carry
    wmat = tril * (qc @ kc.T)  # [w, w] masked affinity tile
    wdec = gam * wmat  # Gamma . W
    qs0 = qc @ s0  # [w, d]
    mb = (qs0 @ qc.T) * gam  # past-key mix tile (pair-decayed)

    if masked:
        u = wdec @ kc  # decayed local moment rows  [w, d]
        ut = wmat @ kc  # plain  local moment rows  [w, d]
        # Intra-chunk masked part q_t^T (S^B_t C^B_t - G^B_t): the S.C term
        # carries pair weights g^{2t-i-j} (all i,j <= t) while the local G
        # correction removes j < i pairs with weight g^{t-j} (the weight the
        # monoid-consistent recurrence actually assigns them).
        kq_full = kc @ qc.T  # (k_i . q_j), unmasked      [w, w]
        mc = ((wdec @ kq_full) - (wmat @ (kq_full * stril))) * gam
        num = (
            gp2[:, None] * (qc @ (s0 @ c0))
            - gp[:, None] * (qc @ g0)
            + gp[:, None] * (mb @ vc + (u - ut) @ c0)
            + mc @ vc
        )
        den = (
            gp2 * (qc @ (s0 @ m0))
            - gp * (qc @ h0)
            + gp * (mb @ ones + (u - ut) @ m0)
            + mc @ ones
        )
    else:
        # prefix ("unmasked") form o_t = q_t^T S_t C_t, Eq. (3.1)
        u = wdec @ kc
        mc = (u @ qc.T) * gam  # q_t^T S_loc,t q_j (j <= t, decayed)
        num = gp2[:, None] * (qc @ (s0 @ c0)) + gp[:, None] * (u @ c0 + mb @ vc) + mc @ vc
        den = gp2 * (qc @ (s0 @ m0)) + gp * (u @ m0 + mb @ ones) + mc @ ones

    if lam != 0.0:
        # ridge: + lam q_t^T C_t and + lam q_t^T m_t (Algorithm 1 S_eff)
        qq = (qc @ qc.T) * gam
        num = num + lam * (gp[:, None] * (qc @ c0) + qq @ vc)
        den = den + lam * (gp * (qc @ m0) + qq @ jnp.ones((w,), dt))

    out = ref.apply_normalization(num, den, norm_mode, eps)

    # ---- carry update (semidirect product with chunk summary) ----
    kw = kc * wp[:, None]  # decay-weighted keys
    qw = qc * wp[:, None]
    s_dec = kw.T @ kc  # decayed local key moment
    s_plain = kc.T @ kc  # plain local key moment (cross term)
    x = stril * (kc @ qc.T)  # (k_i . q_j), j < i
    xw = x * wp[None, :]  # column-weighted by g^(w-j)
    g_loc = kc.T @ (xw @ vc)
    h_loc = kc.T @ (xw @ jnp.ones((w,), dt))
    g1 = gw * g0 + s_plain @ (gw * c0) + g_loc
    h1 = gw * h0 + s_plain @ (gw * m0) + h_loc
    s1 = gw * s0 + s_dec
    c1 = gw * c0 + qw.T @ vc
    m1 = gw * m0 + jnp.sum(qw, axis=0)
    return out, Hla2Carry(s1, c1, m1, g1, h1)


# ---------------------------------------------------------------------------
# AHLA (Section 6)
# ---------------------------------------------------------------------------


class AhlaCarry(NamedTuple):
    p: jnp.ndarray  # [d, dv]
    m: jnp.ndarray  # [d]
    e: jnp.ndarray  # [d, dv]
    n: jnp.ndarray  # [d]


def ahla_carry_init(d: int, dv: int, dtype=jnp.float32) -> AhlaCarry:
    z = jnp.zeros
    return AhlaCarry(z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype))


def ahla_chunk(carry: AhlaCarry, qc, kc, vc, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """One chunk of masked AHLA (Theorem 6.1 / Eq. 6.2).

    Inner rows r_i = q_i^T P_i (inclusive) split into carry and local parts;
    the outer pass reuses the same decayed affinity tile.
    """
    w = qc.shape[0]
    dt = qc.dtype
    gp, wp, gam = decay_factors(w, gamma, dt)
    tril = ref.causal_mask(w, dt)
    gw = jnp.asarray(gamma, dt) ** w

    p0, m0, e0, n0 = carry
    wdec = (tril * (qc @ kc.T)) * gam  # Gam . W, W = tril(Qc Kc^T)

    r_rows = gp[:, None] * (qc @ p0) + wdec @ vc  # r_i = q_i^T P_i   [w, dv]
    s_rows = gp * (qc @ m0) + wdec @ jnp.ones((w,), dt)  # q_i^T m_i  [w]
    num = gp[:, None] * (qc @ e0) + wdec @ r_rows
    den = gp * (qc @ n0) + wdec @ s_rows
    out = ref.apply_normalization(num, den, norm_mode, eps)

    kw = kc * wp[:, None]
    r_plain = kc.T @ qc  # plain segment cross moment R^KQ (DESIGN errata #3)
    p1 = gw * p0 + kw.T @ vc
    m1 = gw * m0 + jnp.sum(kw, axis=0)
    e1 = gw * e0 + r_plain @ (gw * p0) + kw.T @ (wdec @ vc)
    n1 = gw * n0 + r_plain @ (gw * m0) + kw.T @ (wdec @ jnp.ones((w,), dt))
    return out, AhlaCarry(p1, m1, e1, n1)


# ---------------------------------------------------------------------------
# third order (Section 7); chunk-parallel form requires gamma == 1 (Alg. 4)
# ---------------------------------------------------------------------------


class Hla3Carry(NamedTuple):
    s: jnp.ndarray  # [d, d]   S^K
    p: jnp.ndarray  # [d, dv]  P^KV
    m: jnp.ndarray  # [d]      m^K
    f: jnp.ndarray  # [d, dv]  F (corrected)
    eta: jnp.ndarray  # [d]    eta (corrected denominator)


def hla3_carry_init(d: int, dv: int, dtype=jnp.float32) -> Hla3Carry:
    z = jnp.zeros
    return Hla3Carry(
        z((d, d), dtype), z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype)
    )


def hla3_chunk(carry: Hla3Carry, qc, kc, vc, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """One chunk of canonical masked third-order HLA (any gamma).

    The canonical operator streams as F_t = g F + (S_t q_t)(q_t^T P_t)^T
    (see ``ref.Hla3State``).  Splitting S_u = g^u S0 + S^loc_u and
    P_u = g^u P0 + P^loc_u gives four carry/local products per token u,
    each a masked matmul tile:

      (i)   g^{t+u} (S0 q_u)(q_u^T P0)     tile_sq . Gam . gp[cols] @ Qc P0
      (ii)  g^t     (S0 q_u)(q_u^T Ploc_u) tile_sq . (gp rows) @ b
      (iii) g^t     (Sloc_u q_u)(q_u^T P0) (Qc a^T) . (gp rows) @ Qc P0
      (iv)  g^{t-u} (Sloc_u q_u)(q_u^T Ploc_u)  ((Qc a^T) . Gam) @ b

    with a_u = row_u[(Gam.QcKc^T) Kc] and b_u = row_u[(Gam.QcKc^T) Vc].
    Unlike the paper's Algorithm 4 (stated for gamma == 1 and needing
    O(d^3 dv) segment maps), the canonical chunk composition is exact for
    every gamma with only O(d^2 + d dv) carry.
    """
    w = qc.shape[0]
    dt = qc.dtype
    gp, wp, gam = decay_factors(w, gamma, dt)
    tril = ref.causal_mask(w, dt)
    gw = jnp.asarray(gamma, dt) ** w
    ones = jnp.ones((w,), dt)

    s0, p0, m0, f0, eta0 = carry
    wdec = (tril * (qc @ kc.T)) * gam  # Gam . W
    a = wdec @ kc  # a_u = S^loc_u q_u        [w, d]
    b = wdec @ vc  # b_u = q_u^T P^loc_u      [w, dv]
    bm = wdec @ ones  # q_u^T m^loc_u          [w]
    tile_sq = (qc @ s0 @ qc.T) * gam  # (q_t^T S0 q_u) g^{t-u}, u <= t
    tile_a = (qc @ a.T) * gam  # (q_t . a_u) g^{t-u},  u <= t
    qp0 = qc @ p0  # [w, dv]
    qm0 = qc @ m0  # [w]

    gp2 = gp * gp
    num = (
        gp[:, None] * (qc @ f0)
        + (tile_sq * gp2[None, :]) @ qp0
        + (tile_sq * gp[None, :]) @ b
        + (tile_a * gp[None, :]) @ qp0
        + tile_a @ b
    )
    den = (
        gp * (qc @ eta0)
        + (tile_sq * gp2[None, :]) @ qm0
        + (tile_sq * gp[None, :]) @ bm
        + (tile_a * gp[None, :]) @ qm0
        + tile_a @ bm
    )
    out = ref.apply_normalization(num, den, norm_mode, eps)

    # ---- carry update (chunk-end composition, all gamma) ----
    kw = kc * wp[:, None]
    qgp = qc * gp[:, None]
    s1 = gw * s0 + kw.T @ kc
    p1 = gw * p0 + kw.T @ vc
    m1 = gw * m0 + jnp.sum(kw, axis=0)
    sq_gp = qgp.T @ qc  # sum g^u q_u q_u^T
    f1 = (
        gw * f0
        + gw * (s0 @ sq_gp @ p0)
        + gw * (s0 @ (qc.T @ b))
        + gw * ((a.T @ qc) @ p0)
        + (a * wp[:, None]).T @ b
    )
    eta1 = (
        gw * eta0
        + gw * (s0 @ (sq_gp @ m0))
        + gw * (s0 @ (bm @ qc))
        + gw * ((a.T @ qc) @ m0)
        + (wp * bm) @ a
    )
    return out, Hla3Carry(s1, p1, m1, f1, eta1)


# ---------------------------------------------------------------------------
# first-order linear attention baseline (Section 2.2), chunked
# ---------------------------------------------------------------------------


def linear_chunk(carry, qc, kc, vc, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """One chunk of first-order causal linear attention (identity map)."""
    w = qc.shape[0]
    dt = qc.dtype
    gp, wp, gam = decay_factors(w, gamma, dt)
    tril = ref.causal_mask(w, dt)
    gw = jnp.asarray(gamma, dt) ** w

    p0, m0 = carry
    wdec = (tril * (qc @ kc.T)) * gam
    num = gp[:, None] * (qc @ p0) + wdec @ vc
    den = gp * (qc @ m0) + wdec @ jnp.ones((w,), dt)
    out = ref.apply_normalization(num, den, norm_mode, eps)

    kw = kc * wp[:, None]
    p1 = gw * p0 + kw.T @ vc
    m1 = gw * m0 + jnp.sum(kw, axis=0)
    return out, (p1, m1)
