"""Pure-jnp correctness oracles for Higher-order Linear Attention (HLA).

Two families of oracle, per the paper (Zhang et al., 2025):

1. **Quadratic (materialized) oracles** — build the n x n masked weight
   matrices exactly as written in the paper (Sections 3.1, 6.1, 7.1) and
   apply them to V.  These are only defined for ``gamma == 1`` (no decay)
   and are the ground truth for Theorems 3.1 / 6.1 / 7.1.

2. **Serial (streaming) oracles** — the token-by-token recurrences.  These
   are the *canonical semantics* for every configuration (decay, ridge,
   normalization); chunked/pallas/scan implementations must reproduce them
   up to float reassociation.

Decay convention (monoid-consistent; see DESIGN.md errata): a decayed step
is ``X_t = (gamma * X_{t-1}) <+ token_t``, i.e. *every* summary of the
carry is attenuated before the token's deltas and cross terms are added.
For the second-order cross-summaries this gives

    G_t = gamma * (G_{t-1} + k_t (k_t^T C_{t-1}))
    h_t = gamma * (h_{t-1} + k_t (k_t^T m_{t-1}))

which is the form implied by the paper's decayed semidirect product
(Section 4.2); the printed per-token update in Section 4.3 omits the inner
attenuation of ``C_{t-1}`` and is not associative-scan-consistent.  At
``gamma == 1`` the two coincide.

Shapes: q, k are [n, d]; v is [n, dv]; outputs are [n, dv].
All oracles are single-head; batching/heads are vmapped by callers.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "causal_mask",
    "strict_causal_mask",
    "decay_mask",
    "apply_normalization",
    "hla2_quadratic",
    "hla2_prefix_quadratic",
    "ahla_quadratic",
    "hla3_quadratic",
    "linear_attention_quadratic",
    "softmax_attention",
    "Hla2State",
    "AhlaState",
    "Hla3State",
    "hla2_init",
    "hla2_step",
    "hla2_out",
    "hla2_serial",
    "ahla_init",
    "ahla_step",
    "ahla_serial",
    "hla3_init",
    "hla3_step",
    "hla3_serial",
    "linear_attention_serial",
]


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------


def causal_mask(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Binary lower-triangular mask L (ones on and below the diagonal)."""
    return jnp.tril(jnp.ones((n, n), dtype=dtype))


def strict_causal_mask(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Strictly-lower-triangular mask (zeros on the diagonal)."""
    return jnp.tril(jnp.ones((n, n), dtype=dtype), k=-1)


def decay_mask(n: int, gamma: float, dtype=jnp.float32) -> jnp.ndarray:
    """Gamma^(t-j) on and below the diagonal, zero above."""
    t = jnp.arange(n)
    expo = (t[:, None] - t[None, :]).astype(dtype)
    return jnp.where(expo >= 0, jnp.asarray(gamma, dtype) ** expo, 0.0)


def apply_normalization(num, den, norm_mode: str, eps: float):
    """Apply the paper's optional linear normalization.

    norm_mode:
      * ``"none"``   — unnormalized (the paper's default operator).
      * ``"linear"`` — divide by ``den + eps`` (Eq. 3.2 / 3.4 verbatim).
      * ``"abs"``    — divide by ``|den| + eps`` (sign-safe variant used by
        the LM configs; den is not sign-definite for raw q/k).
    """
    if norm_mode == "none":
        return num
    if norm_mode == "linear":
        return num / (den + eps)[..., None]
    if norm_mode == "abs":
        return num / (jnp.abs(den) + eps)[..., None]
    raise ValueError(f"unknown norm_mode {norm_mode!r}")


# ---------------------------------------------------------------------------
# quadratic (materialized) oracles -- gamma == 1 only
# ---------------------------------------------------------------------------


def hla2_quadratic(q, k, v, *, norm_mode="none", eps=1e-6, lam=0.0):
    """Masked second-order HLA via the materialized form of Theorem 3.1.

    ``o_t = row_t[ ((L.QK^T)(L.QK^T)^T . L) V ]``, optionally
    ridge-stabilized (``lam`` implements Algorithm 1's ``S_eff = S + lam I``,
    adding ``lam * q_t^T C_t`` to the numerator and ``lam * q_t^T m_t`` to
    the denominator) and optionally normalized.
    """
    n = q.shape[0]
    mask = causal_mask(n, q.dtype)
    w = mask * (q @ k.T)
    t2 = (w @ w.T) * mask
    num = t2 @ v
    den = jnp.sum(t2, axis=1)
    if lam != 0.0:
        cw = mask * (q @ q.T)  # (q_t . q_j) for j <= t
        num = num + lam * (cw @ v)
        den = den + lam * jnp.sum(cw, axis=1)
    return apply_normalization(num, den, norm_mode, eps)


def hla2_prefix_quadratic(q, k, v, *, norm_mode="none", eps=1e-6):
    """Prefix ("unmasked") second-order HLA, Eq. (3.1)/(3.2).

    ``o_t = q_t^T S_t C_t`` with prefix moments up to t; equals
    ``row_t[ (((L.QK^T)(QK^T)^T) . L) V ]``.
    """
    n = q.shape[0]
    mask = causal_mask(n, q.dtype)
    a = q @ k.T
    w = mask * a
    t2 = (w @ a.T) * mask
    num = t2 @ v
    den = jnp.sum(t2, axis=1)
    return apply_normalization(num, den, norm_mode, eps)


def ahla_quadratic(q, k, v, *, norm_mode="none", eps=1e-6):
    """Masked asymmetric HLA (AHLA) via Eq. (6.1): ((AA) . L) V, A = L.QK^T."""
    n = q.shape[0]
    mask = causal_mask(n, q.dtype)
    a = mask * (q @ k.T)
    w = (a @ a) * mask
    num = w @ v
    den = jnp.sum(w, axis=1)
    return apply_normalization(num, den, norm_mode, eps)


def hla3_quadratic(q, k, v, *, norm_mode="none", eps=1e-6):
    """Masked third-order HLA via Section 7: (((W W^T).L) W).L V, W = L.QK^T.

    Note (DESIGN.md erratum #4): the paper displays ``(A A^T A) . L`` but its
    own Theorem 7.1 proof restricts the middle index to ``u <= t`` — without
    that restriction the operator is anti-causal through u.  The masked
    middle product below is the strictly causal operator the streaming
    algebra (Algorithm 3) actually computes.
    """
    n = q.shape[0]
    mask = causal_mask(n, q.dtype)
    w = mask * (q @ k.T)
    t3 = (((w @ w.T) * mask) @ w) * mask
    num = t3 @ v
    den = jnp.sum(t3, axis=1)
    return apply_normalization(num, den, norm_mode, eps)


def linear_attention_quadratic(q, k, v, *, norm_mode="none", eps=1e-6):
    """First-order causal linear attention with identity feature map."""
    n = q.shape[0]
    mask = causal_mask(n, q.dtype)
    w = mask * (q @ k.T)
    num = w @ v
    den = jnp.sum(w, axis=1)
    return apply_normalization(num, den, norm_mode, eps)


def softmax_attention(q, k, v, *, scale=None):
    """Causal scaled-dot-product attention baseline (Section 2.1)."""
    n, d = q.shape
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = (q @ k.T) * scale
    neg = jnp.asarray(jnp.finfo(q.dtype).min, q.dtype)
    logits = jnp.where(causal_mask(n, q.dtype) > 0, logits, neg)
    return jax.nn.softmax(logits, axis=-1) @ v


# ---------------------------------------------------------------------------
# serial (streaming) oracles -- canonical semantics
# ---------------------------------------------------------------------------


class Hla2State(NamedTuple):
    """Second-order masked state tuple (S, C, m, G, h) of Theorem 3.1."""

    s: jnp.ndarray  # [d, d]
    c: jnp.ndarray  # [d, dv]
    m: jnp.ndarray  # [d]
    g: jnp.ndarray  # [d, dv]
    h: jnp.ndarray  # [d]


def hla2_init(d: int, dv: int, dtype=jnp.float32) -> Hla2State:
    z = jnp.zeros
    return Hla2State(
        z((d, d), dtype), z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype)
    )


def hla2_step(state: Hla2State, qt, kt, vt, *, gamma=1.0) -> Hla2State:
    """One monoid-consistent decayed online update (Sections 3.1, 4.3)."""
    g = gamma * (state.g + jnp.outer(kt, kt @ state.c))
    h = gamma * (state.h + kt * (kt @ state.m))
    s = gamma * state.s + jnp.outer(kt, kt)
    c = gamma * state.c + jnp.outer(qt, vt)
    m = gamma * state.m + qt
    return Hla2State(s, c, m, g, h)


def hla2_out(state: Hla2State, qt, *, masked=True, norm_mode="none", eps=1e-6, lam=0.0):
    """Per-token output from the inclusive state (Theorem 3.1 / Algorithm 1)."""
    u = qt @ state.s
    if lam != 0.0:
        u = u + lam * qt
    num = u @ state.c
    den = u @ state.m
    if masked:
        num = num - qt @ state.g
        den = den - qt @ state.h
    return apply_normalization(num[None, :], den[None], norm_mode, eps)[0]


def hla2_serial(q, k, v, *, gamma=1.0, lam=0.0, masked=True, norm_mode="none", eps=1e-6):
    """Token-by-token masked second-order HLA (the canonical spec)."""
    d, dv = q.shape[1], v.shape[1]

    def body(state, qkv):
        qt, kt, vt = qkv
        state = hla2_step(state, qt, kt, vt, gamma=gamma)
        o = hla2_out(state, qt, masked=masked, norm_mode=norm_mode, eps=eps, lam=lam)
        return state, o

    _, out = jax.lax.scan(body, hla2_init(d, dv, q.dtype), (q, k, v))
    return out


class AhlaState(NamedTuple):
    """AHLA state tuple (P, m, E, n) of Theorem 6.1."""

    p: jnp.ndarray  # [d, dv]
    m: jnp.ndarray  # [d]
    e: jnp.ndarray  # [d, dv]
    n: jnp.ndarray  # [d]


def ahla_init(d: int, dv: int, dtype=jnp.float32) -> AhlaState:
    z = jnp.zeros
    return AhlaState(z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype))


def ahla_step(state: AhlaState, qt, kt, vt, *, gamma=1.0) -> AhlaState:
    """Algorithm 2 update (P before E; the paper's decayed form is already
    monoid-consistent because E's cross term uses the *inclusive* P_t)."""
    p = gamma * state.p + jnp.outer(kt, vt)
    m = gamma * state.m + kt
    e = gamma * state.e + jnp.outer(kt, qt @ p)
    n = gamma * state.n + kt * (qt @ m)
    return AhlaState(p, m, e, n)


def ahla_serial(q, k, v, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """Token-by-token AHLA (Algorithm 2)."""
    d, dv = q.shape[1], v.shape[1]

    def body(state, qkv):
        qt, kt, vt = qkv
        state = ahla_step(state, qt, kt, vt, gamma=gamma)
        num = qt @ state.e
        den = qt @ state.n
        o = apply_normalization(num[None, :], den[None], norm_mode, eps)[0]
        return state, o

    _, out = jax.lax.scan(body, ahla_init(d, dv, q.dtype), (q, k, v))
    return out


class Hla3State(NamedTuple):
    """Canonical third-order state: (S^K, P^KV, m^K) moments plus the
    corrected numerator/denominator (F, eta).

    The strictly causal third-order operator ``(((W W^T).L) W).L V`` admits
    the rank-1 streaming form (DESIGN.md Section 7 notes)

        F_t = gamma F_{t-1} + (S_t q_t) (q_t^T P_t)^T,

    which is *cheaper* than the paper's Eq. (7.5): O(d^2 + d dv) per token
    with a (2 d^2 + 2 d dv)-sized state and no S^Q moment in the carry.
    """

    s: jnp.ndarray  # [d, d]   S^K
    p: jnp.ndarray  # [d, dv]  P^KV
    m: jnp.ndarray  # [d]      m^K
    f: jnp.ndarray  # [d, dv]  F
    eta: jnp.ndarray  # [d]    eta


def hla3_init(d: int, dv: int, dtype=jnp.float32) -> Hla3State:
    z = jnp.zeros
    return Hla3State(
        z((d, d), dtype), z((d, dv), dtype), z((d,), dtype), z((d, dv), dtype), z((d,), dtype)
    )


def hla3_step(state: Hla3State, qt, kt, vt, *, gamma=1.0) -> Hla3State:
    """Rank-1 canonical third-order update (inclusive S_t, P_t, m_t)."""
    s = gamma * state.s + jnp.outer(kt, kt)
    p = gamma * state.p + jnp.outer(kt, vt)
    m = gamma * state.m + kt
    sq = s @ qt
    f = gamma * state.f + jnp.outer(sq, qt @ p)
    eta = gamma * state.eta + sq * (qt @ m)
    return Hla3State(s, p, m, f, eta)


def hla3_serial(q, k, v, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """Token-by-token canonical masked third-order HLA."""
    d, dv = q.shape[1], v.shape[1]

    def body(state, qkv):
        qt, kt, vt = qkv
        state = hla3_step(state, qt, kt, vt, gamma=gamma)
        num = qt @ state.f
        den = qt @ state.eta
        o = apply_normalization(num[None, :], den[None], norm_mode, eps)[0]
        return state, o

    _, out = jax.lax.scan(body, hla3_init(d, dv, q.dtype), (q, k, v))
    return out


# -- the paper's literal third-order recurrence (Eq. 7.5 / Algorithm 3) -----
#
# The printed Theorem 7.1 proof drops the j <= u mask inside W_{u,j} and its
# G-corrections use P_{i-1} where the peeling yields P_t, so the recurrence
# below is a *different* causal operator than the masked W-product (DESIGN.md
# erratum #4).  It is kept verbatim for fidelity: its G-form and F-form are
# mutually consistent, and the Rust `hla::monoid3` reproduces its Algorithm 4
# chunk scan (Theorem 7.2) exactly.


class Hla3PaperState(NamedTuple):
    """Paper-literal state: (S^K, S^Q, P, m) moments plus corrected (F, eta)."""

    sk: jnp.ndarray  # [d, d]
    sq: jnp.ndarray  # [d, d]
    p: jnp.ndarray  # [d, dv]
    m: jnp.ndarray  # [d]
    f: jnp.ndarray  # [d, dv]
    eta: jnp.ndarray  # [d]


def hla3_paper_init(d: int, dv: int, dtype=jnp.float32) -> Hla3PaperState:
    z = jnp.zeros
    return Hla3PaperState(
        z((d, d), dtype),
        z((d, d), dtype),
        z((d, dv), dtype),
        z((d,), dtype),
        z((d, dv), dtype),
        z((d,), dtype),
    )


def hla3_paper_step(state: Hla3PaperState, qt, kt, vt, *, gamma=1.0) -> Hla3PaperState:
    """Eq. (7.5) corrected-state recurrence with monoid-consistent decay.

    With D^K = k k^T, D^Q = q q^T, D^P = k v^T, d^m = k the four cross
    terms reduce to rank-1 updates:

        S^K D^Q D^P = (S^K q)(q.k) v^T       D^K S^Q D^P = k (k^T S^Q k) v^T
        D^K D^Q P   = k (k.q)(q^T P)         D^K D^Q D^P = k (k.q)(q.k) v^T
    """
    sk = gamma * state.sk
    sq = gamma * state.sq
    p = gamma * state.p
    m = gamma * state.m
    kq = jnp.dot(kt, qt)
    sk_q = sk @ qt
    k_sq_k = jnp.dot(kt, sq @ kt)
    f = (
        gamma * state.f
        + jnp.outer(sk_q, kq * vt)
        + jnp.outer(kt, k_sq_k * vt)
        + jnp.outer(kt, kq * (qt @ p))
        + jnp.outer(kt, (kq * kq) * vt)
    )
    eta = (
        gamma * state.eta
        + kq * sk_q
        + k_sq_k * kt
        + (kq * jnp.dot(qt, m)) * kt
        + (kq * kq) * kt
    )
    return Hla3PaperState(
        sk + jnp.outer(kt, kt),
        sq + jnp.outer(qt, qt),
        p + jnp.outer(kt, vt),
        m + kt,
        f,
        eta,
    )


def hla3_paper_serial(q, k, v, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """Token-by-token paper-literal third order (Algorithm 3 semantics)."""
    d, dv = q.shape[1], v.shape[1]

    def body(state, qkv):
        qt, kt, vt = qkv
        state = hla3_paper_step(state, qt, kt, vt, gamma=gamma)
        num = qt @ state.f
        den = qt @ state.eta
        o = apply_normalization(num[None, :], den[None], norm_mode, eps)[0]
        return state, o

    _, out = jax.lax.scan(body, hla3_paper_init(d, dv, q.dtype), (q, k, v))
    return out


def hla3_paper_gform_serial(q, k, v, *, norm_mode="none", eps=1e-6):
    """The paper's G-form (Theorem 7.1 cross-summaries G^(1..3), h^(1..3)),
    implemented directly from the definitions; must equal the F-form
    (internal-consistency check, gamma == 1)."""
    d, dv = q.shape[1], v.shape[1]
    z = jnp.zeros

    def body(state, qkv):
        sk, sq, p, m, g1, g2, g3, h1, h2, h3 = state
        qt, kt, vt = qkv
        kk = jnp.outer(kt, kt)
        qq = jnp.outer(qt, qt)
        g1 = g1 + kk @ sq @ p
        g2 = g2 + sk @ qq @ p
        g3 = g3 + sk @ sq @ jnp.outer(kt, vt)
        h1 = h1 + kk @ sq @ m
        h2 = h2 + sk @ qq @ m
        h3 = h3 + sk @ sq @ kt
        sk = sk + kk
        sq = sq + qq
        p = p + jnp.outer(kt, vt)
        m = m + kt
        num = qt @ (sk @ sq @ p - g1 - g2 - g3)
        den = qt @ (sk @ sq @ m - h1 - h2 - h3)
        o = apply_normalization(num[None, :], den[None], norm_mode, eps)[0]
        return (sk, sq, p, m, g1, g2, g3, h1, h2, h3), o

    init = (
        z((d, d)), z((d, d)), z((d, dv)), z((d,)),
        z((d, dv)), z((d, dv)), z((d, dv)), z((d,)), z((d,)), z((d,)),
    )
    init = tuple(jnp.asarray(x, q.dtype) for x in init)
    _, out = jax.lax.scan(body, init, (q, k, v))
    return out


def linear_attention_serial(q, k, v, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """First-order linear attention recurrence (Section 2.2, identity map)."""
    d, dv = q.shape[1], v.shape[1]
    z = jnp.zeros

    def body(state, qkv):
        p, m = state
        qt, kt, vt = qkv
        p = gamma * p + jnp.outer(kt, vt)
        m = gamma * m + kt
        num = qt @ p
        den = qt @ m
        o = apply_normalization(num[None, :], den[None], norm_mode, eps)[0]
        return (p, m), o

    _, out = jax.lax.scan(body, (z((d, dv), q.dtype), z((d,), q.dtype)), (q, k, v))
    return out
