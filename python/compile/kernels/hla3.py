"""Pallas kernel for masked third-order HLA (Section 7 / Algorithms 3-4).

Implements the *canonical* strictly causal third-order operator
(((W W^T).L) W).L V, which streams with the rank-1 recurrence
F_t = g F + (S_t q_t)(q_t^T P_t)^T (see ref.Hla3State and DESIGN.md
erratum #4 for why this differs from the paper's printed Eq. 7.5).  The
VMEM carry is only (S^K, P, m, F, eta) — no S^Q moment and no O(d^3 dv)
segment maps are needed, and the chunk composition is exact for every
gamma (the paper's Algorithm 4 is stated for gamma == 1 only).  The
paper-literal recurrence is kept in ref.hla3_paper_serial and in the Rust
hla::monoid3 (dense + factored segment maps, bench E9).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import chunk_math
from .chunk_math import Hla3Carry

__all__ = ["hla3_pallas", "hla3_chunked"]


def _hla3_kernel(
    q_ref, k_ref, v_ref, o_ref, s_ref, p_ref, m_ref, f_ref, eta_ref, *, gamma, norm_mode, eps
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        for r in (s_ref, p_ref, m_ref, f_ref, eta_ref):
            r[...] = jnp.zeros_like(r)

    carry = Hla3Carry(s_ref[...], p_ref[...], m_ref[0], f_ref[...], eta_ref[0])
    out, new = chunk_math.hla3_chunk(
        carry, q_ref[...], k_ref[...], v_ref[...], gamma=gamma, norm_mode=norm_mode, eps=eps
    )
    o_ref[...] = out
    s_ref[...] = new.s
    p_ref[...] = new.p
    m_ref[0] = new.m
    f_ref[...] = new.f
    eta_ref[0] = new.eta


@functools.partial(
    jax.jit, static_argnames=("chunk", "gamma", "norm_mode", "eps", "interpret")
)
def hla3_pallas(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    interpret: bool = True,
):
    """Canonical masked third-order HLA over a full sequence (any gamma)."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    kernel = functools.partial(_hla3_kernel, gamma=gamma, norm_mode=norm_mode, eps=eps)
    tok_spec = lambda width: pl.BlockSpec((chunk, width), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // chunk,),
        in_specs=[tok_spec(d), tok_spec(d), tok_spec(dv)],
        out_specs=tok_spec(dv),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), q.dtype),  # S^K
            pltpu.VMEM((d, dv), q.dtype),  # P^KV
            pltpu.VMEM((1, d), q.dtype),  # m^K
            pltpu.VMEM((d, dv), q.dtype),  # F
            pltpu.VMEM((1, d), q.dtype),  # eta
        ],
        interpret=interpret,
    )(q, k, v)


def hla3_chunked(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    carry: Hla3Carry | None = None,
    return_carry: bool = False,
):
    """Differentiable chunked canonical third-order HLA (any gamma)."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    nc = n // chunk
    if carry is None:
        carry = chunk_math.hla3_carry_init(d, dv, q.dtype)

    def body(state, qkv):
        qc, kc, vc = qkv
        out, state = chunk_math.hla3_chunk(
            state, qc, kc, vc, gamma=gamma, norm_mode=norm_mode, eps=eps
        )
        return state, out

    final, outs = jax.lax.scan(
        body, carry, (q.reshape(nc, chunk, d), k.reshape(nc, chunk, d), v.reshape(nc, chunk, dv))
    )
    outs = outs.reshape(n, dv)
    if return_carry:
        return outs, final
    return outs
