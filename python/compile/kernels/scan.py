"""Associative-scan (Blelloch) implementations of HLA — Figure 1(C) literal.

This module implements the paper's Section 4 exactly as written: token-level
segment leaves, the (decayed) semidirect-product concatenation, and
``jax.lax.associative_scan`` as the parallel scan.  It exists to validate
Theorem 4.1 / Remark 4.2 / Theorem 6.1's scan form against the serial
recurrences and the chunked kernels — three independent routes to the same
activations.

Monoid elements are dicts of arrays; the leading axis is the scan axis.
Per DESIGN.md errata, the decayed cross terms compose with the *plain*
(undecayed) segment moments, so the masked second-order element carries an
extra ``st`` (S-tilde) component and AHLA's ``r`` composes undecayed; at
gamma == 1 these coincide with the paper's Eq. (4.1) / Eq. (6.2) verbatim.

The third-order token-level scan is not implemented in JAX: its segment
maps are O(d^3 dv) per element (Section 7.3); the Rust ``hla::monoid3``
implements both the dense and the factored form at small d (bench E9).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref

__all__ = [
    "hla2_leaves",
    "hla2_combine",
    "ahla_leaves",
    "ahla_combine",
    "hla2_scan",
    "ahla_scan",
    "hla2_scan_exclusive",
    "hla2_two_level_scan",
]


# ---------------------------------------------------------------------------
# masked second order: element (s, c, m, g, h, st, rho)
# ---------------------------------------------------------------------------


def hla2_leaves(q, k, v, gamma: float):
    """Single-token segments T_t (Section 4.2); g = h = 0 for a token."""
    n, d = q.shape
    dv = v.shape[1]
    kk = k[:, :, None] * k[:, None, :]  # [n, d, d]
    return {
        "s": kk,
        "c": q[:, :, None] * v[:, None, :],
        "m": q,
        "g": jnp.zeros((n, d, dv), q.dtype),
        "h": jnp.zeros((n, d), q.dtype),
        "st": kk,
        "rho": jnp.full((n,), gamma, q.dtype),
    }


def hla2_combine(a, b):
    """Decayed semidirect product, Eq. (4.1) with the S-tilde correction."""
    rb = b["rho"][:, None, None]
    rb1 = b["rho"][:, None]
    return {
        "s": rb * a["s"] + b["s"],
        "c": rb * a["c"] + b["c"],
        "m": rb1 * a["m"] + b["m"],
        "g": rb * a["g"] + b["g"] + jnp.einsum("nij,njk->nik", b["st"], rb * a["c"]),
        "h": rb1 * a["h"] + b["h"] + jnp.einsum("nij,nj->ni", b["st"], rb1 * a["m"]),
        "st": a["st"] + b["st"],
        "rho": a["rho"] * b["rho"],
    }


def _hla2_outputs(states, q, *, lam, masked, norm_mode, eps):
    u = jnp.einsum("nd,nde->ne", q, states["s"])
    if lam != 0.0:
        u = u + lam * q
    num = jnp.einsum("ne,nek->nk", u, states["c"])
    den = jnp.einsum("ne,ne->n", u, states["m"])
    if masked:
        num = num - jnp.einsum("nd,ndk->nk", q, states["g"])
        den = den - jnp.einsum("nd,nd->n", q, states["h"])
    return ref.apply_normalization(num, den, norm_mode, eps)


def hla2_scan(q, k, v, *, gamma=1.0, lam=0.0, masked=True, norm_mode="none", eps=1e-6):
    """Masked second-order HLA via an inclusive associative scan (Thm 4.1)."""
    leaves = hla2_leaves(q, k, v, gamma)
    states = jax.lax.associative_scan(hla2_combine, leaves)
    return _hla2_outputs(states, q, lam=lam, masked=masked, norm_mode=norm_mode, eps=eps)


def _identity_like(leaves):
    """Zero-length segment E: all-zero summaries, rho = 1 (Remark 4.2)."""
    e = {k: jnp.zeros_like(v[:1]) for k, v in leaves.items()}
    e["rho"] = jnp.ones_like(leaves["rho"][:1])
    return e


def hla2_scan_exclusive(q, k, v, *, gamma=1.0, lam=0.0, masked=True, norm_mode="none", eps=1e-6):
    """Remark 4.2 route: exclusive Blelloch scan, then local inclusion.

    Must produce the same activations as ``hla2_scan`` — this is the form
    the paper's Algorithm 1 states (prefixes P_t, then P_t (+) T_t).
    """
    leaves = hla2_leaves(q, k, v, gamma)
    inclusive = jax.lax.associative_scan(hla2_combine, leaves)
    ident = _identity_like(leaves)
    exclusive = jax.tree_util.tree_map(
        lambda e, s: jnp.concatenate([e, s[:-1]], axis=0), ident, inclusive
    )
    states = hla2_combine(exclusive, leaves)  # local inclusion P_t (+) T_t
    return _hla2_outputs(states, q, lam=lam, masked=masked, norm_mode=norm_mode, eps=eps)


def hla2_two_level_scan(
    q, k, v, *, chunk=16, gamma=1.0, lam=0.0, masked=True, norm_mode="none", eps=1e-6
):
    """Two-level scan of Section 4.2: within-chunk Blelloch scan + exclusive
    inter-chunk scan over chunk summaries, then per-token merge.

    This is Figure 1(C) verbatim (intra-chunk parallelism over w positions,
    inter-chunk scan across B_c summaries).
    """
    n = q.shape[0]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    nc = n // chunk
    leaves = hla2_leaves(q, k, v, gamma)
    # reshape leading axis to [nc, w, ...]
    tiled = jax.tree_util.tree_map(lambda x: x.reshape(nc, chunk, *x.shape[1:]), leaves)
    # within-chunk inclusive scan (vmapped over chunks -> intra-chunk parallel)
    intra = jax.vmap(lambda lv: jax.lax.associative_scan(hla2_combine, lv))(tiled)
    # chunk summaries = last position of each chunk's inclusive scan
    summaries = jax.tree_util.tree_map(lambda x: x[:, -1], intra)
    # exclusive scan across chunk summaries
    inc_sum = jax.lax.associative_scan(hla2_combine, summaries)
    ident = _identity_like(summaries)
    carry = jax.tree_util.tree_map(
        lambda e, s: jnp.concatenate([e, s[:-1]], axis=0), ident, inc_sum
    )
    # merge carry-in prefix with each intra-chunk inclusive state
    carry_b = jax.tree_util.tree_map(
        lambda x: jnp.repeat(x, chunk, axis=0), carry
    )
    flat_intra = jax.tree_util.tree_map(lambda x: x.reshape(n, *x.shape[2:]), intra)
    states = hla2_combine(carry_b, flat_intra)
    return _hla2_outputs(states, q, lam=lam, masked=masked, norm_mode=norm_mode, eps=eps)


# ---------------------------------------------------------------------------
# AHLA: element (p, m, e, n, r, rho)
# ---------------------------------------------------------------------------


def ahla_leaves(q, k, v, gamma: float):
    """Single-token AHLA segments; e uses the token's own inclusive P."""
    qk = jnp.sum(q * k, axis=1)  # (q_t . k_t)
    kv = k[:, :, None] * v[:, None, :]
    return {
        "p": kv,
        "m": k,
        "e": qk[:, None, None] * kv,
        "n": qk[:, None] * k,
        "r": k[:, :, None] * q[:, None, :],  # plain R^KQ (DESIGN errata #3)
        "rho": jnp.full((q.shape[0],), gamma, q.dtype),
    }


def ahla_combine(a, b):
    """AHLA concatenation, Eq. (6.2); r composes undecayed."""
    rb = b["rho"][:, None, None]
    rb1 = b["rho"][:, None]
    return {
        "p": rb * a["p"] + b["p"],
        "m": rb1 * a["m"] + b["m"],
        "e": rb * a["e"] + b["e"] + jnp.einsum("nij,njk->nik", b["r"], rb * a["p"]),
        "n": rb1 * a["n"] + b["n"] + jnp.einsum("nij,nj->ni", b["r"], rb1 * a["m"]),
        "r": a["r"] + b["r"],
        "rho": a["rho"] * b["rho"],
    }


def ahla_scan(q, k, v, *, gamma=1.0, norm_mode="none", eps=1e-6):
    """AHLA via an inclusive associative scan (Section 6.2)."""
    leaves = ahla_leaves(q, k, v, gamma)
    states = jax.lax.associative_scan(ahla_combine, leaves)
    num = jnp.einsum("nd,ndk->nk", q, states["e"])
    den = jnp.einsum("nd,nd->n", q, states["n"])
    return ref.apply_normalization(num, den, norm_mode, eps)
