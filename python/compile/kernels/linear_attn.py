"""First-order linear-attention baseline kernel (Section 2.2).

Identity feature map; chunked exactly like the HLA kernels so throughput
comparisons (bench E3) isolate the cost of the higher-order summaries
rather than differences in kernel structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import chunk_math

__all__ = ["linear_attn_pallas", "linear_attn_chunked"]


def _linear_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, m_ref, *, gamma, norm_mode, eps):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    out, (p1, m1) = chunk_math.linear_chunk(
        (p_ref[...], m_ref[0]),
        q_ref[...],
        k_ref[...],
        v_ref[...],
        gamma=gamma,
        norm_mode=norm_mode,
        eps=eps,
    )
    o_ref[...] = out
    p_ref[...] = p1
    m_ref[0] = m1


@functools.partial(jax.jit, static_argnames=("chunk", "gamma", "norm_mode", "eps", "interpret"))
def linear_attn_pallas(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    interpret: bool = True,
):
    """First-order causal linear attention over a full sequence."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    kernel = functools.partial(_linear_kernel, gamma=gamma, norm_mode=norm_mode, eps=eps)
    tok_spec = lambda width: pl.BlockSpec((chunk, width), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // chunk,),
        in_specs=[tok_spec(d), tok_spec(d), tok_spec(dv)],
        out_specs=tok_spec(dv),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, dv), q.dtype),  # P^KV
            pltpu.VMEM((1, d), q.dtype),  # m^K
        ],
        interpret=interpret,
    )(q, k, v)


def linear_attn_chunked(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    carry=None,
    return_carry: bool = False,
):
    """Differentiable chunked linear attention."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    nc = n // chunk
    if carry is None:
        carry = (jnp.zeros((d, dv), q.dtype), jnp.zeros((d,), q.dtype))

    def body(state, qkv):
        qc, kc, vc = qkv
        out, state = chunk_math.linear_chunk(
            state, qc, kc, vc, gamma=gamma, norm_mode=norm_mode, eps=eps
        )
        return state, out

    final, outs = jax.lax.scan(
        body, carry, (q.reshape(nc, chunk, d), k.reshape(nc, chunk, d), v.reshape(nc, chunk, dv))
    )
    outs = outs.reshape(n, dv)
    if return_carry:
        return outs, final
    return outs
