"""Pallas kernel for masked second-order HLA (chunkwise, Algorithm 1).

TPU mapping (DESIGN.md "Hardware adaptation"): one grid step per chunk of
``w`` tokens; the constant-size state tuple (S, C, m, G, h) lives in VMEM
scratch and is carried across grid steps (TPU grid execution is sequential,
which realizes the inter-chunk serial composition of Section 4.2).  The
intra-chunk work is the masked w x w tile math of ``chunk_math.hla2_chunk``
— all contractions are matmuls so they map onto the MXU.

``interpret=True`` everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; numerics are identical (see /opt/xla-example/README.md).

The module also exposes ``hla2_chunked`` — the same math driven by
``jax.lax.scan`` — which is the differentiable path used by the L2 model.
Both must agree with ``ref.hla2_serial`` exactly (pytest enforces this).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import chunk_math
from .chunk_math import Hla2Carry

__all__ = ["hla2_pallas", "hla2_chunked"]


def _hla2_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    s_ref,
    c_ref,
    m_ref,
    g_ref,
    h_ref,
    *,
    gamma,
    lam,
    masked,
    norm_mode,
    eps,
):
    """Kernel body: one chunk per grid step, VMEM-resident carry."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)
        m_ref[...] = jnp.zeros_like(m_ref)
        g_ref[...] = jnp.zeros_like(g_ref)
        h_ref[...] = jnp.zeros_like(h_ref)

    carry = Hla2Carry(s_ref[...], c_ref[...], m_ref[0], g_ref[...], h_ref[0])
    out, new = chunk_math.hla2_chunk(
        carry,
        q_ref[...],
        k_ref[...],
        v_ref[...],
        gamma=gamma,
        lam=lam,
        masked=masked,
        norm_mode=norm_mode,
        eps=eps,
    )
    o_ref[...] = out
    s_ref[...] = new.s
    c_ref[...] = new.c
    m_ref[0] = new.m
    g_ref[...] = new.g
    h_ref[0] = new.h


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "gamma", "lam", "masked", "norm_mode", "eps", "interpret"),
)
def hla2_pallas(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    lam: float = 0.0,
    masked: bool = True,
    norm_mode: str = "none",
    eps: float = 1e-6,
    interpret: bool = True,
):
    """Masked second-order HLA over a full sequence via the Pallas kernel.

    Args:
      q, k: [n, d]; v: [n, dv].  ``n`` must be a multiple of ``chunk``.
    Returns:
      [n, dv] outputs identical to ``ref.hla2_serial`` (same options).
    """
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    grid = (n // chunk,)
    kernel = functools.partial(
        _hla2_kernel, gamma=gamma, lam=lam, masked=masked, norm_mode=norm_mode, eps=eps
    )
    tok_spec = lambda width: pl.BlockSpec((chunk, width), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[tok_spec(d), tok_spec(d), tok_spec(dv)],
        out_specs=tok_spec(dv),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), q.dtype),  # S
            pltpu.VMEM((d, dv), q.dtype),  # C
            pltpu.VMEM((1, d), q.dtype),  # m
            pltpu.VMEM((d, dv), q.dtype),  # G
            pltpu.VMEM((1, d), q.dtype),  # h
        ],
        interpret=interpret,
    )(q, k, v)


def hla2_chunked(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    lam: float = 0.0,
    masked: bool = True,
    norm_mode: str = "none",
    eps: float = 1e-6,
    carry: Hla2Carry | None = None,
    return_carry: bool = False,
):
    """Differentiable chunked HLA (lax.scan over ``chunk_math.hla2_chunk``).

    Used by the L2 model for training (the Pallas call has no VJP); also
    serves as ``prefill`` when ``return_carry=True``.
    """
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    nc = n // chunk
    if carry is None:
        carry = chunk_math.hla2_carry_init(d, dv, q.dtype)

    def body(state, qkv):
        qc, kc, vc = qkv
        out, state = chunk_math.hla2_chunk(
            state, qc, kc, vc, gamma=gamma, lam=lam, masked=masked, norm_mode=norm_mode, eps=eps
        )
        return state, out

    qs = q.reshape(nc, chunk, d)
    ks = k.reshape(nc, chunk, d)
    vs = v.reshape(nc, chunk, dv)
    final, outs = jax.lax.scan(body, carry, (qs, ks, vs))
    outs = outs.reshape(n, dv)
    if return_carry:
        return outs, final
    return outs
