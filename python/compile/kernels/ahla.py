"""Pallas kernel for Asymmetric HLA (AHLA, Section 6 / Algorithm 2).

Same chunked grid layout as ``hla2.py``: the (P, m, E, n) state tuple of
Theorem 6.1 lives in VMEM scratch, one grid step per chunk, intra-chunk
math from ``chunk_math.ahla_chunk`` (two passes through the decayed masked
affinity tile: inner rows r_i = q_i^T P_i, then the outer contraction).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import chunk_math
from .chunk_math import AhlaCarry

__all__ = ["ahla_pallas", "ahla_chunked"]


def _ahla_kernel(q_ref, k_ref, v_ref, o_ref, p_ref, m_ref, e_ref, n_ref, *, gamma, norm_mode, eps):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        p_ref[...] = jnp.zeros_like(p_ref)
        m_ref[...] = jnp.zeros_like(m_ref)
        e_ref[...] = jnp.zeros_like(e_ref)
        n_ref[...] = jnp.zeros_like(n_ref)

    carry = AhlaCarry(p_ref[...], m_ref[0], e_ref[...], n_ref[0])
    out, new = chunk_math.ahla_chunk(
        carry, q_ref[...], k_ref[...], v_ref[...], gamma=gamma, norm_mode=norm_mode, eps=eps
    )
    o_ref[...] = out
    p_ref[...] = new.p
    m_ref[0] = new.m
    e_ref[...] = new.e
    n_ref[0] = new.n


@functools.partial(
    jax.jit, static_argnames=("chunk", "gamma", "norm_mode", "eps", "interpret")
)
def ahla_pallas(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    interpret: bool = True,
):
    """AHLA over a full sequence via the Pallas kernel (matches Algorithm 2)."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    kernel = functools.partial(_ahla_kernel, gamma=gamma, norm_mode=norm_mode, eps=eps)
    tok_spec = lambda width: pl.BlockSpec((chunk, width), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // chunk,),
        in_specs=[tok_spec(d), tok_spec(d), tok_spec(dv)],
        out_specs=tok_spec(dv),
        out_shape=jax.ShapeDtypeStruct((n, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, dv), q.dtype),  # P
            pltpu.VMEM((1, d), q.dtype),  # m
            pltpu.VMEM((d, dv), q.dtype),  # E
            pltpu.VMEM((1, d), q.dtype),  # n
        ],
        interpret=interpret,
    )(q, k, v)


def ahla_chunked(
    q,
    k,
    v,
    *,
    chunk: int = 64,
    gamma: float = 1.0,
    norm_mode: str = "none",
    eps: float = 1e-6,
    carry: AhlaCarry | None = None,
    return_carry: bool = False,
):
    """Differentiable chunked AHLA (lax.scan over ``chunk_math.ahla_chunk``)."""
    n, d = q.shape
    dv = v.shape[1]
    if n % chunk != 0:
        raise ValueError(f"sequence length {n} not divisible by chunk {chunk}")
    nc = n // chunk
    if carry is None:
        carry = chunk_math.ahla_carry_init(d, dv, q.dtype)

    def body(state, qkv):
        qc, kc, vc = qkv
        out, state = chunk_math.ahla_chunk(
            state, qc, kc, vc, gamma=gamma, norm_mode=norm_mode, eps=eps
        )
        return state, out

    final, outs = jax.lax.scan(
        body, carry, (q.reshape(nc, chunk, d), k.reshape(nc, chunk, d), v.reshape(nc, chunk, dv))
    )
    outs = outs.reshape(n, dv)
    if return_carry:
        return outs, final
    return outs
