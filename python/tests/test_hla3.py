"""Third-order HLA (Section 7).

The canonical operator here is the strictly causal masked W-product
``(((W W^T).L) W).L V`` with its rank-1 streaming form (ref.hla3_serial).
The paper's printed Eq. (7.5)/Algorithm 3 recurrence is a *different*
causal operator (DESIGN.md erratum #4); it is kept as
``ref.hla3_paper_serial`` and its internal consistency (G-form == F-form,
Theorem 7.1's two descriptions) is tested below.
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import hla3 as hla3_mod
from compile.kernels import ref

from .conftest import make_qkv

TOL = dict(rtol=1e-8, atol=1e-9)


@pytest.mark.parametrize("norm_mode", ["none", "linear"])
@pytest.mark.parametrize("n,d,dv", [(1, 4, 4), (11, 3, 5), (48, 8, 8)])
def test_serial_matches_quadratic(rng, n, d, dv, norm_mode):
    """Canonical streaming == (((W W^T).L) W).L V."""
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla3_quadratic(q, k, v, norm_mode=norm_mode)
    got = ref.hla3_serial(q, k, v, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.9])
@pytest.mark.parametrize("chunk", [1, 4, 16, 48])
def test_chunked_matches_serial(rng, gamma, chunk):
    """Exact chunk composition, any gamma (beyond the paper's Alg. 4)."""
    q, k, v = make_qkv(rng, 48, 6, 6)
    want = ref.hla3_serial(q, k, v, gamma=gamma)
    got = hla3_mod.hla3_chunked(q, k, v, chunk=chunk, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.95])
@pytest.mark.parametrize("norm_mode", ["none", "abs"])
def test_pallas_matches_serial(rng, gamma, norm_mode):
    q, k, v = make_qkv(rng, 64, 8, 8)
    want = ref.hla3_serial(q, k, v, gamma=gamma, norm_mode=norm_mode)
    got = hla3_mod.hla3_pallas(q, k, v, chunk=16, gamma=gamma, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_paper_gform_matches_fform(rng):
    """Theorem 7.1 internal consistency: the G^(1..3)/h^(1..3) description
    and the Eq. (7.5) corrected-state recurrence agree (gamma == 1)."""
    q, k, v = make_qkv(rng, 24, 4, 4)
    for norm_mode in ("none", "linear"):
        gform = ref.hla3_paper_gform_serial(q, k, v, norm_mode=norm_mode)
        fform = ref.hla3_paper_serial(q, k, v, norm_mode=norm_mode)
        assert_allclose(np.asarray(gform), np.asarray(fform), **TOL)


def test_paper_form_differs_from_masked_product(rng):
    """Erratum #4: the printed recurrence is not the masked W-product."""
    q, k, v = make_qkv(rng, 16, 4, 4)
    paper = np.asarray(ref.hla3_paper_serial(q, k, v))
    causal = np.asarray(ref.hla3_quadratic(q, k, v))
    assert np.max(np.abs(paper - causal)) > 1e-8
    # first token agrees (no history to mis-mask)
    assert_allclose(paper[0], causal[0], **TOL)


def test_paper_form_is_causal(rng):
    """The paper operator, though not the masked product, is still causal."""
    n = 18
    q, k, v = make_qkv(rng, n, 4, 4)
    base = np.asarray(ref.hla3_paper_serial(q, k, v))
    q2, k2, v2 = make_qkv(rng, n, 4, 4)
    t = 7
    import jax.numpy as jnp

    qm = jnp.concatenate([q[: t + 1], q2[t + 1 :]])
    km = jnp.concatenate([k[: t + 1], k2[t + 1 :]])
    vm = jnp.concatenate([v[: t + 1], v2[t + 1 :]])
    pert = np.asarray(ref.hla3_paper_serial(qm, km, vm))
    assert_allclose(pert[: t + 1], base[: t + 1], **TOL)


def test_decayed_serial_is_finite_and_reduces(rng):
    """Decay keeps third-order states bounded; gamma -> 1 recovers gamma=1."""
    q, k, v = make_qkv(rng, 32, 4, 4)
    base = np.asarray(ref.hla3_serial(q, k, v, gamma=1.0))
    near = np.asarray(ref.hla3_serial(q, k, v, gamma=1.0 - 1e-12))
    assert np.all(np.isfinite(near))
    assert_allclose(near, base, rtol=1e-6, atol=1e-8)
    decayed = np.asarray(ref.hla3_serial(q, k, v, gamma=0.5))
    assert np.all(np.isfinite(decayed))
    assert np.max(np.abs(decayed)) < np.max(np.abs(base))


def test_strict_causality(rng):
    n = 20
    q, k, v = make_qkv(rng, n, 5, 5)
    base = np.asarray(ref.hla3_serial(q, k, v))
    q2, k2, v2 = make_qkv(rng, n, 5, 5)
    t = 8
    import jax.numpy as jnp

    qm = jnp.concatenate([q[: t + 1], q2[t + 1 :]])
    km = jnp.concatenate([k[: t + 1], k2[t + 1 :]])
    vm = jnp.concatenate([v[: t + 1], v2[t + 1 :]])
    pert = np.asarray(ref.hla3_serial(qm, km, vm))
    assert_allclose(pert[: t + 1], base[: t + 1], **TOL)


def test_prefill_carry_composes(rng):
    q, k, v = make_qkv(rng, 32, 5, 5)
    full = hla3_mod.hla3_chunked(q, k, v, chunk=8, gamma=0.97)
    first, carry = hla3_mod.hla3_chunked(
        q[:16], k[:16], v[:16], chunk=8, gamma=0.97, return_carry=True
    )
    second = hla3_mod.hla3_chunked(q[16:], k[16:], v[16:], chunk=8, gamma=0.97, carry=carry)
    got = np.concatenate([np.asarray(first), np.asarray(second)])
    assert_allclose(got, np.asarray(full), **TOL)


def test_third_order_grows_faster_than_second(rng):
    """Unnormalized magnitudes: |o3| ~ t^3 vs |o2| ~ t^2 (complexity table)."""
    q, k, v = make_qkv(rng, 256, 4, 4, scale=1.0)
    o2 = np.abs(np.asarray(ref.hla2_serial(q, k, v))).mean(axis=1)
    o3 = np.abs(np.asarray(ref.hla3_serial(q, k, v))).mean(axis=1)
    g2 = o2[-64:].mean() / max(o2[:64].mean(), 1e-30)
    g3 = o3[-64:].mean() / max(o3[:64].mean(), 1e-30)
    assert g3 > g2


from hypothesis import given, settings
from hypothesis import strategies as st


@settings(max_examples=15, deadline=None)
@given(
    n_chunks=st.integers(1, 4),
    chunk=st.sampled_from([1, 3, 8]),
    d=st.integers(1, 7),
    dv=st.integers(1, 7),
    gamma=st.sampled_from([1.0, 0.9]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_chunked_vs_serial(n_chunks, chunk, d, dv, gamma, seed):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, n_chunks * chunk, d, dv)
    want = ref.hla3_serial(q, k, v, gamma=gamma)
    got = hla3_mod.hla3_chunked(q, k, v, chunk=chunk, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-7, atol=1e-8)
