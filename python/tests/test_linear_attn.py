"""First-order linear-attention baseline: ref / chunked / pallas agreement."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import linear_attn, ref

from .conftest import make_qkv

TOL = dict(rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("norm_mode", ["none", "linear"])
def test_serial_matches_quadratic(rng, norm_mode):
    q, k, v = make_qkv(rng, 32, 8, 8)
    want = ref.linear_attention_quadratic(q, k, v, norm_mode=norm_mode)
    got = ref.linear_attention_serial(q, k, v, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.9])
@pytest.mark.parametrize("chunk", [1, 8, 32])
def test_chunked_matches_serial(rng, gamma, chunk):
    q, k, v = make_qkv(rng, 32, 8, 8)
    want = ref.linear_attention_serial(q, k, v, gamma=gamma)
    got = linear_attn.linear_attn_chunked(q, k, v, chunk=chunk, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.95])
def test_pallas_matches_serial(rng, gamma):
    q, k, v = make_qkv(rng, 64, 8, 8)
    want = ref.linear_attention_serial(q, k, v, gamma=gamma)
    got = linear_attn.linear_attn_pallas(q, k, v, chunk=16, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_softmax_attention_rows_sum_to_one(rng):
    """Baseline sanity: softmax weights are a proper causal distribution."""
    import jax.numpy as jnp

    q, k, v = make_qkv(rng, 16, 4, 4)
    ones = jnp.ones((16, 4))
    out = ref.softmax_attention(q, k, ones)
    assert_allclose(np.asarray(out), np.ones((16, 4)), rtol=1e-9, atol=1e-9)


def test_hla2_strictly_richer_than_first_order(rng):
    """Section 3: HLA's data-adaptive metric S != I differs from first-order
    linear attention even with tied q == k."""
    q, _, v = make_qkv(rng, 16, 4, 4)
    lin = np.asarray(ref.linear_attention_serial(q, q, v, norm_mode="linear"))
    hla = np.asarray(ref.hla2_serial(q, q, v, norm_mode="linear"))
    assert np.max(np.abs(lin - hla)) > 1e-8
