"""L2 model: shapes, mixer equivalences, training step, decode == forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.model import HlaConfig

CFG = HlaConfig(name="test", d_model=32, n_layers=2, n_heads=2, chunk=8, vocab=64)


def _params(cfg=CFG):
    return model.init_params(jax.random.PRNGKey(0), cfg)


def _tokens(key, b, t, cfg=CFG):
    return jax.random.randint(key, (b, t), 0, cfg.vocab)


def test_forward_shapes():
    p = _params()
    toks = _tokens(jax.random.PRNGKey(1), 2, 16)
    logits = model.forward(CFG, p, toks)
    assert logits.shape == (2, 16, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("mixer", ["hla2", "ahla", "hla3", "linear", "softmax"])
def test_all_mixers_forward(mixer):
    gamma = 1.0 if mixer == "hla3" else 0.99
    cfg = HlaConfig(
        name="t", d_model=32, n_layers=2, n_heads=2, chunk=8, vocab=64, mixer=mixer, gamma=gamma
    )
    p = _params(cfg)
    toks = _tokens(jax.random.PRNGKey(2), 2, 16, cfg)
    logits = model.forward(cfg, p, toks)
    assert logits.shape == (2, 16, 64)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_forward_is_causal():
    """Changing future tokens must not change earlier logits."""
    p = _params()
    t1 = _tokens(jax.random.PRNGKey(3), 1, 16)
    t2 = t1.at[0, 10:].set((t1[0, 10:] + 7) % CFG.vocab)
    l1 = np.asarray(model.forward(CFG, p, t1))
    l2 = np.asarray(model.forward(CFG, p, t2))
    assert_allclose(l2[0, :10], l1[0, :10], rtol=1e-5, atol=1e-5)
    assert np.max(np.abs(l2[0, 10:] - l1[0, 10:])) > 1e-6


def test_param_count_formula():
    p = _params()
    n = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(p))
    assert n == CFG.n_params()


def test_train_step_reduces_loss_on_overfit():
    """A few Adam steps on one repeated batch must reduce the loss."""
    p = _params()
    mu, nu = model.adam_init(p)
    toks = _tokens(jax.random.PRNGKey(4), 2, 17)
    step_fn = jax.jit(
        lambda p, mu, nu, s, t, lr: model.train_step(CFG, p, mu, nu, s, t, lr)
    )
    first = None
    loss = None
    for i in range(12):
        p, mu, nu, loss = step_fn(p, mu, nu, jnp.asarray(float(i)), toks, jnp.asarray(3e-3))
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.2, (first, float(loss))


@pytest.mark.parametrize("mixer", ["hla2", "ahla", "hla3", "linear"])
def test_decode_matches_forward(mixer):
    """Streaming decode (O(1) state) reproduces the chunked forward logits —
    the serving path and the training path are the same operator."""
    gamma = 1.0 if mixer == "hla3" else 0.99
    cfg = HlaConfig(
        name="t", d_model=32, n_layers=2, n_heads=2, chunk=4, vocab=64, mixer=mixer, gamma=gamma
    )
    p = _params(cfg)
    b, t = 2, 12
    toks = _tokens(jax.random.PRNGKey(5), b, t, cfg)
    want = np.asarray(model.forward(cfg, p, toks))

    state = model.state_init(cfg, b)
    dec = jax.jit(lambda s, tok: model.decode_step(cfg, p, s, tok))
    got = []
    for i in range(t):
        logits, state = dec(state, toks[:, i])
        got.append(np.asarray(logits))
    got = np.stack(got, axis=1)
    assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_matches_forward():
    """prefill(prompt) + decode(rest) == forward over the whole sequence."""
    cfg = HlaConfig(name="t", d_model=32, n_layers=2, n_heads=2, chunk=4, vocab=64)
    p = _params(cfg)
    b, tp, td = 2, 8, 4
    toks = _tokens(jax.random.PRNGKey(6), b, tp + td, cfg)
    want = np.asarray(model.forward(cfg, p, toks))

    state = model.state_init(cfg, b)
    logits, state = model.prefill(cfg, p, state, toks[:, :tp])
    assert_allclose(np.asarray(logits), want[:, tp - 1], rtol=2e-4, atol=2e-4)
    for i in range(td):
        logits, state = model.decode_step(cfg, p, state, toks[:, tp + i])
        assert_allclose(np.asarray(logits), want[:, tp + i], rtol=2e-4, atol=2e-4)


def test_multi_query_state_sharing():
    """Section 5.2: multi-query halves nothing at h=2 K/V-side params but
    keeps the model well-formed; K/V projections shrink to one head."""
    cfg = HlaConfig(
        name="t", d_model=32, n_layers=2, n_heads=2, chunk=8, vocab=64, multi_query=True
    )
    p = _params(cfg)
    assert p["layers"][0]["wk"].shape == (32, cfg.head_dim)
    toks = _tokens(jax.random.PRNGKey(7), 2, 16, cfg)
    logits = model.forward(cfg, p, toks)
    assert np.all(np.isfinite(np.asarray(logits)))
    # decode parity holds under multi-query too
    state = model.state_init(cfg, 2)
    want = np.asarray(model.forward(cfg, p, toks))
    got, state = model.decode_step(cfg, p, state, toks[:, 0])
    assert_allclose(np.asarray(got), want[:, 0], rtol=2e-4, atol=2e-4)


def test_grads_flow_through_mixer():
    """No stop-gradients anywhere: every parameter receives a gradient."""
    p = _params()
    toks = _tokens(jax.random.PRNGKey(8), 2, 9)
    grads = jax.grad(lambda pp: model.loss_fn(CFG, pp, toks))(p)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in leaves)
    nonzero = [float(jnp.max(jnp.abs(g))) > 0 for g in leaves]
    assert all(nonzero), nonzero
