"""AOT pipeline: HLO-text emission, manifest consistency, scan module."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.model import HlaConfig


def test_hlo_text_emission_roundtrips():
    """to_hlo_text produces parseable HLO with the right entry signature."""
    def fn(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f32[4,4]" in text


def test_manifest_for_micro_config(tmp_path):
    """Emitting one config produces a consistent manifest + artifact files."""
    out = str(tmp_path)
    manifest = {"configs": {}, "artifacts": {}}
    entry = dict(aot.CONFIGS["micro"])
    entry["kinds"] = ("init", "decode_step")  # keep the test fast
    aot.emit_config(out, "micro", entry, manifest)
    cfg = manifest["configs"]["micro"]
    # parameter accounting is exact
    assert cfg["n_params"] == HlaConfig(
        name="micro", d_model=64, n_layers=2, n_heads=2, chunk=16
    ).n_params()
    assert len(cfg["param_paths"]) == cfg["n_param_tensors"]
    assert len(cfg["state_paths"]) == cfg["n_state_tensors"]
    # decode artifact arity: params + state + tokens
    dec = manifest["artifacts"]["decode_step_micro"]
    assert len(dec["inputs"]) == cfg["n_param_tensors"] + cfg["n_state_tensors"] + 1
    assert dec["outputs"][0]["shape"] == [cfg["decode_batch"], cfg["vocab"]]
    # bucketed decode widths ride along (micro: decode_b=2 → one b1
    # rung): same arity, token input and logits narrowed to width 1 —
    # the shapes runtime/bucket.rs discovers the ladder from
    b1 = manifest["artifacts"]["decode_step_micro_b1"]
    assert len(b1["inputs"]) == len(dec["inputs"])
    assert b1["inputs"][-1]["shape"] == [1]
    assert b1["outputs"][0]["shape"] == [1, cfg["vocab"]]
    for art in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(out, art["file"]))
    # manifest is valid JSON end to end
    json.loads(json.dumps(manifest))


def test_param_paths_are_tree_flatten_order():
    """The manifest's param order must match tree_flatten (Rust relies on it)."""
    cfg = HlaConfig(name="t", d_model=32, n_layers=2, n_heads=2, chunk=8)
    paths = model.param_paths(cfg)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    leaves = jax.tree_util.tree_leaves(params)
    assert len(paths) == len(leaves)
    for (name, shape), leaf in zip(paths, leaves):
        assert list(leaf.shape) == shape, name
    # dict order: embed < layers < norm_f
    assert paths[0][0] == "['embed']"
    assert paths[-1][0] == "['norm_f']"


def test_state_init_shapes_by_mixer():
    for mixer, n_comp in [("hla2", 5), ("ahla", 4), ("hla3", 5), ("linear", 2)]:
        cfg = HlaConfig(
            name="t", d_model=32, n_layers=3, n_heads=2, chunk=8, mixer=mixer, gamma=1.0
        )
        st = model.state_init(cfg, batch=4)
        assert len(st) == n_comp, mixer
        for comp in st.values():
            assert comp.shape[:3] == (3, 4, 2), mixer  # [L, B, H, ...]

    with pytest.raises(ValueError):
        model.state_init(
            HlaConfig(name="t", d_model=32, n_heads=2, mixer="softmax"), batch=1
        )


def test_registered_configs_are_well_formed():
    for name, entry in aot.CONFIGS.items():
        cfg = entry["cfg"]
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0, name
        bt, t = entry["train_bt"]
        assert t % cfg.chunk == 0, f"{name}: train_seq must be chunk-aligned"
        assert entry["prefill_t"] % cfg.chunk == 0, name
        if cfg.mixer == "hla3":
            assert cfg.gamma == 1.0, f"{name}: hla3 chunk path requires gamma=1 upstream"
