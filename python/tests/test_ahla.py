"""AHLA (Section 6): Theorem 6.1 identity + chunk/pallas/scan equivalences."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ahla as ahla_mod
from compile.kernels import ref, scan

from .conftest import make_qkv

TOL = dict(rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("norm_mode", ["none", "linear"])
@pytest.mark.parametrize("n,d,dv", [(1, 4, 4), (9, 3, 5), (64, 16, 8)])
def test_serial_matches_quadratic(rng, n, d, dv, norm_mode):
    """Theorem 6.1: streaming == ((AA) . L) V with A = L . QK^T."""
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.ahla_quadratic(q, k, v, norm_mode=norm_mode)
    got = ref.ahla_serial(q, k, v, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.9])
@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
def test_chunked_matches_serial(rng, gamma, chunk):
    q, k, v = make_qkv(rng, 64, 8, 8)
    want = ref.ahla_serial(q, k, v, gamma=gamma)
    got = ahla_mod.ahla_chunked(q, k, v, chunk=chunk, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.93])
@pytest.mark.parametrize("norm_mode", ["none", "abs"])
def test_pallas_matches_serial(rng, gamma, norm_mode):
    q, k, v = make_qkv(rng, 64, 8, 8)
    want = ref.ahla_serial(q, k, v, gamma=gamma, norm_mode=norm_mode)
    got = ahla_mod.ahla_pallas(q, k, v, chunk=16, gamma=gamma, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("gamma", [1.0, 0.85])
def test_scan_matches_serial(rng, gamma):
    """Section 6.2 scan equivalence (with the plain-R correction)."""
    q, k, v = make_qkv(rng, 40, 6, 10)
    want = ref.ahla_serial(q, k, v, gamma=gamma)
    got = scan.ahla_scan(q, k, v, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_strict_causality(rng):
    n = 24
    q, k, v = make_qkv(rng, n, 6, 6)
    base = np.asarray(ref.ahla_serial(q, k, v))
    q2, k2, v2 = make_qkv(rng, n, 6, 6)
    t = 9
    import jax.numpy as jnp

    qm = jnp.concatenate([q[: t + 1], q2[t + 1 :]])
    km = jnp.concatenate([k[: t + 1], k2[t + 1 :]])
    vm = jnp.concatenate([v[: t + 1], v2[t + 1 :]])
    pert = np.asarray(ref.ahla_serial(qm, km, vm))
    assert_allclose(pert[: t + 1], base[: t + 1], **TOL)


def test_ahla_differs_from_symmetric_hla2(rng):
    """Relation to AA^T V (Section 6.3): same asymptotics, different operator."""
    q, k, v = make_qkv(rng, 16, 4, 4)
    sym = np.asarray(ref.hla2_serial(q, k, v))
    asym = np.asarray(ref.ahla_serial(q, k, v))
    assert np.max(np.abs(sym - asym)) > 1e-8


def test_prefill_carry_composes(rng):
    q, k, v = make_qkv(rng, 48, 8, 8)
    full = ahla_mod.ahla_chunked(q, k, v, chunk=8, gamma=0.97)
    first, carry = ahla_mod.ahla_chunked(
        q[:24], k[:24], v[:24], chunk=8, gamma=0.97, return_carry=True
    )
    second = ahla_mod.ahla_chunked(q[24:], k[24:], v[24:], chunk=8, gamma=0.97, carry=carry)
    got = np.concatenate([np.asarray(first), np.asarray(second)])
    assert_allclose(got, np.asarray(full), **TOL)


from hypothesis import given, settings
from hypothesis import strategies as st


@settings(max_examples=20, deadline=None)
@given(
    n_chunks=st.integers(1, 5),
    chunk=st.sampled_from([1, 2, 5, 8]),
    d=st.integers(1, 8),
    dv=st.integers(1, 8),
    gamma=st.sampled_from([1.0, 0.9, 0.6]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_chunked_vs_serial(n_chunks, chunk, d, dv, gamma, seed):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, n_chunks * chunk, d, dv)
    want = ref.ahla_serial(q, k, v, gamma=gamma)
    got = ahla_mod.ahla_chunked(q, k, v, chunk=chunk, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8, atol=1e-8)
