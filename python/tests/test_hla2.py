"""Second-order HLA: Theorem 3.1 / 4.1 equivalences across all four forms.

Routes under test (all must agree with the serial recurrence, which is the
canonical spec):

  quadratic (materialized)  <- Theorem 3.1, gamma == 1 only
  serial recurrence         <- ref.hla2_serial (ground truth)
  chunked (lax.scan)        <- hla2.hla2_chunked, any chunk width
  pallas kernel             <- hla2.hla2_pallas (interpret=True)
  associative scan          <- scan.hla2_scan / _exclusive / two-level
"""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile.kernels import ahla as ahla_mod
from compile.kernels import hla2 as hla2_mod
from compile.kernels import linear_attn, ref, scan

from .conftest import make_qkv

TOL = dict(rtol=1e-9, atol=1e-9)


# ---------------------------------------------------------------------------
# Theorem 3.1: masked streaming identity == materialized masked form
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("norm_mode", ["none", "linear", "abs"])
@pytest.mark.parametrize("n,d,dv", [(1, 4, 4), (7, 3, 5), (64, 16, 8)])
def test_serial_matches_quadratic_masked(rng, n, d, dv, norm_mode):
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_quadratic(q, k, v, norm_mode=norm_mode)
    got = ref.hla2_serial(q, k, v, norm_mode=norm_mode)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("n,d,dv", [(5, 4, 4), (33, 8, 16)])
def test_serial_matches_quadratic_prefix(rng, n, d, dv):
    """Unmasked (prefix) form, Eq. (3.1)."""
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_prefix_quadratic(q, k, v)
    got = ref.hla2_serial(q, k, v, masked=False)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_ridge_matches_quadratic(rng):
    """Algorithm 1's S_eff = S + lam*I against the materialized equivalent."""
    q, k, v = make_qkv(rng, 24, 6, 6)
    for lam in (0.1, 1.0):
        want = ref.hla2_quadratic(q, k, v, lam=lam)
        got = ref.hla2_serial(q, k, v, lam=lam)
        assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_normalized_denominator_identity(rng):
    """den_t == row sums of the masked second-order weight matrix."""
    q, k, v = make_qkv(rng, 16, 4, 4)
    unnorm = np.asarray(ref.hla2_serial(q, k, v, norm_mode="none"))
    lin = np.asarray(ref.hla2_serial(q, k, v, norm_mode="linear", eps=0.0))
    ones = np.ones((16, 4))
    den = np.asarray(ref.hla2_serial(q, k, np.asarray(ones), norm_mode="none"))[:, 0]
    assert_allclose(unnorm / den[:, None], lin, rtol=1e-8, atol=1e-10)


# ---------------------------------------------------------------------------
# connection with linear attention (Section 3, "Connection with linear attention")
# ---------------------------------------------------------------------------


def test_reduces_to_first_order_with_identity_metric(rng):
    """With q == k and a single past step the operators coincide; more
    generally the first token's output equals (q.k)^2-weighted v_1."""
    q, k, v = make_qkv(rng, 1, 8, 8)
    o2 = np.asarray(ref.hla2_serial(q, k, v))[0]
    w = float(np.asarray(q[0] @ k[0])) ** 2
    assert_allclose(o2, w * np.asarray(v[0]), **TOL)


# ---------------------------------------------------------------------------
# chunked / pallas / scan vs serial (Theorem 4.1)
# ---------------------------------------------------------------------------

CASES = [
    dict(gamma=1.0, lam=0.0, masked=True, norm_mode="none"),
    dict(gamma=1.0, lam=0.0, masked=True, norm_mode="linear"),
    dict(gamma=0.9, lam=0.0, masked=True, norm_mode="none"),
    dict(gamma=0.97, lam=0.05, masked=True, norm_mode="abs"),
    dict(gamma=1.0, lam=0.0, masked=False, norm_mode="none"),
    dict(gamma=0.9, lam=0.0, masked=False, norm_mode="none"),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("chunk", [1, 4, 16, 64])
def test_chunked_matches_serial(rng, case, chunk):
    n, d, dv = 64, 8, 8
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_serial(q, k, v, eps=1e-6, **case)
    got = hla2_mod.hla2_chunked(q, k, v, chunk=chunk, eps=1e-6, **case)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("case", CASES)
def test_pallas_matches_serial(rng, case):
    n, d, dv = 64, 8, 8
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_serial(q, k, v, eps=1e-6, **case)
    got = hla2_mod.hla2_pallas(q, k, v, chunk=16, eps=1e-6, **case)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


@pytest.mark.parametrize("case", CASES[:4])
def test_scan_matches_serial(rng, case):
    n, d, dv = 48, 6, 10
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_serial(q, k, v, **case)
    got = scan.hla2_scan(q, k, v, **case)
    assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_exclusive_scan_plus_local_inclusion(rng):
    """Remark 4.2: exclusive scan + local inclusion == inclusive scan."""
    q, k, v = make_qkv(rng, 32, 6, 6)
    for gamma in (1.0, 0.9):
        a = scan.hla2_scan(q, k, v, gamma=gamma)
        b = scan.hla2_scan_exclusive(q, k, v, gamma=gamma)
        assert_allclose(np.asarray(b), np.asarray(a), **TOL)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_two_level_scan_matches_serial(rng, chunk):
    """Section 4.2's intra-/inter-chunk two-level scan (Figure 1C)."""
    q, k, v = make_qkv(rng, 32, 6, 6)
    for gamma in (1.0, 0.93):
        want = ref.hla2_serial(q, k, v, gamma=gamma)
        got = scan.hla2_two_level_scan(q, k, v, chunk=chunk, gamma=gamma)
        assert_allclose(np.asarray(got), np.asarray(want), **TOL)


def test_prefill_carry_composes(rng):
    """Splitting a sequence across two chunked calls == one call (streaming)."""
    q, k, v = make_qkv(rng, 64, 8, 8)
    full = hla2_mod.hla2_chunked(q, k, v, chunk=8, gamma=0.95)
    first, carry = hla2_mod.hla2_chunked(
        q[:32], k[:32], v[:32], chunk=8, gamma=0.95, return_carry=True
    )
    second = hla2_mod.hla2_chunked(q[32:], k[32:], v[32:], chunk=8, gamma=0.95, carry=carry)
    got = np.concatenate([np.asarray(first), np.asarray(second)])
    assert_allclose(got, np.asarray(full), **TOL)


# ---------------------------------------------------------------------------
# causality and structural properties
# ---------------------------------------------------------------------------


def test_strict_causality(rng):
    """Perturbing tokens > t must not change output at t (masked form)."""
    n = 24
    q, k, v = make_qkv(rng, n, 6, 6)
    base = np.asarray(ref.hla2_serial(q, k, v))
    q2, k2, v2 = make_qkv(rng, n, 6, 6)
    t = 10
    import jax.numpy as jnp

    qm = jnp.concatenate([q[: t + 1], q2[t + 1 :]])
    km = jnp.concatenate([k[: t + 1], k2[t + 1 :]])
    vm = jnp.concatenate([v[: t + 1], v2[t + 1 :]])
    pert = np.asarray(ref.hla2_serial(qm, km, vm))
    assert_allclose(pert[: t + 1], base[: t + 1], **TOL)


def test_prefix_form_is_not_strictly_causal(rng):
    """The unmasked Eq. (3.1) prefix form leaks i in (j, t]: changing a
    *future-of-j but past-of-t* interaction is fine, but the masked and
    unmasked operators genuinely differ (the G correction is non-zero)."""
    q, k, v = make_qkv(rng, 16, 4, 4)
    masked = np.asarray(ref.hla2_serial(q, k, v, masked=True))
    unmasked = np.asarray(ref.hla2_serial(q, k, v, masked=False))
    assert np.max(np.abs(masked - unmasked)) > 1e-8


def test_decay_shrinks_state(rng):
    """Decay bounds the state norm (Section 4.3): gamma < 1 keeps ||S||
    bounded while gamma == 1 grows linearly."""
    import jax.numpy as jnp

    n, d = 512, 4
    q, k, v = make_qkv(rng, n, d, 4, scale=1.0)
    s_decay = jnp.zeros((d, d))
    s_grow = jnp.zeros((d, d))
    for t in range(n):
        s_decay = 0.9 * s_decay + jnp.outer(k[t], k[t])
        s_grow = s_grow + jnp.outer(k[t], k[t])
    assert float(jnp.linalg.norm(s_decay)) < 0.2 * float(jnp.linalg.norm(s_grow))


# ---------------------------------------------------------------------------
# hypothesis sweep: shapes, chunk widths, decay
# ---------------------------------------------------------------------------

from hypothesis import given, settings
from hypothesis import strategies as st


@settings(max_examples=25, deadline=None)
@given(
    n_chunks=st.integers(1, 6),
    chunk=st.sampled_from([1, 2, 3, 8]),
    d=st.integers(1, 9),
    dv=st.integers(1, 9),
    gamma=st.sampled_from([1.0, 0.9, 0.5]),
    masked=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_chunked_vs_serial(n_chunks, chunk, d, dv, gamma, masked, seed):
    rng = np.random.default_rng(seed)
    n = n_chunks * chunk
    q, k, v = make_qkv(rng, n, d, dv)
    want = ref.hla2_serial(q, k, v, gamma=gamma, masked=masked)
    got = hla2_mod.hla2_chunked(q, k, v, chunk=chunk, gamma=gamma, masked=masked)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(
    d=st.integers(1, 8),
    dv=st.integers(1, 8),
    gamma=st.sampled_from([1.0, 0.8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_scan_vs_serial(d, dv, gamma, seed):
    rng = np.random.default_rng(seed)
    q, k, v = make_qkv(rng, 17, d, dv)
    want = ref.hla2_serial(q, k, v, gamma=gamma)
    got = scan.hla2_scan(q, k, v, gamma=gamma)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-8, atol=1e-8)


# ---------------------------------------------------------------------------
# f32 smoke (artifact dtype)
# ---------------------------------------------------------------------------


def test_f32_pallas_close_to_serial(rng):
    import jax.numpy as jnp

    q, k, v = make_qkv(rng, 128, 16, 16, dtype=jnp.float32)
    want = np.asarray(ref.hla2_serial(q, k, v, gamma=0.99, norm_mode="abs"))
    got = np.asarray(hla2_mod.hla2_pallas(q, k, v, chunk=32, gamma=0.99, norm_mode="abs"))
    assert_allclose(got, want, rtol=2e-3, atol=2e-3)
