"""Shared pytest fixtures for the HLA kernel/model suite.

Correctness tests run in float64 (tight tolerances; the paper's identities
are exact in real arithmetic) — x64 must be enabled before jax initializes.
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_qkv(rng, n, d, dv, dtype=jnp.float64, scale=None):
    """Random q, k, v with O(1/sqrt(d)) entries so higher-order sums stay tame."""
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    q = jnp.asarray(rng.normal(size=(n, d)) * scale, dtype)
    k = jnp.asarray(rng.normal(size=(n, d)) * scale, dtype)
    v = jnp.asarray(rng.normal(size=(n, dv)), dtype)
    return q, k, v
