//! Differential acceptance test for the chunk-parallel prefill engine:
//! for random prompts, scan-based prefill must produce lane state and the
//! first sampled token identical to decode-as-prefill — fresh lanes and
//! resumed sessions, for second order, AHLA, third order and the linear
//! baseline.  Runs artifact-free on the pure-Rust model, like
//! `session_resume.rs`, on the shared [`hla::testing::fixtures`] models.
//!
//! "Identical" is exact for the sampled token (greedy argmax) and up to
//! f32 reassociation for the state floats: the scan reorders the same
//! additions Theorem 4.1 licenses, so the relative-diff distribution sits
//! at f32 noise (median ≲ 1e-6; compared by quantiles because the
//! abs-normalized outputs amplify noise wherever |den| ~ 0) while the
//! serial path stays the bit-exact reference.

use hla::model::sampler::argmax;
use hla::model::{ModelState, RustModel};
use hla::prefill::{advance, forward_logits, ingest, PrefillCfg};
use hla::testing::fixtures::{build_model, build_model_full, random_prompt, ModelShape};
use hla::util::rng::Rng;

/// The shared differential-test fixture (2 layers, d_model 16) at γ.
fn fixture_model(mixer: &str, gamma: f64, seed: u64) -> RustModel {
    build_model(mixer, &ModelShape { gamma, ..ModelShape::default() }, seed)
}

/// Relative closeness for f32 slices, judged by quantiles: the model's
/// abs-normalized mixer outputs amplify f32 reassociation noise wherever
/// |den| ~ 0 (same reason the kernel-artifact test compares by quantiles),
/// so a rare position may drift while the distribution stays tight.
fn assert_quantile_close(diffs: &mut [f32], what: &str) {
    assert!(!diffs.is_empty(), "{what}: nothing compared");
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| diffs[(p * (diffs.len() - 1) as f64) as usize];
    assert!(q(0.5) < 1e-4, "{what}: median rel diff {}", q(0.5));
    assert!(q(0.99) < 2e-2, "{what}: p99 rel diff {}", q(0.99));
}

/// Relative closeness for f32 state vectors (scan reassociation noise).
fn assert_state_close(a: &ModelState, b: &ModelState, what: &str) {
    let mut diffs = vec![];
    for (i, (ha, hb)) in a.layers.iter().flatten().zip(b.layers.iter().flatten()).enumerate() {
        let va = ha.state_vec().unwrap();
        let vb = hb.state_vec().unwrap();
        assert_eq!(va.len(), vb.len(), "{what}: head {i} arity");
        for (x, y) in va.iter().zip(&vb) {
            let denom = 1f32.max(x.abs()).max(y.abs());
            diffs.push((x - y).abs() / denom);
        }
    }
    assert_quantile_close(&mut diffs, what);
}

/// The coordinator's two prompt paths, side by side: decode-as-prefill
/// (serial decode_step over the prompt) vs scan prefill of prompt[..n-1]
/// followed by one normal decode step on the final token.
fn differential(model: &RustModel, prompt: &[u8], chunk: usize, threads: usize, what: &str) {
    // path A: decode-as-prefill
    let mut state_a = ModelState::new(&model.cfg);
    let logits_a = ingest(model, &mut state_a, prompt, &PrefillCfg::serial());
    // path B: scan prefill all but the last token, then a decode step
    let mut state_b = ModelState::new(&model.cfg);
    advance(model, &mut state_b, &prompt[..prompt.len() - 1], &PrefillCfg::scan(chunk, threads));
    let logits_b = model.decode_step(&mut state_b, prompt[prompt.len() - 1]);
    assert_state_close(&state_a, &state_b, what);
    assert_eq!(
        argmax(&logits_a),
        argmax(&logits_b),
        "{what}: first sampled token diverged"
    );
}

#[test]
fn scan_prefill_matches_decode_as_prefill_fresh_lanes() {
    let mut rng = Rng::new(41);
    for mixer in ["hla2", "ahla", "hla3", "linear"] {
        let model = fixture_model(mixer, 0.98, 17);
        for n in [2usize, 9, 64, 193] {
            let prompt = random_prompt(&mut rng, n, 64);
            for (chunk, threads) in [(1usize, 1usize), (7, 3), (32, 4), (256, 2)] {
                differential(&model, &prompt, chunk, threads, &format!("{mixer} n={n} w={chunk}"));
            }
        }
    }
}

#[test]
fn scan_prefill_matches_decode_as_prefill_gamma_one_third_order() {
    let mut rng = Rng::new(43);
    let model = fixture_model("hla3", 1.0, 19);
    let prompt = random_prompt(&mut rng, 80, 64);
    for (chunk, threads) in [(1usize, 1usize), (16, 4), (128, 2)] {
        differential(&model, &prompt, chunk, threads, &format!("hla3 g=1 w={chunk}"));
    }
}

#[test]
fn scan_prefill_matches_decode_as_prefill_resumed_sessions() {
    // a resumed lane's restored state enters the scan as the non-identity
    // initial segment; the new turn's prompt must land the same state and
    // token as serially decoding it from the restored state
    let mut rng = Rng::new(47);
    for mixer in ["hla2", "ahla", "hla3", "linear"] {
        let model = fixture_model(mixer, 0.98, 29);
        // first turn: serial, shared by both paths (this is the snapshot)
        let mut restored = ModelState::new(&model.cfg);
        let turn1 = random_prompt(&mut rng, 57, 64);
        advance(&model, &mut restored, &turn1, &PrefillCfg::serial());
        let turn2 = random_prompt(&mut rng, 91, 64);

        let mut state_a = restored.clone();
        let logits_a = ingest(&model, &mut state_a, &turn2, &PrefillCfg::serial());
        let mut state_b = restored.clone();
        advance(&model, &mut state_b, &turn2[..turn2.len() - 1], &PrefillCfg::scan(16, 4));
        let logits_b = model.decode_step(&mut state_b, turn2[turn2.len() - 1]);

        assert_state_close(&state_a, &state_b, &format!("{mixer} resumed"));
        assert_eq!(argmax(&logits_a), argmax(&logits_b), "{mixer}: resumed token diverged");
    }
}

#[test]
fn forward_scan_matches_forward_serial() {
    // Model::forward now routes through the prefill engine; the serial
    // fallback is the differential baseline (teacher-forced logits)
    let mut rng = Rng::new(53);
    for mixer in ["hla2", "ahla", "hla3", "linear"] {
        let model = fixture_model(mixer, 0.98, 31);
        let tokens = random_prompt(&mut rng, 70, 64);
        let scan = model.forward(&tokens);
        let serial = model.forward_serial(&tokens);
        assert_eq!(scan.rows, serial.rows);
        let mut diffs: Vec<f32> = scan
            .data
            .iter()
            .zip(&serial.data)
            .map(|(a, b)| (a - b).abs() / 1f32.max(a.abs()).max(b.abs()))
            .collect();
        assert_quantile_close(&mut diffs, &format!("{mixer} forward"));
        // softmax mixers have no monoid: forward must fall back serially
        // and stay exactly equal
        let sm = build_model("softmax", &ModelShape { gamma: 1.0, ..ModelShape::default() }, 31);
        let a = sm.forward(&tokens[..20]);
        let b = sm.forward_serial(&tokens[..20]);
        assert_eq!(a.data, b.data, "softmax forward must be the serial path");
    }
}

#[test]
fn prefiller_lands_lane_components_and_leaves_final_token() {
    use hla::prefill::Prefiller;
    // the full-state fixture: state_paths cover the whole hla2 state, so
    // lane component round-trips are lossless (Prefiller::new checks)
    let model = build_model_full("hla2", &ModelShape::default(), 61);
    let cfg = model.cfg.clone();
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(8, 2)).unwrap();

    let mut rng = Rng::new(61);
    let prompt = random_prompt(&mut rng, 40, 64);
    let (parts, consumed) = pf.ingest_lane(None, &prompt).unwrap();
    assert_eq!(consumed, prompt.len() - 1, "final token stays with the lane");
    assert_eq!(parts.len(), cfg.state_paths.len());

    // the landed components equal the serial state over the same tokens
    let mut want = ModelState::new(&cfg);
    advance(&model, &mut want, &prompt[..consumed], &PrefillCfg::serial());
    let mut got = ModelState::new(&cfg);
    got.load_components(&cfg, &parts).unwrap();
    assert_state_close(&want, &got, "prefilled lane components");

    // resume: the components round-trip back in as the initial segment
    let turn2 = random_prompt(&mut rng, 33, 64);
    let (parts2, consumed2) = pf.ingest_lane(Some(&parts), &turn2).unwrap();
    assert_eq!(consumed2, turn2.len() - 1);
    let mut want2 = got.clone();
    advance(&model, &mut want2, &turn2[..consumed2], &PrefillCfg::serial());
    let mut got2 = ModelState::new(&cfg);
    got2.load_components(&cfg, &parts2).unwrap();
    assert_state_close(&want2, &got2, "resumed lane components");

    // single-token prompts have nothing to prefill
    assert!(pf.ingest_lane(None, &prompt[..1]).is_err());
}

#[test]
fn forward_logits_shares_one_prompt_loop() {
    // the dedup check: forward_logits over a prompt then one decode_step
    // equals ingest over prompt+token — both route through prefill
    let model = fixture_model("hla2", 0.98, 37);
    let mut rng = Rng::new(59);
    let prompt = random_prompt(&mut rng, 30, 64);
    let cfg = PrefillCfg::scan(8, 2);

    let mut s1 = ModelState::new(&model.cfg);
    let all = forward_logits(&model, &mut s1, &prompt, &cfg);
    let mut s2 = ModelState::new(&model.cfg);
    let last = ingest(&model, &mut s2, &prompt, &cfg);
    for (a, b) in all.row(prompt.len() - 1).iter().zip(&last) {
        let denom = 1f32.max(a.abs()).max(b.abs());
        assert!((a - b).abs() / denom < 1e-5, "{a} vs {b}");
    }
    assert_state_close(&s1, &s2, "forward vs ingest state");
}
