//! Integration: AOT HLO artifacts vs the pure-Rust reimplementation.
//!
//! These tests require `artifacts/` (run `make artifacts` first); they are
//! skipped gracefully when it is absent so `cargo test` works pre-build.

use hla::model::{ModelState, RustModel};
use hla::runtime::{literal::literal_to_tensor, Engine, HostValue};
use hla::tensor::{Mat, Tensor, TensorI32};

fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts/ (run `make artifacts`)");
        return None;
    }
    Some(Engine::open(dir).expect("open artifacts"))
}

#[test]
fn fwd_artifact_matches_rust_model() {
    let Some(engine) = engine() else { return };
    let cfg = engine.model_cfg("micro").unwrap().clone();
    let params = engine.init_params("micro", 3).unwrap();
    let tensors: Vec<Tensor> =
        params.iter().map(|p| literal_to_tensor(p).unwrap()).collect();
    let rust = RustModel::from_tensors(&cfg, &tensors).unwrap();

    let (b, t) = (cfg.train_batch, cfg.train_seq);
    let text = b"It was the best of times, it was the worst of times, and the model streams.";
    let tokens: Vec<i32> = text.iter().cycle().take(b * t).map(|&x| x as i32).collect();

    let mut inputs: Vec<HostValue> = tensors.iter().cloned().map(HostValue::F32).collect();
    inputs.push(HostValue::I32(TensorI32::from_vec(&[b, t], tokens.clone())));
    let outs = engine.run_host("fwd_micro", &inputs).unwrap();
    let logits = &outs[0]; // [B, T, V]

    let vocab = cfg.vocab;
    let mut worst = 0f32;
    for bi in 0..b {
        let seq: Vec<u8> = tokens[bi * t..(bi + 1) * t].iter().map(|&x| x as u8).collect();
        let rust_logits: Mat<f32> = rust.forward(&seq);
        for ti in 0..t {
            for vi in 0..vocab {
                let a = logits.at(&[bi, ti, vi]);
                let r = rust_logits[(ti, vi)];
                worst = worst.max((a - r).abs());
            }
        }
    }
    assert!(worst < 2e-2, "fwd artifact vs rust model diff {worst}");
}

#[test]
fn kernel_artifact_matches_rust_algebra() {
    // the Pallas-lowered kernel artifact (L1) vs the Rust serial state (L3)
    let Some(engine) = engine() else { return };
    use hla::hla::state2::hla2_serial;
    use hla::hla::{HlaOptions, NormMode};
    use hla::util::rng::Rng;

    let (n, d) = (1024, 64);
    let mut rng = Rng::new(5);
    let mk = |rng: &mut Rng, scale: f32| {
        let mut m = Mat::<f32>::zeros(n, d);
        for x in &mut m.data {
            *x = rng.normal() as f32 * scale;
        }
        m
    };
    let scale = 1.0 / (d as f32).sqrt();
    let q = mk(&mut rng, scale);
    let k = mk(&mut rng, scale);
    let v = mk(&mut rng, 1.0);

    let to_t = |m: &Mat<f32>| Tensor::from_vec(&[n, d], m.data.clone());
    let outs = engine
        .run_host(
            "kernel_hla2_n1024_d64",
            &[HostValue::F32(to_t(&q)), HostValue::F32(to_t(&k)), HostValue::F32(to_t(&v))],
        )
        .unwrap();
    // kernel artifact burns in gamma=0.99, norm=abs (see aot.py)
    let opts = HlaOptions::<f32>::default().with_gamma(0.99).with_norm(NormMode::Abs);
    let want = hla2_serial(&q, &k, &v, &opts);
    let got = &outs[0];
    // abs-normalized outputs amplify f32 noise wherever |den| ~ 0, so
    // compare by quantiles rather than max (median is ~6e-7 here).
    let mut diffs: Vec<f32> =
        got.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs()).collect();
    diffs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| diffs[(p * (diffs.len() - 1) as f64) as usize];
    assert!(q(0.5) < 1e-4, "median diff {}", q(0.5));
    assert!(q(0.99) < 1e-2, "p99 diff {}", q(0.99));
}

#[test]
fn prefill_then_decode_matches_fwd() {
    let Some(engine) = engine() else { return };
    let cfg = engine.model_cfg("micro").unwrap().clone();
    let params = engine.init_params("micro", 7).unwrap();
    let tensors: Vec<Tensor> =
        params.iter().map(|p| literal_to_tensor(p).unwrap()).collect();
    let b = cfg.decode_batch;
    let tp = cfg.prefill_len;
    let extra = 4usize;

    let text: Vec<u8> = b"the kernel composes the carry and the scan streams the prefix . "
        .iter()
        .copied()
        .cycle()
        .take(b * (tp + extra))
        .collect();

    // ground truth: rust model forward per sequence
    let rust = RustModel::from_tensors(&cfg, &tensors).unwrap();

    // prefill
    let mut inputs: Vec<HostValue> = tensors.iter().cloned().map(HostValue::F32).collect();
    for (_, shape) in &cfg.state_paths {
        inputs.push(HostValue::F32(Tensor::zeros(shape)));
    }
    let prompt_tokens: Vec<i32> = (0..b)
        .flat_map(|bi| text[bi * (tp + extra)..bi * (tp + extra) + tp].iter().map(|&x| x as i32))
        .collect();
    inputs.push(HostValue::I32(TensorI32::from_vec(&[b, tp], prompt_tokens)));
    let outs = engine.run_host(&format!("prefill_{}", cfg.name), &inputs).unwrap();
    let prefill_logits = outs[0].clone();
    let mut state: Vec<Tensor> = outs[1..].to_vec();

    // decode the remaining tokens, comparing each step to the rust model
    for step in 0..extra {
        let mut inputs: Vec<HostValue> = tensors.iter().cloned().map(HostValue::F32).collect();
        inputs.extend(state.iter().cloned().map(HostValue::F32));
        let toks: Vec<i32> = (0..b)
            .map(|bi| text[bi * (tp + extra) + tp + step] as i32)
            .collect();
        inputs.push(HostValue::I32(TensorI32::from_vec(&[b], toks)));
        let outs = engine.run_host(&format!("decode_step_{}", cfg.name), &inputs).unwrap();
        state = outs[1..].to_vec();
    }

    // check prefill last-token logits vs rust forward at position tp-1
    let vocab = cfg.vocab;
    let mut worst = 0f32;
    for bi in 0..b {
        let seq = &text[bi * (tp + extra)..bi * (tp + extra) + tp];
        let rust_logits = rust.forward(seq);
        for vi in 0..vocab {
            worst = worst.max((prefill_logits.at(&[bi, vi]) - rust_logits[(tp - 1, vi)]).abs());
        }
    }
    assert!(worst < 2e-2, "prefill vs rust forward diff {worst}");
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(engine) = engine() else { return };
    let a = engine.init_params("micro", 11).unwrap();
    let b = engine.init_params("micro", 11).unwrap();
    let c = engine.init_params("micro", 12).unwrap();
    let ta = literal_to_tensor(&a[0]).unwrap();
    let tb = literal_to_tensor(&b[0]).unwrap();
    let tc = literal_to_tensor(&c[0]).unwrap();
    assert_eq!(ta, tb, "same seed must reproduce params");
    assert_ne!(ta, tc, "different seeds must differ");
}

#[test]
fn manifest_shapes_match_artifacts() {
    let Some(engine) = engine() else { return };
    // spot-check: decode_step input arity = params + state + 1
    for cfg_name in ["micro", "micro-ahla", "micro-hla3", "micro-linear"] {
        let cfg = engine.model_cfg(cfg_name).unwrap();
        let spec = &engine.manifest.artifacts[&format!("decode_step_{cfg_name}")];
        assert_eq!(
            spec.inputs.len(),
            cfg.n_param_tensors + cfg.n_state_tensors + 1,
            "{cfg_name} arity"
        );
        assert_eq!(spec.outputs.len(), 1 + cfg.n_state_tensors);
        assert_eq!(spec.outputs[0].shape, vec![cfg.decode_batch, cfg.vocab]);
    }
}
