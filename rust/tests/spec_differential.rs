//! Differential acceptance test for the speculative decoding engine:
//! the emitted token stream must be **byte-identical** to non-speculative
//! serial decode — greedy and seeded sampling, hla2/ahla/hla3, both
//! drafters, fresh lanes and session-resumed lanes.  Speculation may
//! change the schedule (how many tokens land per verify step), never the
//! tokens.  Runs artifact-free on the pure-Rust model, like
//! `session_resume.rs` / `prefill_differential.rs`.
//!
//! Exactness ledger:
//! * **Serial verify backend** (`verify_chunk: 0`): the verifier's
//!   forward is the same `decode_step` chain serial decode runs, its
//!   rollback re-advance is serial, and the coupled acceptance rule
//!   spends exactly one sampler draw per emitted token — so equality is
//!   *bit-exact by construction*, and the seeded-sampling grid asserts it
//!   there.
//! * **Scan verify backend** (one chunked step per draft — the perf
//!   path): logits agree with serial up to f32 reassociation (Thm 4.1),
//!   so the greedy grid asserts exact token equality on it, the same
//!   robustness bar `prefill_differential.rs` already holds the scan to.

use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{ModelState, RustModel};
use hla::prefill::{advance, PrefillCfg};
use hla::session::SamplerState;
use hla::spec::{Drafter, DrafterKind, ModelDrafter, NgramDrafter, SpecCfg, SpecDecoder};
use hla::testing::fixtures::{build_model, ModelShape};
use hla::util::rng::Rng;

/// 2-layer target (d_model 16) — the shared differential-test shape —
/// and the 1-layer small-config draft model (d_model 8).
fn target_model(mixer: &str, seed: u64) -> RustModel {
    build_model(mixer, &ModelShape::default(), seed)
}

fn draft_model(mixer: &str, seed: u64) -> RustModel {
    build_model(mixer, &ModelShape::draft(), seed)
}

fn random_prompt(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(64) as u8).collect()
}

/// The non-speculative reference: one `decode_step` + one sampler draw
/// per emitted token (exactly the coordinator lane's generating phase).
fn serial_generate(
    model: &RustModel,
    state: &mut ModelState,
    sampler: &mut Sampler,
    mut last: u8,
    max_new: usize,
    eos: Option<u8>,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(max_new);
    while out.len() < max_new {
        let logits = model.decode_step(state, last);
        let y = sampler.sample(&logits) as u8;
        out.push(y);
        if eos == Some(y) {
            break;
        }
        last = y;
    }
    out
}

fn serial_from_prompt(
    model: &RustModel,
    prompt: &[u8],
    scfg: SamplerCfg,
    max_new: usize,
    eos: Option<u8>,
) -> Vec<u8> {
    let mut state = ModelState::new(&model.cfg);
    let mut sampler = Sampler::new(scfg);
    advance(model, &mut state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
    serial_generate(model, &mut state, &mut sampler, prompt[prompt.len() - 1], max_new, eos)
}

/// Serial verify backend (bit-exact) with a fixed draft length.
fn serial_cfg(k: usize, drafter: DrafterKind) -> SpecCfg {
    SpecCfg { k, adaptive: false, drafter, verify_chunk: 0, ..Default::default() }
}

/// Chunked-scan verify backend (the perf path) with a fixed draft length.
fn scan_cfg(k: usize, drafter: DrafterKind) -> SpecCfg {
    SpecCfg { k, adaptive: false, drafter, verify_chunk: 8, verify_threads: 2, ..Default::default() }
}

/// Build a decoder for (target, cfg), honoring the drafter kind; the
/// drafters' own stream ingestion is kept serial so self-draft is a
/// bit-exact calibration case.
fn decoder(target: &RustModel, draft: Option<&RustModel>, cfg: SpecCfg) -> SpecDecoder {
    let kind = cfg.drafter.clone();
    let dm = match &kind {
        DrafterKind::Ngram => None,
        DrafterKind::Model(name) if name.is_empty() => Some(target.clone()),
        DrafterKind::Model(_) => Some(draft.expect("model drafter needs a draft model").clone()),
    };
    let dec = SpecDecoder::new(target.clone(), dm, cfg).unwrap();
    match kind {
        DrafterKind::Ngram => dec.with_drafter(Box::new(NgramDrafter::default())),
        DrafterKind::Model(name) => {
            let dm = if name.is_empty() { target.clone() } else { draft.unwrap().clone() };
            dec.with_drafter(Box::new(ModelDrafter::with_prefill(dm, PrefillCfg::serial())))
        }
    }
}

#[test]
fn spec_matches_serial_greedy_both_backends_all_mixers() {
    let mut rng = Rng::new(71);
    for mixer in ["hla2", "ahla", "hla3"] {
        let target = target_model(mixer, 17);
        let draft = draft_model(mixer, 19);
        let prompt = random_prompt(&mut rng, 23);
        let want = serial_from_prompt(&target, &prompt, SamplerCfg::greedy(), 64, None);
        assert_eq!(want.len(), 64);
        for kind in [
            DrafterKind::Ngram,
            DrafterKind::Model(String::new()), // self-draft
            DrafterKind::Model("d".into()),    // small-config draft model
        ] {
            for k in [1usize, 4, 8] {
                for cfg in [serial_cfg(k, kind.clone()), scan_cfg(k, kind.clone())] {
                    let label = format!("{mixer} {} k={k} chunk={}", kind.label(), cfg.verify_chunk);
                    let mut dec = decoder(&target, Some(&draft), cfg);
                    let got =
                        dec.generate(&prompt, SamplerCfg::greedy(), 64, None).unwrap();
                    assert_eq!(got, want, "{label}: stream diverged");
                    let stats = &dec.engine.stats;
                    assert_eq!(stats.emitted, 64, "{label}: emitted accounting");
                    assert!(stats.accepted <= stats.drafted, "{label}");
                    assert!(stats.rollbacks <= stats.rounds, "{label}");
                }
            }
        }
    }
}

#[test]
fn self_draft_greedy_serial_backend_accepts_everything() {
    // self-draft + serial verify + serial drafter ingestion: the draft IS
    // the target's greedy continuation, bit for bit, so every proposal
    // must land and no rollback may ever fire — the calibration case that
    // catches off-by-one desyncs between draft, verify and commit.
    let mut rng = Rng::new(73);
    for mixer in ["hla2", "ahla", "hla3"] {
        let target = target_model(mixer, 29);
        let prompt = random_prompt(&mut rng, 17);
        let want = serial_from_prompt(&target, &prompt, SamplerCfg::greedy(), 48, None);
        let mut dec = decoder(&target, None, serial_cfg(6, DrafterKind::Model(String::new())));
        let got = dec.generate(&prompt, SamplerCfg::greedy(), 48, None).unwrap();
        assert_eq!(got, want, "{mixer}");
        let stats = &dec.engine.stats;
        assert_eq!(stats.accepted, stats.drafted, "{mixer}: a self-draft must always land");
        assert_eq!(stats.rollbacks, 0, "{mixer}: full acceptance never rolls back");
        assert!(
            stats.rounds < 48,
            "{mixer}: {} rounds for 48 tokens is not speculation",
            stats.rounds
        );
    }
}

#[test]
fn spec_matches_serial_seeded_sampling() {
    // seeded sampling on the bit-exact serial verify backend: the coupled
    // acceptance rule spends exactly one categorical draw per emitted
    // token, so the stream — and the RNG position after it — must equal
    // serial decode's exactly
    let mut rng = Rng::new(79);
    for mixer in ["hla2", "ahla", "hla3"] {
        let target = target_model(mixer, 31);
        let draft = draft_model(mixer, 37);
        for scfg in [
            SamplerCfg { temperature: 0.9, top_k: 8, seed: 11 },
            SamplerCfg { temperature: 1.3, top_k: 0, seed: 12 },
        ] {
            let prompt = random_prompt(&mut rng, 19);
            let want = serial_from_prompt(&target, &prompt, scfg.clone(), 56, None);
            for kind in [DrafterKind::Ngram, DrafterKind::Model("d".into())] {
                for k in [1usize, 3, 8] {
                    let label = format!("{mixer} {} k={k} t={}", kind.label(), scfg.temperature);
                    let mut dec = decoder(&target, Some(&draft), serial_cfg(k, kind.clone()));
                    let got = dec.generate(&prompt, scfg.clone(), 56, None).unwrap();
                    assert_eq!(got, want, "{label}: sampled stream diverged");
                }
            }
        }
    }
}

#[test]
fn spec_sessions_resume_without_desync() {
    // a conversation that decodes turn 1 speculatively, snapshots, and
    // resumes (speculatively or serially) must emit exactly the one
    // uninterrupted serial stream — state, sampler RNG position and last
    // token all survive the snapshot
    let mut rng = Rng::new(83);
    for mixer in ["hla2", "ahla", "hla3"] {
        let target = target_model(mixer, 41);
        let scfg = SamplerCfg { temperature: 0.8, top_k: 12, seed: 23 };
        let prompt = random_prompt(&mut rng, 21);
        let full = serial_from_prompt(&target, &prompt, scfg.clone(), 96, None);
        assert_eq!(full.len(), 96);

        // turn 1: speculative (serial verify backend = bit-exact)
        let mut dec = decoder(&target, None, serial_cfg(5, DrafterKind::Ngram));
        let mut sampler = Sampler::new(scfg.clone());
        dec.lane.drafter.commit(&prompt);
        advance(
            dec.engine.model(),
            &mut dec.lane.state,
            &prompt[..prompt.len() - 1],
            &PrefillCfg::serial(),
        );
        let t1 = dec.run(&mut sampler, prompt[prompt.len() - 1], 40, None).unwrap();
        assert_eq!(t1, full[..40], "{mixer}: turn 1 diverged");

        // snapshot: state tensors + sampler stream position + last token
        // (the session-store carrier formats)
        let parts = dec.lane.state.to_tensors().unwrap();
        let samp = SamplerState::capture(&sampler);
        let last = *t1.last().unwrap();

        // resume speculatively in a fresh decoder
        let mut dec2 = decoder(&target, None, serial_cfg(5, DrafterKind::Ngram));
        dec2.lane.state.load_tensors(&parts).unwrap();
        let mut ctx = prompt.clone();
        ctx.extend_from_slice(&t1);
        dec2.lane.drafter.commit(&ctx);
        let mut sampler2 = samp.rebuild();
        let t2 = dec2.run(&mut sampler2, last, 56, None).unwrap();
        assert_eq!(t2, full[40..], "{mixer}: speculative resume diverged");

        // and resume serially from the very same snapshot
        let mut state3 = ModelState::new(&target.cfg);
        state3.load_tensors(&parts).unwrap();
        let mut sampler3 = samp.rebuild();
        let t3 = serial_generate(&target, &mut state3, &mut sampler3, last, 56, None);
        assert_eq!(t3, full[40..], "{mixer}: serial resume from a spec snapshot diverged");
    }
}

#[test]
fn eos_and_token_budget_do_not_desync_the_stream() {
    let mut rng = Rng::new(89);
    let target = target_model("hla2", 43);
    let prompt = random_prompt(&mut rng, 15);
    let scfg = SamplerCfg { temperature: 0.9, top_k: 8, seed: 31 };

    // eos: pick a token known to appear mid-stream; speculative decode
    // must stop exactly where serial stops (drafts beyond the eos are
    // rolled back, not absorbed)
    let probe = serial_from_prompt(&target, &prompt, scfg.clone(), 32, None);
    let eos = probe[7];
    let want = serial_from_prompt(&target, &prompt, scfg.clone(), 32, Some(eos));
    assert_eq!(want.last(), Some(&eos));
    for cfg in [serial_cfg(8, DrafterKind::Ngram), serial_cfg(8, DrafterKind::Model(String::new()))]
    {
        let mut dec = decoder(&target, None, cfg);
        let got = dec.generate(&prompt, scfg.clone(), 32, Some(eos)).unwrap();
        assert_eq!(got, want, "eos stream diverged");
    }

    // token budget: a k=8 decoder asked for 5 tokens must emit exactly 5
    // AND leave state + sampler where serial left them — proven by
    // continuing the same lane for 10 more and matching serial's 15
    let want15 = serial_from_prompt(&target, &prompt, scfg.clone(), 15, None);
    let mut dec = decoder(&target, None, serial_cfg(8, DrafterKind::Model(String::new())));
    let first5 = dec.generate(&prompt, scfg.clone(), 5, None).unwrap();
    assert_eq!(first5.len(), 5);
    assert_eq!(first5, want15[..5]);
    // generate() consumed its own sampler; rebuild the continuation draw
    // stream the way a session resume would
    let mut sampler = Sampler::new(scfg);
    let mut burn = ModelState::new(&target.cfg);
    burn.load_tensors(&dec.lane.state.to_tensors().unwrap()).unwrap();
    // replay serial's first 5 draws to align the fresh sampler
    {
        let mut s = ModelState::new(&target.cfg);
        advance(&target, &mut s, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
        serial_generate(&target, &mut s, &mut sampler, prompt[prompt.len() - 1], 5, None);
    }
    let rest = dec.run(&mut sampler, first5[4], 10, None).unwrap();
    assert_eq!(rest, want15[5..], "continuation after a budget-capped round diverged");
}

#[test]
fn adaptive_k_grows_on_acceptance_and_shrinks_on_rejection() {
    let mut rng = Rng::new(97);
    let target = target_model("hla2", 47);
    let prompt = random_prompt(&mut rng, 13);
    let want = serial_from_prompt(&target, &prompt, SamplerCfg::greedy(), 96, None);

    // self-draft greedy: every draft lands, so the controller must ride
    // acceptance up to k_max — and the stream still equals serial
    let grow_cfg = SpecCfg {
        k: 2,
        adaptive: true,
        drafter: DrafterKind::Model(String::new()),
        verify_chunk: 0,
        ..Default::default()
    };
    let mut grower = decoder(&target, None, grow_cfg.clone());
    let got = grower.generate(&prompt, SamplerCfg::greedy(), 96, None).unwrap();
    assert_eq!(got, want);
    assert_eq!(grower.lane.ctrl.k(), grow_cfg.k_max, "sustained acceptance must max out k");
    assert!(grower.engine.stats.accept_rate() > 0.99);

    // a wrong-weights draft model: almost nothing lands, so k must
    // collapse to k_min (speculation self-throttles toward serial) while
    // the stream stays exact
    let wrong = target_model("hla2", 999);
    let shrink_cfg = SpecCfg {
        k: 8,
        adaptive: true,
        drafter: DrafterKind::Model("w".into()),
        verify_chunk: 0,
        ..Default::default()
    };
    let mut shrinker = decoder(&target, Some(&wrong), shrink_cfg.clone());
    let got = shrinker.generate(&prompt, SamplerCfg::greedy(), 96, None).unwrap();
    assert_eq!(got, want);
    assert_eq!(shrinker.lane.ctrl.k(), shrink_cfg.k_min, "sustained rejection must floor k");
    assert!(shrinker.engine.stats.rollbacks > 0, "rejections must exercise the rollback path");
}
