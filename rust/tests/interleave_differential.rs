//! Scheduler-differential acceptance suite for chunked prefill/decode
//! interleaving (`--prefill-budget`): a prompt ingested in budgeted
//! window cuts that ride the decode cycle must land **bit-identical**
//! state — and therefore byte-identical token streams — to the same
//! cursor driven monolithically, across mixers, samplers, and every
//! subsystem the scheduler composes with.  Runs artifact-free on the
//! pure-Rust [`hla::testing::fixtures`] models, like the bucketing /
//! prefix-cache / spec differential suites.
//!
//! Exactness ledger (see `prefill::cursor` for the contract):
//! * A cursor fixes its cut quantum at creation, so the bit-exact end
//!   state depends only on the window sequence — never on how many
//!   windows run per engine cycle.  Budgeted-interleaved vs monolithic
//!   same-window is therefore bitwise equal for **greedy AND seeded**
//!   sampling; greedy streams additionally equal plain serial decode
//!   (segmentation-independence of the greedy grid, already pinned for
//!   scan-vs-serial).
//! * Cached cursors cut at `cache.chunk()` multiples — the identical
//!   segmentation `ingest_lane_cached` has always used — so budgeted
//!   cached ingestion is bitwise equal to the monolithic cached path
//!   and warm stays byte-identical to cold.
//! * Composition: session detach/resume/fork read and seed the same
//!   component tensors, spec rounds run on their own state between a
//!   parked lane's chunks, `--decode-threads` decode is bitwise equal
//!   to serial by the pool's own contract, and bucket churn moves a
//!   parked lane's (dead-weight) slot without corrupting the landing.
//!
//! The harness below is the host-side twin of `EngineLoop`'s budgeted
//! cycle: FIFO admissions park cursors, `run_prefill_round` deals one
//! window per visit round-robin, landed lanes decode one token per
//! cycle — the same arithmetic the engine runs, minus the threads.

use hla::cache::{PrefixCache, PrefixCacheCfg};
use hla::coordinator::interleave::{bounded_admissions, run_prefill_round, RoundRobin};
use hla::coordinator::repack::{compaction_moves, identity_moves, remap_components};
use hla::coordinator::{BucketSpec, BucketSwitch, BucketTracker};
use hla::model::pool::DecodePool;
use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{
    slice_components, splice_components, zero_component_lane, ModelState, RustModel,
};
use hla::prefill::{advance, PrefillCfg, Prefiller, PrefillCursor};
use hla::runtime::ModelCfg;
use hla::session::SamplerState;
use hla::spec::{DrafterKind, SpecCfg, SpecDecoder};
use hla::tensor::Tensor;
use hla::testing::fixtures::{build_model_full, random_prompt, ModelShape};
use hla::util::rng::Rng;

fn seeded(seed: u64) -> SamplerCfg {
    SamplerCfg { temperature: 0.9, top_k: 20, seed }
}

/// Bit-level equality for state component tensors: a different chunking
/// of the same scan must not perturb a single ULP.
fn assert_state_bits_equal(a: &[Tensor], b: &[Tensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: component arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let xb: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u32> = y.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "{what}: component {i} bits");
    }
}

/// The reference the budgeted path is pinned to: the *same* cursor
/// window driven to completion in one call.  Identical cut sequence by
/// construction, so the landing must match bitwise however the budgeted
/// run slices its cycles.
fn monolithic_same_window(
    pf: &Prefiller,
    resume: Option<&[Tensor]>,
    prompt: &[u8],
    window: usize,
) -> (Vec<Tensor>, usize) {
    let mut cur = pf.cursor(resume, prompt, window).unwrap();
    while !cur.done() {
        cur.advance_budget(pf, None, usize::MAX).unwrap();
    }
    let (parts, consumed, _) = cur.finish(pf).unwrap();
    (parts, consumed)
}

/// Decode `max_new` tokens from a landed component state; returns the
/// stream, the post-decode components (the detach snapshot), and the
/// last sampled-but-not-fed token.
fn decode_from(
    model: &RustModel,
    parts: &[Tensor],
    first: u8,
    sampler: &mut Sampler,
    max_new: usize,
) -> (Vec<u8>, Vec<Tensor>, u8) {
    let mc = &model.cfg;
    let mut state = ModelState::new(mc);
    state.load_components(mc, parts).unwrap();
    let mut out = Vec::with_capacity(max_new);
    let mut last = first;
    while out.len() < max_new {
        let logits = model.decode_step(&mut state, last);
        let y = sampler.sample(&logits) as u8;
        out.push(y);
        last = y;
    }
    (out, state.to_components(mc).unwrap(), last)
}

/// Serial decode from scratch — the greedy-grid reference (greedy
/// streams are segmentation-independent; seeded ones are pinned to the
/// same-window reference instead).
fn serial_stream(model: &RustModel, prompt: &[u8], scfg: &SamplerCfg, max_new: usize) -> Vec<u8> {
    let mut state = ModelState::new(&model.cfg);
    let mut sampler = Sampler::new(scfg.clone());
    advance(model, &mut state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
    let mut out = Vec::with_capacity(max_new);
    let mut last = prompt[prompt.len() - 1];
    while out.len() < max_new {
        let logits = model.decode_step(&mut state, last);
        let y = sampler.sample(&logits) as u8;
        out.push(y);
        last = y;
    }
    out
}

/// One lane of the interleaved harness: a parked cursor until landing,
/// then a decoding state — `EngineLoop`'s lane phases, host-side.
struct Lane {
    req: usize,
    cursor: Option<PrefillCursor>,
    state: Option<ModelState>,
    last: u8,
    sampler: Sampler,
    max_new: usize,
    out: Vec<u8>,
    landing: Vec<Tensor>,
    hit_tokens: usize,
}

/// Everything a finished request leaves behind, for differential
/// comparison: the stream, the prefill landing, the detach snapshot.
struct RunOut {
    stream: Vec<u8>,
    landing: Vec<Tensor>,
    detach: Vec<Tensor>,
    last: u8,
    sampler: SamplerState,
    hit_tokens: usize,
}

/// Drive a staggered workload through the budgeted cycle: FIFO
/// admissions park cursors (uncached window = `budget`, cached window =
/// the cache chunk), `run_prefill_round` deals one window per visit,
/// every landed lane decodes one token per cycle (optionally through a
/// [`DecodePool`]).  Returns one [`RunOut`] per request.
fn run_interleaved(
    model: &RustModel,
    pf: &Prefiller,
    cache: Option<&PrefixCache>,
    requests: &[(usize, Vec<u8>, usize)],
    budget: usize,
    n_lanes: usize,
    scfg_of: &dyn Fn(u64) -> SamplerCfg,
    pool: Option<&DecodePool>,
) -> Vec<RunOut> {
    let mc = &model.cfg;
    let mut rr = RoundRobin::new();
    let mut waiting: Vec<(usize, usize)> =
        (0..requests.len()).map(|i| (requests[i].0, i)).collect();
    let mut lanes: Vec<Option<Lane>> = (0..n_lanes).map(|_| None).collect();
    let mut done: Vec<Option<RunOut>> = (0..requests.len()).map(|_| None).collect();
    let mut cycle = 0usize;
    while done.iter().any(|d| d.is_none()) {
        // admissions: arrived requests into free lanes (FIFO) — parking
        // a cursor, never running the scan at admission time
        while let Some(pos) = waiting.iter().position(|&(at, _)| at <= cycle) {
            let Some(slot) = lanes.iter().position(|l| l.is_none()) else { break };
            let (_, req) = waiting.remove(pos);
            let (_, prompt, max_new) = &requests[req];
            let cursor = match cache {
                Some(c) => pf.cursor_cached(c, prompt).unwrap(),
                None => pf.cursor(None, prompt, budget).unwrap(),
            };
            lanes[slot] = Some(Lane {
                req,
                hit_tokens: cursor.hit_tokens(),
                cursor: Some(cursor),
                state: None,
                last: prompt[prompt.len() - 1],
                sampler: Sampler::new(scfg_of(req as u64)),
                max_new: *max_new,
                out: vec![],
                landing: vec![],
            });
        }
        // the budgeted prefill round: one window per visit, round-robin
        let parked: Vec<usize> = lanes
            .iter()
            .enumerate()
            .filter(|(_, l)| l.as_ref().is_some_and(|l| l.cursor.is_some()))
            .map(|(i, _)| i)
            .collect();
        run_prefill_round(&mut rr, &parked, budget, |b| {
            let cur = lanes[b].as_mut().unwrap().cursor.as_mut().unwrap();
            let used = cur.advance_budget(pf, cache, 1).unwrap();
            (used, cur.done())
        });
        // landings: finished cursors become decoding states
        for l in lanes.iter_mut().flatten() {
            if l.cursor.as_ref().is_some_and(|c| c.done()) {
                let (parts, _, _) = l.cursor.take().unwrap().finish(pf).unwrap();
                let mut st = ModelState::new(mc);
                st.load_components(mc, &parts).unwrap();
                l.state = Some(st);
                l.landing = parts;
            }
        }
        // one decode token per landed lane per cycle
        for slot in 0..n_lanes {
            let finished = {
                let Some(l) = lanes[slot].as_mut() else { continue };
                let Some(state) = l.state.as_mut() else { continue };
                let logits = match pool {
                    Some(p) => model.decode_step_pooled(state, l.last, p).unwrap(),
                    None => model.decode_step(state, l.last),
                };
                let y = l.sampler.sample(&logits) as u8;
                l.last = y;
                l.out.push(y);
                l.out.len() >= l.max_new
            };
            if finished {
                let l = lanes[slot].take().unwrap();
                done[l.req] = Some(RunOut {
                    detach: l.state.as_ref().unwrap().to_components(mc).unwrap(),
                    stream: l.out,
                    landing: l.landing,
                    last: l.last,
                    sampler: SamplerState::capture(&l.sampler),
                    hit_tokens: l.hit_tokens,
                });
            }
        }
        cycle += 1;
        assert!(cycle < 10_000, "interleaved workload did not drain");
    }
    done.into_iter().map(Option::unwrap).collect()
}

/// Staggered arrivals with prompts long enough to park across several
/// cycles at the suite's budgets — real interleaving, not degenerate
/// single-window landings.
fn staggered_requests(rng: &mut Rng, vocab: usize) -> Vec<(usize, Vec<u8>, usize)> {
    (0..5)
        .map(|i| {
            let arrive = i * 2;
            let prompt = random_prompt(rng, 9 + (i % 4) * 7, vocab);
            let max_new = 6 + (i % 3) * 3;
            (arrive, prompt, max_new)
        })
        .collect()
}

#[test]
fn interleaved_streams_match_monolithic_all_mixers_greedy_and_seeded() {
    const BUDGET: usize = 6;
    for mixer in ["hla2", "ahla", "hla3"] {
        let model = build_model_full(mixer, &ModelShape::default(), 11);
        let pf = Prefiller::new(model.clone(), PrefillCfg::scan(4, 1)).unwrap();
        let mut rng = Rng::new(31);
        let requests = staggered_requests(&mut rng, model.cfg.vocab);
        let cases: [(&str, &dyn Fn(u64) -> SamplerCfg); 2] = [
            ("greedy", &|_| SamplerCfg::greedy()),
            ("seeded", &|req| seeded(100 + req)),
        ];
        for (name, scfg_of) in cases {
            // 3 lanes < 5 requests: admissions queue behind live lanes,
            // parked prefills interleave with landed lanes' decode steps
            let got = run_interleaved(&model, &pf, None, &requests, BUDGET, 3, scfg_of, None);
            for (req, (_, prompt, max_new)) in requests.iter().enumerate() {
                let (parts, consumed) = monolithic_same_window(&pf, None, prompt, BUDGET);
                assert_state_bits_equal(
                    &got[req].landing,
                    &parts,
                    &format!("{mixer}/{name}: request {req} landing"),
                );
                let mut sampler = Sampler::new(scfg_of(req as u64));
                let (want, _, _) =
                    decode_from(&model, &parts, prompt[consumed], &mut sampler, *max_new);
                assert_eq!(
                    got[req].stream, want,
                    "{mixer}/{name}: request {req} diverged from monolithic same-window"
                );
                if name == "greedy" {
                    // greedy grid: any segmentation equals serial decode
                    let serial = serial_stream(&model, prompt, &SamplerCfg::greedy(), *max_new);
                    assert_eq!(got[req].stream, serial, "{mixer}: request {req} vs serial");
                }
            }
        }
    }
}

#[test]
fn cache_seeded_interleave_is_monolithic_bitwise_and_warm_equals_cold() {
    const CHUNK: usize = 8;
    let model = build_model_full("hla2", &ModelShape::default(), 17);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(CHUNK, 2)).unwrap();
    let cache = PrefixCache::new(PrefixCacheCfg::new(1 << 20, CHUNK));
    let mut rng = Rng::new(29);
    let vocab = model.cfg.vocab;
    let prefix = random_prompt(&mut rng, 2 * CHUNK, vocab);
    let mut p1 = prefix.clone();
    p1.extend(random_prompt(&mut rng, 5, vocab));
    let mut p2 = prefix.clone();
    p2.extend(random_prompt(&mut rng, 7, vocab));
    let requests = vec![(0usize, p1.clone(), 8usize), (0, p2.clone(), 8)];
    let cases: [(&str, &dyn Fn(u64) -> SamplerCfg); 2] =
        [("greedy", &|_| SamplerCfg::greedy()), ("seeded", &|req| seeded(3 + req))];
    for (name, scfg_of) in cases {
        cache.clear();
        // cold pass: both cursors created before any boundary insert
        let cold = run_interleaved(&model, &pf, Some(&cache), &requests, 5, 2, scfg_of, None);
        assert!(cold.iter().all(|r| r.hit_tokens == 0), "{name}: first pass must be cold");
        // warm pass: the shared prefix now seeds both admissions
        let warm = run_interleaved(&model, &pf, Some(&cache), &requests, 5, 2, scfg_of, None);
        assert!(warm.iter().all(|r| r.hit_tokens > 0), "{name}: second pass must hit");
        for (req, (c, w)) in cold.iter().zip(&warm).enumerate() {
            assert_eq!(c.stream, w.stream, "{name}: warm vs cold stream, request {req}");
            assert_state_bits_equal(
                &w.landing,
                &c.landing,
                &format!("{name}: warm vs cold landing, request {req}"),
            );
        }
        // budgeted cached ingestion == the monolithic cached path, bitwise
        let ref_cache = PrefixCache::new(PrefixCacheCfg::new(1 << 20, CHUNK));
        for (req, prompt) in [&p1, &p2].into_iter().enumerate() {
            ref_cache.clear();
            let (parts, consumed, _) = pf.ingest_lane_cached(&ref_cache, prompt).unwrap();
            assert_state_bits_equal(
                &cold[req].landing,
                &parts,
                &format!("{name}: budgeted vs ingest_lane_cached, request {req}"),
            );
            if name == "greedy" {
                let mut sampler = Sampler::new(SamplerCfg::greedy());
                let (want, _, _) = decode_from(&model, &parts, prompt[consumed], &mut sampler, 8);
                assert_eq!(cold[req].stream, want);
                assert_eq!(
                    cold[req].stream,
                    serial_stream(&model, prompt, &SamplerCfg::greedy(), 8),
                    "cached interleave vs serial, request {req}"
                );
            }
        }
    }
}

#[test]
fn session_resume_and_fork_compose_with_budgeted_prefill() {
    const WINDOW: usize = 5;
    let model = build_model_full("ahla", &ModelShape::default(), 13);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(4, 1)).unwrap();
    let mut rng = Rng::new(5);
    let vocab = model.cfg.vocab;
    let prompt = random_prompt(&mut rng, 18, vocab);
    let cont = random_prompt(&mut rng, 11, vocab);
    let fork_a = random_prompt(&mut rng, 7, vocab);
    let fork_b = random_prompt(&mut rng, 9, vocab);
    let (turn1, turn2) = (6usize, 6usize);

    // a budgeted turn-2 ingestion: resume parts seed the cursor, windows
    // dealt one at a time as the engine cycle would
    let budgeted_turn =
        |resume: &[Tensor], t2: &[u8], sampler: &mut Sampler, max_new: usize| {
            let mut cur = pf.cursor(Some(resume), t2, WINDOW).unwrap();
            while !cur.done() {
                cur.advance_budget(&pf, None, 1).unwrap();
            }
            let (parts, consumed, _) = cur.finish(&pf).unwrap();
            let (out, _, _) = decode_from(&model, &parts, t2[consumed], sampler, max_new);
            (out, parts)
        };

    for scfg in [SamplerCfg::greedy(), seeded(7)] {
        // turn 1 through the interleaved harness (a sibling request
        // keeps the rotation honest)
        let requests = vec![
            (0usize, prompt.clone(), turn1),
            (1, random_prompt(&mut Rng::new(99), 13, vocab), 4),
        ];
        let out = run_interleaved(&model, &pf, None, &requests, WINDOW, 2, &|_| scfg.clone(), None);
        // the detach snapshot equals the monolithic reference's detach
        let (parts, consumed) = monolithic_same_window(&pf, None, &prompt, WINDOW);
        let mut ref_sampler = Sampler::new(scfg.clone());
        let (want1, ref_detach, ref_last) =
            decode_from(&model, &parts, prompt[consumed], &mut ref_sampler, turn1);
        assert_eq!(out[0].stream, want1, "turn 1 stream");
        assert_state_bits_equal(&out[0].detach, &ref_detach, "turn-1 detach snapshot");
        assert_eq!(out[0].last, ref_last, "turn-1 last sampled token");

        // resume: feed the snapshot's last sampled token ahead of the new
        // turn's prompt (the session contract), ingested under budget
        let mut t2 = vec![out[0].last];
        t2.extend_from_slice(&cont);
        let mut s_budget = out[0].sampler.rebuild();
        let (got2, got2_parts) = budgeted_turn(&out[0].detach, &t2, &mut s_budget, turn2);
        let (ref2_parts, ref2_consumed) =
            monolithic_same_window(&pf, Some(&ref_detach), &t2, WINDOW);
        assert_state_bits_equal(&got2_parts, &ref2_parts, "resumed turn-2 landing");
        let mut s_ref = out[0].sampler.rebuild();
        let (want2, _, _) = decode_from(&model, &ref2_parts, t2[ref2_consumed], &mut s_ref, turn2);
        assert_eq!(got2, want2, "resumed turn-2 stream");

        // forks: two divergent continuations from one detach, each with
        // its own sampler seed, each pinned to its own reference
        for (fseed, extra) in [(101u64, &fork_a), (202, &fork_b)] {
            let mut tf = vec![out[0].last];
            tf.extend_from_slice(extra);
            let mut s_fork = Sampler::new(seeded(fseed));
            let (got, got_parts) = budgeted_turn(&out[0].detach, &tf, &mut s_fork, 5);
            let (fparts, fconsumed) = monolithic_same_window(&pf, Some(&ref_detach), &tf, WINDOW);
            assert_state_bits_equal(&got_parts, &fparts, "fork landing");
            let mut s_want = Sampler::new(seeded(fseed));
            let (want, _, _) = decode_from(&model, &fparts, tf[fconsumed], &mut s_want, 5);
            assert_eq!(got, want, "fork {fseed} stream");
        }
    }
}

#[test]
fn spec_rounds_between_chunks_disturb_nothing() {
    const WINDOW: usize = 4;
    let model = build_model_full("hla2", &ModelShape::default(), 19);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(4, 1)).unwrap();
    let mut rng = Rng::new(37);
    let vocab = model.cfg.vocab;
    let prompt = random_prompt(&mut rng, 21, vocab);
    let spec_prompt = random_prompt(&mut rng, 12, vocab);
    // park a lane mid-prompt
    let mut cur = pf.cursor(None, &prompt, WINDOW).unwrap();
    cur.advance_budget(&pf, None, 1).unwrap();
    assert!(!cur.done(), "cursor must be parked mid-prompt");
    // full speculative generations run between this lane's chunks — the
    // spec engine's lossless rule holds, and the parked cursor is inert
    for scfg in [SamplerCfg::greedy(), seeded(41)] {
        let cfg = SpecCfg {
            k: 3,
            adaptive: false,
            drafter: DrafterKind::Ngram,
            verify_chunk: 0,
            ..Default::default()
        };
        let mut dec = SpecDecoder::new(model.clone(), None, cfg).unwrap();
        let spec_stream = dec.generate(&spec_prompt, scfg.clone(), 10, None).unwrap();
        assert_eq!(
            spec_stream,
            serial_stream(&model, &spec_prompt, &scfg, 10),
            "spec stream changed by a parked prefill (temp {})",
            scfg.temperature
        );
    }
    // ... and the lane lands exactly as if nothing ran in between
    while !cur.done() {
        cur.advance_budget(&pf, None, 1).unwrap();
    }
    let (parts, consumed, _) = cur.finish(&pf).unwrap();
    let (want, _) = monolithic_same_window(&pf, None, &prompt, WINDOW);
    assert_state_bits_equal(&parts, &want, "parked landing after spec rounds");
    let mut sampler = Sampler::new(SamplerCfg::greedy());
    let (stream, _, _) = decode_from(&model, &parts, prompt[consumed], &mut sampler, 8);
    assert_eq!(stream, serial_stream(&model, &prompt, &SamplerCfg::greedy(), 8));
}

#[test]
fn decode_pool_composes_byte_identically() {
    const BUDGET: usize = 6;
    let model = build_model_full("hla3", &ModelShape::default(), 23);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(4, 1)).unwrap();
    let mut rng = Rng::new(43);
    let requests = staggered_requests(&mut rng, model.cfg.vocab);
    let pool = DecodePool::new(4); // serve --decode-threads 4
    let cases: [(&str, &dyn Fn(u64) -> SamplerCfg); 2] =
        [("greedy", &|_| SamplerCfg::greedy()), ("seeded", &|req| seeded(500 + req))];
    for (name, scfg_of) in cases {
        let serial = run_interleaved(&model, &pf, None, &requests, BUDGET, 3, scfg_of, None);
        let pooled =
            run_interleaved(&model, &pf, None, &requests, BUDGET, 3, scfg_of, Some(&pool));
        for (req, (s, p)) in serial.iter().zip(&pooled).enumerate() {
            assert_eq!(s.stream, p.stream, "{name}: pooled decode diverged, request {req}");
            assert_state_bits_equal(
                &p.detach,
                &s.detach,
                &format!("{name}: pooled detach, request {req}"),
            );
        }
    }
}

/// Slimmed host-side twin of the engine's bucketed state handling (the
/// audited version lives in `bucketing_differential.rs`): enough to
/// churn the layout while parked prefills hold slots as dead weight.
struct ChurnPool {
    comps: Vec<Tensor>,
    capacity: usize,
    tracker: BucketTracker,
    slot_of: Vec<usize>,
    active: Vec<bool>,
    grows: usize,
    shrinks: usize,
}

impl ChurnPool {
    fn new(cfg: &ModelCfg, capacity: usize, shrink_after: usize) -> ChurnPool {
        let comps = cfg
            .state_paths
            .iter()
            .map(|(_, sh)| {
                let mut sh = sh.clone();
                sh[1] = capacity;
                Tensor::zeros(&sh)
            })
            .collect();
        ChurnPool {
            comps,
            capacity,
            tracker: BucketTracker::new(BucketSpec::Pow2.ladder(capacity), shrink_after, capacity),
            slot_of: vec![0; capacity],
            active: vec![false; capacity],
            grows: 0,
            shrinks: 0,
        }
    }

    fn live(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn read(&self, lane: usize) -> Vec<Tensor> {
        slice_components(&self.comps, self.slot_of[lane])
    }

    fn write(&mut self, lane: usize, parts: &[Tensor]) {
        splice_components(&mut self.comps, self.slot_of[lane], parts);
    }

    fn apply(&mut self, sw: BucketSwitch) {
        let lanes: Vec<usize> = (0..self.capacity).filter(|&b| self.active[b]).collect();
        let slots: Vec<usize> = lanes.iter().map(|&b| self.slot_of[b]).collect();
        let (w, moves) = match sw {
            BucketSwitch::Grow(w) => {
                self.grows += 1;
                (w, identity_moves(&slots))
            }
            BucketSwitch::Shrink(w) => {
                self.shrinks += 1;
                (w, compaction_moves(&slots))
            }
        };
        self.comps = remap_components(&self.comps, &moves, w);
        for (i, &b) in lanes.iter().enumerate() {
            self.slot_of[b] = moves[i].1;
        }
    }

    fn admit(&mut self, lane: usize) {
        assert!(!self.active[lane], "lane {lane} already live");
        if let Some(sw) = self.tracker.on_admit(self.live() + 1) {
            self.apply(sw);
        }
        let used: Vec<usize> =
            (0..self.capacity).filter(|&b| self.active[b]).map(|b| self.slot_of[b]).collect();
        let slot = (0..self.tracker.width())
            .find(|s| !used.contains(s))
            .expect("admission grow guarantees a free slot");
        self.active[lane] = true;
        self.slot_of[lane] = slot;
        for c in &mut self.comps {
            zero_component_lane(c, slot);
        }
    }

    fn finish(&mut self, lane: usize) {
        self.active[lane] = false;
    }

    fn after_cycle(&mut self) {
        let live = self.live();
        if let Some(sw) = self.tracker.after_step(live) {
            self.apply(sw);
        }
    }
}

#[test]
fn parked_prefills_ride_bucket_churn_as_dead_weight() {
    // parked (mid-prefill) lanes occupy bucket slots as PAD passengers
    // while the layout grows and shrinks around them; the landing splices
    // into whatever slot churn assigned, and every stream stays pinned to
    // its monolithic reference — greedy and seeded.
    const CAPACITY: usize = 4;
    const BUDGET: usize = 5;
    let model = build_model_full("hla2", &ModelShape::default(), 47);
    let mc = model.cfg.clone();
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(4, 1)).unwrap();
    let mut rng = Rng::new(53);
    let vocab = mc.vocab;
    let requests: Vec<(usize, Vec<u8>, usize)> = (0..6)
        .map(|i| {
            let arrive = i * 2;
            let prompt = random_prompt(&mut rng, 9 + (i % 4) * 5, vocab);
            let max_new = 5 + (i % 3) * 3;
            (arrive, prompt, max_new)
        })
        .collect();
    let cases: [(&str, &dyn Fn(u64) -> SamplerCfg); 2] =
        [("greedy", &|_| SamplerCfg::greedy()), ("seeded", &|req| seeded(700 + req))];
    for (name, scfg_of) in cases {
        let mut pool = ChurnPool::new(&mc, CAPACITY, 1);
        let mut rr = RoundRobin::new();
        let mut waiting: Vec<(usize, usize)> =
            (0..requests.len()).map(|i| (requests[i].0, i)).collect();
        let mut lanes: Vec<Option<Lane>> = (0..CAPACITY).map(|_| None).collect();
        let mut done: Vec<Option<Vec<u8>>> = (0..requests.len()).map(|_| None).collect();
        let mut cycle = 0usize;
        while done.iter().any(|d| d.is_none()) {
            while let Some(pos) = waiting.iter().position(|&(at, _)| at <= cycle) {
                let Some(slot) = lanes.iter().position(|l| l.is_none()) else { break };
                let (_, req) = waiting.remove(pos);
                let (_, prompt, max_new) = &requests[req];
                pool.admit(slot); // the parked lane's zeroed PAD slot
                let cursor = pf.cursor(None, prompt, BUDGET).unwrap();
                lanes[slot] = Some(Lane {
                    req,
                    hit_tokens: 0,
                    cursor: Some(cursor),
                    state: None,
                    last: prompt[prompt.len() - 1],
                    sampler: Sampler::new(scfg_of(req as u64)),
                    max_new: *max_new,
                    out: vec![],
                    landing: vec![],
                });
            }
            let parked: Vec<usize> = lanes
                .iter()
                .enumerate()
                .filter(|(_, l)| l.as_ref().is_some_and(|l| l.cursor.is_some()))
                .map(|(i, _)| i)
                .collect();
            run_prefill_round(&mut rr, &parked, BUDGET, |b| {
                let cur = lanes[b].as_mut().unwrap().cursor.as_mut().unwrap();
                let used = cur.advance_budget(&pf, None, 1).unwrap();
                (used, cur.done())
            });
            for slot in 0..CAPACITY {
                let Some(l) = lanes[slot].as_mut() else { continue };
                if l.cursor.as_ref().is_some_and(|c| c.done()) {
                    // the dead-weight slice must still be the zeros it was
                    // admitted with: repacks moved it, never corrupted it
                    assert!(
                        pool.read(slot).iter().all(|t| t.data.iter().all(|&x| x == 0.0)),
                        "{name}: parked PAD slice corrupted by churn"
                    );
                    let (parts, _, _) = l.cursor.take().unwrap().finish(&pf).unwrap();
                    pool.write(slot, &parts);
                    l.landing = parts;
                    l.state = Some(ModelState::new(&mc)); // marker: landed
                }
            }
            for slot in 0..CAPACITY {
                let finished = {
                    let Some(l) = lanes[slot].as_mut() else { continue };
                    if l.state.is_none() {
                        continue; // still parked: PAD passenger this cycle
                    }
                    // the slot-resident decode step: slice out, step,
                    // splice back — the batched per-slot math
                    let mut state = ModelState::new(&mc);
                    state.load_components(&mc, &pool.read(slot)).unwrap();
                    let logits = model.decode_step(&mut state, l.last);
                    pool.write(slot, &state.to_components(&mc).unwrap());
                    let y = l.sampler.sample(&logits) as u8;
                    l.last = y;
                    l.out.push(y);
                    l.out.len() >= l.max_new
                };
                if finished {
                    let l = lanes[slot].take().unwrap();
                    pool.finish(slot);
                    done[l.req] = Some(l.out);
                }
            }
            pool.after_cycle();
            cycle += 1;
            assert!(cycle < 10_000, "{name}: churn workload did not drain");
        }
        assert!(pool.grows >= 2, "{name}: workload must force grows (got {})", pool.grows);
        assert!(pool.shrinks >= 2, "{name}: workload must force shrinks (got {})", pool.shrinks);
        for (req, (_, prompt, max_new)) in requests.iter().enumerate() {
            let (parts, consumed) = monolithic_same_window(&pf, None, prompt, BUDGET);
            let mut sampler = Sampler::new(scfg_of(req as u64));
            let (want, _, _) =
                decode_from(&model, &parts, prompt[consumed], &mut sampler, *max_new);
            assert_eq!(
                done[req].as_ref().unwrap(),
                &want,
                "{name}: request {req} diverged under bucket churn"
            );
        }
    }
}

#[test]
fn burst_of_64_shorts_cannot_stall_an_inflight_lane_beyond_budget() {
    // the fairness regression (pure counters): 64 short prompts arrive at
    // once while a lane is mid-decode.  Unbounded monolithic admission
    // scans the whole queue before the next decode step; the bounded
    // cycle caps admissions AND per-cycle scan work, so the in-flight
    // lane decodes every cycle and its worst stall is one budget round.
    const BUDGET: usize = 8;
    const SHORT: usize = 4; // scan tokens per short prompt
    const BURST: usize = 64;
    const ADMIT_CAP: usize = 2;
    const INFLIGHT_TOKENS: usize = 20;

    // the bug being pinned: every burst prompt's scan runs at admission,
    // before the cycle's decode step
    let monolithic_first_cycle_stall = BURST * SHORT;

    struct Ctr {
        pos: usize,
        target: usize,
    }
    impl Ctr {
        // one window, the cursor's arithmetic (window = BUDGET > SHORT,
        // so each short prompt is a single indivisible window)
        fn advance_one(&mut self) -> (usize, bool) {
            let next = (self.pos + BUDGET).min(self.target);
            let used = next - self.pos;
            self.pos = next;
            (used, self.pos >= self.target)
        }
    }

    let mut queue = BURST;
    let mut cursors: Vec<Ctr> = vec![];
    let mut rr = RoundRobin::new();
    let mut inflight_decoded = 0usize;
    let mut max_stall = 0usize;
    let mut cycles = 0usize;
    let mut scanned_total = 0usize;
    while inflight_decoded < INFLIGHT_TOKENS
        || queue > 0
        || cursors.iter().any(|c| c.pos < c.target)
    {
        cycles += 1;
        assert!(cycles < 10_000, "burst did not drain");
        // bounded admissions: however deep the queue, at most ADMIT_CAP
        // prompts park per cycle (policy allowance = whole queue)
        let admitted = bounded_admissions(queue, ADMIT_CAP);
        assert!(admitted <= ADMIT_CAP, "admissions cap violated");
        for _ in 0..admitted {
            cursors.push(Ctr { pos: 0, target: SHORT });
        }
        queue -= admitted;
        // the budgeted prefill round is the only scan work this cycle
        let parked: Vec<usize> =
            (0..cursors.len()).filter(|&i| cursors[i].pos < cursors[i].target).collect();
        let spent = run_prefill_round(&mut rr, &parked, BUDGET, |i| cursors[i].advance_one());
        scanned_total += spent;
        max_stall = max_stall.max(spent);
        // the starvation bound: at most one budget round between decode
        // steps (max window here is the SHORT prompt itself)
        assert!(
            spent <= BUDGET - 1 + SHORT,
            "cycle {cycles}: prefill spend {spent} exceeds budget bound"
        );
        // the in-flight lane decodes EVERY cycle — never skipped
        if inflight_decoded < INFLIGHT_TOKENS {
            inflight_decoded += 1;
        }
    }
    // every burst token was scanned exactly once, no prompt starved out
    assert_eq!(scanned_total, BURST * SHORT);
    assert!(cursors.iter().all(|c| c.pos == c.target));
    // the in-flight lane finished in exactly its own token count
    assert!(inflight_decoded == INFLIGHT_TOKENS && cycles >= INFLIGHT_TOKENS);
    // and the regression margin: the old behavior's first-cycle stall is
    // an order of magnitude past the bounded cycle's worst case
    assert!(
        max_stall * 10 <= monolithic_first_cycle_stall,
        "bounded stall {max_stall} too close to monolithic {monolithic_first_cycle_stall}"
    );
}
