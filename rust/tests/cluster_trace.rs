//! Distributed trace-id propagation, pinned at the wire: a request that
//! carries `"trace_id"` must have every engine span keyed by that id in
//! the `trace_export` payload; a request without one must keep tracing
//! under process-local request ids (no invented fleet ids); a malformed
//! id must come back as a one-line typed error that leaves the
//! connection serving.
//!
//! This is the replica half of the stitching contract — the router half
//! (minted ids crossing process boundaries, the failover instant) is
//! pinned end-to-end in `cluster_failover.rs`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hla::cluster::{fixture_identity, spawn_fixture_engine_traced};
use hla::coordinator::router::{RoutePolicy, Router};
use hla::metrics::trace::{SpanEvent, TraceCfg, Tracer};
use hla::metrics::LiveStats;
use hla::server::client::{Client, GenOpts};
use hla::server::{serve_cluster, ServeObs};
use hla::session::SessionStore;
use hla::testing::fixtures::{build_model_full, ModelShape};
use hla::util::json::Json;

/// One traced fixture replica behind the real wire server.
fn spawn_traced_replica() -> (String, Arc<Tracer>, Arc<AtomicBool>) {
    let tracer = Arc::new(Tracer::new(&TraceCfg { sample: 1.0, capacity: 512 }));
    let model = build_model_full("hla2", &ModelShape::default(), 7);
    let identity = Arc::new(fixture_identity(&model));
    let store = Arc::new(SessionStore::in_memory(16));
    let stats = Arc::new(LiveStats::new());
    let (tx, _engine) =
        spawn_fixture_engine_traced(model, store.clone(), stats.clone(), Some(tracer.clone()));
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let obs = Arc::new(ServeObs { stats: vec![stats], tracers: vec![tracer.clone()] });
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_cluster("127.0.0.1:0", router, Some(store), Some(obs), Some(identity), stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), tracer, stop)
}

/// Pull the replica's span ring over the wire and decode it.
fn exported_spans(addr: &str) -> Vec<SpanEvent> {
    let export = Client::connect(addr).unwrap().trace_export().unwrap();
    assert_eq!(export.get("schema").and_then(Json::as_str), Some("hla-trace/1"), "{export}");
    export
        .get("spans")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|s| SpanEvent::from_json(s).expect("well-formed exported span"))
        .collect()
}

#[test]
fn explicit_trace_id_keys_every_span_of_the_request() {
    let (addr, _tracer, _stop) = spawn_traced_replica();
    let mut c = Client::connect(&addr).unwrap();
    let done = c
        .generate_opts(
            "trace me",
            &GenOpts { max_tokens: 6, trace: Some(0xab), ..GenOpts::default() },
        )
        .unwrap();
    assert_eq!(done.tokens.len(), 6);

    let spans = exported_spans(&addr);
    let tagged: Vec<&SpanEvent> = spans.iter().filter(|s| s.request == 0xab).collect();
    assert!(
        tagged.iter().any(|s| s.stage.name() == "admission"),
        "the request's admission span must carry the fleet trace id: {spans:?}"
    );
    // nothing else in this process shares the fleet id, and the request's
    // spans never leak under the local request id once a trace id is set
    assert!(
        !spans.iter().any(|s| s.request != 0xab && s.stage.name() == "admission"),
        "a lone traced request must produce exactly one admission key: {spans:?}"
    );
}

#[test]
fn untraced_requests_stay_keyed_by_local_request_ids() {
    let (addr, _tracer, _stop) = spawn_traced_replica();
    let mut c = Client::connect(&addr).unwrap();
    let done = c.generate("no trace id", 6, 0.0, None).unwrap();
    assert_eq!(done.tokens.len(), 6);

    let spans = exported_spans(&addr);
    assert!(!spans.is_empty(), "tracing itself must still run without a trace id");
    // local request ids are small sequential integers; a minted fleet id
    // is a full-width SplitMix64 output — its presence here would mean
    // the replica invented a trace id the router never handed it
    assert!(
        spans.iter().all(|s| s.request < 1 << 20),
        "untraced spans must key by process-local request ids only: {spans:?}"
    );
}

#[test]
fn malformed_trace_id_is_a_typed_error_not_a_panic() {
    let (addr, _tracer, _stop) = spawn_traced_replica();
    let stream = TcpStream::connect(&addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();

    // wrong length, non-hex, and non-string: each rejected in one line
    for bad in [
        "{\"prompt\": \"x\", \"max_tokens\": 4, \"trace_id\": \"abc\"}",
        "{\"prompt\": \"x\", \"max_tokens\": 4, \"trace_id\": \"zzzzzzzzzzzzzzzz\"}",
        "{\"prompt\": \"x\", \"max_tokens\": 4, \"trace_id\": 171}",
    ] {
        writeln!(writer, "{bad}").unwrap();
        buf.clear();
        assert!(reader.read_line(&mut buf).unwrap() > 0, "no reply to {bad}");
        let msg = Json::parse(&buf).unwrap();
        let err = msg.get("error").and_then(Json::as_str).unwrap_or_else(|| {
            panic!("malformed trace_id must yield an error line, got {buf}")
        });
        assert!(err.contains("trace_id"), "the error must name the field: {err}");
    }

    // ...and the connection keeps serving afterwards
    writeln!(writer, "{}", "{\"prompt\": \"x\", \"max_tokens\": 3, \"temperature\": 0}").unwrap();
    let mut tokens = 0;
    loop {
        buf.clear();
        assert!(reader.read_line(&mut buf).unwrap() > 0, "stream died after rejections");
        if buf.contains("\"done\"") {
            break;
        }
        assert!(!buf.contains("\"error\""), "healthy request errored: {buf}");
        tokens += 1;
    }
    assert_eq!(tokens, 3, "the post-rejection generation must stream normally");
}
