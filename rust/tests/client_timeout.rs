//! Client resilience against a silent server: a replica that accepts the
//! TCP connection but never replies must not hang the caller.  With
//! `connect_timeout`, every read is capped; a timed-out admin round-trip
//! is retried exactly once on a fresh connection after the configured
//! backoff, then surfaces an error naming the unresponsive server.  This
//! is the failure mode the cluster front-end leans on: a wedged (not
//! crashed) replica must strike out in bounded time.

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use hla::server::client::Client;

/// A listener that accepts connections and then says nothing, counting
/// how many victims it swallowed.
fn spawn_silent_listener() -> (String, Arc<AtomicUsize>, Arc<AtomicBool>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let accepted = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    {
        let accepted = accepted.clone();
        let stop = stop.clone();
        listener.set_nonblocking(true).unwrap();
        std::thread::spawn(move || {
            let mut held = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        accepted.fetch_add(1, Ordering::Relaxed);
                        held.push(stream); // hold open, never reply
                    }
                    Err(_) => std::thread::sleep(Duration::from_millis(2)),
                }
            }
        });
    }
    (addr, accepted, stop)
}

#[test]
fn silent_server_times_out_with_exactly_one_retry() {
    let (addr, accepted, stop) = spawn_silent_listener();
    let timeout = Duration::from_millis(150);
    let backoff = Duration::from_millis(30);

    let mut client = Client::connect_timeout(&addr, timeout).expect("dial succeeds");
    client.set_retry_backoff(backoff);

    let t0 = Instant::now();
    let err = client.stats().expect_err("a silent server must not look healthy");
    let elapsed = t0.elapsed();

    // the error names the unresponsive server and admits the retry
    let msg = format!("{err:#}");
    assert!(
        msg.contains("unresponsive") && msg.contains("retried once"),
        "error should describe the timeout+retry, got: {msg}"
    );
    assert!(msg.contains(&addr), "error should name the server, got: {msg}");

    // exactly one retry: the original dial plus one reconnect
    std::thread::sleep(Duration::from_millis(20)); // let the accept loop drain
    assert_eq!(
        accepted.load(Ordering::Relaxed),
        2,
        "expected the initial connection plus exactly one retry"
    );

    // bounded: two timed-out reads + one backoff (plus scheduling slack),
    // nowhere near a hang
    assert!(elapsed >= timeout, "must actually wait out the read timeout");
    assert!(
        elapsed < 2 * timeout + backoff + Duration::from_millis(500),
        "two capped reads + backoff expected, took {elapsed:?}"
    );
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn timeout_free_client_is_untouched_by_retry_plumbing() {
    // without connect_timeout the retry path must never engage: a plain
    // connect against a dead port fails immediately at dial time
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // port is now closed
    assert!(Client::connect(&addr).is_err(), "dialing a closed port must fail");
}
