//! Integration: TCP line-JSON server round-trip over the router.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use hla::coordinator::router::{RoutePolicy, Router};
use hla::coordinator::{spawn_engine, SchedPolicy};
use hla::server::{client::Client, serve};

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

#[test]
fn server_round_trip_and_concurrent_clients() {
    if !have_artifacts() {
        return;
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));

    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();

    // two concurrent clients
    let addr2 = addr.clone();
    let c2 = std::thread::spawn(move || {
        let mut client = Client::connect(&addr2).unwrap();
        client.generate("second client says", 5, 0.0, Some(2)).unwrap()
    });
    let mut client = Client::connect(&addr).unwrap();
    let done = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    let done2 = c2.join().unwrap();

    assert_eq!(done.tokens.len(), 8);
    assert_eq!(done.finish, "length");
    assert!(done.ttft <= done.latency);
    assert_eq!(done2.tokens.len(), 5);

    // sequential reuse of one connection
    let again = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    assert_eq!(again.tokens.len(), 8);
    drop(client);

    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}

#[test]
fn server_rejects_garbage_gracefully() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();
    let mut sock = std::net::TcpStream::connect(addr).unwrap();
    writeln!(sock, "this is not json").unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    drop(sock);
    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}
