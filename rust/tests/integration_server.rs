//! Integration: TCP line-JSON server round-trip over the router — plus
//! the artifact-free streaming-protocol suite (streamed vs buffered
//! byte-identity, mid-stream disconnect, the typed `overloaded` reply)
//! on the fixture replica engine.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use hla::cluster::spawn_fixture_engine;
use hla::coordinator::router::{RoutePolicy, Router};
use hla::coordinator::{
    spawn_engine, spawn_engine_full, EngineOpts, FinishReason, SchedPolicy, TokenEvent,
};
use hla::metrics::trace::write_chrome_trace;
use hla::metrics::{LiveStats, TraceCfg, Tracer};
use hla::prefill::PrefillCfg;
use hla::server::client::{GenOpts, OverloadedError};
use hla::server::{client::Client, serve, serve_full, ServeObs};
use hla::session::SessionStore;
use hla::testing::fixtures::{build_model_full, ModelShape};
use hla::util::json::Json;

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

#[test]
fn server_round_trip_and_concurrent_clients() {
    if !have_artifacts() {
        return;
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));

    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();

    // two concurrent clients
    let addr2 = addr.clone();
    let c2 = std::thread::spawn(move || {
        let mut client = Client::connect(&addr2).unwrap();
        client.generate("second client says", 5, 0.0, Some(2)).unwrap()
    });
    let mut client = Client::connect(&addr).unwrap();
    let done = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    let done2 = c2.join().unwrap();

    assert_eq!(done.tokens.len(), 8);
    assert_eq!(done.finish, "length");
    assert!(done.ttft <= done.latency);
    assert_eq!(done2.tokens.len(), 5);

    // sequential reuse of one connection
    let again = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    assert_eq!(again.tokens.len(), 8);
    drop(client);

    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}

#[test]
fn server_rejects_garbage_gracefully() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();
    let mut sock = std::net::TcpStream::connect(addr).unwrap();
    writeln!(sock, "this is not json").unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    drop(sock);
    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}

/// Observability is read-only: a fully-sampled tracer plus a live registry
/// must not perturb a single streamed byte, the `"stats"` request must
/// reconcile with what the clients saw, and the exported Chrome trace must
/// cover the engine cycle end to end.
#[test]
fn traced_server_streams_identical_and_serves_live_stats() {
    if !have_artifacts() {
        return;
    }
    let artifacts = || concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let prompts = ["observe the engine", "trace me twice", "a third request"];

    // one serve pass; returns the streamed tokens per prompt
    let run = |obs: Option<(Arc<LiveStats>, Arc<Tracer>)>| -> (Vec<Vec<u8>>, Option<String>) {
        let (stats, tracer) = match &obs {
            Some((s, t)) => (Some(s.clone()), Some(t.clone())),
            None => (None, None),
        };
        let (tx, engine_handle) = spawn_engine_full(
            artifacts(),
            "micro".into(),
            EngineOpts {
                policy: Some(SchedPolicy::PrefillFirst),
                seed: 0,
                // scan prefill in both runs so Prefill spans fire in the
                // traced one (and the byte-compare stays apples-to-apples)
                prefill: Some(PrefillCfg::scan(8, 1)),
                stats: stats.clone(),
                tracer,
                ..Default::default()
            },
        );
        let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
        let stop = Arc::new(AtomicBool::new(false));
        let (addr_tx, addr_rx) = mpsc::channel();
        let stop2 = stop.clone();
        let serve_obs = stats.map(|s| Arc::new(ServeObs::stats_only(vec![s])));
        let server_handle = std::thread::spawn(move || {
            serve_full("127.0.0.1:0", router, None, serve_obs, stop2, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        });
        let addr = addr_rx.recv().unwrap().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let streams: Vec<Vec<u8>> =
            prompts.iter().map(|p| client.generate(p, 8, 0.0, None).unwrap().tokens).collect();
        // live snapshot while the server is still up, on a fresh connection
        let prom = if obs.is_some() {
            let mut admin = Client::connect(&addr).unwrap();
            let snap = admin.stats().unwrap();
            assert_eq!(snap.completed as usize, prompts.len());
            let streamed: usize = streams.iter().map(Vec::len).sum();
            assert_eq!(snap.tokens_out as usize, streamed, "registry vs streamed tokens");
            assert!(snap.steps > 0 && snap.elapsed_s > 0.0);
            Some(admin.stats_prometheus().unwrap())
        } else {
            None
        };
        drop(client);
        stop.store(true, Ordering::Relaxed);
        server_handle.join().unwrap();
        engine_handle.join().unwrap().unwrap();
        (streams, prom)
    };

    let stats = Arc::new(LiveStats::new());
    let tracer = Arc::new(Tracer::new(&TraceCfg { sample: 1.0, capacity: 1 << 12 }));
    let (traced, prom) = run(Some((stats.clone(), tracer.clone())));
    let (bare, _) = run(None);
    assert_eq!(traced, bare, "tracing at sample=1.0 must not perturb streams");

    let prom = prom.unwrap();
    assert!(prom.contains("hla_tokens_out_total"), "{prom}");
    assert!(prom.contains("hla_step_us{quantile="), "{prom}");

    // the trace covers admission -> prefill -> decode for every request
    let events = tracer.events();
    let stage_count = |s: hla::metrics::Stage| events.iter().filter(|e| e.stage == s).count();
    assert_eq!(stage_count(hla::metrics::Stage::Admission), prompts.len());
    assert_eq!(stage_count(hla::metrics::Stage::Prefill), prompts.len());
    assert!(stage_count(hla::metrics::Stage::DecodeStep) > 0);
    let dir = std::env::temp_dir().join(format!("hla-trace-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.trace.json");
    write_chrome_trace(&path, &[(0, &tracer)]).unwrap();
    let doc = hla::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > prompts.len());
    std::fs::remove_dir_all(&dir).ok();
}

/// Artifact-free server over the deterministic fixture replica engine;
/// `max_queue` is the router's admission cap (0 = unbounded).  Returns
/// the bound address plus the stop flag and both join handles.
fn fixture_server(
    max_queue: usize,
) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<()>, std::thread::JoinHandle<()>) {
    let model = build_model_full("hla2", &ModelShape::default(), 71);
    let store = Arc::new(SessionStore::in_memory(8));
    let stats = Arc::new(LiveStats::new());
    let (tx, engine) = spawn_fixture_engine(model, store, stats);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    router.set_capacity(max_queue);
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server = std::thread::spawn(move || {
        serve_full("127.0.0.1:0", router, None, None, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    (addr_rx.recv().unwrap().to_string(), stop, server, engine)
}

#[test]
fn streamed_and_buffered_replies_are_byte_identical() {
    use std::io::{BufRead, BufReader, Write};
    let (addr, stop, server, engine) = fixture_server(0);
    let mut client = Client::connect(&addr).unwrap();
    // same prompt + seed, both wire modes: identical bytes by contract
    let opts = GenOpts {
        max_tokens: 24,
        temperature: 0.9,
        top_k: 8,
        seed: Some(31),
        ..GenOpts::default()
    };
    let streamed = client.generate_opts("stream differential", &opts).unwrap();
    let buffered = client
        .generate_opts("stream differential", &GenOpts { stream: false, ..opts.clone() })
        .unwrap();
    assert_eq!(streamed.tokens.len(), 24);
    assert_eq!(buffered.tokens, streamed.tokens, "buffered reply must carry identical bytes");
    assert_eq!(buffered.text, streamed.text);
    assert_eq!(buffered.finish, streamed.finish);

    // raw wire shape: `"stream": false` is exactly one line — done=true
    // with the tokens array, no per-token lines ahead of it
    let mut sock = std::net::TcpStream::connect(&addr).unwrap();
    writeln!(sock, r#"{{"prompt": "raw buffered", "max_tokens": 5, "stream": false}}"#).unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
    let msg = Json::parse(&line).unwrap();
    assert_eq!(msg.get("done").and_then(Json::as_bool), Some(true), "{line}");
    assert!(msg.get("token").is_none(), "buffered mode must not emit token lines: {line}");
    assert_eq!(msg.get("tokens").and_then(Json::as_arr).unwrap().len(), 5, "{line}");
    assert!(msg.get("text").and_then(Json::as_str).is_some(), "{line}");
    drop(sock);

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    engine.join().unwrap();
}

#[test]
fn mid_stream_disconnect_aborts_without_leaking_the_slot() {
    use std::io::{BufRead, BufReader, Write};
    // capacity 1: if the aborted request leaked its in-flight slot, every
    // later request would be refused — the retry loop below would spin out
    let (addr, stop, server, engine) = fixture_server(1);

    // a streaming client that reads two tokens and hangs up mid-stream
    let mut sock = std::net::TcpStream::connect(&addr).unwrap();
    // enough tokens that the stream outlives the socket's send buffer:
    // the server must hit the failed write, set the cancel flag, and
    // drain — not wedge on the dead connection
    writeln!(sock, r#"{{"prompt": "going away", "max_tokens": 2000}}"#).unwrap();
    let mut rd = BufReader::new(sock.try_clone().unwrap());
    for _ in 0..2 {
        let mut line = String::new();
        rd.read_line(&mut line).unwrap();
        let msg = Json::parse(&line).unwrap();
        assert!(msg.get("token").is_some(), "expected a token line, got {line}");
    }
    drop(rd);
    drop(sock); // mid-stream hangup: the server must cancel + drain, not wedge

    // the server stays healthy and the slot frees: a fresh request
    // completes (tolerating the typed refusal while the abort drains)
    let mut client = Client::connect(&addr).unwrap();
    let mut tries = 0;
    let done = loop {
        match client.generate("after the hangup", 8, 0.0, None) {
            Ok(c) => break c,
            Err(e) if e.downcast_ref::<OverloadedError>().is_some() => {
                tries += 1;
                assert!(tries < 200, "aborted request never freed its slot");
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected error after disconnect: {e}"),
        }
    };
    assert_eq!(done.tokens.len(), 8);
    assert_eq!(done.finish, "length");

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    engine.join().unwrap();
}

#[test]
fn overloaded_reply_is_typed_and_drains_before_reject() {
    // a hand-driven replica: requests park until the test serves them, so
    // the overload window is deterministic (no timing races)
    let (tx, rx) = mpsc::channel();
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    router.set_capacity(1);
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server = std::thread::spawn(move || {
        serve_full("127.0.0.1:0", router, None, None, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();

    // A occupies the only slot; its handler parks on the silent replica
    let addr_a = addr.clone();
    let a = std::thread::spawn(move || {
        let mut c = Client::connect(&addr_a).unwrap();
        c.generate("first", 4, 0.0, None).unwrap()
    });
    let parked = rx.recv().unwrap();

    // B is refused with the typed reply while A is in flight — and the
    // refusal is an error *line*, not a dropped connection
    let mut b = Client::connect(&addr).unwrap();
    let err = b.generate("second", 4, 0.0, None).unwrap_err();
    let over = err.downcast_ref::<OverloadedError>().expect("typed overloaded error");
    assert_eq!(over.queue_depth, 1);

    // drain-before-reject: serving A frees the slot, nothing was dropped
    for i in 0..4u8 {
        parked.events.send(TokenEvent::token(parked.id, i)).unwrap();
    }
    parked
        .events
        .send(TokenEvent::finished_resumed(parked.id, FinishReason::Length, false))
        .unwrap();
    let done_a = a.join().unwrap();
    assert_eq!(done_a.tokens, vec![0, 1, 2, 3]);
    assert_eq!(done_a.finish, "length");

    // ... and B's retry (same connection) now admits and completes
    let serve_b = std::thread::spawn(move || {
        let parked = rx.recv().unwrap();
        parked.events.send(TokenEvent::token(parked.id, 9)).unwrap();
        parked
            .events
            .send(TokenEvent::finished_resumed(parked.id, FinishReason::Length, false))
            .unwrap();
    });
    let done_b = loop {
        match b.generate("second again", 4, 0.0, None) {
            Ok(c) => break c,
            Err(e) if e.downcast_ref::<OverloadedError>().is_some() => {
                // A's handler may still be between done-event and complete()
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    };
    assert_eq!(done_b.tokens, vec![9]);
    serve_b.join().unwrap();

    drop(b);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
}
