//! Integration: TCP line-JSON server round-trip over the router.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use hla::coordinator::router::{RoutePolicy, Router};
use hla::coordinator::{spawn_engine, spawn_engine_full, EngineOpts, SchedPolicy};
use hla::metrics::trace::write_chrome_trace;
use hla::metrics::{LiveStats, TraceCfg, Tracer};
use hla::prefill::PrefillCfg;
use hla::server::{client::Client, serve, serve_full, ServeObs};

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

#[test]
fn server_round_trip_and_concurrent_clients() {
    if !have_artifacts() {
        return;
    }
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));

    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();

    // two concurrent clients
    let addr2 = addr.clone();
    let c2 = std::thread::spawn(move || {
        let mut client = Client::connect(&addr2).unwrap();
        client.generate("second client says", 5, 0.0, Some(2)).unwrap()
    });
    let mut client = Client::connect(&addr).unwrap();
    let done = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    let done2 = c2.join().unwrap();

    assert_eq!(done.tokens.len(), 8);
    assert_eq!(done.finish, "length");
    assert!(done.ttft <= done.latency);
    assert_eq!(done2.tokens.len(), 5);

    // sequential reuse of one connection
    let again = client.generate("hello world", 8, 0.0, Some(1)).unwrap();
    assert_eq!(again.tokens.len(), 8);
    drop(client);

    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}

#[test]
fn server_rejects_garbage_gracefully() {
    if !have_artifacts() {
        return;
    }
    use std::io::{BufRead, BufReader, Write};
    let artifacts = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let (tx, engine_handle) =
        spawn_engine(artifacts, "micro".into(), SchedPolicy::PrefillFirst, 0);
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let server_handle = std::thread::spawn(move || {
        serve("127.0.0.1:0", router, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap();
    let mut sock = std::net::TcpStream::connect(addr).unwrap();
    writeln!(sock, "this is not json").unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");
    drop(sock);
    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}

/// Observability is read-only: a fully-sampled tracer plus a live registry
/// must not perturb a single streamed byte, the `"stats"` request must
/// reconcile with what the clients saw, and the exported Chrome trace must
/// cover the engine cycle end to end.
#[test]
fn traced_server_streams_identical_and_serves_live_stats() {
    if !have_artifacts() {
        return;
    }
    let artifacts = || concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let prompts = ["observe the engine", "trace me twice", "a third request"];

    // one serve pass; returns the streamed tokens per prompt
    let run = |obs: Option<(Arc<LiveStats>, Arc<Tracer>)>| -> (Vec<Vec<u8>>, Option<String>) {
        let (stats, tracer) = match &obs {
            Some((s, t)) => (Some(s.clone()), Some(t.clone())),
            None => (None, None),
        };
        let (tx, engine_handle) = spawn_engine_full(
            artifacts(),
            "micro".into(),
            EngineOpts {
                policy: Some(SchedPolicy::PrefillFirst),
                seed: 0,
                // scan prefill in both runs so Prefill spans fire in the
                // traced one (and the byte-compare stays apples-to-apples)
                prefill: Some(PrefillCfg::scan(8, 1)),
                stats: stats.clone(),
                tracer,
                ..Default::default()
            },
        );
        let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
        let stop = Arc::new(AtomicBool::new(false));
        let (addr_tx, addr_rx) = mpsc::channel();
        let stop2 = stop.clone();
        let serve_obs = stats.map(|s| Arc::new(ServeObs::stats_only(vec![s])));
        let server_handle = std::thread::spawn(move || {
            serve_full("127.0.0.1:0", router, None, serve_obs, stop2, move |addr| {
                addr_tx.send(addr).unwrap();
            })
            .unwrap();
        });
        let addr = addr_rx.recv().unwrap().to_string();
        let mut client = Client::connect(&addr).unwrap();
        let streams: Vec<Vec<u8>> =
            prompts.iter().map(|p| client.generate(p, 8, 0.0, None).unwrap().tokens).collect();
        // live snapshot while the server is still up, on a fresh connection
        let prom = if obs.is_some() {
            let mut admin = Client::connect(&addr).unwrap();
            let snap = admin.stats().unwrap();
            assert_eq!(snap.completed as usize, prompts.len());
            let streamed: usize = streams.iter().map(Vec::len).sum();
            assert_eq!(snap.tokens_out as usize, streamed, "registry vs streamed tokens");
            assert!(snap.steps > 0 && snap.elapsed_s > 0.0);
            Some(admin.stats_prometheus().unwrap())
        } else {
            None
        };
        drop(client);
        stop.store(true, Ordering::Relaxed);
        server_handle.join().unwrap();
        engine_handle.join().unwrap().unwrap();
        (streams, prom)
    };

    let stats = Arc::new(LiveStats::new());
    let tracer = Arc::new(Tracer::new(&TraceCfg { sample: 1.0, capacity: 1 << 12 }));
    let (traced, prom) = run(Some((stats.clone(), tracer.clone())));
    let (bare, _) = run(None);
    assert_eq!(traced, bare, "tracing at sample=1.0 must not perturb streams");

    let prom = prom.unwrap();
    assert!(prom.contains("hla_tokens_out_total"), "{prom}");
    assert!(prom.contains("hla_step_us{quantile="), "{prom}");

    // the trace covers admission -> prefill -> decode for every request
    let events = tracer.events();
    let stage_count = |s: hla::metrics::Stage| events.iter().filter(|e| e.stage == s).count();
    assert_eq!(stage_count(hla::metrics::Stage::Admission), prompts.len());
    assert_eq!(stage_count(hla::metrics::Stage::Prefill), prompts.len());
    assert!(stage_count(hla::metrics::Stage::DecodeStep) > 0);
    let dir = std::env::temp_dir().join(format!("hla-trace-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serve.trace.json");
    write_chrome_trace(&path, &[(0, &tracer)]).unwrap();
    let doc = hla::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > prompts.len());
    std::fs::remove_dir_all(&dir).ok();
}
