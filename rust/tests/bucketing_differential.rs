//! Differential acceptance test for occupancy-adaptive decode
//! bucketing: a stream served through any sequence of bucket grows and
//! shrinks must be **byte-identical** to its fixed-batch serial
//! counterpart — repacking moves *state bytes*, never math.  Runs
//! artifact-free on the pure-Rust [`hla::testing::fixtures`] models,
//! like the prefill / spec / prefix-cache differential suites.
//!
//! Exactness ledger:
//! * A lane's slice of the batched `[L, W, ...]` component layout is a
//!   constant-size block of floats (Thm 3.1).  The repack move sets
//!   (`coordinator::repack`) copy those floats verbatim, so the state a
//!   lane decodes from after any grow/shrink is bit-identical to the
//!   state it wrote — asserted here after *every* repack against a
//!   shadow map, and end-to-end by token-stream equality (greedy AND
//!   seeded) against serial decode.
//! * Composition: session detach reads the lane's *current* slot (not
//!   its admission slot), prefix-cache seeds splice into whatever slot
//!   the bucketed layout assigns, and speculative passenger lanes ride
//!   the layout as dead weight — all three run here under forced bucket
//!   churn (`shrink_after = 1`, staggered admissions and finishes).
//!
//! The harness below (`BucketedPool` + `LaneSim`) is the host-side twin
//! of `EngineLoop`'s bucketed state handling: same [`BucketTracker`]
//! policy, same move sets, same slice/splice primitives — only the
//! batched artifact step is replaced by per-lane `decode_step` on the
//! extracted slice, which is exactly the per-slot math the artifact
//! runs.

use std::collections::HashMap;

use hla::cache::{PrefixCache, PrefixCacheCfg};
use hla::coordinator::repack::{compaction_moves, identity_moves, remap_components};
use hla::coordinator::{BucketSpec, BucketSwitch, BucketTracker};
use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{
    slice_components, splice_components, zero_component_lane, ModelState, RustModel,
};
use hla::prefill::{advance, PrefillCfg, Prefiller};
use hla::runtime::ModelCfg;
use hla::session::SamplerState;
use hla::spec::{DrafterKind, SpecCfg, SpecDecoder};
use hla::tensor::Tensor;
use hla::testing::fixtures::{build_model_full, random_prompt, ModelShape};
use hla::util::rng::Rng;

/// Engine capacity (B_max) for every harness in this suite; the pow2
/// ladder under it is 1/2/4, so 3-ish live lanes cross bucket edges.
const CAPACITY: usize = 4;

fn seeded(seed: u64) -> SamplerCfg {
    SamplerCfg { temperature: 0.9, top_k: 20, seed }
}

/// Bit-level equality for state component tensors (f32 compared by
/// bits: a repack must not perturb a single ULP).
fn assert_state_bits_equal(a: &[Tensor], b: &[Tensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: component arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let xb: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u32> = y.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "{what}: component {i} bits");
    }
}

/// Host-side twin of the engine loop's bucketed state handling: batched
/// component tensors at the current bucket width, the lane-id→slot
/// table, and the exact repack move sets `EngineLoop` applies.  Every
/// repack is audited bit-for-bit against a shadow of each live lane's
/// last-written parts.
struct BucketedPool {
    comps: Vec<Tensor>,
    capacity: usize,
    tracker: BucketTracker,
    slot_of: Vec<usize>,
    active: Vec<bool>,
    shadow: HashMap<usize, Vec<Tensor>>,
    grows: usize,
    shrinks: usize,
}

impl BucketedPool {
    fn new(cfg: &ModelCfg, capacity: usize, shrink_after: usize) -> BucketedPool {
        let comps = cfg
            .state_paths
            .iter()
            .map(|(_, sh)| {
                let mut sh = sh.clone();
                sh[1] = capacity;
                Tensor::zeros(&sh)
            })
            .collect();
        BucketedPool {
            comps,
            capacity,
            tracker: BucketTracker::new(
                BucketSpec::Pow2.ladder(capacity),
                shrink_after,
                capacity,
            ),
            slot_of: vec![0; capacity],
            active: vec![false; capacity],
            shadow: HashMap::new(),
            grows: 0,
            shrinks: 0,
        }
    }

    fn live(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn read(&self, lane: usize) -> Vec<Tensor> {
        slice_components(&self.comps, self.slot_of[lane])
    }

    fn write(&mut self, lane: usize, parts: &[Tensor]) {
        splice_components(&mut self.comps, self.slot_of[lane], parts);
        self.shadow.insert(lane, parts.to_vec());
    }

    /// Apply a switch with the engine loop's move sets, then audit every
    /// live lane's slice against its shadow — the repack exactness gate.
    fn apply(&mut self, sw: BucketSwitch) {
        let lanes: Vec<usize> = (0..self.capacity).filter(|&b| self.active[b]).collect();
        let slots: Vec<usize> = lanes.iter().map(|&b| self.slot_of[b]).collect();
        let (w, moves) = match sw {
            BucketSwitch::Grow(w) => {
                self.grows += 1;
                (w, identity_moves(&slots))
            }
            BucketSwitch::Shrink(w) => {
                self.shrinks += 1;
                (w, compaction_moves(&slots))
            }
        };
        self.comps = remap_components(&self.comps, &moves, w);
        for (i, &b) in lanes.iter().enumerate() {
            self.slot_of[b] = moves[i].1;
        }
        for &b in &lanes {
            assert_state_bits_equal(&self.read(b), &self.shadow[&b], "post-repack lane slice");
        }
    }

    /// Admit into the lowest free slot, growing the layout first when the
    /// new live count does not fit (the engine's grow-on-admission).
    /// `parts` seeds the slot (session resume / cache-seeded prefill);
    /// `None` zeroes it (a fresh lane).
    fn admit(&mut self, lane: usize, parts: Option<&[Tensor]>) {
        assert!(!self.active[lane], "lane {lane} already live");
        if let Some(sw) = self.tracker.on_admit(self.live() + 1) {
            self.apply(sw);
        }
        let used: Vec<usize> =
            (0..self.capacity).filter(|&b| self.active[b]).map(|b| self.slot_of[b]).collect();
        let slot = (0..self.tracker.width())
            .find(|s| !used.contains(s))
            .expect("admission grow guarantees a free slot");
        self.active[lane] = true;
        self.slot_of[lane] = slot;
        match parts {
            Some(p) => self.write(lane, p),
            None => {
                for c in &mut self.comps {
                    zero_component_lane(c, slot);
                }
                let zeros = self.read(lane);
                self.shadow.insert(lane, zeros);
            }
        }
    }

    /// Detach: read the lane's state from its *current* slot (repacks may
    /// have moved it since admission — the session-detach invariant).
    fn finish(&mut self, lane: usize) -> Vec<Tensor> {
        let parts = self.read(lane);
        self.active[lane] = false;
        self.shadow.remove(&lane);
        parts
    }

    /// The engine cycle's debounced shrink check.
    fn after_cycle(&mut self) {
        let live = self.live();
        if let Some(sw) = self.tracker.after_step(live) {
            self.apply(sw);
        }
    }
}

/// One decode lane driven through the pool: decode-as-prefill over its
/// pending input tokens, then sampling — the `Lane` state machine.
struct LaneSim {
    lane: usize,
    /// Which workload request this lane serves (stream bookkeeping).
    req: usize,
    inputs: Vec<u8>,
    cursor: usize,
    sampler: Sampler,
    last: u8,
    max_new: usize,
    out: Vec<u8>,
}

impl LaneSim {
    fn fresh(lane: usize, prompt: &[u8], scfg: &SamplerCfg, max_new: usize) -> LaneSim {
        LaneSim {
            lane,
            req: 0,
            inputs: prompt.to_vec(),
            cursor: 0,
            sampler: Sampler::new(scfg.clone()),
            last: 0,
            max_new,
            out: vec![],
        }
    }
}

/// One batched-step slot's worth of work: extract the lane's slice, run
/// `decode_step` on it, write it back.  Returns true when finished.
fn step_lane(model: &RustModel, pool: &mut BucketedPool, sim: &mut LaneSim) -> bool {
    let mc = &model.cfg;
    let mut state = ModelState::new(mc);
    state.load_components(mc, &pool.read(sim.lane)).unwrap();
    let tok = if sim.cursor < sim.inputs.len() {
        let t = sim.inputs[sim.cursor];
        sim.cursor += 1;
        t
    } else {
        sim.last
    };
    let logits = model.decode_step(&mut state, tok);
    pool.write(sim.lane, &state.to_components(mc).unwrap());
    if sim.cursor < sim.inputs.len() {
        return false; // mid-prompt: logits ignored, like the engine lane
    }
    let y = sim.sampler.sample(&logits) as u8;
    sim.last = y;
    sim.out.push(y);
    sim.out.len() >= sim.max_new
}

/// Serial decode from scratch — the bit-exact fixed-batch reference.
fn serial_stream(model: &RustModel, prompt: &[u8], scfg: &SamplerCfg, max_new: usize) -> Vec<u8> {
    let mut state = ModelState::new(&model.cfg);
    let mut sampler = Sampler::new(scfg.clone());
    advance(model, &mut state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
    let mut out = Vec::with_capacity(max_new);
    let mut last = prompt[prompt.len() - 1];
    while out.len() < max_new {
        let logits = model.decode_step(&mut state, last);
        let y = sampler.sample(&logits) as u8;
        out.push(y);
        last = y;
    }
    out
}

/// Drive a staggered multi-request workload through the bucketed pool
/// with maximal churn (`shrink_after = 1`) and pin every stream to its
/// serial reference, byte for byte.
fn churn_workload(mixer: &str, scfg_of: impl Fn(u64) -> SamplerCfg) {
    let model = build_model_full(mixer, &ModelShape::default(), 11);
    let mut rng = Rng::new(23);
    let vocab = model.cfg.vocab;
    // 8 requests, staggered arrivals, varied prompt/output lengths — the
    // admit/finish pattern walks occupancy 0→3→1→2→0 across bucket edges
    let requests: Vec<(usize, Vec<u8>, usize)> = (0..8)
        .map(|i| {
            let arrive = i * 3;
            let prompt = random_prompt(&mut rng, 4 + (i % 5) * 3, vocab);
            let max_new = 5 + (i % 4) * 3;
            (arrive, prompt, max_new)
        })
        .collect();

    let mut pool = BucketedPool::new(&model.cfg, CAPACITY, 1);
    let mut waiting: Vec<(usize, usize)> = (0..requests.len()).map(|i| (requests[i].0, i)).collect();
    let mut running: Vec<LaneSim> = vec![];
    let mut done: HashMap<usize, Vec<u8>> = HashMap::new();
    let mut cycle = 0usize;
    while done.len() < requests.len() {
        // admissions: arrived requests into free lanes (FIFO)
        while let Some(pos) = waiting.iter().position(|&(at, _)| at <= cycle) {
            let free_lane = (0..CAPACITY).find(|b| !running.iter().any(|s| s.lane == *b));
            let Some(lane) = free_lane else { break };
            let (_, req) = waiting.remove(pos);
            let (_, prompt, max_new) = &requests[req];
            pool.admit(lane, None);
            let mut sim = LaneSim::fresh(lane, prompt, &scfg_of(req as u64), *max_new);
            sim.req = req;
            running.push(sim);
        }
        // one batched step over every live lane
        let mut finished: Vec<usize> = vec![];
        for sim in running.iter_mut() {
            if step_lane(&model, &mut pool, sim) {
                finished.push(sim.lane);
            }
        }
        for lane in finished {
            let pos = running.iter().position(|s| s.lane == lane).unwrap();
            let sim = running.remove(pos);
            pool.finish(lane);
            done.insert(sim.req, sim.out);
        }
        pool.after_cycle();
        cycle += 1;
        assert!(cycle < 10_000, "workload did not drain");
    }
    assert!(pool.grows >= 2, "{mixer}: workload must force grows (got {})", pool.grows);
    assert!(pool.shrinks >= 2, "{mixer}: workload must force shrinks (got {})", pool.shrinks);
    for (req, (_, prompt, max_new)) in requests.iter().enumerate() {
        let want = serial_stream(&model, prompt, &scfg_of(req as u64), *max_new);
        assert_eq!(done[&req], want, "{mixer}: request {req} diverged from serial decode");
    }
}

#[test]
fn bucketed_streams_match_serial_greedy_all_mixers() {
    for mixer in ["hla2", "ahla", "hla3"] {
        churn_workload(mixer, |_| SamplerCfg::greedy());
    }
}

#[test]
fn bucketed_streams_match_serial_seeded_all_mixers() {
    for mixer in ["hla2", "ahla", "hla3"] {
        churn_workload(mixer, |req| seeded(100 + req));
    }
}

#[test]
fn session_detach_reads_the_current_slot_across_repacks() {
    // lane A runs a conversation turn while lanes B/C churn the bucket
    // layout around it (A's slot moves under compaction); A then
    // detaches, and a later resumed lane continues — the combined stream
    // must equal one uninterrupted serial generation, greedy and seeded.
    for scfg in [SamplerCfg::greedy(), seeded(7)] {
        let model = build_model_full("hla2", &ModelShape::default(), 13);
        let mut rng = Rng::new(5);
        let prompt = random_prompt(&mut rng, 10, model.cfg.vocab);
        let (turn1, turn2) = (6usize, 6usize);
        let want = serial_stream(&model, &prompt, &scfg, turn1 + turn2);

        let mut pool = BucketedPool::new(&model.cfg, CAPACITY, 1);
        // churn companions admitted BEFORE A so they hold the lower
        // slots: their mid-turn finishes trigger compactions that
        // genuinely relocate A's slot (slot 2 → 0)
        pool.admit(1, None);
        let mut b = LaneSim::fresh(1, &random_prompt(&mut rng, 6, model.cfg.vocab), &scfg, 3);
        pool.admit(2, None);
        let mut c = LaneSim::fresh(2, &random_prompt(&mut rng, 5, model.cfg.vocab), &scfg, 2);
        pool.admit(0, None);
        let mut a = LaneSim::fresh(0, &prompt, &scfg, turn1);
        let mut a_done = false;
        let (mut b_done, mut c_done) = (false, false);
        while !a_done {
            a_done = step_lane(&model, &mut pool, &mut a);
            if !b_done && step_lane(&model, &mut pool, &mut b) {
                pool.finish(1);
                b_done = true;
            }
            if !c_done && step_lane(&model, &mut pool, &mut c) {
                pool.finish(2);
                c_done = true;
            }
            pool.after_cycle();
        }
        // detach A from whatever slot churn left it in
        let (parts, sstate, last) = (pool.finish(0), SamplerState::capture(&a.sampler), a.last);
        assert!(pool.shrinks >= 1, "companion finishes must have compacted the layout");
        let first_half = a.out.clone();

        // resume on a fresh lane id; continue-in-place feeds the
        // snapshot's last sampled token first (Lane::resume semantics)
        pool.admit(3, Some(&parts[..]));
        let mut resumed = LaneSim {
            lane: 3,
            req: 0,
            inputs: vec![last],
            cursor: 0,
            sampler: sstate.rebuild(),
            last,
            max_new: turn2,
            out: vec![],
        };
        while !step_lane(&model, &mut pool, &mut resumed) {}
        pool.finish(3);

        let got: Vec<u8> = first_half.iter().chain(&resumed.out).copied().collect();
        assert_eq!(got, want, "detach/resume across repacks diverged (temp {})", scfg.temperature);
    }
}

#[test]
fn cache_seeded_lanes_stay_byte_identical_under_churn() {
    // two requests share a chunk-aligned prefix; the second is seeded
    // warm from the prefix cache and decodes through a churning bucketed
    // layout.  Warm and cold streams must be byte-identical (greedy and
    // seeded), and greedy must also equal plain serial decode.
    const CHUNK: usize = 8;
    let model = build_model_full("hla2", &ModelShape::default(), 17);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(CHUNK, 2)).unwrap();
    let cache = PrefixCache::new(PrefixCacheCfg::new(1 << 20, CHUNK));
    let mut rng = Rng::new(29);
    let prefix = random_prompt(&mut rng, 2 * CHUNK, model.cfg.vocab);
    let mut prompt = prefix.clone();
    prompt.extend(random_prompt(&mut rng, 5, model.cfg.vocab));
    let max_new = 8;

    let run_cached = |scfg: &SamplerCfg| -> (Vec<u8>, Vec<Tensor>, usize) {
        let (parts, consumed, outcome) = pf.ingest_lane_cached(&cache, &prompt).unwrap();
        let mut pool = BucketedPool::new(&model.cfg, CAPACITY, 1);
        // the churn companion holds the lower slot, so its finish
        // compacts the cached lane's seeded state into a new slot
        pool.admit(1, None);
        let mut side = LaneSim::fresh(1, &prompt[..4], scfg, 3);
        pool.admit(0, Some(&parts[..]));
        let mut sim = LaneSim::fresh(0, &prompt[consumed..], scfg, max_new);
        let mut side_done = false;
        while !step_lane(&model, &mut pool, &mut sim) {
            if !side_done && step_lane(&model, &mut pool, &mut side) {
                pool.finish(1);
                side_done = true;
            }
            pool.after_cycle();
        }
        let parts = pool.finish(0);
        assert!(pool.shrinks + pool.grows >= 1, "cached decode must see churn");
        (sim.out, parts, outcome.hit_tokens)
    };

    for scfg in [SamplerCfg::greedy(), seeded(3)] {
        cache.clear();
        let (cold, cold_state, cold_hits) = run_cached(&scfg);
        assert_eq!(cold_hits, 0, "first pass must be cold");
        let (warm, warm_state, warm_hits) = run_cached(&scfg);
        assert!(warm_hits > 0, "second pass must hit the shared prefix");
        assert_eq!(warm, cold, "warm vs cold under churn (temp {})", scfg.temperature);
        assert_state_bits_equal(&warm_state, &cold_state, "warm vs cold landing state");
    }
    // the scan path equals serial decode exactly on the greedy grid
    let (cold, _, _) = {
        cache.clear();
        run_cached(&SamplerCfg::greedy())
    };
    assert_eq!(cold, serial_stream(&model, &prompt, &SamplerCfg::greedy(), max_new));
}

#[test]
fn spec_passenger_lanes_compose_with_bucket_churn() {
    // a speculative lane occupies a slot as dead weight (its tokens come
    // from draft/verify rounds on the host twin) while batched lanes
    // grow/shrink the layout around it.  The passenger's stream is
    // pinned to serial decode via the serial verify backend, and the
    // batched lanes must be untouched by the passenger's slot moves.
    let model = build_model_full("hla2", &ModelShape::default(), 19);
    let mut rng = Rng::new(37);
    let spec_prompt = random_prompt(&mut rng, 12, model.cfg.vocab);
    let batched_prompt = random_prompt(&mut rng, 9, model.cfg.vocab);
    let max_new = 10;
    for scfg in [SamplerCfg::greedy(), seeded(41)] {
        let mut pool = BucketedPool::new(&model.cfg, CAPACITY, 1);
        // the short-lived companion takes the lowest slot so its finish
        // relocates both the passenger and the batched lane
        pool.admit(2, None);
        let mut side = LaneSim::fresh(2, &batched_prompt[..3], &scfg, 2);
        // the passenger occupies lane 0; its slice never advances
        pool.admit(0, None);
        // batched lane churns beside it
        pool.admit(1, None);
        let mut sim = LaneSim::fresh(1, &batched_prompt, &scfg, max_new);
        let mut side_done = false;
        while !step_lane(&model, &mut pool, &mut sim) {
            if !side_done && step_lane(&model, &mut pool, &mut side) {
                pool.finish(2);
                side_done = true;
            }
            pool.after_cycle();
        }
        assert!(pool.shrinks >= 1, "companion finish must compact around the passenger");
        // the passenger's dead-weight slice is still the zeros it was
        // admitted with — repacks moved it without corruption
        let passenger = pool.finish(0);
        assert!(
            passenger.iter().all(|t| t.data.iter().all(|&x| x == 0.0)),
            "passenger slice corrupted by churn"
        );
        // batched stream unaffected by the passenger
        assert_eq!(sim.out, serial_stream(&model, &batched_prompt, &scfg, max_new));
        // and the passenger's own (host-side) speculative stream equals
        // serial decode — the spec engine's lossless rule, unchanged by
        // bucketing because spec state never lives in the batched layout
        let cfg = SpecCfg {
            k: 3,
            adaptive: false,
            drafter: DrafterKind::Ngram,
            verify_chunk: 0,
            ..Default::default()
        };
        let mut dec = SpecDecoder::new(model.clone(), None, cfg).unwrap();
        let spec_stream = dec.generate(&spec_prompt, scfg.clone(), max_new, None).unwrap();
        assert_eq!(spec_stream, serial_stream(&model, &spec_prompt, &scfg, max_new));
    }
}
