//! Differential acceptance test for the shared-prefix radix cache: a
//! warm-hit stream must be **byte-identical** to its cold counterpart —
//! the cache moves *work*, never tokens.  Runs artifact-free on the
//! pure-Rust [`hla::testing::fixtures`] models, like the prefill and
//! spec differential suites.
//!
//! Exactness ledger (mirrors `spec_differential.rs`):
//! * **Warm vs cold through the cache path**: bit-exact by construction
//!   under BOTH prefill modes — the cache-aware ingest always cuts its
//!   scan at the same chunk-aligned boundaries, so the state at boundary
//!   `b` is a function of `prompt[..b]` alone, whether it was computed
//!   in this request or restored from the cache.  Asserted for greedy
//!   AND seeded sampling, state floats compared bit-for-bit.
//! * **Cache path vs serial decode**: with serial ingestion the
//!   segmentation is irrelevant (a `decode_step` chain splits anywhere),
//!   so equality is bit-exact and asserted for seeded sampling too.
//!   With scan ingestion the logits agree up to f32 reassociation
//!   (Thm 4.1), so exact token equality is asserted on the greedy grid —
//!   the same robustness bar `prefill_differential.rs` holds the scan to.
//!
//! `HLA_PREFIX_CACHE_BUDGET` (bytes) overrides the churn test's budget;
//! CI runs the suite at a tiny budget to force eviction churn under the
//! same byte-identity assertions.

use hla::cache::{PrefixCache, PrefixCacheCfg};
use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{ModelState, RustModel};
use hla::prefill::{advance, PrefillCfg, Prefiller};
use hla::session::SamplerState;
use hla::spec::{Drafter, DrafterKind, SpecCfg, SpecDecoder};
use hla::tensor::Tensor;
use hla::testing::fixtures::{build_model_full, random_prompt, shared_prefix_prompts, ModelShape};
use hla::util::rng::Rng;

/// Boundary stride shared by every cache in this suite.
const CHUNK: usize = 8;

fn seeded() -> SamplerCfg {
    SamplerCfg { temperature: 0.9, top_k: 20, seed: 7 }
}

fn cache(budget: usize) -> PrefixCache {
    PrefixCache::new(PrefixCacheCfg::new(budget, CHUNK))
}

/// The coordinator lane's generating phase: one `decode_step` + one
/// sampler draw per emitted token, starting from `first_input`.
fn decode_stream(
    model: &RustModel,
    state: &mut ModelState,
    sampler: &mut Sampler,
    first_input: u8,
    max_new: usize,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(max_new);
    let mut last = first_input;
    while out.len() < max_new {
        let logits = model.decode_step(state, last);
        let y = sampler.sample(&logits) as u8;
        out.push(y);
        last = y;
    }
    out
}

/// Serial decode from scratch — the bit-exact reference stream.
fn serial_stream(model: &RustModel, prompt: &[u8], scfg: &SamplerCfg, max_new: usize) -> Vec<u8> {
    let mut state = ModelState::new(&model.cfg);
    let mut sampler = Sampler::new(scfg.clone());
    advance(model, &mut state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
    decode_stream(model, &mut state, &mut sampler, prompt[prompt.len() - 1], max_new)
}

/// One request through the cache-enabled admission path: cached ingest,
/// then the normal decode loop.  Returns the stream, the post-generation
/// state parts, and how many prompt tokens the cache skipped.
fn cached_generate(
    pf: &Prefiller,
    cache: &PrefixCache,
    prompt: &[u8],
    scfg: &SamplerCfg,
    max_new: usize,
) -> (Vec<u8>, Vec<Tensor>, usize) {
    let mc = &pf.model().cfg;
    let (parts, consumed, outcome) = pf.ingest_lane_cached(cache, prompt).unwrap();
    let mut state = ModelState::new(mc);
    state.load_components(mc, &parts).unwrap();
    let mut sampler = Sampler::new(scfg.clone());
    let stream = decode_stream(pf.model(), &mut state, &mut sampler, prompt[consumed], max_new);
    (stream, state.to_components(mc).unwrap(), outcome.hit_tokens)
}

/// Bit-level equality for state component tensors (f32 compared by bits:
/// the cache must not perturb a single ULP).
fn assert_state_bits_equal(a: &[Tensor], b: &[Tensor], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: component arity");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.shape, y.shape, "{what}: component {i} shape");
        let xb: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        let yb: Vec<u32> = y.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xb, yb, "{what}: component {i} floats drifted");
    }
}

#[test]
fn warm_hit_byte_identical_to_cold_prefill_all_mixers_greedy_and_seeded() {
    let mut rng = Rng::new(101);
    for mixer in ["hla2", "ahla", "hla3"] {
        let model = build_model_full(mixer, &ModelShape::default(), 17);
        for (mode, pcfg) in [("scan", PrefillCfg::scan(8, 2)), ("serial", PrefillCfg::serial())] {
            let pf = Prefiller::new(model.clone(), pcfg).unwrap();
            // one 32-token preamble fanned into three full prompts
            let groups = shared_prefix_prompts(&mut rng, 1, 4 * CHUNK, 3, 11, 64);
            let group = &groups[0];
            for scfg in [SamplerCfg::greedy(), seeded()] {
                let warm_cache = cache(1 << 20);
                for (i, prompt) in group.iter().enumerate() {
                    let label = format!("{mixer} {mode} t={} req {i}", scfg.temperature);
                    // cold twin: the same request on an empty cache
                    let (cold, cold_parts, cold_hit) =
                        cached_generate(&pf, &cache(1 << 20), prompt, &scfg, 32);
                    assert_eq!(cold_hit, 0, "{label}: empty cache cannot hit");
                    // warm: the shared cache has seen this preamble before
                    let (warm, warm_parts, warm_hit) =
                        cached_generate(&pf, &warm_cache, prompt, &scfg, 32);
                    if i > 0 {
                        assert!(
                            warm_hit >= 4 * CHUNK,
                            "{label}: expected a preamble-deep hit, got {warm_hit}"
                        );
                    }
                    assert_eq!(warm, cold, "{label}: warm stream diverged from cold");
                    assert_state_bits_equal(&warm_parts, &cold_parts, &label);
                    // vs the serial reference: bit-exact when the
                    // ingestion itself is serial; greedy-exact on the scan
                    let want = serial_stream(&model, prompt, &scfg, 32);
                    if mode == "serial" || scfg.temperature == 0.0 {
                        assert_eq!(warm, want, "{label}: diverged from serial decode");
                    }
                }
                let st = warm_cache.stats();
                assert!(st.hits >= 2, "{mixer} {mode}: warm cache never hit");
                assert_eq!(st.evictions, 0, "roomy budget must not evict");
            }
        }
    }
}

#[test]
fn spec_decode_tolerates_cache_seeded_prompts() {
    // a speculative lane only diverges from the batched path after its
    // prompt is ingested — which is exactly what the cache seeds.  Under
    // the serial verify backend the whole pipeline is bit-exact, so the
    // cache-seeded spec stream must equal serial decode byte-for-byte,
    // greedy AND seeded.
    let mut rng = Rng::new(211);
    for mixer in ["hla2", "hla3"] {
        let model = build_model_full(mixer, &ModelShape::default(), 17);
        let pf = Prefiller::new(model.clone(), PrefillCfg::serial()).unwrap();
        let groups = shared_prefix_prompts(&mut rng, 1, 3 * CHUNK, 2, 9, 64);
        let group = &groups[0];
        let spec_cfg = SpecCfg {
            k: 4,
            adaptive: false,
            drafter: DrafterKind::Ngram,
            verify_chunk: 0,
            ..Default::default()
        };
        for scfg in [SamplerCfg::greedy(), seeded()] {
            let shared = cache(1 << 20);
            for (i, prompt) in group.iter().enumerate() {
                let label = format!("{mixer} spec t={} req {i}", scfg.temperature);
                let want = serial_stream(&model, prompt, &scfg, 40);
                // non-cached spec decode (the spec suite's pinned path)
                let mut dec = SpecDecoder::new(model.clone(), None, spec_cfg.clone()).unwrap();
                let plain = dec.generate(prompt, scfg.clone(), 40, None).unwrap();
                assert_eq!(plain, want, "{label}: plain spec diverged");
                // cache-seeded prompt: land the cached ingest in the spec
                // lane, commit the drafter context, and run rounds
                let (parts, consumed, hit) = pf.ingest_lane_cached(&shared, prompt).unwrap();
                if i > 0 {
                    assert!(hit > 0, "{label}: expected a warm hit");
                }
                let mut dec = SpecDecoder::new(model.clone(), None, spec_cfg.clone()).unwrap();
                dec.lane.state.load_components(&model.cfg, &parts).unwrap();
                dec.lane.drafter.commit(&prompt[..=consumed]);
                let mut sampler = Sampler::new(scfg.clone());
                let got = dec.run(&mut sampler, prompt[consumed], 40, None).unwrap();
                assert_eq!(got, want, "{label}: cache-seeded spec diverged");
            }
        }
    }
}

#[test]
fn cache_paths_stay_exact_across_session_resume() {
    // turn 1 warm-hits the cache; the lane detaches into a session
    // snapshot; turn 2 resumes it (bypassing the cache, as the engine
    // does).  Both turns must be byte-identical to one uninterrupted
    // two-turn generation that never saw the cache.
    let mut rng = Rng::new(307);
    let model = build_model_full("hla2", &ModelShape::default(), 17);
    let mc = model.cfg.clone();
    let pf = Prefiller::new(model.clone(), PrefillCfg::serial()).unwrap();
    let groups = shared_prefix_prompts(&mut rng, 1, 3 * CHUNK, 2, 7, 64);
    let group = &groups[0];
    let turn2_text = random_prompt(&mut rng, 13, 64);
    let scfg = seeded();

    // reference: cold turn 1, then turn 2 continues in place — the
    // resumed lane feeds [last_token] ++ turn2 before sampling again
    let reference = |prompt: &[u8]| -> (Vec<u8>, Vec<u8>) {
        let mut state = ModelState::new(&mc);
        let mut sampler = Sampler::new(scfg.clone());
        advance(&model, &mut state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
        let t1 = decode_stream(&model, &mut state, &mut sampler, prompt[prompt.len() - 1], 24);
        let mut turn2 = vec![*t1.last().unwrap()];
        turn2.extend_from_slice(&turn2_text);
        advance(&model, &mut state, &turn2[..turn2.len() - 1], &PrefillCfg::serial());
        let t2 = decode_stream(&model, &mut state, &mut sampler, turn2[turn2.len() - 1], 24);
        (t1, t2)
    };

    let shared = cache(1 << 20);
    // request 0 populates the preamble boundaries; request 1 warm-hits
    for (i, prompt) in group.iter().enumerate() {
        let (want_t1, want_t2) = reference(prompt);
        // turn 1 through the cache path
        let (parts, consumed, hit) = pf.ingest_lane_cached(&shared, prompt).unwrap();
        if i > 0 {
            assert!(hit > 0, "req {i}: second sighting of the preamble must hit");
        }
        let mut state = ModelState::new(&mc);
        state.load_components(&mc, &parts).unwrap();
        let mut sampler = Sampler::new(scfg.clone());
        let t1 = decode_stream(&model, &mut state, &mut sampler, prompt[consumed], 24);
        assert_eq!(t1, want_t1, "req {i}: turn 1 diverged");
        // detach: state components + exact sampler position (what the
        // engine snapshots into the session store)
        let snap_parts = state.to_components(&mc).unwrap();
        let snap_sampler = SamplerState::capture(&sampler);
        let last_token = *t1.last().unwrap();
        // resume on a "different lane": fresh state, restored snapshot —
        // the cache is NOT consulted (resumed lanes bypass it)
        let mut lane = ModelState::new(&mc);
        lane.load_components(&mc, &snap_parts).unwrap();
        let mut sampler = snap_sampler.rebuild();
        let mut turn2 = vec![last_token];
        turn2.extend_from_slice(&turn2_text);
        advance(&model, &mut lane, &turn2[..turn2.len() - 1], &PrefillCfg::serial());
        let t2 = decode_stream(&model, &mut lane, &mut sampler, turn2[turn2.len() - 1], 24);
        assert_eq!(t2, want_t2, "req {i}: resumed turn 2 diverged");
    }
}

#[test]
fn eviction_churn_keeps_streams_byte_identical() {
    // a tiny byte budget forces constant LRU churn (this is the CI gate:
    // HLA_PREFIX_CACHE_BUDGET shrinks it further) — eviction may cost
    // hits, but it must never cost correctness
    let (budget, from_env) = match std::env::var("HLA_PREFIX_CACHE_BUDGET") {
        Ok(v) => (v.parse::<usize>().expect("HLA_PREFIX_CACHE_BUDGET must be bytes"), true),
        Err(_) => (12 * 1024, false),
    };
    let mut rng = Rng::new(401);
    let model = build_model_full("hla2", &ModelShape::default(), 17);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(8, 2)).unwrap();
    let groups = shared_prefix_prompts(&mut rng, 2, 4 * CHUNK, 4, 9, 64);
    let tiny = cache(budget);
    for (g, group) in groups.iter().enumerate() {
        for (i, prompt) in group.iter().enumerate() {
            let label = format!("group {g} req {i} (budget {budget})");
            let (cold, cold_parts, _) =
                cached_generate(&pf, &cache(1 << 20), prompt, &seeded(), 24);
            let (warm, warm_parts, _) = cached_generate(&pf, &tiny, prompt, &seeded(), 24);
            assert_eq!(warm, cold, "{label}: stream diverged under churn");
            assert_state_bits_equal(&warm_parts, &cold_parts, &label);
            let st = tiny.stats();
            assert!(
                st.resident_bytes <= budget,
                "{label}: resident {} over budget",
                st.resident_bytes
            );
        }
    }
    let st = tiny.stats();
    if !from_env {
        // the default 12 KiB holds ~3 boundary snapshots of this fixture:
        // 8 requests x 4 boundaries each must have churned…
        assert!(st.evictions > 0, "budget never forced an eviction: {st:?}");
        // …while back-to-back same-preamble requests still hit
        assert!(st.hits > 0, "no warm hits under churn: {st:?}");
    }
}

#[test]
fn repeated_identical_prompt_reuses_its_deepest_boundary() {
    // lookup is strict against the full prompt, not the head — so a
    // resubmitted prompt whose head length is chunk-aligned reuses the
    // boundary stored at exactly that depth and skips prefill entirely
    let mut rng = Rng::new(601);
    let model = build_model_full("hla2", &ModelShape::default(), 17);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(8, 2)).unwrap();
    let shared = cache(1 << 20);
    // prompt of 41 tokens: head = 40 = 5 chunks, exactly boundary-aligned
    let prompt = random_prompt(&mut rng, 4 * CHUNK + 9, 64);
    let (a, a_parts, hit_a) = cached_generate(&pf, &shared, &prompt, &SamplerCfg::greedy(), 24);
    assert_eq!(hit_a, 0, "first sighting is cold");
    let (b, b_parts, hit_b) = cached_generate(&pf, &shared, &prompt, &SamplerCfg::greedy(), 24);
    assert_eq!(hit_b, prompt.len() - 1, "aligned head must be reused in full");
    assert_eq!(b, a, "full-head reuse changed the stream");
    assert_state_bits_equal(&b_parts, &a_parts, "full-head reuse");
    // and the warm full-hit still equals a fresh cold twin
    let (c, c_parts, _) = cached_generate(&pf, &cache(1 << 20), &prompt, &SamplerCfg::greedy(), 24);
    assert_eq!(c, a, "cold twin agrees with the populating run");
    assert_state_bits_equal(&c_parts, &a_parts, "repeat cold");
}

#[test]
fn opt_out_path_matches_cached_path_greedy() {
    // the per-request opt-out takes the plain ingest_lane route; for
    // greedy sampling its stream must match the cache-enabled route (the
    // two only differ by scan segmentation, which argmax shrugs off) —
    // and it must leave no trace in the cache
    let mut rng = Rng::new(503);
    let model = build_model_full("ahla", &ModelShape::default(), 17);
    let pf = Prefiller::new(model.clone(), PrefillCfg::scan(8, 2)).unwrap();
    let mc = model.cfg.clone();
    let prompt = random_prompt(&mut rng, 40, 64);

    let shared = cache(1 << 20);
    let (with_cache, _, _) = cached_generate(&pf, &shared, &prompt, &SamplerCfg::greedy(), 24);
    let inserted = shared.stats().inserts;
    assert!(inserted > 0, "the cached route contributes boundaries");

    // opt-out: plain ingest, no cache interaction at all
    let (parts, consumed) = pf.ingest_lane(None, &prompt).unwrap();
    let mut state = ModelState::new(&mc);
    state.load_components(&mc, &parts).unwrap();
    let mut sampler = Sampler::new(SamplerCfg::greedy());
    let opted_out = decode_stream(&model, &mut state, &mut sampler, prompt[consumed], 24);
    assert_eq!(opted_out, with_cache, "opt-out changed the greedy stream");
    let st = shared.stats();
    assert_eq!(st.inserts, inserted, "opt-out must not insert");
    assert_eq!(st.hits + st.misses, 1, "opt-out must not even look");
}
