//! Cluster failover, pinned byte-for-byte: kill the replica serving a
//! session mid-generation and the front-end must resume the stream on a
//! survivor with *identical bytes* to an uninterrupted run — greedy and
//! seeded alike.  This is the serving payoff of constant-size HLA state:
//! the front-end's parked snapshot is a few KB, so failover is re-attach
//! + replay, not a context re-ingest.
//!
//! Two layers:
//!
//! * In-process chaos (always on): real fixture replicas behind
//!   `serve_cluster`, with the doomed one reached through a chaos proxy
//!   that severs the wire after exactly N relayed reply lines — a
//!   deterministic mid-stream death, timing plays no part.
//! * Process-level smoke (`HLA_CLUSTER_SMOKE=1`): two `hla serve
//!   --fixture` child processes and an `hla router` child, with a real
//!   SIGKILL between turns; resume must still be byte-identical.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hla::cluster::{fixture_identity, serve_frontend, spawn_fixture_engine, Frontend, FrontendCfg};
use hla::coordinator::router::{RoutePolicy, Router};
use hla::metrics::LiveStats;
use hla::server::{serve_cluster, ServeObs};
use hla::session::SessionStore;
use hla::testing::fixtures::{build_model_full, ModelShape};

const SEED: u64 = 7;

/// A full in-process replica: fixture engine + session store behind the
/// real wire server with cluster identity.  Same `SEED` everywhere —
/// failover replays must continue on identical weights.
fn spawn_replica() -> (String, Arc<AtomicBool>) {
    let model = build_model_full("hla2", &ModelShape::default(), SEED);
    let identity = Arc::new(fixture_identity(&model));
    let store = Arc::new(SessionStore::in_memory(64));
    let stats = Arc::new(LiveStats::new());
    let (tx, _engine) = spawn_fixture_engine(model, store.clone(), stats.clone());
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let obs = Arc::new(ServeObs { stats: vec![stats] });
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_cluster("127.0.0.1:0", router, Some(store), Some(obs), Some(identity), stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), stop)
}

/// TCP chaos proxy in front of a replica.  Transparent until `armed`;
/// once armed, the first connection whose replica→client side reaches
/// `cut_after` forwarded lines is severed and the proxy stops accepting —
/// a deterministic mid-stream crash, as seen from the front-end.
fn spawn_chaos_proxy(target: String, cut_after: usize) -> (String, Arc<AtomicBool>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    listener.set_nonblocking(true).unwrap();
    let armed = Arc::new(AtomicBool::new(false));
    let dead = Arc::new(AtomicBool::new(false));
    let armed2 = armed.clone();
    std::thread::spawn(move || loop {
        if dead.load(Ordering::Relaxed) {
            return; // crashed: refuse all future connections
        }
        match listener.accept() {
            Ok((client, _)) => {
                client.set_nodelay(true).unwrap();
                let Ok(upstream) = TcpStream::connect(&target) else { return };
                upstream.set_nodelay(true).unwrap();
                let mut c_read = client.try_clone().unwrap();
                let mut u_write = upstream.try_clone().unwrap();
                std::thread::spawn(move || {
                    let _ = std::io::copy(&mut c_read, &mut u_write);
                    let _ = u_write.shutdown(Shutdown::Both);
                });
                let armed = armed2.clone();
                let dead = dead.clone();
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(upstream);
                    let mut writer = client;
                    let mut lines = 0usize;
                    let mut buf = String::new();
                    loop {
                        buf.clear();
                        match reader.read_line(&mut buf) {
                            Ok(0) | Err(_) => {
                                let _ = writer.shutdown(Shutdown::Both);
                                return;
                            }
                            Ok(_) => {}
                        }
                        if writer.write_all(buf.as_bytes()).is_err() {
                            return;
                        }
                        lines += 1;
                        if armed.load(Ordering::Relaxed) && lines >= cut_after {
                            // the crash: both directions die mid-stream
                            dead.store(true, Ordering::Relaxed);
                            let _ = writer.shutdown(Shutdown::Both);
                            let _ = reader.get_ref().shutdown(Shutdown::Both);
                            return;
                        }
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => return,
        }
    });
    (addr, armed)
}

fn spawn_test_frontend(replicas: Vec<String>) -> (String, Arc<Frontend>, Arc<AtomicBool>) {
    let fe = Arc::new(Frontend::new(FrontendCfg {
        replica_addrs: replicas,
        policy: RoutePolicy::RoundRobin,
        health_interval: Duration::from_millis(100),
        io_timeout: Duration::from_millis(500),
    }));
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let fe2 = fe.clone();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_frontend("127.0.0.1:0", fe2, stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), fe, stop)
}

/// One request over a fresh connection; returns the raw reply lines:
/// every token line plus the terminal (`done`/`error`) line.
fn request(addr: &str, line: &str) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writeln!(writer, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut lines = Vec::new();
    let mut buf = String::new();
    loop {
        buf.clear();
        let n = reader.read_line(&mut buf).unwrap();
        assert!(n > 0, "connection closed before a terminal line (got {lines:?})");
        let l = buf.trim_end().to_string();
        let terminal = l.contains("\"done\"") || l.contains("\"error\"");
        lines.push(l);
        if terminal {
            return lines;
        }
    }
}

fn turn1_line(session: u64, sampler: &str) -> String {
    format!(
        "{{\"prompt\": \"higher-order linear attention\", \"max_tokens\": 16, {sampler} \
         \"session\": {session}}}"
    )
}

fn turn2_line(session: u64, sampler: &str) -> String {
    format!(
        "{{\"prompt\": \" resumes mid-stream\", \"max_tokens\": 24, {sampler} \
         \"session\": {session}, \"resume\": true}}"
    )
}

/// The chaos scenario for one sampler config: the session's home replica
/// dies after exactly 7 tokens of turn 2 have reached the front-end; the
/// resumed stream must be byte-identical to an uninterrupted reference.
fn assert_failover_byte_identical(session: u64, sampler: &str) {
    // reference fleet: one healthy replica behind its own front-end
    let (ref_replica, _ref_stop) = spawn_replica();
    let (ref_fe_addr, _ref_fe, _ref_fe_stop) = spawn_test_frontend(vec![ref_replica]);
    let ref_turn1 = request(&ref_fe_addr, &turn1_line(session, sampler));
    let ref_turn2 = request(&ref_fe_addr, &turn2_line(session, sampler));
    assert_eq!(ref_turn1.len(), 17, "16 tokens + done expected: {ref_turn1:?}");
    assert_eq!(ref_turn2.len(), 25, "24 tokens + done expected: {ref_turn2:?}");
    assert!(ref_turn2.last().unwrap().contains("\"resumed\":true"), "{ref_turn2:?}");

    // chaos fleet: replica A sits behind the proxy; round-robin sends the
    // session's first turn to index 0, so A becomes its pinned home
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (proxy_addr, armed) = spawn_chaos_proxy(a_addr, 7);
    let (fe_addr, fe, _fe_stop) = spawn_test_frontend(vec![proxy_addr, b_addr]);

    let turn1 = request(&fe_addr, &turn1_line(session, sampler));
    assert_eq!(turn1, ref_turn1, "pre-failover turn diverged from reference");
    assert_eq!(fe.desk_len(), 1, "completed session must be parked at the desk");

    // arm the wire-cut and run turn 2: 7 tokens flow, then A "crashes";
    // the front-end must re-attach the desk snapshot to B and continue
    armed.store(true, Ordering::Relaxed);
    let turn2 = request(&fe_addr, &turn2_line(session, sampler));
    assert_eq!(
        turn2, ref_turn2,
        "failed-over stream is not byte-identical to the uninterrupted one"
    );
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "exactly one mid-stream failover");
    assert!(fe.migrations.load(Ordering::Relaxed) >= 1, "the session must have migrated");
    assert!(!fe.registry.replicas[0].is_alive(), "the cut replica must be marked dead");
    assert!(fe.registry.replicas[1].is_alive(), "the survivor must stay alive");
}

#[test]
fn mid_stream_failover_is_byte_identical_greedy() {
    assert_failover_byte_identical(42, "\"temperature\": 0,");
}

#[test]
fn mid_stream_failover_is_byte_identical_seeded() {
    // temperature 1 with a fixed seed: failover must restore the exact
    // RNG state, not just the weights — any drift diverges immediately
    assert_failover_byte_identical(43, "\"temperature\": 1.0, \"seed\": 99,");
}

#[test]
fn drain_refuses_a_replica_with_requests_in_flight() {
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (_fe_addr, fe, _fe_stop) = spawn_test_frontend(vec![a_addr, b_addr]);
    // a consuming detach racing an in-flight generation would leave the
    // session on both replicas with diverging state — drain must refuse
    fe.registry.replicas[0].begin_request();
    let err = fe.drain_replica(0).unwrap_err().to_string();
    assert!(err.contains("in flight"), "drain must demand a quiesced replica: {err}");
    fe.registry.replicas[0].end_request();
    assert_eq!(fe.drain_replica(0).unwrap(), 0, "quiesced drain of an empty replica moves 0");
}

#[test]
fn stats_fan_out_merges_the_fleet() {
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (fe_addr, _fe, _fe_stop) = spawn_test_frontend(vec![a_addr, b_addr]);
    // one generation per replica (round-robin), then a merged stats pull
    for _ in 0..2 {
        request(&fe_addr, "{\"prompt\": \"ab\", \"max_tokens\": 4, \"temperature\": 0}");
    }
    let reply = request(&fe_addr, "{\"stats\": true}");
    assert_eq!(reply.len(), 1, "stats is a single-line reply: {reply:?}");
    let line = &reply[0];
    assert!(line.contains("\"replicas\":2"), "both replicas must answer: {line}");
    assert!(line.contains("\"tokens_out\":8"), "4 tokens per replica summed: {line}");
}

// ---------------------------------------------------------------------------
// Process-level smoke: real processes, real SIGKILL.  Opt-in via
// HLA_CLUSTER_SMOKE=1 (CI runs it; plain `cargo test` skips to stay hermetic).
// ---------------------------------------------------------------------------

/// Spawn an `hla` subcommand and wait for its "listening on ADDR" line.
fn spawn_hla(args: &[&str]) -> (std::process::Child, String) {
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_hla"))
        .args(args)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning hla");
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(a) = line.trim().strip_prefix("listening on ") {
            addr = Some(a.to_string());
            break;
        }
        line.clear();
    }
    let addr = addr.unwrap_or_else(|| {
        let _ = child.kill();
        panic!("child never printed its listen address");
    });
    // keep the pipe drained so the child never blocks on a full stdout
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).unwrap_or(0) > 0 {
            sink.clear();
        }
    });
    (child, addr)
}

#[test]
fn process_level_failover_smoke() {
    if std::env::var("HLA_CLUSTER_SMOKE").as_deref() != Ok("1") {
        eprintln!("skipping process-level smoke (set HLA_CLUSTER_SMOKE=1 to run)");
        return;
    }
    let fixture_args =
        ["serve", "--fixture", "true", "--seed", "7", "--addr", "127.0.0.1:0"];
    // reference: one uninterrupted replica process spoken to directly
    let (mut ref_child, ref_addr) = spawn_hla(&fixture_args);
    let sampler = "\"temperature\": 1.0, \"seed\": 5,";
    let ref_turn1 = request(&ref_addr, &turn1_line(91, sampler));
    let ref_turn2 = request(&ref_addr, &turn2_line(91, sampler));

    // the fleet: two replica processes plus the router process
    let (mut a, a_addr) = spawn_hla(&fixture_args);
    let (mut b, b_addr) = spawn_hla(&fixture_args);
    let (mut router, fe_addr) = spawn_hla(&[
        "router",
        "--addr",
        "127.0.0.1:0",
        "--replicas",
        &format!("{a_addr},{b_addr}"),
        "--route",
        "round-robin",
        "--health-interval",
        "0.2",
    ]);

    let turn1 = request(&fe_addr, &turn1_line(91, sampler));
    assert_eq!(turn1, ref_turn1, "routed turn diverged from the direct reference");

    // SIGKILL the session's home (round-robin pinned it to replica A),
    // then resume: the router must discover the death at relay time,
    // re-attach the parked snapshot to B, and replay byte-identically
    a.kill().expect("killing replica A");
    let _ = a.wait();
    let turn2 = request(&fe_addr, &turn2_line(91, sampler));
    assert_eq!(turn2, ref_turn2, "post-SIGKILL resume is not byte-identical");

    let _ = router.kill();
    let _ = b.kill();
    let _ = ref_child.kill();
    let _ = router.wait();
    let _ = b.wait();
    let _ = ref_child.wait();
}
