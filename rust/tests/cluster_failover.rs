//! Cluster failover, pinned byte-for-byte: kill the replica serving a
//! session mid-generation and the front-end must resume the stream on a
//! survivor with *identical bytes* to an uninterrupted run — greedy and
//! seeded alike.  This is the serving payoff of constant-size HLA state:
//! the front-end's parked snapshot is a few KB, so failover is re-attach
//! + replay, not a context re-ingest.
//!
//! Two layers:
//!
//! * In-process chaos (always on): real fixture replicas behind
//!   `serve_cluster`, with the doomed one reached through a chaos proxy
//!   that severs the wire after exactly N relayed reply lines — a
//!   deterministic mid-stream death, timing plays no part.
//! * Process-level smoke (`HLA_CLUSTER_SMOKE=1`): two `hla serve
//!   --fixture` child processes and an `hla router` child, with a real
//!   SIGKILL between turns; resume must still be byte-identical.

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hla::cluster::{
    fixture_identity, serve_frontend, spawn_fixture_engine_traced, EventLog, Frontend, FrontendCfg,
};
use hla::coordinator::router::{RoutePolicy, Router};
use hla::metrics::stitch::{write_stitched, ProcessTrace};
use hla::metrics::trace::{TraceCfg, Tracer};
use hla::metrics::LiveStats;
use hla::server::client::Client;
use hla::server::{serve_cluster, ServeObs};
use hla::session::SessionStore;
use hla::testing::fixtures::{build_model_full, ModelShape};
use hla::util::json::Json;

const SEED: u64 = 7;

/// A full in-process replica: fixture engine + session store behind the
/// real wire server with cluster identity.  Same `SEED` everywhere —
/// failover replays must continue on identical weights.
fn spawn_replica() -> (String, Arc<AtomicBool>) {
    spawn_replica_traced(None)
}

/// Same, with an optional span ring attached to the engine and exposed
/// over the wire via the `trace_export` control verb.
fn spawn_replica_traced(tracer: Option<Arc<Tracer>>) -> (String, Arc<AtomicBool>) {
    let model = build_model_full("hla2", &ModelShape::default(), SEED);
    let identity = Arc::new(fixture_identity(&model));
    let store = Arc::new(SessionStore::in_memory(64));
    let stats = Arc::new(LiveStats::new());
    let (tx, _engine) =
        spawn_fixture_engine_traced(model, store.clone(), stats.clone(), tracer.clone());
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let obs = Arc::new(ServeObs { stats: vec![stats], tracers: tracer.into_iter().collect() });
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_cluster("127.0.0.1:0", router, Some(store), Some(obs), Some(identity), stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), stop)
}

/// TCP chaos proxy in front of a replica.  Transparent until `armed`;
/// once armed, the first connection whose replica→client side reaches
/// `cut_after` forwarded lines is severed and the proxy stops accepting —
/// a deterministic mid-stream crash, as seen from the front-end.
fn spawn_chaos_proxy(target: String, cut_after: usize) -> (String, Arc<AtomicBool>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    listener.set_nonblocking(true).unwrap();
    let armed = Arc::new(AtomicBool::new(false));
    let dead = Arc::new(AtomicBool::new(false));
    let armed2 = armed.clone();
    std::thread::spawn(move || loop {
        if dead.load(Ordering::Relaxed) {
            return; // crashed: refuse all future connections
        }
        match listener.accept() {
            Ok((client, _)) => {
                client.set_nodelay(true).unwrap();
                let Ok(upstream) = TcpStream::connect(&target) else { return };
                upstream.set_nodelay(true).unwrap();
                let mut c_read = client.try_clone().unwrap();
                let mut u_write = upstream.try_clone().unwrap();
                std::thread::spawn(move || {
                    let _ = std::io::copy(&mut c_read, &mut u_write);
                    let _ = u_write.shutdown(Shutdown::Both);
                });
                let armed = armed2.clone();
                let dead = dead.clone();
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(upstream);
                    let mut writer = client;
                    let mut lines = 0usize;
                    let mut buf = String::new();
                    loop {
                        buf.clear();
                        match reader.read_line(&mut buf) {
                            Ok(0) | Err(_) => {
                                let _ = writer.shutdown(Shutdown::Both);
                                return;
                            }
                            Ok(_) => {}
                        }
                        if writer.write_all(buf.as_bytes()).is_err() {
                            return;
                        }
                        lines += 1;
                        if armed.load(Ordering::Relaxed) && lines >= cut_after {
                            // the crash: both directions die mid-stream
                            dead.store(true, Ordering::Relaxed);
                            let _ = writer.shutdown(Shutdown::Both);
                            let _ = reader.get_ref().shutdown(Shutdown::Both);
                            return;
                        }
                    }
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => return,
        }
    });
    (addr, armed)
}

fn spawn_test_frontend(replicas: Vec<String>) -> (String, Arc<Frontend>, Arc<AtomicBool>) {
    let fe = Arc::new(Frontend::new(FrontendCfg {
        replica_addrs: replicas,
        policy: RoutePolicy::RoundRobin,
        health_interval: Duration::from_millis(100),
        io_timeout: Duration::from_millis(500),
    }));
    let (addr, stop) = spawn_frontend_arc(fe.clone());
    (addr, fe, stop)
}

/// Serve an already-built front-end (lets a test attach observability
/// sinks before the listener starts).
fn spawn_frontend_arc(fe: Arc<Frontend>) -> (String, Arc<AtomicBool>) {
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_frontend("127.0.0.1:0", fe, stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), stop)
}

/// One single-line admin round-trip (stats / events) over a fresh
/// connection — admin replies have no `done` terminal, they are one line.
fn admin(addr: &str, line: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writeln!(writer, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    assert!(reader.read_line(&mut buf).unwrap() > 0, "no admin reply");
    buf.trim_end().to_string()
}

/// One request over a fresh connection; returns the raw reply lines:
/// every token line plus the terminal (`done`/`error`) line.
fn request(addr: &str, line: &str) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writeln!(writer, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut lines = Vec::new();
    let mut buf = String::new();
    loop {
        buf.clear();
        let n = reader.read_line(&mut buf).unwrap();
        assert!(n > 0, "connection closed before a terminal line (got {lines:?})");
        let l = buf.trim_end().to_string();
        let terminal = l.contains("\"done\"") || l.contains("\"error\"");
        lines.push(l);
        if terminal {
            return lines;
        }
    }
}

fn turn1_line(session: u64, sampler: &str) -> String {
    format!(
        "{{\"prompt\": \"higher-order linear attention\", \"max_tokens\": 16, {sampler} \
         \"session\": {session}}}"
    )
}

fn turn2_line(session: u64, sampler: &str) -> String {
    format!(
        "{{\"prompt\": \" resumes mid-stream\", \"max_tokens\": 24, {sampler} \
         \"session\": {session}, \"resume\": true}}"
    )
}

/// The chaos scenario for one sampler config: the session's home replica
/// dies after exactly 7 tokens of turn 2 have reached the front-end; the
/// resumed stream must be byte-identical to an uninterrupted reference.
fn assert_failover_byte_identical(session: u64, sampler: &str) {
    // reference fleet: one healthy replica behind its own front-end
    let (ref_replica, _ref_stop) = spawn_replica();
    let (ref_fe_addr, _ref_fe, _ref_fe_stop) = spawn_test_frontend(vec![ref_replica]);
    let ref_turn1 = request(&ref_fe_addr, &turn1_line(session, sampler));
    let ref_turn2 = request(&ref_fe_addr, &turn2_line(session, sampler));
    assert_eq!(ref_turn1.len(), 17, "16 tokens + done expected: {ref_turn1:?}");
    assert_eq!(ref_turn2.len(), 25, "24 tokens + done expected: {ref_turn2:?}");
    assert!(ref_turn2.last().unwrap().contains("\"resumed\":true"), "{ref_turn2:?}");

    // chaos fleet: replica A sits behind the proxy; round-robin sends the
    // session's first turn to index 0, so A becomes its pinned home
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (proxy_addr, armed) = spawn_chaos_proxy(a_addr, 7);
    let (fe_addr, fe, _fe_stop) = spawn_test_frontend(vec![proxy_addr, b_addr]);

    let turn1 = request(&fe_addr, &turn1_line(session, sampler));
    assert_eq!(turn1, ref_turn1, "pre-failover turn diverged from reference");
    assert_eq!(fe.desk_len(), 1, "completed session must be parked at the desk");

    // arm the wire-cut and run turn 2: 7 tokens flow, then A "crashes";
    // the front-end must re-attach the desk snapshot to B and continue
    armed.store(true, Ordering::Relaxed);
    let turn2 = request(&fe_addr, &turn2_line(session, sampler));
    assert_eq!(
        turn2, ref_turn2,
        "failed-over stream is not byte-identical to the uninterrupted one"
    );
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "exactly one mid-stream failover");
    assert!(fe.migrations.load(Ordering::Relaxed) >= 1, "the session must have migrated");
    assert!(!fe.registry.replicas[0].is_alive(), "the cut replica must be marked dead");
    assert!(fe.registry.replicas[1].is_alive(), "the survivor must stay alive");
}

#[test]
fn mid_stream_failover_is_byte_identical_greedy() {
    assert_failover_byte_identical(42, "\"temperature\": 0,");
}

#[test]
fn mid_stream_failover_is_byte_identical_seeded() {
    // temperature 1 with a fixed seed: failover must restore the exact
    // RNG state, not just the weights — any drift diverges immediately
    assert_failover_byte_identical(43, "\"temperature\": 1.0, \"seed\": 99,");
}

#[test]
fn drain_refuses_a_replica_with_requests_in_flight() {
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (_fe_addr, fe, _fe_stop) = spawn_test_frontend(vec![a_addr, b_addr]);
    // a consuming detach racing an in-flight generation would leave the
    // session on both replicas with diverging state — drain must refuse
    fe.registry.replicas[0].begin_request();
    let err = fe.drain_replica(0).unwrap_err().to_string();
    assert!(err.contains("in flight"), "drain must demand a quiesced replica: {err}");
    fe.registry.replicas[0].end_request();
    assert_eq!(fe.drain_replica(0).unwrap(), 0, "quiesced drain of an empty replica moves 0");
}

#[test]
fn stats_fan_out_merges_the_fleet() {
    let (a_addr, _a_stop) = spawn_replica();
    let (b_addr, _b_stop) = spawn_replica();
    let (fe_addr, _fe, _fe_stop) = spawn_test_frontend(vec![a_addr, b_addr]);
    // one generation per replica (round-robin), then a merged stats pull
    for _ in 0..2 {
        request(&fe_addr, "{\"prompt\": \"ab\", \"max_tokens\": 4, \"temperature\": 0}");
    }
    let line = admin(&fe_addr, "{\"stats\": true}");
    assert!(line.contains("\"replicas\":2"), "both replicas must answer: {line}");
    assert!(line.contains("\"tokens_out\":8"), "4 tokens per replica summed: {line}");
    assert!(line.contains("\"skipped\":[]"), "a fully-answered fleet skips nobody: {line}");
    assert!(line.contains("\"router\""), "the front-end's own metrics plane rides along: {line}");
}

/// The ISSUE's chaos acceptance scenario: a traced failover run must
/// yield ONE stitched Chrome trace (router pid 0 + both replica pids
/// sharing the request's trace id, the failover as an instant event) and
/// an event journal carrying the ordered sequence
/// strike → dead → failover_begin → attach → failover_end.
#[test]
fn chaos_failover_emits_a_stitched_trace_and_an_ordered_event_journal() {
    let mk = || Arc::new(Tracer::new(&TraceCfg { sample: 1.0, capacity: 512 }));
    let (a_tr, b_tr, r_tr) = (mk(), mk(), mk());
    let (a_addr, _a_stop) = spawn_replica_traced(Some(a_tr));
    let (b_addr, _b_stop) = spawn_replica_traced(Some(b_tr));
    let (proxy_addr, armed) = spawn_chaos_proxy(a_addr.clone(), 7);

    let dir = std::env::temp_dir().join(format!("hla_cluster_obs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("events.jsonl");
    std::fs::remove_file(&journal).ok();
    let fe = Arc::new(
        Frontend::new(FrontendCfg {
            replica_addrs: vec![proxy_addr, b_addr.clone()],
            policy: RoutePolicy::RoundRobin,
            health_interval: Duration::from_millis(100),
            io_timeout: Duration::from_millis(500),
        })
        .with_observability(Some(r_tr), Some(EventLog::with_journal(&journal).unwrap())),
    );
    let (fe_addr, _fe_stop) = spawn_frontend_arc(fe.clone());

    let sampler = "\"temperature\": 0,";
    let turn1 = request(&fe_addr, &turn1_line(70, sampler));
    assert!(turn1.last().unwrap().contains("\"done\""), "{turn1:?}");
    armed.store(true, Ordering::Relaxed);
    let turn2 = request(&fe_addr, &turn2_line(70, sampler));
    assert!(turn2.last().unwrap().contains("\"done\""), "{turn2:?}");
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "exactly one mid-stream failover");

    // ONE stitched trace: every ring pulled over the wire — the router
    // answers `trace_export` itself, the replicas via the control plane
    let pull = |addr: &str| {
        let export = Client::connect(addr).unwrap().trace_export().unwrap();
        ProcessTrace::from_export(&export).unwrap()
    };
    let procs = vec![pull(&fe_addr), pull(&a_addr), pull(&b_addr)];
    let out = dir.join("stitched.json");
    write_stitched(&out, &procs).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    for e in evs {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        assert!(["X", "i", "M", "s", "f"].contains(&ph), "Perfetto-unknown phase {ph}");
        if ph == "X" {
            assert!(e.get("dur").and_then(Json::as_f64).is_some(), "complete spans need dur");
        }
        assert!(e.get("pid").and_then(Json::as_f64).is_some(), "every event needs a pid");
    }
    // the failover is an instant event on the router track, keyed by the
    // minted trace id of the interrupted request
    let failover = evs
        .iter()
        .find(|e| e.get("name").and_then(Json::as_str) == Some("failover"))
        .expect("failover instant event in the stitched trace");
    assert_eq!(failover.get("ph").and_then(Json::as_str), Some("i"));
    assert_eq!(failover.get("pid").and_then(Json::as_f64), Some(0.0));
    let trace_id = failover.path("args.request").and_then(Json::as_str).unwrap().to_string();
    assert_ne!(trace_id, format!("{:016x}", 0u64), "failover must carry a real trace id");
    // that id spans pid 0 (the relay) and BOTH replica pids: the doomed
    // home admitted it, the survivor admitted the replay
    let pids_with_id: std::collections::BTreeSet<u64> = evs
        .iter()
        .filter(|e| e.path("args.request").and_then(Json::as_str) == Some(trace_id.as_str()))
        .map(|e| e.get("pid").and_then(Json::as_f64).unwrap() as u64)
        .collect();
    assert!(pids_with_id.contains(&0), "the router relay span must carry the trace id");
    assert!(
        pids_with_id.iter().filter(|p| **p > 0).count() >= 2,
        "spans from >= 2 replica pids must share the trace id, got {pids_with_id:?}"
    );

    // the journal holds the ordered failover sequence (other events —
    // register, detach — may interleave; the order of these five may not)
    let kinds: Vec<String> = std::fs::read_to_string(&journal)
        .unwrap()
        .lines()
        .map(|l| {
            Json::parse(l).unwrap().get("kind").and_then(Json::as_str).unwrap().to_string()
        })
        .collect();
    let mut want = vec!["strike", "dead", "failover_begin", "attach", "failover_end"];
    for k in &kinds {
        if !want.is_empty() && k == want[0] {
            want.remove(0);
        }
    }
    assert!(
        want.is_empty(),
        "journal missing the ordered failover sequence (still want {want:?}) in {kinds:?}"
    );

    // the same ring answers over the wire as {"events": N}
    let ev_reply = Json::parse(&admin(&fe_addr, "{\"events\": 64}")).unwrap();
    let listed = ev_reply.get("events").and_then(Json::as_arr).unwrap();
    assert!(!listed.is_empty(), "the in-memory ring must answer the wire query");
    assert!(
        ev_reply.get("count").and_then(Json::as_f64).unwrap() >= listed.len() as f64,
        "count is the lifetime total"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Process-level smoke: real processes, real SIGKILL.  Opt-in via
// HLA_CLUSTER_SMOKE=1 (CI runs it; plain `cargo test` skips to stay hermetic).
// ---------------------------------------------------------------------------

/// Spawn an `hla` subcommand and wait for its "listening on ADDR" line.
fn spawn_hla(args: &[&str]) -> (std::process::Child, String) {
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_hla"))
        .args(args)
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawning hla");
    let stdout = child.stdout.take().unwrap();
    let mut reader = BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    while reader.read_line(&mut line).unwrap_or(0) > 0 {
        if let Some(a) = line.trim().strip_prefix("listening on ") {
            addr = Some(a.to_string());
            break;
        }
        line.clear();
    }
    let addr = addr.unwrap_or_else(|| {
        let _ = child.kill();
        panic!("child never printed its listen address");
    });
    // keep the pipe drained so the child never blocks on a full stdout
    std::thread::spawn(move || {
        let mut sink = String::new();
        while reader.read_line(&mut sink).unwrap_or(0) > 0 {
            sink.clear();
        }
    });
    (child, addr)
}

#[test]
fn process_level_failover_smoke() {
    if std::env::var("HLA_CLUSTER_SMOKE").as_deref() != Ok("1") {
        eprintln!("skipping process-level smoke (set HLA_CLUSTER_SMOKE=1 to run)");
        return;
    }
    let fixture_args =
        ["serve", "--fixture", "true", "--seed", "7", "--addr", "127.0.0.1:0"];
    // reference: one uninterrupted replica process spoken to directly
    let (mut ref_child, ref_addr) = spawn_hla(&fixture_args);
    let sampler = "\"temperature\": 1.0, \"seed\": 5,";
    let ref_turn1 = request(&ref_addr, &turn1_line(91, sampler));
    let ref_turn2 = request(&ref_addr, &turn2_line(91, sampler));

    // the fleet: two replica processes plus the router process
    let (mut a, a_addr) = spawn_hla(&fixture_args);
    let (mut b, b_addr) = spawn_hla(&fixture_args);
    let (mut router, fe_addr) = spawn_hla(&[
        "router",
        "--addr",
        "127.0.0.1:0",
        "--replicas",
        &format!("{a_addr},{b_addr}"),
        "--route",
        "round-robin",
        "--health-interval",
        "0.2",
    ]);

    let turn1 = request(&fe_addr, &turn1_line(91, sampler));
    assert_eq!(turn1, ref_turn1, "routed turn diverged from the direct reference");

    // SIGKILL the session's home (round-robin pinned it to replica A),
    // then resume: the router must discover the death at relay time,
    // re-attach the parked snapshot to B, and replay byte-identically
    a.kill().expect("killing replica A");
    let _ = a.wait();
    let turn2 = request(&fe_addr, &turn2_line(91, sampler));
    assert_eq!(turn2, ref_turn2, "post-SIGKILL resume is not byte-identical");

    let _ = router.kill();
    let _ = b.kill();
    let _ = ref_child.kill();
    let _ = router.wait();
    let _ = b.wait();
    let _ = ref_child.wait();
}
