//! The committed perf trajectory stays loadable: every `BENCH_*.json`
//! at the repo root must validate against schema `hla-bench/1`.
//!
//! This is the reader-side half of the contract `bench::report` writes
//! under — a bench that emits a malformed or NaN-bearing report fails
//! here (and in CI) instead of silently rotting the trajectory.

use hla::bench::report::{load, validate, BENCH_SCHEMA};
use hla::util::json::Json;

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn committed_bench_reports_validate() {
    let mut found = vec![];
    for entry in std::fs::read_dir(repo_root()).unwrap() {
        let path = entry.unwrap().path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            let j = load(&path).unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert_eq!(j.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA), "{name}");
            found.push(name.to_string());
        }
    }
    // the serving, observability, cluster, roofline, and interleaving
    // trajectories ship with the repo
    for want in [
        "BENCH_e8.json",
        "BENCH_e18.json",
        "BENCH_e19.json",
        "BENCH_e20.json",
        "BENCH_e21.json",
        "BENCH_e22.json",
    ] {
        assert!(found.iter().any(|n| n == want), "missing {want} (found {found:?})");
    }
}

#[test]
fn validator_rejects_what_ci_must_catch() {
    // the failure modes the CI gate exists for: truncated writes, NaN
    // metrics, schema drift
    assert!(validate(&Json::parse("{}").unwrap()).is_err());
    let nan = r#"{"schema": "hla-bench/1", "bench": "x", "title": "t",
                  "created_unix_s": 1, "cases": [{"name": "c", "metrics": {"m": 1}}]}"#;
    let mut j = Json::parse(nan).unwrap();
    validate(&j).unwrap();
    // surgically corrupt one metric to a non-finite value
    if let Json::Obj(m) = &mut j {
        if let Some(Json::Arr(cases)) = m.get_mut("cases") {
            if let Json::Obj(c) = &mut cases[0] {
                if let Some(Json::Obj(metrics)) = c.get_mut("metrics") {
                    metrics.insert("m".into(), Json::Num(f64::NAN));
                }
            }
        }
    }
    assert!(validate(&j).is_err(), "NaN metric must fail validation");
    // schema drift
    let drifted = nan.replace("hla-bench/1", "hla-bench/2");
    assert!(validate(&Json::parse(&drifted).unwrap()).is_err());
}
