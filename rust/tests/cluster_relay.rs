//! Relay-path regression tests against hand-rolled fake replicas.
//!
//! `cluster_failover.rs` pins the happy failover path byte-for-byte on
//! real fixture engines; this file pins the *policy* of the relay loop —
//! which failures trigger failover and which must not — using scripted
//! TCP replicas so each scenario is deterministic:
//!
//! * a client that disconnects mid-stream must NOT mark the (healthy)
//!   replica dead or count as a failover — otherwise every routine
//!   disconnect would cascade sessions around the fleet and could mark
//!   every replica dead;
//! * a failover replay must never duplicate non-token reply lines (the
//!   suppression prefix counts every non-terminal line, not just tokens);
//! * a resume whose snapshot cannot follow it to a survivor — desk empty,
//!   or the survivor silently degrades to a fresh lane — must surface an
//!   error, never splice a fresh tail onto the already-delivered prefix;
//! * both replica streaming modes relay unchanged: per-token lines are
//!   forwarded as they arrive (and count toward the failover suppression
//!   prefix), while a `"stream": false` request produces exactly one
//!   terminal line — with nothing delivered before it, a failover replay
//!   suppresses nothing.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hla::cluster::{serve_frontend, Frontend, FrontendCfg};
use hla::coordinator::router::RoutePolicy;

/// A non-terminal, non-token reply line (a "future protocol extension"
/// as the relay sees it).
const NOTE: &str = "{\"note\":\"keepalive\"}";
/// A session-less terminal line (no "resumed" field — exactly what a
/// lane that silently degraded to fresh would report at best).
const DONE: &str = "{\"done\":true,\"finish\":\"length\",\"n\":4}";

fn token_line(i: usize) -> String {
    format!("{{\"text\":\"t\",\"token\":{i}}}")
}

/// What a scripted replica does with one generation request.
#[derive(Clone, Copy)]
enum Gen {
    /// NOTE, `n` token lines, then [`DONE`].
    Full(usize),
    /// NOTE, `n` token lines, then drop the socket — a mid-stream death
    /// as the front-end sees it.
    Cut(usize),
    /// Token lines forever, no terminal — guarantees the *downstream*
    /// write is what fails when the client walks away.
    Flood,
}

#[derive(Clone, Copy)]
struct FakeCfg {
    /// Behavior for plain generation requests.
    gen: Gen,
    /// Behavior for `"resume": true` requests.
    resume: Gen,
    /// `detach_session` replies with a stub snapshot (true) or an error
    /// (false — the desk never gets a copy, narrowing failover cover).
    detach_ok: bool,
}

/// A scripted replica: answers the control plane like a real one
/// (register / health / detach / attach) and runs the configured [`Gen`]
/// script for generation requests.
fn spawn_fake_replica(cfg: FakeCfg) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { return };
            std::thread::spawn(move || handle_fake_conn(stream, cfg));
        }
    });
    addr
}

fn fake(gen: Gen, resume: Gen, detach_ok: bool) -> String {
    spawn_fake_replica(FakeCfg { gen, resume, detach_ok })
}

/// A replica that registers once and then vanishes: its listener accepts
/// exactly one connection (the front-end's startup `register` handshake)
/// and then closes, so every later dial is refused — the shape of a
/// replica that crashed between the health checker's probes.
fn spawn_vanishing_replica() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        if let Ok((stream, _)) = listener.accept() {
            handle_fake_conn(
                stream,
                FakeCfg { gen: Gen::Full(1), resume: Gen::Full(1), detach_ok: false },
            );
        }
    });
    addr
}

fn handle_fake_conn(stream: TcpStream, cfg: FakeCfg) {
    let _ = stream.set_nodelay(true);
    let Ok(clone) = stream.try_clone() else { return };
    let mut reader = BufReader::new(clone);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        if line.contains("\"register\"") {
            let reply = "{\"cfg\":\"fake\",\"fingerprint\":\"00000000000000ff\",\
                         \"ok\":true,\"state_bytes\":0}";
            let _ = writeln!(writer, "{reply}");
        } else if line.contains("\"health\"") {
            let _ = writeln!(writer, "{{\"in_flight\":0,\"ok\":true}}");
        } else if line.contains("\"detach_session\"") {
            if cfg.detach_ok {
                let _ = writeln!(writer, "{{\"ok\":true,\"session\":5,\"snapshot\":\"AAAA\"}}");
            } else {
                let _ = writeln!(writer, "{{\"error\":\"detach refused\"}}");
            }
        } else if line.contains("\"attach_session\"") {
            let _ = writeln!(writer, "{{\"ok\":true,\"session\":5}}");
        } else if line.contains("\"stats\"") {
            let _ = writeln!(writer, "{{\"replicas\":1,\"stats\":{{\"tokens_out\":4}}}}");
        } else if line.contains("\"prompt\"") {
            let gen = if line.contains("\"resume\"") { cfg.resume } else { cfg.gen };
            if line.contains("\"stream\": false") {
                // buffered mode: a replica emits no non-terminal lines —
                // the whole completion on one line, or (the scripted
                // death) nothing at all before the socket drops
                match gen {
                    Gen::Full(n) => {
                        let toks: Vec<String> = (1..=n).map(|i| i.to_string()).collect();
                        let _ = writeln!(
                            writer,
                            "{{\"done\":true,\"finish\":\"length\",\"tokens\":[{}]}}",
                            toks.join(",")
                        );
                    }
                    Gen::Cut(_) | Gen::Flood => return,
                }
                continue;
            }
            if run_gen(&mut writer, gen).is_err() {
                return;
            }
            if matches!(gen, Gen::Cut(_)) {
                return; // drop the connection: the scripted crash
            }
        } else {
            let _ = writeln!(writer, "{{\"error\":\"unknown request\"}}");
        }
    }
}

fn run_gen(writer: &mut TcpStream, gen: Gen) -> std::io::Result<()> {
    match gen {
        Gen::Full(n) => {
            writeln!(writer, "{NOTE}")?;
            for i in 1..=n {
                writeln!(writer, "{}", token_line(i))?;
            }
            writeln!(writer, "{DONE}")
        }
        Gen::Cut(n) => {
            writeln!(writer, "{NOTE}")?;
            for i in 1..=n {
                writeln!(writer, "{}", token_line(i))?;
            }
            Ok(())
        }
        Gen::Flood => {
            let mut i = 0usize;
            loop {
                i += 1;
                writeln!(writer, "{}", token_line(i))?;
            }
        }
    }
}

/// LeastLoaded ties break to the lowest index, so with an idle fleet the
/// first replica is always picked — the scripts rely on that. The health
/// interval is set far past the test horizon: a fake replica's listener
/// keeps answering probes after a scripted mid-stream death, so a running
/// checker could revive it and perturb the scripted routing.
fn spawn_fake_frontend(replicas: Vec<String>) -> (String, Arc<Frontend>, Arc<AtomicBool>) {
    let fe = Arc::new(Frontend::new(FrontendCfg {
        replica_addrs: replicas,
        policy: RoutePolicy::LeastLoaded,
        health_interval: Duration::from_secs(60),
        io_timeout: Duration::from_millis(500),
    }));
    let stop = Arc::new(AtomicBool::new(false));
    let (atx, arx) = mpsc::channel();
    let fe2 = fe.clone();
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        serve_frontend("127.0.0.1:0", fe2, stop2, |a| {
            atx.send(a.to_string()).unwrap();
        })
        .unwrap();
    });
    (arx.recv().unwrap(), fe, stop)
}

/// One request over a fresh connection; returns the raw reply lines up to
/// and including the terminal (`done`/`error`) line.
fn request(addr: &str, line: &str) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writeln!(writer, "{line}").unwrap();
    let mut reader = BufReader::new(stream);
    let mut lines = Vec::new();
    let mut buf = String::new();
    loop {
        buf.clear();
        let n = reader.read_line(&mut buf).unwrap();
        assert!(n > 0, "connection closed before a terminal line (got {lines:?})");
        let l = buf.trim_end().to_string();
        let terminal = l.contains("\"done\"") || l.contains("\"error\"");
        lines.push(l);
        if terminal {
            return lines;
        }
    }
}

/// One admin request (stats / events) over a fresh connection: admin
/// replies are a single line with no `done`/`error` terminal marker.
fn admin(addr: &str, line: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    writeln!(writer, "{line}").unwrap();
    let mut buf = String::new();
    BufReader::new(stream).read_line(&mut buf).unwrap();
    buf
}

#[test]
fn stats_fanout_names_unreachable_replicas_instead_of_dropping_them() {
    let a = fake(Gen::Full(4), Gen::Full(4), false);
    let gone = spawn_vanishing_replica();
    let (fe_addr, _fe, _stop) = spawn_fake_frontend(vec![a, gone.clone()]);
    // one generation so the live replica's fake snapshot is plausible
    let lines = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 4}");
    assert!(lines.last().unwrap().contains("\"done\""), "{lines:?}");
    let reply = admin(&fe_addr, "{\"stats\": true}");
    assert!(reply.contains("\"replicas\":1"), "only the live replica merges: {reply}");
    assert!(reply.contains("\"tokens_out\":4"), "the live snapshot still merges: {reply}");
    assert!(
        reply.contains("\"skipped\"") && reply.contains(&gone),
        "the skipped array must name the unreachable replica instead of \
         silently narrowing the merge: {reply}"
    );
    assert!(reply.contains("\"router\""), "the reply carries the router metrics plane: {reply}");
}

#[test]
fn client_disconnect_does_not_poison_fleet_liveness() {
    let a = fake(Gen::Flood, Gen::Flood, false);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a]);
    {
        let stream = TcpStream::connect(&fe_addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut writer = stream.try_clone().unwrap();
        writeln!(writer, "{{\"prompt\": \"abandoned\", \"max_tokens\": 8}}").unwrap();
        let mut reader = BufReader::new(stream);
        let mut buf = String::new();
        for _ in 0..2 {
            buf.clear();
            assert!(reader.read_line(&mut buf).unwrap() > 0);
        }
        // dropped here: the client walks away with the stream mid-flight;
        // the flooding replica guarantees the front-end's next writes to
        // this dead socket fail
    }
    std::thread::sleep(Duration::from_millis(500));
    assert_eq!(
        fe.failovers.load(Ordering::Relaxed),
        0,
        "a client disconnect must never be treated as a replica failure"
    );
    assert!(
        fe.registry.replicas[0].is_alive(),
        "the replica served correctly and must stay alive"
    );
}

#[test]
fn failover_replay_never_duplicates_non_token_lines() {
    let a = fake(Gen::Cut(2), Gen::Cut(2), false);
    let b = fake(Gen::Full(4), Gen::Full(4), false);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a, b]);
    // replica 0 dies after NOTE + 2 tokens; the replay on replica 1
    // re-streams from the start and must suppress all three lines the
    // client already holds — NOTE included
    let lines = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 8}");
    let mut expect = vec![NOTE.to_string()];
    expect.extend((1..=4).map(token_line));
    expect.push(DONE.to_string());
    assert_eq!(lines, expect, "replayed stream must deliver every line exactly once");
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "the replica death is one failover");
}

#[test]
fn lost_snapshot_resume_errors_instead_of_splicing() {
    // replica 0 refuses the end-of-turn export, so the desk holds nothing
    // to fail over with; it then dies mid-resume
    let a = fake(Gen::Full(4), Gen::Cut(2), false);
    let b = fake(Gen::Full(4), Gen::Full(4), false);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a, b]);
    let turn1 = request(&fe_addr, "{\"prompt\": \"seed\", \"max_tokens\": 8, \"session\": 5}");
    assert!(turn1.last().unwrap().contains("\"done\""), "{turn1:?}");
    assert_eq!(fe.desk_len(), 0, "the refused export must leave the desk empty");
    let turn2 = request(
        &fe_addr,
        "{\"prompt\": \"more\", \"max_tokens\": 8, \"session\": 5, \"resume\": true}",
    );
    let last = turn2.last().unwrap();
    assert!(
        last.contains("\"error\"") && last.contains("cannot resume"),
        "a resume with no re-attachable snapshot must error, not splice: {turn2:?}"
    );
    assert_eq!(turn2.len(), 4, "NOTE + 2 relayed tokens + the error line: {turn2:?}");
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "the replica death is a real failover");
}

#[test]
fn degraded_resume_on_survivor_errors_instead_of_splicing() {
    // here the snapshot DOES migrate — but the survivor's resume comes
    // back without resumed:true (a silent degrade to a fresh lane), so
    // the spliced stream would not be byte-identical
    let a = fake(Gen::Full(4), Gen::Cut(2), true);
    let b = fake(Gen::Full(4), Gen::Full(4), true);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a, b]);
    let turn1 = request(&fe_addr, "{\"prompt\": \"seed\", \"max_tokens\": 8, \"session\": 5}");
    assert!(turn1.last().unwrap().contains("\"done\""), "{turn1:?}");
    assert_eq!(fe.desk_len(), 1, "the exported snapshot must be parked at the desk");
    let turn2 = request(
        &fe_addr,
        "{\"prompt\": \"more\", \"max_tokens\": 8, \"session\": 5, \"resume\": true}",
    );
    let last = turn2.last().unwrap();
    assert!(
        last.contains("\"error\"") && last.contains("did not resume"),
        "a degraded replay must error, not masquerade as a resumed stream: {turn2:?}"
    );
    assert_eq!(turn2.len(), 6, "NOTE + 2 + 2 relayed tokens + the error line: {turn2:?}");
    assert_eq!(fe.migrations.load(Ordering::Relaxed), 1, "the snapshot did migrate first");
}

#[test]
fn streamed_relay_is_unchanged_by_an_explicit_stream_true() {
    // `"stream": true` is the wire default spelled out; the router must
    // relay the identical per-token line sequence either way
    let a = fake(Gen::Full(4), Gen::Full(4), false);
    let (fe_addr, _fe, _stop) = spawn_fake_frontend(vec![a]);
    let explicit = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 4, \"stream\": true}");
    let implicit = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 4}");
    assert_eq!(explicit, implicit, "explicit stream:true must not change the relay");
    let mut expect = vec![NOTE.to_string()];
    expect.extend((1..=4).map(token_line));
    expect.push(DONE.to_string());
    assert_eq!(explicit, expect, "per-token lines relay exactly as the replica sent them");
}

#[test]
fn buffered_replies_relay_as_a_single_terminal_line() {
    // the router never needs to know the mode: a buffered completion is
    // just a terminal line, relayed untouched — no token-line synthesis,
    // no duplication
    let a = fake(Gen::Full(4), Gen::Full(4), false);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a]);
    let lines = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 4, \"stream\": false}");
    assert_eq!(lines.len(), 1, "buffered mode is exactly one terminal line: {lines:?}");
    assert!(
        lines[0].contains("\"done\":true") && lines[0].contains("\"tokens\":[1,2,3,4]"),
        "the buffered payload must pass through unchanged: {}",
        lines[0]
    );
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 0);
}

#[test]
fn buffered_failover_replays_to_exactly_one_terminal_line() {
    // replica 0 dies before its buffered line, so the client holds a
    // zero-line prefix: the replay on replica 1 suppresses nothing and
    // the client still sees exactly one terminal line
    let a = fake(Gen::Cut(2), Gen::Cut(2), false);
    let b = fake(Gen::Full(4), Gen::Full(4), false);
    let (fe_addr, fe, _stop) = spawn_fake_frontend(vec![a, b]);
    let lines = request(&fe_addr, "{\"prompt\": \"x\", \"max_tokens\": 4, \"stream\": false}");
    assert_eq!(lines.len(), 1, "one replayed terminal line, zero suppressed: {lines:?}");
    assert!(lines[0].contains("\"tokens\":[1,2,3,4]"), "{}", lines[0]);
    assert_eq!(fe.failovers.load(Ordering::Relaxed), 1, "the replica death is one failover");
}
