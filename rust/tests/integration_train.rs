//! Integration: the AOT train_step loop learns (loss drops below the
//! uniform baseline) and checkpoints round-trip into a servable engine.

use hla::runtime::Engine;
use hla::train::{checkpoint, train, uniform_loss, LrSchedule, TrainOpts};

fn engine() -> Option<Engine> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(dir).join("manifest.json").exists() {
        return None;
    }
    Some(Engine::open(dir).unwrap())
}

#[test]
fn micro_training_reduces_loss() {
    let Some(engine) = engine() else { return };
    let steps = 40;
    let opts = TrainOpts {
        cfg_name: "micro".into(),
        steps,
        lr: LrSchedule { peak: 3e-3, warmup: 5, total: steps, floor: 1e-4 },
        seed: 0,
        log_every: 10,
        checkpoint: None,
        corpus_bytes: 1 << 16,
    };
    let (curve, _params) = train(&engine, &opts).unwrap();
    let first = curve.first().unwrap().loss;
    let last = curve.last().unwrap().loss;
    let baseline = uniform_loss(256);
    assert!(first > 3.0, "initial loss {first} suspiciously low");
    assert!(last < first - 0.8, "no learning: {first} -> {last}");
    assert!(last < baseline, "final loss {last} above uniform {baseline}");
}

#[test]
fn checkpoint_roundtrips_through_engine() {
    let Some(engine) = engine() else { return };
    let path = std::env::temp_dir().join(format!("hla-int-ckpt-{}", std::process::id()));
    let opts = TrainOpts {
        cfg_name: "micro".into(),
        steps: 6,
        lr: LrSchedule { peak: 1e-3, warmup: 2, total: 6, floor: 1e-4 },
        seed: 1,
        log_every: 3,
        checkpoint: Some(path.to_str().unwrap().into()),
        corpus_bytes: 1 << 15,
    };
    let (_, params) = train(&engine, &opts).unwrap();
    let (meta, tensors) = checkpoint::load(&path).unwrap();
    assert_eq!(meta.config, "micro");
    assert_eq!(meta.step, 6);
    assert_eq!(tensors.len(), params.len());
    // loaded params evaluate identically to in-memory params
    let lits = checkpoint::tensors_to_literals(&tensors).unwrap();
    let a = hla::train::evaluate(&engine, "micro", &params, 2, 42).unwrap();
    let b = hla::train::evaluate(&engine, "micro", &lits, 2, 42).unwrap();
    assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    std::fs::remove_file(path).unwrap();
}

#[test]
fn hla2_and_linear_both_train_on_micro() {
    // E10 shape check at micro scale: both mixers learn on the same corpus.
    let Some(engine) = engine() else { return };
    let mut finals = vec![];
    for cfg in ["micro", "micro-linear"] {
        let steps = 25;
        let opts = TrainOpts {
            cfg_name: cfg.into(),
            steps,
            lr: LrSchedule { peak: 3e-3, warmup: 5, total: steps, floor: 1e-4 },
            seed: 2,
            log_every: 25,
            checkpoint: None,
            corpus_bytes: 1 << 15,
        };
        let (curve, _) = train(&engine, &opts).unwrap();
        finals.push((cfg, curve.last().unwrap().loss));
    }
    for (cfg, loss) in &finals {
        assert!(*loss < uniform_loss(256), "{cfg} failed to beat uniform: {loss}");
    }
}
