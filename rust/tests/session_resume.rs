//! Resume/fork correctness, end-to-end on the pure-Rust decode path (no
//! artifacts needed): generating N tokens, snapshotting, evicting the
//! state, resuming, and generating M more tokens must produce the
//! identical token stream to one uninterrupted N+M-token generation with
//! the same seed — the acceptance bar for the session subsystem.

use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{ModelState, RustModel};
use hla::runtime::Manifest;
use hla::session::{SamplerState, SessionSnapshot, SessionStore, StoreCfg};
use hla::util::rng::Rng;

const CFG_TEMPLATE: &str = r#"{
  "configs": {"t": {"vocab": 64, "d_model": 16, "n_layers": 2,
    "n_heads": 2, "head_dim": 8, "d_ffn": 32, "kv_heads": 2,
    "mixer": "MIXER", "chunk": 8, "gamma": 0.98, "lam": 0.0,
    "norm_mode": "abs", "eps": 1e-6, "n_params": 4000,
    "n_param_tensors": 20, "n_state_tensors": 2,
    "param_paths": [
      ["['embed']", [64, 16]],
      ["['norm_f']", [16]],
      ["['layers'][0]['norm1']", [16]],
      ["['layers'][0]['wq']", [16, 16]],
      ["['layers'][0]['wk']", [16, 16]],
      ["['layers'][0]['wv']", [16, 16]],
      ["['layers'][0]['wo']", [16, 16]],
      ["['layers'][0]['norm2']", [16]],
      ["['layers'][0]['w_gate']", [16, 32]],
      ["['layers'][0]['w_up']", [16, 32]],
      ["['layers'][0]['w_down']", [32, 16]],
      ["['layers'][1]['norm1']", [16]],
      ["['layers'][1]['wq']", [16, 16]],
      ["['layers'][1]['wk']", [16, 16]],
      ["['layers'][1]['wv']", [16, 16]],
      ["['layers'][1]['wo']", [16, 16]],
      ["['layers'][1]['norm2']", [16]],
      ["['layers'][1]['w_gate']", [16, 32]],
      ["['layers'][1]['w_up']", [16, 32]],
      ["['layers'][1]['w_down']", [32, 16]]],
    "state_paths": [["['c']", [2, 1, 2, 8, 8]], ["['m']", [2, 1, 2, 8]]],
    "train_batch": 1, "train_seq": 8, "decode_batch": 1,
    "prefill_len": 8}},
  "artifacts": {}
}"#;

/// Random-weight byte-LM for the given mixer (no artifacts involved).
fn build_model(mixer: &str, seed: u64) -> RustModel {
    let json = CFG_TEMPLATE.replace("MIXER", mixer);
    let cfg = Manifest::parse(&json).unwrap().configs["t"].clone();
    let mut rng = Rng::new(seed);
    let tensors: Vec<hla::tensor::Tensor> = cfg
        .param_paths
        .iter()
        .map(|(_, shape)| {
            let mut t = hla::tensor::Tensor::zeros(shape);
            if shape.len() == 1 {
                // norm weights sit near 1 so activations keep their scale
                for x in &mut t.data {
                    *x = 1.0 + 0.1 * rng.normal() as f32;
                }
            } else {
                rng.fill_normal(&mut t.data, 0.3);
            }
            t
        })
        .collect();
    RustModel::from_tensors(&cfg, &tensors).unwrap()
}

/// Feed `input` then sample, n times — the decode loop of a single lane.
fn generate(
    model: &RustModel,
    state: &mut ModelState,
    sampler: &mut Sampler,
    first_input: u8,
    n: usize,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    let mut input = first_input;
    for _ in 0..n {
        let logits = model.decode_step(state, input);
        input = sampler.sample(&logits) as u8;
        out.push(input);
    }
    out
}

/// Run the prompt through the state; returns the last prompt byte (the
/// first decode input, matching the coordinator's decode-as-prefill).
fn prefill(model: &RustModel, state: &mut ModelState, prompt: &[u8]) -> u8 {
    for &t in &prompt[..prompt.len() - 1] {
        model.decode_step(state, t);
    }
    *prompt.last().unwrap()
}

fn snapshot_of(
    id: u64,
    model: &RustModel,
    state: &ModelState,
    sampler: &Sampler,
    last_token: u8,
    tokens: u64,
) -> SessionSnapshot {
    SessionSnapshot {
        id,
        cfg_name: model.cfg.name.clone(),
        tokens_generated: tokens,
        last_token,
        sampler: SamplerState::capture(sampler),
        state: state.to_tensors().unwrap(),
    }
}

#[test]
fn resume_reproduces_uninterrupted_stream_for_every_mixer() {
    for mixer in ["hla2", "ahla", "hla3", "linear"] {
        let model = build_model(mixer, 17);
        let mut state = ModelState::new(&model.cfg);
        let mut sampler =
            Sampler::new(SamplerCfg { temperature: 1.0, top_k: 0, seed: 13 });
        let last_prompt = prefill(&model, &mut state, b"higher-order linear attention");

        // N tokens, then snapshot through the store's *disk* tier: put the
        // session, force an LRU spill, and claim it back from the file
        let (n, m) = (12, 10);
        let first = generate(&model, &mut state, &mut sampler, last_prompt, n);
        let last = *first.last().unwrap();
        let snap = snapshot_of(1, &model, &state, &sampler, last, n as u64);

        let dir = std::env::temp_dir()
            .join(format!("hla-resume-{mixer}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SessionStore::new(StoreCfg { capacity: 1, spill_dir: Some(dir.clone()) });
        store.put(snap.clone());
        store.put(snap.fork(2, None)); // evicts session 1 to disk

        // the uninterrupted reference: M more tokens, no snapshot involved
        let uninterrupted = generate(&model, &mut state, &mut sampler, last, m);

        // evict the "lane" (drop state entirely), resume from the store
        drop(state);
        drop(sampler);
        let restored = store.claim(1, Some(&model.cfg.name)).expect("disk-tier resume");
        assert_eq!(restored.tokens_generated, n as u64, "{mixer}");
        let mut state2 = ModelState::new(&model.cfg);
        state2.load_tensors(&restored.state).unwrap();
        let mut sampler2 = restored.sampler.rebuild();
        let resumed = generate(&model, &mut state2, &mut sampler2, restored.last_token, m);

        assert_eq!(
            resumed, uninterrupted,
            "{mixer}: resumed stream diverged from the uninterrupted one"
        );
        assert_eq!(store.stats().spill_loads, 1, "{mixer}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn forks_share_the_prefix_and_diverge_only_by_seed() {
    let model = build_model("hla2", 23);
    let mut state = ModelState::new(&model.cfg);
    // hot temperature flattens the distribution so differently-seeded
    // forks are effectively guaranteed to diverge within a few tokens
    let mut sampler = Sampler::new(SamplerCfg { temperature: 2.0, top_k: 0, seed: 5 });
    let last_prompt = prefill(&model, &mut state, b"shared prompt prefix, forked N ways");
    let first = generate(&model, &mut state, &mut sampler, last_prompt, 8);
    let last = *first.last().unwrap();
    let snap = snapshot_of(7, &model, &state, &sampler, last, 8);

    let store = SessionStore::in_memory(16);
    store.put(snap.clone());
    store.fork(7, 70, Some(111)).unwrap();
    store.fork(7, 71, Some(222)).unwrap();
    store.fork(7, 72, Some(111)).unwrap(); // same seed as 70

    let continue_fork = |id: u64| {
        let s = store.claim(id, Some(&model.cfg.name)).unwrap();
        // forks carry the identical prefix state...
        assert_eq!(s.state, snap.state, "fork {id} state differs");
        assert_eq!(s.last_token, snap.last_token);
        let mut st = ModelState::new(&model.cfg);
        st.load_tensors(&s.state).unwrap();
        let mut sp = s.sampler.rebuild();
        generate(&model, &mut st, &mut sp, s.last_token, 16)
    };
    let a = continue_fork(70);
    let b = continue_fork(71);
    let c = continue_fork(72);
    // ...and diverge exactly by their sampler seeds
    assert_ne!(a, b, "different seeds must diverge");
    assert_eq!(a, c, "same seed must produce the same continuation");

    // an unseeded fork continues the parent's exact stream
    store.fork(7, 73, None).unwrap();
    let mut cont_state = ModelState::new(&model.cfg);
    let parent = store.claim(7, None).unwrap();
    cont_state.load_tensors(&parent.state).unwrap();
    let mut cont_sampler = parent.sampler.rebuild();
    let parent_cont =
        generate(&model, &mut cont_state, &mut cont_sampler, parent.last_token, 16);
    let unseeded = continue_fork(73);
    assert_eq!(unseeded, parent_cont);
}

#[test]
fn snapshot_survives_bytes_roundtrip_with_live_state() {
    let model = build_model("hla3", 31);
    let mut state = ModelState::new(&model.cfg);
    let mut sampler = Sampler::new(SamplerCfg { temperature: 0.7, top_k: 8, seed: 2 });
    let last_prompt = prefill(&model, &mut state, b"bytes on the wire");
    let toks = generate(&model, &mut state, &mut sampler, last_prompt, 6);
    let snap = snapshot_of(3, &model, &state, &sampler, *toks.last().unwrap(), 6);
    let back = SessionSnapshot::from_bytes(&snap.to_bytes()).unwrap();
    assert_eq!(back, snap);
}
