//! Integration: continuous-batching coordinator over the micro artifacts.

use std::sync::mpsc;

use hla::coordinator::{collect_tokens, spawn_engine, FinishReason, GenRequest, SchedPolicy};
use hla::model::sampler::SamplerCfg;

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

#[test]
fn completes_more_requests_than_lanes() {
    if !have_artifacts() {
        return;
    }
    // micro has decode_batch = 2; submit 5 requests -> continuous batching
    let (tx, handle) = spawn_engine(artifacts(), "micro".into(), SchedPolicy::PrefillFirst, 0);
    let mut rxs = vec![];
    for i in 0..5u64 {
        let (etx, erx) = mpsc::channel();
        let req = GenRequest::new(
            i,
            format!("request number {i} says ").into_bytes(),
            6 + i as usize,
            SamplerCfg::greedy(),
            etx,
        );
        tx.send(req).unwrap();
        rxs.push((i, erx));
    }
    drop(tx);
    for (i, erx) in rxs {
        let (tokens, finish) = collect_tokens(&erx);
        assert_eq!(tokens.len(), 6 + i as usize, "request {i}");
        assert_eq!(finish, Some(FinishReason::Length));
    }
    let stats = handle.join().unwrap().unwrap();
    assert_eq!(stats.completed, 5);
    assert!(stats.tokens_out >= 6 + 7 + 8 + 9 + 10);
    assert!(stats.lane_occupancy > 0.3, "occupancy {}", stats.lane_occupancy);
}

#[test]
fn greedy_generation_is_deterministic_across_batching() {
    if !have_artifacts() {
        return;
    }
    // Same prompt alone vs batched with other traffic must produce the same
    // greedy tokens: lanes are state-isolated (the whole point of the pool).
    let run = |with_noise: bool| -> Vec<u8> {
        let (tx, handle) =
            spawn_engine(artifacts(), "micro".into(), SchedPolicy::PrefillFirst, 0);
        let (etx, erx) = mpsc::channel();
        tx.send(GenRequest::new(
            1,
            b"the quick brown fox".to_vec(),
            12,
            SamplerCfg::greedy(),
            etx,
        ))
        .unwrap();
        if with_noise {
            let (ntx, _nrx) = mpsc::channel();
            tx.send(GenRequest::new(
                2,
                b"completely different interference prompt!".to_vec(),
                20,
                SamplerCfg { temperature: 1.0, top_k: 0, seed: 99 },
                ntx,
            ))
            .unwrap();
        }
        drop(tx);
        let (tokens, _) = collect_tokens(&erx);
        handle.join().unwrap().unwrap();
        tokens
    };
    let alone = run(false);
    let batched = run(true);
    assert_eq!(alone, batched, "lane isolation violated");
}

#[test]
fn decode_first_policy_serializes_admissions() {
    if !have_artifacts() {
        return;
    }
    let (tx, handle) = spawn_engine(artifacts(), "micro".into(), SchedPolicy::DecodeFirst, 0);
    let mut rxs = vec![];
    for i in 0..3u64 {
        let (etx, erx) = mpsc::channel();
        tx.send(GenRequest::new(i, vec![b'a' + i as u8; 3], 4, SamplerCfg::greedy(), etx))
            .unwrap();
        rxs.push(erx);
    }
    drop(tx);
    for erx in rxs {
        let (tokens, finish) = collect_tokens(&erx);
        assert_eq!(tokens.len(), 4);
        assert_eq!(finish, Some(FinishReason::Length));
    }
    handle.join().unwrap().unwrap();
}

#[test]
fn empty_prompt_and_long_prompt_edge_cases() {
    if !have_artifacts() {
        return;
    }
    let (tx, handle) = spawn_engine(artifacts(), "micro".into(), SchedPolicy::Hybrid(1), 0);
    // empty prompt -> padded to one token
    let (etx1, erx1) = mpsc::channel();
    tx.send(GenRequest::new(1, vec![], 3, SamplerCfg::greedy(), etx1)).unwrap();
    // long prompt (crosses many steps of decode-as-prefill)
    let (etx2, erx2) = mpsc::channel();
    tx.send(GenRequest::new(2, vec![b'x'; 100], 3, SamplerCfg::greedy(), etx2)).unwrap();
    drop(tx);
    let (t1, f1) = collect_tokens(&erx1);
    let (t2, f2) = collect_tokens(&erx2);
    assert_eq!((t1.len(), f1), (3, Some(FinishReason::Length)));
    assert_eq!((t2.len(), f2), (3, Some(FinishReason::Length)));
    handle.join().unwrap().unwrap();
}

#[test]
fn all_micro_mixer_variants_serve() {
    if !have_artifacts() {
        return;
    }
    for cfg in ["micro", "micro-ahla", "micro-hla3", "micro-linear", "micro-mq"] {
        let (tx, handle) =
            spawn_engine(artifacts(), cfg.into(), SchedPolicy::PrefillFirst, 1);
        let (etx, erx) = mpsc::channel();
        tx.send(GenRequest::new(1, b"hello".to_vec(), 4, SamplerCfg::greedy(), etx)).unwrap();
        drop(tx);
        let (tokens, finish) = collect_tokens(&erx);
        assert_eq!(tokens.len(), 4, "{cfg}");
        assert_eq!(finish, Some(FinishReason::Length), "{cfg}");
        handle.join().unwrap().unwrap();
    }
}

/// Drive the REAL EngineLoop with bucketing enabled and pin its streams
/// to the fixed-width engine's, token for token — the production wiring
/// (admit's occupied-slot scan, step's slot-routed tokens/logits,
/// apply_switch's slot_of updates) exercised end to end, not the host
/// twin the artifact-free differential suite uses.  Staggered request
/// sizes force admit/finish churn; shrink_after = 1 maximizes repacks.
/// If the artifact dir predates the bucketed emission, set_buckets
/// degrades to fixed width and the assertion still holds (trivially).
#[test]
fn bucketed_engine_streams_match_fixed_width() {
    if !have_artifacts() {
        return;
    }
    use hla::coordinator::{spawn_engine_full, BucketCfg, BucketSpec, EngineOpts};
    let run = |buckets: Option<BucketCfg>| -> Vec<Vec<u8>> {
        let (tx, handle) = spawn_engine_full(
            artifacts(),
            "micro".into(),
            EngineOpts {
                policy: Some(SchedPolicy::Hybrid(1)),
                seed: 0,
                buckets,
                ..Default::default()
            },
        );
        let mut rxs = vec![];
        for i in 0..5u64 {
            let (etx, erx) = mpsc::channel();
            let prompt = format!("bucketed request {i} ").into_bytes();
            tx.send(GenRequest::new(i, prompt, 4 + i as usize, SamplerCfg::greedy(), etx))
                .unwrap();
            rxs.push(erx);
        }
        drop(tx);
        let streams: Vec<Vec<u8>> = rxs
            .iter()
            .map(|erx| {
                let (tokens, finish) = collect_tokens(erx);
                assert_eq!(finish, Some(FinishReason::Length));
                tokens
            })
            .collect();
        let stats = handle.join().unwrap().unwrap();
        assert_eq!(stats.completed, 5);
        streams
    };
    let fixed = run(None);
    let bucketed = run(Some(BucketCfg { spec: BucketSpec::Pow2, shrink_after: 1 }));
    assert_eq!(bucketed, fixed, "bucketed decode must be byte-identical to fixed-width");
}
