//! Snapshot framing under hostile transport — the property suite behind
//! the cluster control plane's claim that a snapshot frame is either
//! delivered intact or rejected, never silently corrupted and never a
//! panic:
//!
//! * round-trips survive arbitrary transport chunking (byte and base64
//!   splits — reassembly is concatenation, framing carries no positional
//!   state);
//! * every truncated prefix is rejected;
//! * every single-bit flip is rejected (CRC-32 detects all 1-bit errors);
//! * corrupted base64 text never yields a valid snapshot.

use hla::session::{SamplerState, SessionSnapshot};
use hla::tensor::Tensor;
use hla::testing::quick;
use hla::util::b64;
use hla::util::rng::Rng;

/// A random but internally consistent snapshot (shapes and payloads
/// agree, so only transport damage can make it invalid).
fn random_snapshot(rng: &mut Rng) -> SessionSnapshot {
    let n_tensors = rng.range(1, 4);
    let state: Vec<Tensor> = (0..n_tensors)
        .map(|_| {
            let rank = rng.range(1, 5);
            let shape: Vec<usize> = (0..rank).map(|_| rng.range(1, 5)).collect();
            let mut t = Tensor::zeros(&shape);
            rng.fill_normal(&mut t.data, 1.0);
            t
        })
        .collect();
    SessionSnapshot {
        id: rng.next_u64(),
        cfg_name: format!("cfg-{}", rng.below(1000)),
        tokens_generated: rng.next_u64() % 1_000_000,
        last_token: rng.below(256) as u8,
        sampler: SamplerState {
            temperature: rng.f32() * 2.0,
            top_k: rng.below(64),
            seed: rng.next_u64(),
            rng_state: rng.next_u64(),
            rng_spare: rng.bool(0.5).then(|| rng.f64()),
        },
        state,
    }
}

#[test]
fn roundtrip_survives_arbitrary_chunked_transport() {
    quick("codec-chunked-roundtrip", 48, |rng, _| {
        let snap = random_snapshot(rng);
        let bytes = snap.to_bytes();

        // byte-level reassembly from random split points
        let mut rejoined = Vec::with_capacity(bytes.len());
        let mut pos = 0;
        while pos < bytes.len() {
            let take = rng.range(1, 17).min(bytes.len() - pos);
            rejoined.extend_from_slice(&bytes[pos..pos + take]);
            pos += take;
        }
        let back = SessionSnapshot::from_bytes(&rejoined)
            .map_err(|e| format!("chunked bytes rejected: {e}"))?;
        if back != snap {
            return Err("byte-chunked roundtrip changed the snapshot".into());
        }

        // base64 transport (the control-plane encoding), split and rejoined
        // as text the way a line-JSON relay would see it
        let text = b64::encode(&bytes);
        let mut retext = String::with_capacity(text.len());
        let mut pos = 0;
        while pos < text.len() {
            let take = rng.range(1, 33).min(text.len() - pos);
            retext.push_str(&text[pos..pos + take]);
            pos += take;
        }
        let decoded = b64::decode(&retext).map_err(|e| format!("b64 reassembly: {e}"))?;
        let back = SessionSnapshot::from_bytes(&decoded)
            .map_err(|e| format!("b64 roundtrip rejected: {e}"))?;
        if back != snap {
            return Err("b64 roundtrip changed the snapshot".into());
        }
        Ok(())
    });
}

#[test]
fn every_truncated_prefix_is_rejected() {
    quick("codec-truncation", 24, |rng, _| {
        let bytes = random_snapshot(rng).to_bytes();
        // a spread of cut points plus the hard edges (empty, sub-CRC,
        // one-short); each must fail cleanly — an Err, never a panic
        let mut cuts = vec![0, 1, 3, 4, bytes.len() - 1];
        for _ in 0..16 {
            cuts.push(rng.below(bytes.len()));
        }
        for cut in cuts {
            if SessionSnapshot::from_bytes(&bytes[..cut]).is_ok() {
                return Err(format!("prefix of {cut}/{} bytes parsed", bytes.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn every_single_bit_flip_is_rejected() {
    quick("codec-bitflip", 24, |rng, _| {
        let bytes = random_snapshot(rng).to_bytes();
        for _ in 0..24 {
            let mut bad = bytes.clone();
            let byte = rng.below(bad.len());
            let bit = rng.below(8) as u8;
            bad[byte] ^= 1 << bit;
            if SessionSnapshot::from_bytes(&bad).is_ok() {
                return Err(format!("bit {bit} of byte {byte}/{} flipped undetected", bytes.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn corrupted_base64_never_yields_a_snapshot() {
    quick("codec-b64-corruption", 24, |rng, _| {
        let text = b64::encode(&random_snapshot(rng).to_bytes());
        let bytes = text.as_bytes();
        for _ in 0..12 {
            let mut bad = bytes.to_vec();
            let i = rng.below(bad.len());
            // rotate within the alphabet so the damage may survive decoding
            // (decode-level rejects are fine too; parse-level must catch
            // whatever gets through)
            bad[i] = match bad[i] {
                b'A'..=b'Y' | b'a'..=b'y' | b'0'..=b'8' => bad[i] + 1,
                b'Z' => b'a',
                b'z' => b'0',
                b'9' => b'+',
                _ => b'A',
            };
            let bad = String::from_utf8(bad).unwrap();
            if let Ok(decoded) = b64::decode(&bad) {
                if SessionSnapshot::from_bytes(&decoded).is_ok() {
                    return Err(format!("corrupt b64 at char {i} parsed as a snapshot"));
                }
            }
        }
        Ok(())
    });
}
