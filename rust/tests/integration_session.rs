//! Integration: session snapshot/resume/fork through the real engine loop
//! and the TCP protocol (requires artifacts, like the other integration
//! suites — each test is a no-op without `artifacts/manifest.json`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use hla::coordinator::router::{RoutePolicy, Router};
use hla::coordinator::{collect_tokens, spawn_engine_with_store, GenRequest, SchedPolicy};
use hla::model::sampler::SamplerCfg;
use hla::server::client::{Client, GenOpts};
use hla::server::serve_sessions;
use hla::session::SessionStore;

fn have_artifacts() -> bool {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json")).exists()
}

fn artifacts() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn sampler() -> SamplerCfg {
    SamplerCfg { temperature: 0.9, top_k: 0, seed: 3 }
}

/// One engine run: submit the given requests sequentially (waiting for
/// each to finish) and return their token streams.
fn run_requests(
    store: Arc<SessionStore>,
    reqs: Vec<(Vec<u8>, usize, Option<u64>, bool)>,
) -> Vec<Vec<u8>> {
    let (tx, handle) = spawn_engine_with_store(
        artifacts(),
        "micro".into(),
        SchedPolicy::PrefillFirst,
        0,
        Some(store),
    );
    let mut streams = vec![];
    for (i, (prompt, max_new, session, resume)) in reqs.into_iter().enumerate() {
        let (etx, erx) = mpsc::channel();
        let mut req = GenRequest::new(i as u64 + 1, prompt, max_new, sampler(), etx);
        if let Some(sid) = session {
            req = req.with_session(sid);
        }
        if resume {
            req = req.resuming();
        }
        tx.send(req).unwrap();
        let (tokens, _) = collect_tokens(&erx);
        streams.push(tokens);
    }
    drop(tx);
    handle.join().unwrap().unwrap();
    streams
}

#[test]
fn engine_resume_matches_uninterrupted_generation() {
    if !have_artifacts() {
        return;
    }
    let (n, m) = (10usize, 8usize);
    let prompt = b"the quick brown fox".to_vec();

    // uninterrupted reference: one N+M-token generation
    let whole = run_requests(
        Arc::new(SessionStore::in_memory(8)),
        vec![(prompt.clone(), n + m, Some(1), false)],
    )
    .remove(0);

    // split run: N tokens (snapshotted on completion), then resume with an
    // empty prompt for M more — the lane state was evicted in between
    // (the engine re-admits from the store, not from a held lane)
    let store = Arc::new(SessionStore::in_memory(8));
    let parts = run_requests(
        store.clone(),
        vec![(prompt, n, Some(1), false), (vec![], m, Some(1), true)],
    );
    let stitched: Vec<u8> =
        parts[0].iter().chain(parts[1].iter()).copied().collect();

    assert_eq!(
        stitched, whole,
        "resumed stream must equal the uninterrupted N+M stream"
    );
    let st = store.stats();
    assert_eq!(st.resume_hits, 1);
    assert_eq!(st.hit_rate(), 1.0);
}

#[test]
fn engine_forks_diverge_only_by_seed() {
    if !have_artifacts() {
        return;
    }
    let store = Arc::new(SessionStore::in_memory(8));
    // build the shared prefix once
    let _ = run_requests(store.clone(), vec![(b"common prefix: ".to_vec(), 12, Some(1), false)]);
    store.fork(1, 2, Some(100)).unwrap();
    store.fork(1, 3, Some(200)).unwrap();
    store.fork(1, 4, Some(100)).unwrap();
    let streams = run_requests(
        store,
        vec![(vec![], 16, Some(2), true), (vec![], 16, Some(3), true), (vec![], 16, Some(4), true)],
    );
    assert_ne!(streams[0], streams[1], "different fork seeds must diverge");
    assert_eq!(streams[0], streams[2], "equal fork seeds must agree");
}

#[test]
fn server_protocol_resume_fork_and_unknown_session() {
    if !have_artifacts() {
        return;
    }
    let store = Arc::new(SessionStore::in_memory(8));
    let (tx, engine_handle) = spawn_engine_with_store(
        artifacts(),
        "micro".into(),
        SchedPolicy::PrefillFirst,
        0,
        Some(store.clone()),
    );
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let store2 = store.clone();
    let server_handle = std::thread::spawn(move || {
        serve_sessions("127.0.0.1:0", router, Some(store2), stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();
    let mut client = Client::connect(&addr).unwrap();

    // resume of an unknown session is an error reply, not a generation
    let err = client.generate_opts(
        "hi",
        &GenOpts { max_tokens: 4, session: Some(404), resume: true, ..GenOpts::default() },
    );
    assert!(err.is_err(), "unknown session must error");
    assert!(format!("{}", err.unwrap_err()).contains("unknown session 404"));

    // turn 1 creates the session; turn 2 resumes it over the same protocol
    let t1 = client
        .generate_opts(
            "hello session",
            &GenOpts { max_tokens: 6, session: Some(9), ..GenOpts::default() },
        )
        .unwrap();
    assert!(!t1.resumed);
    let t2 = client
        .generate_opts(
            "",
            &GenOpts { max_tokens: 6, session: Some(9), resume: true, ..GenOpts::default() },
        )
        .unwrap();
    assert!(t2.resumed);
    assert_eq!(t2.tokens.len(), 6);

    // fork 9 -> 10 with a fresh seed, over the protocol
    let f = client
        .generate_opts(
            "",
            &GenOpts {
                max_tokens: 6,
                session: Some(10),
                fork_of: Some(9),
                seed: Some(77),
                ..GenOpts::default()
            },
        )
        .unwrap();
    assert!(f.resumed);
    assert!(store.contains(10), "fork completion re-snapshots the child");

    drop(client);
    stop.store(true, Ordering::Relaxed);
    server_handle.join().unwrap();
    engine_handle.join().unwrap().unwrap();
}
