//! Integration: the `"stats"` admin request against a live server,
//! artifact-free.
//!
//! A fake engine thread stands in for the real `EngineLoop` (no artifacts
//! needed): it drains `GenRequest`s from a real `Router`, streams tokens
//! with a small delay, and drives a real `LiveStats` registry exactly the
//! way the engine does.  That lets the test poll the `"stats"` endpoint
//! from a second connection *while* the first is mid-stream and pin the
//! contract the CLI `hla top` view relies on: snapshots are readable at
//! any time, counters are monotone, and the final snapshot reconciles
//! with what the client actually received.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use hla::coordinator::router::{RoutePolicy, Router};
use hla::coordinator::{FinishReason, GenRequest, TokenEvent};
use hla::metrics::LiveStats;
use hla::server::client::Client;
use hla::server::{serve, serve_full, ServeObs};

/// Fake engine: one token every `delay` per request, registry updated in
/// place per token like the real loop's `step()` tail.
fn spawn_fake_engine(
    stats: Arc<LiveStats>,
    delay: Duration,
) -> (mpsc::Sender<GenRequest>, std::thread::JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<GenRequest>();
    let handle = std::thread::spawn(move || {
        stats.batch_lanes.set(1);
        while let Ok(req) = rx.recv() {
            for i in 0..req.max_new_tokens {
                std::thread::sleep(delay);
                let tok = b'a' + (i % 26) as u8;
                if req.events.send(TokenEvent::token(req.id, tok)).is_err() {
                    break;
                }
                stats.tokens_out.incr();
                stats.steps.incr();
                stats.occupied_lanes.add(1);
                stats.width_steps.add(1);
                stats.batched_steps.incr();
                stats.step_hist.record(delay);
            }
            let _ = req.events.send(TokenEvent::finished(req.id, FinishReason::Length));
            stats.completed.incr();
        }
    });
    (tx, handle)
}

fn start_server(
    obs: Option<Arc<ServeObs>>,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
) -> (String, std::thread::JoinHandle<()>) {
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let handle = std::thread::spawn(move || {
        serve_full("127.0.0.1:0", router, None, obs, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    (addr_rx.recv().unwrap().to_string(), handle)
}

#[test]
fn stats_request_is_live_monotone_and_consistent() {
    const TOKENS: usize = 40;
    let stats = Arc::new(LiveStats::new());
    let (tx, engine) = spawn_fake_engine(stats.clone(), Duration::from_millis(2));
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));
    let obs = Arc::new(ServeObs::stats_only(vec![stats]));
    let (addr, server) = start_server(Some(obs), router, stop.clone());

    // client A streams on its own thread...
    let addr2 = addr.clone();
    let streamer = std::thread::spawn(move || {
        let mut c = Client::connect(&addr2).unwrap();
        c.generate("stream me", TOKENS, 0.0, None).unwrap()
    });

    // ...while client B polls the stats endpoint on a second connection
    let mut admin = Client::connect(&addr).unwrap();
    let mut polled = vec![];
    while !streamer.is_finished() {
        let snap = admin.stats().unwrap();
        assert!(
            snap.tokens_out as usize <= TOKENS,
            "registry ran ahead of the stream: {}",
            snap.tokens_out
        );
        polled.push(snap.tokens_out);
        std::thread::sleep(Duration::from_millis(5));
    }
    let done = streamer.join().unwrap();
    assert_eq!(done.tokens.len(), TOKENS);

    // counters only ever move forward
    assert!(polled.windows(2).all(|w| w[0] <= w[1]), "non-monotone polls: {polled:?}");
    // ~80ms of streaming polled at 5ms: some poll must land mid-stream
    assert!(polled.iter().any(|&t| t > 0 && (t as usize) < TOKENS), "no mid-stream snapshot: {polled:?}");

    // the final snapshot reconciles with what the client received
    let fin = admin.stats().unwrap();
    assert_eq!(fin.tokens_out as usize, TOKENS);
    assert_eq!(fin.completed, 1);
    assert_eq!(fin.steps as usize, TOKENS);
    assert!(fin.elapsed_s > 0.0);
    assert!(fin.step_us_p50 > 0.0, "step histogram flowed through the snapshot");

    // prometheus form over the same registry
    let text = admin.stats_prometheus().unwrap();
    assert!(text.contains(&format!("hla_tokens_out_total {TOKENS}")), "{text}");
    assert!(text.contains("hla_step_us{quantile=\"0.5\"}"), "{text}");

    drop(admin);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    engine.join().unwrap();
}

#[test]
fn stats_request_without_registry_errors_and_bad_format_rejected() {
    let stats = Arc::new(LiveStats::new());
    let (tx, engine) = spawn_fake_engine(stats.clone(), Duration::from_millis(1));
    let router = Arc::new(Router::new(vec![tx], RoutePolicy::RoundRobin));
    let stop = Arc::new(AtomicBool::new(false));

    // a server without observability handles refuses the request...
    let (addr_tx, addr_rx) = mpsc::channel();
    let stop2 = stop.clone();
    let router2 = router.clone();
    let server = std::thread::spawn(move || {
        serve("127.0.0.1:0", router2, stop2, move |addr| {
            addr_tx.send(addr).unwrap();
        })
        .unwrap();
    });
    let addr = addr_rx.recv().unwrap().to_string();
    let mut c = Client::connect(&addr).unwrap();
    let err = c.stats().unwrap_err().to_string();
    assert!(err.contains("without a live metrics registry"), "{err}");
    // ...but keeps serving generations on the same connection afterwards
    let done = c.generate("still alive", 3, 0.0, None).unwrap();
    assert_eq!(done.tokens.len(), 3);
    drop(c);
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();

    // a server with handles rejects an unknown stats format
    let stop = Arc::new(AtomicBool::new(false));
    let obs = Arc::new(ServeObs::stats_only(vec![stats]));
    let (addr, server) = start_server(Some(obs), router, stop.clone());
    {
        use std::io::{BufRead, BufReader, Write};
        let mut sock = std::net::TcpStream::connect(&addr).unwrap();
        writeln!(sock, "{}", r#"{"stats": "yaml"}"#).unwrap();
        let mut line = String::new();
        BufReader::new(sock.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(line.contains("error"), "{line}");
    }
    stop.store(true, Ordering::Relaxed);
    server.join().unwrap();
    engine.join().unwrap();
}
