//! Differential acceptance test for the persistent-pool parallel decode
//! step: threaded decode must be **byte-identical** to serial — logits,
//! sampled token streams, and the state tensors left behind — for
//! hla2/ahla/hla3, greedy and seeded sampling, fresh lanes and lanes
//! seeded through the chunked prefill scan and a session snapshot.
//! Runs artifact-free on the pure-Rust model, like
//! `prefill_differential.rs` / `spec_differential.rs`.
//!
//! Why exact equality is the right bar (not a tolerance): each head shard
//! runs the *same* floating-point op sequence as the serial loop and
//! writes a disjoint, index-addressed output slice, and lane shards run
//! the serial step itself — completion order changes nothing.  See
//! `hla::model::pool`.
//!
//! Also pinned here (the failure half of the contract): a poisoned shard
//! — the promoted length asserts in `tensor::ops` firing on a corrupted
//! state — surfaces as a typed `PoolError` promptly instead of a hang,
//! the pool keeps serving afterwards, and the fixture engine / model
//! drafter built on top degrade the way their docs promise (aborted
//! request, dropped proposal).

use std::sync::{mpsc, Arc};

use hla::cluster::spawn_fixture_engine_pooled;
use hla::coordinator::request::FinishReason;
use hla::coordinator::{collect_tokens, GenRequest};
use hla::metrics::LiveStats;
use hla::model::pool::{decode_steps_pooled, DecodePool, PoolError};
use hla::model::sampler::{Sampler, SamplerCfg};
use hla::model::{ModelState, RustModel};
use hla::prefill::{advance, PrefillCfg};
use hla::session::SessionStore;
use hla::spec::{Drafter, ModelDrafter};
use hla::testing::fixtures::{build_model, build_model_full, ModelShape};
use hla::util::rng::Rng;

fn random_prompt(rng: &mut Rng, n: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(64) as u8).collect()
}

/// Decode `max_new` tokens serially; returns (stream, final state).
fn serial_stream(
    model: &RustModel,
    mut state: ModelState,
    mut last: u8,
    scfg: SamplerCfg,
    max_new: usize,
) -> (Vec<u8>, ModelState) {
    let mut sampler = Sampler::new(scfg);
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = model.decode_step(&mut state, last);
        last = sampler.sample(&logits) as u8;
        out.push(last);
    }
    (out, state)
}

/// Same loop through the pooled step.
fn pooled_stream(
    model: &RustModel,
    mut state: ModelState,
    mut last: u8,
    scfg: SamplerCfg,
    max_new: usize,
    pool: &DecodePool,
) -> (Vec<u8>, ModelState) {
    let mut sampler = Sampler::new(scfg);
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = model.decode_step_pooled(&mut state, last, pool).unwrap();
        last = sampler.sample(&logits) as u8;
        out.push(last);
    }
    (out, state)
}

fn assert_states_equal(a: &ModelState, b: &ModelState, label: &str) {
    for (i, (sa, sb)) in a.layers.iter().flatten().zip(b.layers.iter().flatten()).enumerate() {
        assert_eq!(
            sa.state_vec().unwrap(),
            sb.state_vec().unwrap(),
            "{label}: head state {i} diverged"
        );
    }
}

#[test]
fn pooled_decode_matches_serial_bitwise_all_mixers() {
    let mut rng = Rng::new(101);
    for mixer in ["hla2", "ahla", "hla3"] {
        let model = build_model(mixer, &ModelShape::default(), 51);
        let prompt = random_prompt(&mut rng, 19);
        for scfg in [
            SamplerCfg::greedy(),
            SamplerCfg { temperature: 0.9, top_k: 8, seed: 13 },
            SamplerCfg { temperature: 1.2, top_k: 0, seed: 14 },
        ] {
            // seed both lanes through the *same* serial prefill so only the
            // decode path under test differs
            let mut seed_state = ModelState::new(&model.cfg);
            advance(&model, &mut seed_state, &prompt[..prompt.len() - 1], &PrefillCfg::serial());
            let snapshot = seed_state.to_tensors().unwrap();
            let restore = || {
                let mut s = ModelState::new(&model.cfg);
                s.load_tensors(&snapshot).unwrap();
                s
            };
            let last = prompt[prompt.len() - 1];
            let (want, want_state) =
                serial_stream(&model, restore(), last, scfg.clone(), 48);
            for threads in [2usize, 4, 7] {
                let label = format!("{mixer} t={} threads={threads}", scfg.temperature);
                let pool = DecodePool::new(threads);
                let (got, got_state) =
                    pooled_stream(&model, restore(), last, scfg.clone(), 48, &pool);
                assert_eq!(got, want, "{label}: stream diverged");
                assert_states_equal(&want_state, &got_state, &label);
            }
        }
    }
}

#[test]
fn pooled_decode_composes_with_scan_prefill_and_snapshot_resume() {
    // the serving composition: chunked-scan prefill seeds the lane, a
    // session snapshot round-trips it, then decode runs pooled — the
    // stream must equal the same composition over serial decode
    let mut rng = Rng::new(103);
    for mixer in ["hla2", "ahla", "hla3"] {
        let model = build_model(mixer, &ModelShape::default(), 53);
        let prompt = random_prompt(&mut rng, 33);
        let scan = PrefillCfg::scan(8, 2);
        let mut state = ModelState::new(&model.cfg);
        advance(&model, &mut state, &prompt[..prompt.len() - 1], &scan);
        let snapshot = state.to_tensors().unwrap();
        let restore = || {
            let mut s = ModelState::new(&model.cfg);
            s.load_tensors(&snapshot).unwrap();
            s
        };
        let last = prompt[prompt.len() - 1];
        let scfg = SamplerCfg { temperature: 0.8, top_k: 12, seed: 23 };
        let (want, _) = serial_stream(&model, restore(), last, scfg.clone(), 40);
        let pool = DecodePool::new(4);
        let (got, _) = pooled_stream(&model, restore(), last, scfg, 40, &pool);
        assert_eq!(got, want, "{mixer}: scan-prefill + resume + pooled decode diverged");
    }
}

#[test]
fn one_thread_is_the_serial_path_by_construction() {
    // --decode-threads 1 must not merely equal serial, it must *be* it:
    // the pool spawns no workers and the pooled entry points fall through
    let pool = DecodePool::new(1);
    assert!(!pool.is_parallel());
    let model = build_model("hla2", &ModelShape::default(), 57);
    let mut a = ModelState::new(&model.cfg);
    let mut b = ModelState::new(&model.cfg);
    for tok in [5u8, 9, 2, 61, 0] {
        let want = model.decode_step(&mut a, tok);
        let got = model.decode_step_pooled(&mut b, tok, &pool).unwrap();
        assert_eq!(want, got);
    }
    assert_states_equal(&a, &b, "threads=1");
}

#[test]
fn lane_partitioned_decode_matches_serial_even_oversubscribed() {
    // more workers than lanes x heads: excess workers idle, results are
    // still routed by lane index
    let shape = ModelShape::default(); // 2 layers x 2 heads
    let model = Arc::new(build_model("ahla", &shape, 59));
    let pool = DecodePool::new(16);
    let mut rng = Rng::new(107);
    let n_lanes = 3;
    let mut serial: Vec<ModelState> =
        (0..n_lanes).map(|_| ModelState::new(&model.cfg)).collect();
    let mut pooled: Vec<ModelState> =
        (0..n_lanes).map(|_| ModelState::new(&model.cfg)).collect();
    for _ in 0..24 {
        let toks: Vec<u8> = (0..n_lanes).map(|_| rng.below(64) as u8).collect();
        let want: Vec<Vec<f32>> = serial
            .iter_mut()
            .zip(&toks)
            .map(|(st, &t)| model.decode_step(st, t))
            .collect();
        let mut lanes: Vec<(&mut ModelState, u8)> =
            pooled.iter_mut().zip(toks.iter().copied()).collect();
        let got = decode_steps_pooled(&model, &mut lanes, &pool).unwrap();
        assert_eq!(got, want, "per-lane logits diverged");
    }
    for (s, p) in serial.iter().zip(&pooled) {
        assert_states_equal(s, p, "lane states");
    }
}

/// Swap in a head state built for a different head_dim: the promoted
/// length asserts in `tensor::ops` fire inside the shard.
fn poison_head(state: &mut ModelState, donor_cfg: &hla::runtime::ModelCfg) {
    let mut wrong = ModelState::new(donor_cfg);
    std::mem::swap(&mut state.layers[0][0], &mut wrong.layers[0][0]);
}

#[test]
fn poisoned_shard_surfaces_as_typed_error_not_a_hang() {
    let model = build_model("hla2", &ModelShape::default(), 61);
    let donor = build_model("hla2", &ModelShape::draft(), 61); // head_dim 4 vs 8
    let pool = DecodePool::new(4);
    let mut state = ModelState::new(&model.cfg);
    assert!(model.decode_step_pooled(&mut state, 3, &pool).is_ok());
    poison_head(&mut state, &donor.cfg);
    match model.decode_step_pooled(&mut state, 3, &pool) {
        Err(PoolError::WorkerPanicked(msg)) => {
            assert!(
                msg.contains("length mismatch") || msg.contains("assert"),
                "the kernel asserts should name the mismatch, got: {msg}"
            );
        }
        other => panic!("want WorkerPanicked, got {other:?}"),
    }
    // the pool survives its dead shard: a fresh lane decodes fine
    let mut fresh = ModelState::new(&model.cfg);
    assert!(model.decode_step_pooled(&mut fresh, 3, &pool).is_ok());
}

#[test]
fn model_drafter_proposals_identical_with_and_without_pool() {
    // the spec model drafter is the host-side path EngineLoop hands the
    // pool to: its tentative k-step greedy decode through the pool must
    // propose exactly the serial drafter's bytes, across commits
    for mixer in ["hla2", "ahla", "hla3"] {
        let model = build_model(mixer, &ModelShape::default(), 63);
        let pool = Arc::new(DecodePool::new(3));
        let mut serial = ModelDrafter::with_prefill(model.clone(), PrefillCfg::serial());
        let mut pooled = ModelDrafter::with_prefill(model.clone(), PrefillCfg::serial())
            .with_pool(Some(pool));
        let mut rng = Rng::new(109);
        for round in 0..6 {
            let chunk = random_prompt(&mut rng, 5 + round);
            serial.commit(&chunk);
            pooled.commit(&chunk);
            let want = serial.propose(6);
            assert_eq!(want.len(), 6, "{mixer}: healthy drafter proposes k tokens");
            assert_eq!(pooled.propose(6), want, "{mixer} round {round}: proposal diverged");
        }
    }
}

#[test]
fn fixture_engine_pooled_streams_match_serial_engine() {
    // end to end: the cluster replica engine with a 4-thread pool must
    // emit exactly the bytes of the serial engine, and its completion
    // snapshot must land (the lane was never poisoned)
    let shape = ModelShape::default();
    let run = |threads: usize| -> (Vec<u8>, Option<FinishReason>, Vec<f32>) {
        let model = build_model_full("hla2", &shape, 71);
        let store = Arc::new(SessionStore::in_memory(8));
        let stats = Arc::new(LiveStats::new());
        let (tx, handle) =
            spawn_fixture_engine_pooled(model, store.clone(), stats, None, threads);
        let (etx, erx) = mpsc::channel();
        let req = GenRequest::new(
            1,
            b"parallel decode differential".to_vec(),
            32,
            SamplerCfg { temperature: 0.9, top_k: 8, seed: 31 },
            etx,
        )
        .with_session(77);
        tx.send(req).unwrap();
        drop(tx);
        let (tokens, finish) = collect_tokens(&erx);
        handle.join().unwrap();
        let snap = store.claim(77, None).expect("completion snapshot landed");
        let state_bytes: Vec<f32> =
            snap.state.iter().flat_map(|t| t.data.iter().copied()).collect();
        (tokens, finish, state_bytes)
    };
    let (want, want_fin, want_state) = run(1);
    assert_eq!(want.len(), 32);
    let (got, got_fin, got_state) = run(4);
    assert_eq!(got, want, "pooled fixture engine stream diverged");
    assert_eq!(got_fin, want_fin);
    assert_eq!(got_state, want_state, "snapshot state diverged");
}
