//! Baseline sequence mixers (§2): causal softmax attention (quadratic, with
//! a growing KV-cache) and first-order linear attention (streaming).  Both
//! are implemented from scratch and drive the comparison benches (E2/E3/E6).

use crate::hla::{HlaOptions, NormMode};
use crate::tensor::{ops, Mat, Scalar};

/// Full-sequence causal softmax attention, O(n² d) (Section 2.1).
pub fn softmax_attention(q: &Mat<f32>, k: &Mat<f32>, v: &Mat<f32>, scale: f32) -> Mat<f32> {
    let n = q.rows;
    let mut out = Mat::zeros(n, v.cols);
    let mut logits = vec![0f32; n];
    for t in 0..n {
        for j in 0..=t {
            logits[j] = ops::dot(q.row(t), k.row(j)) * scale;
        }
        ops::softmax_inplace(&mut logits[..=t]);
        let row = out.row_mut(t);
        for j in 0..=t {
            ops::axpy(logits[j], v.row(j), row);
        }
    }
    out
}

/// Streaming softmax-attention decoder state: the KV-cache grows O(t) —
/// the memory/latency contrast to HLA's constant state (benches E2/E6).
#[derive(Debug, Clone, Default)]
pub struct KvCache {
    pub keys: Vec<Vec<f32>>,
    pub values: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn nbytes(&self) -> usize {
        self.keys.iter().map(|k| k.len() * 4).sum::<usize>()
            + self.values.iter().map(|v| v.len() * 4).sum::<usize>()
    }

    /// Append (k, v) and attend with q over the whole cache: O(t·d)/token.
    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], scale: f32) -> Vec<f32> {
        self.keys.push(k.to_vec());
        self.values.push(v.to_vec());
        let t = self.keys.len();
        let mut logits: Vec<f32> = self.keys.iter().map(|ki| ops::dot(q, ki) * scale).collect();
        ops::softmax_inplace(&mut logits);
        let mut out = vec![0f32; v.len()];
        for j in 0..t {
            ops::axpy(logits[j], &self.values[j], &mut out);
        }
        out
    }
}

/// First-order linear attention streaming state (identity feature map):
/// P = Σ k vᵀ, m = Σ k (Section 2.2).
#[derive(Debug, Clone)]
pub struct LinearAttnState<T> {
    pub p: Mat<T>,
    pub m: Vec<T>,
}

impl<T: Scalar> LinearAttnState<T> {
    pub fn new(d: usize, dv: usize) -> Self {
        LinearAttnState { p: Mat::zeros(d, dv), m: vec![T::ZERO; d] }
    }

    pub fn nbytes(&self) -> usize {
        std::mem::size_of::<T>() * (self.p.data.len() + self.m.len())
    }

    pub fn step(&mut self, k: &[T], v: &[T], gamma: T) {
        if gamma != T::ONE {
            self.p.scale(gamma);
            ops::scale(gamma, &mut self.m);
        }
        self.p.add_outer(T::ONE, k, v);
        ops::axpy(T::ONE, k, &mut self.m);
    }

    pub fn output(&self, q: &[T], norm: NormMode, eps: T) -> Vec<T> {
        let mut num = self.p.t_matvec(q);
        let den = ops::dot(q, &self.m);
        norm.apply(&mut num, den, eps);
        num
    }
}

/// First-order linear-attention segment: the (decayed) moments compose
/// purely additively — the degenerate case of the paper's semidirect
/// product (no cross terms).  Used by the prefill scan for `linear` lanes.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearSeg<T> {
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub rho: T,
}

impl<T: Scalar> LinearSeg<T> {
    pub fn empty(d: usize, dv: usize) -> Self {
        LinearSeg { p: Mat::zeros(d, dv), m: vec![T::ZERO; d], rho: T::ONE }
    }

    pub fn token(k: &[T], v: &[T], gamma: T) -> Self {
        let mut seg = LinearSeg::empty(k.len(), v.len());
        seg.p.add_outer(T::ONE, k, v);
        seg.m.copy_from_slice(k);
        seg.rho = gamma;
        seg
    }

    /// Embed a streaming state as a scan segment (resume case).  With no
    /// cross terms the embedding is exact in any combine position, but by
    /// convention it is only ever used as the scan's left-most segment.
    pub fn from_state(st: &LinearAttnState<T>) -> Self {
        LinearSeg { p: st.p.clone(), m: st.m.clone(), rho: T::ONE }
    }

    pub fn as_state(&self) -> LinearAttnState<T> {
        LinearAttnState { p: self.p.clone(), m: self.m.clone() }
    }
}

impl<T: Scalar> crate::hla::scan::Monoid for LinearSeg<T> {
    fn identity_like(&self) -> Self {
        LinearSeg::empty(self.p.rows, self.p.cols)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let rb = rhs.rho;
        let mut p = self.p.clone();
        p.scale(rb);
        p.add_scaled(T::ONE, &rhs.p);
        let mut m: Vec<T> = self.m.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &rhs.m, &mut m);
        LinearSeg { p, m, rho: self.rho * rb }
    }
}

/// Full-sequence linear attention via the streaming state.
pub fn linear_attention_serial<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let mut st = LinearAttnState::new(d, dv);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        st.step(k.row(t), v.row(t), opts.gamma);
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts.norm, opts.eps));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize) -> Mat<f32> {
        let mut m = Mat::zeros(n, d);
        for x in &mut m.data {
            *x = rng.normal() as f32;
        }
        m
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        let mut rng = Rng::new(1);
        let (q, k) = (random(&mut rng, 12, 4), random(&mut rng, 12, 4));
        let ones = Mat::from_vec(12, 3, vec![1.0; 36]);
        let out = softmax_attention(&q, &k, &ones, 0.5);
        for x in &out.data {
            assert!((x - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn kv_cache_matches_full_attention() {
        let mut rng = Rng::new(2);
        let n = 16;
        let (q, k, v) = (random(&mut rng, n, 4), random(&mut rng, n, 4), random(&mut rng, n, 4));
        let full = softmax_attention(&q, &k, &v, 0.5);
        let mut cache = KvCache::new();
        for t in 0..n {
            let got = cache.step(q.row(t), k.row(t), v.row(t), 0.5);
            for (a, b) in got.iter().zip(full.row(t)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
        assert_eq!(cache.len(), n);
        assert_eq!(cache.nbytes(), n * 2 * 4 * 4); // grows with n
    }

    #[test]
    fn linear_attention_is_constant_state() {
        let st = LinearAttnState::<f32>::new(64, 64);
        assert_eq!(st.nbytes(), 4 * (64 * 64 + 64));
    }

    #[test]
    fn linear_seg_scan_matches_serial() {
        use crate::hla::scan::{blelloch_exclusive, Monoid};
        let mut rng = Rng::new(7);
        let n = 17;
        let (q, k, v) = (random(&mut rng, n, 4), random(&mut rng, n, 4), random(&mut rng, n, 4));
        for gamma in [1.0f32, 0.9] {
            let opts = HlaOptions::<f32>::default().with_gamma(gamma as f64);
            let want = linear_attention_serial(&q, &k, &v, &opts);
            let leaves: Vec<LinearSeg<f32>> =
                (0..n).map(|t| LinearSeg::token(k.row(t), v.row(t), gamma)).collect();
            let prefixes = blelloch_exclusive(&leaves);
            for t in 0..n {
                let st = prefixes[t].combine(&leaves[t]).as_state();
                let got = st.output(q.row(t), opts.norm, opts.eps);
                for (a, b) in got.iter().zip(want.row(t)) {
                    assert!((a - b).abs() < 1e-4, "g={gamma} t={t}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn linear_matches_hla_first_token() {
        // at t = 1 both normalized operators return v_1-proportional rows
        let mut rng = Rng::new(3);
        let (q, k, v) = (random(&mut rng, 1, 4), random(&mut rng, 1, 4), random(&mut rng, 1, 4));
        let opts = HlaOptions::<f32>::default().with_norm(NormMode::Linear);
        let lin = linear_attention_serial(&q, &k, &v, &opts);
        let hla = crate::hla::state2::hla2_serial(&q, &k, &v, &opts);
        for (a, b) in lin.data.iter().zip(&hla.data) {
            assert!((a - b).abs() < 2e-5, "{a} vs {b}");
        }
    }
}
