//! Property-testing substrate (proptest is unavailable offline).
//!
//! `check` runs a property over N deterministically-seeded random cases and
//! panics with the offending seed on failure, so a red run is reproducible
//! with `PropConfig { only_seed: Some(s), .. }`.

use crate::util::rng::Rng;

pub mod fixtures;

#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: u64,
    pub base_seed: u64,
    /// Re-run a single failing seed.
    pub only_seed: Option<u64>,
}

/// Base seed (mnemonic: "HLA 2025").
const HLA_SEED_BASE: u64 = 0x41AA_2025;

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, base_seed: HLA_SEED_BASE, only_seed: None }
    }
}

/// Run `property(rng, case_index)`; panic with seed on failure or error.
pub fn check<F>(name: &str, cfg: PropConfig, mut property: F)
where
    F: FnMut(&mut Rng, u64) -> Result<(), String>,
{
    let seeds: Vec<u64> = match cfg.only_seed {
        Some(s) => vec![s],
        None => (0..cfg.cases).map(|i| cfg.base_seed.wrapping_add(i)).collect(),
    };
    for (i, seed) in seeds.iter().enumerate() {
        let mut rng = Rng::new(*seed);
        if let Err(msg) = property(&mut rng, i as u64) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}):\n  {msg}\n  \
                 reproduce with PropConfig {{ only_seed: Some({seed:#x}), ..Default::default() }}"
            );
        }
    }
}

/// Convenience: default config with a given case count.
pub fn quick<F>(name: &str, cases: u64, property: F)
where
    F: FnMut(&mut Rng, u64) -> Result<(), String>,
{
    check(name, PropConfig { cases, ..Default::default() }, property);
}

/// Assert two f64 slices are close; returns Err with context otherwise.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let denom = 1.0f64.max(x.abs()).max(y.abs());
        if (x - y).abs() / denom > tol {
            return Err(format!("{what}: idx {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        quick("sum-commutes", 16, |rng, _| {
            let a = rng.f64();
            let b = rng.f64();
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err("addition not commutative?!".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn reports_seed_on_failure() {
        quick("always-fails", 4, |_, _| Err("nope".into()));
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9, "x").is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, "x").is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9, "x").is_err());
    }
}
