//! Synthetic pure-Rust byte-LM fixtures for the artifact-free
//! differential tests and E-series benches.
//!
//! Every artifact-free test/bench needs the same thing: a [`ModelCfg`]
//! with manifest-ordered param paths and a deterministically-initialized
//! [`RustModel`] built from it.  Building the config directly (instead of
//! each file carrying its own ~40-line manifest-JSON template) keeps the
//! fixture in one place; the manifest *parsing* path has its own tests in
//! `runtime/artifact.rs`.

use crate::model::RustModel;
use crate::runtime::ModelCfg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Shape knobs for a synthetic byte-LM fixture.
#[derive(Debug, Clone, Copy)]
pub struct ModelShape {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub chunk: usize,
    pub gamma: f64,
}

impl Default for ModelShape {
    /// The differential-test shape (2 layers, d_model 16) used by
    /// `prefill_differential.rs` / `spec_differential.rs`.
    fn default() -> Self {
        ModelShape {
            vocab: 64,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            head_dim: 8,
            d_ffn: 32,
            chunk: 8,
            gamma: 0.98,
        }
    }
}

impl ModelShape {
    /// The serving-shaped bench twin (E14/E15): d_model 32, head_dim 16.
    pub fn bench() -> Self {
        ModelShape { d_model: 32, head_dim: 16, d_ffn: 64, chunk: 32, ..Default::default() }
    }

    /// A 1-layer draft-model shape (d_model 8) — cheap enough that
    /// drafting k tokens costs a fraction of one target step.
    pub fn draft() -> Self {
        ModelShape { d_model: 8, n_layers: 1, head_dim: 4, d_ffn: 16, chunk: 4, ..Default::default() }
    }
}

/// A [`ModelCfg`] for `shape` with param paths in the manifest's
/// tree-flatten order (embed, norm_f, then per-layer norm1, wq, wk, wv,
/// wo, norm2, w_gate, w_up, w_down) — the order `RustModel::from_tensors`
/// binds and the order [`build_model`] draws its init randomness in.
pub fn model_cfg(mixer: &str, s: &ModelShape) -> ModelCfg {
    let d = s.d_model;
    let mut param_paths: Vec<(String, Vec<usize>)> = vec![
        ("['embed']".into(), vec![s.vocab, d]),
        ("['norm_f']".into(), vec![d]),
    ];
    for li in 0..s.n_layers {
        let p = |f: &str| format!("['layers'][{li}]['{f}']");
        param_paths.push((p("norm1"), vec![d]));
        param_paths.push((p("wq"), vec![d, d]));
        param_paths.push((p("wk"), vec![d, d]));
        param_paths.push((p("wv"), vec![d, d]));
        param_paths.push((p("wo"), vec![d, d]));
        param_paths.push((p("norm2"), vec![d]));
        param_paths.push((p("w_gate"), vec![d, s.d_ffn]));
        param_paths.push((p("w_up"), vec![d, s.d_ffn]));
        param_paths.push((p("w_down"), vec![s.d_ffn, d]));
    }
    let n_params = param_paths.iter().map(|(_, sh)| sh.iter().product::<usize>()).sum();
    let n_param_tensors = param_paths.len();
    ModelCfg {
        name: "fixture".into(),
        vocab: s.vocab,
        d_model: d,
        n_layers: s.n_layers,
        n_heads: s.n_heads,
        head_dim: s.head_dim,
        d_ffn: s.d_ffn,
        kv_heads: s.n_heads,
        mixer: mixer.into(),
        chunk: s.chunk,
        gamma: s.gamma,
        lam: 0.0,
        norm_mode: "abs".into(),
        eps: 1e-6,
        multi_query: false,
        n_params,
        n_param_tensors,
        n_state_tensors: 2,
        param_paths,
        // hla2-shaped artifact lane layout; the pure-Rust ModelState
        // derives its real per-mixer layout from `mixer`, not from here
        state_paths: vec![
            ("['c']".into(), vec![s.n_layers, 1, s.n_heads, s.head_dim, s.head_dim]),
            ("['m']".into(), vec![s.n_layers, 1, s.n_heads, s.head_dim]),
        ],
        train_batch: 1,
        train_seq: s.chunk,
        decode_batch: 1,
        prefill_len: s.chunk,
    }
}

/// A [`model_cfg`] whose `state_paths` cover the mixer's **full** state
/// (every [`crate::model::MixerState::component`] name), so
/// `ModelState::to_components`/`load_components` round-trips are lossless
/// — the shape [`crate::prefill::Prefiller`] and the prefix cache
/// ([`crate::cache`]) require.
pub fn model_cfg_full_state(mixer: &str, s: &ModelShape) -> ModelCfg {
    let mut cfg = model_cfg(mixer, s);
    let (l, h, dh) = (s.n_layers, s.n_heads, s.head_dim);
    let mat = |name: &str| (format!("['{name}']"), vec![l, 1, h, dh, dh]);
    let vec_ = |name: &str| (format!("['{name}']"), vec![l, 1, h, dh]);
    cfg.state_paths = match mixer {
        "hla2" => vec![mat("s"), mat("c"), vec_("m"), mat("g"), vec_("h")],
        "ahla" => vec![mat("p"), vec_("m"), mat("e"), vec_("n")],
        "hla3" => vec![mat("s"), mat("p"), vec_("m"), mat("f"), vec_("eta")],
        "linear" => vec![mat("p"), vec_("m")],
        other => panic!("no full-state layout for mixer {other:?}"),
    };
    cfg.n_state_tensors = cfg.state_paths.len();
    cfg
}

/// Deterministically-initialized pure-Rust model: 1-d params (norms) near
/// 1, matrices ~N(0, 0.3) — the init every artifact-free test/bench uses.
pub fn build_model(mixer: &str, shape: &ModelShape, seed: u64) -> RustModel {
    model_from_cfg(model_cfg(mixer, shape), seed)
}

/// [`build_model`] over a [`model_cfg_full_state`] config — same weights
/// for the same seed (init draws follow `param_paths`, which the state
/// layout does not touch), but lane component round-trips are lossless.
pub fn build_model_full(mixer: &str, shape: &ModelShape, seed: u64) -> RustModel {
    model_from_cfg(model_cfg_full_state(mixer, shape), seed)
}

fn model_from_cfg(cfg: ModelCfg, seed: u64) -> RustModel {
    let mut rng = Rng::new(seed);
    let tensors: Vec<Tensor> = cfg
        .param_paths
        .iter()
        .map(|(_, sh)| {
            let mut t = Tensor::zeros(sh);
            if sh.len() == 1 {
                for x in &mut t.data {
                    *x = 1.0 + 0.1 * rng.normal() as f32;
                }
            } else {
                rng.fill_normal(&mut t.data, 0.3);
            }
            t
        })
        .collect();
    RustModel::from_tensors(&cfg, &tensors).expect("fixture param paths bind by construction")
}

/// A uniform random byte prompt below `vocab` — the prompt generator the
/// differential tests share (formerly hand-rolled per file).
pub fn random_prompt(rng: &mut Rng, n: usize, vocab: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(vocab.max(2)) as u8).collect()
}

/// Shared-prefix prompt sets for the prefix-cache tests: `n_prefixes`
/// random preambles of `prefix_len` tokens, each fanned out into
/// `n_per_prefix` full prompts with distinct `suffix_len`-token suffixes.
/// Prompts are grouped by prefix: `out[p][i]` shares `out[p][j]`'s first
/// `prefix_len` tokens and nothing else (almost surely).
pub fn shared_prefix_prompts(
    rng: &mut Rng,
    n_prefixes: usize,
    prefix_len: usize,
    n_per_prefix: usize,
    suffix_len: usize,
    vocab: usize,
) -> Vec<Vec<Vec<u8>>> {
    (0..n_prefixes.max(1))
        .map(|_| {
            let prefix = random_prompt(rng, prefix_len, vocab);
            (0..n_per_prefix)
                .map(|_| {
                    let mut p = prefix.clone();
                    p.extend(random_prompt(rng, suffix_len, vocab));
                    p
                })
                .collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelState;

    #[test]
    fn fixture_models_build_and_step_for_every_scannable_mixer() {
        for mixer in ["hla2", "ahla", "hla3", "linear"] {
            for shape in [ModelShape::default(), ModelShape::bench(), ModelShape::draft()] {
                let m = build_model(mixer, &shape, 7);
                assert_eq!(m.cfg.param_paths.len(), 2 + 9 * shape.n_layers);
                assert_eq!(m.layers.len(), shape.n_layers);
                let mut state = ModelState::new(&m.cfg);
                let logits = m.decode_step(&mut state, 3);
                assert_eq!(logits.len(), shape.vocab);
                assert!(logits.iter().all(|x| x.is_finite()), "{mixer}: non-finite logits");
            }
        }
    }

    #[test]
    fn full_state_cfg_round_trips_every_scannable_mixer() {
        for mixer in ["hla2", "ahla", "hla3", "linear"] {
            let shape = ModelShape::default();
            let m = build_model_full(mixer, &shape, 7);
            let mut state = ModelState::new(&m.cfg);
            m.decode_step(&mut state, 5);
            // lossless: every mixer component is covered by state_paths
            let parts = state.to_components(&m.cfg).unwrap_or_else(|e| panic!("{mixer}: {e}"));
            assert_eq!(parts.len(), m.cfg.state_paths.len());
            let mut back = ModelState::new(&m.cfg);
            back.load_components(&m.cfg, &parts).unwrap();
            for (a, b) in state.layers.iter().flatten().zip(back.layers.iter().flatten()) {
                assert_eq!(a.state_vec().unwrap(), b.state_vec().unwrap(), "{mixer}");
            }
            // same seed, same weights as the plain fixture
            let plain = build_model(mixer, &shape, 7);
            assert_eq!(m.embed.data, plain.embed.data, "{mixer}: init must not shift");
        }
    }

    #[test]
    fn shared_prefix_prompts_share_exactly_the_prefix() {
        let mut rng = Rng::new(9);
        let groups = shared_prefix_prompts(&mut rng, 3, 24, 5, 8, 64);
        assert_eq!(groups.len(), 3);
        for group in &groups {
            assert_eq!(group.len(), 5);
            let prefix = &group[0][..24];
            for p in group {
                assert_eq!(p.len(), 32);
                assert_eq!(&p[..24], prefix, "group shares its preamble");
                assert!(p.iter().all(|&b| (b as usize) < 64));
            }
        }
        assert_ne!(&groups[0][0][..24], &groups[1][0][..24], "distinct preambles");
    }

    #[test]
    fn fixture_init_is_deterministic() {
        let a = build_model("hla2", &ModelShape::default(), 11);
        let b = build_model("hla2", &ModelShape::default(), 11);
        assert_eq!(a.embed.data, b.embed.data);
        let c = build_model("hla2", &ModelShape::default(), 12);
        assert_ne!(a.embed.data, c.embed.data);
    }
}
