//! Compressed radix trie over token prefixes with LRU eviction under a
//! byte budget.
//!
//! The trie stores opaque payload bytes (the cache's checksummed state
//! snapshots) at token-prefix keys.  Edges carry multi-token labels
//! (path compression), so the node count scales with the number of
//! *distinct* stored prefixes, not with their length — the natural shape
//! for serving traffic where a handful of system prompts fan out into
//! many per-request suffixes.
//!
//! Structural invariants (pinned by the property tests below and by
//! [`RadixTrie::check_invariants`]):
//!
//! * a lookup result is always a **strict** token-prefix of the query
//!   (the serving path must keep at least the final prompt token for the
//!   normal decode step);
//! * `resident_bytes` never exceeds the byte budget — inserting past it
//!   evicts least-recently-used payloads first;
//! * eviction removes *payloads*, never a node that still has live
//!   descendants: a payload-less interior node survives as long as ≥ 2
//!   children hang off it, and single-child payload-less nodes are merged
//!   back into their child (full path re-compression).

use std::collections::HashMap;

/// One stored payload plus its LRU recency.
#[derive(Debug)]
struct Payload {
    bytes: Vec<u8>,
    tick: u64,
}

#[derive(Debug, Default)]
struct Node {
    /// Edge label from the parent (empty only at the root).
    edge: Vec<u8>,
    /// Children keyed by the first token of their edge.
    children: HashMap<u8, Node>,
    payload: Option<Payload>,
}

/// What an insert did (the cache's counter hooks).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertOutcome {
    /// False when the payload alone exceeds the whole budget (rejected)
    /// or the key was already resident (recency refreshed, bytes swapped).
    pub fresh: bool,
    /// LRU payloads evicted to get back under budget.
    pub evicted: usize,
}

/// The trie: payload bytes at token-prefix keys, LRU within a byte budget.
#[derive(Debug)]
pub struct RadixTrie {
    root: Node,
    budget: usize,
    resident_bytes: usize,
    entries: usize,
    tick: u64,
}

impl RadixTrie {
    pub fn new(budget: usize) -> RadixTrie {
        RadixTrie {
            root: Node::default(),
            budget: budget.max(1),
            resident_bytes: 0,
            entries: 0,
            tick: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    pub fn nbytes(&self) -> usize {
        self.resident_bytes
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Insert (or refresh) `bytes` at `key`, then evict LRU payloads
    /// until the budget holds again.  A payload larger than the whole
    /// budget is rejected outright rather than evicting everything else
    /// for an entry that still cannot fit.
    pub fn insert(&mut self, key: &[u8], bytes: Vec<u8>) -> InsertOutcome {
        if bytes.len() > self.budget {
            return InsertOutcome { fresh: false, evicted: 0 };
        }
        self.tick += 1;
        let payload = Payload { bytes, tick: self.tick };
        let delta_new = payload.bytes.len();
        let replaced = insert_in(&mut self.root, key, payload);
        self.resident_bytes += delta_new;
        let fresh = match replaced {
            Some(old) => {
                self.resident_bytes -= old.bytes.len();
                false
            }
            None => {
                self.entries += 1;
                true
            }
        };
        let mut evicted = 0;
        while self.resident_bytes > self.budget {
            // O(entries) LRU scan, like the session store: the trie is
            // small (hundreds of boundaries) and insert runs at
            // admission, off the per-token hot loop
            let victim = self.lru_key().expect("over budget implies a resident payload");
            self.remove(&victim);
            evicted += 1;
        }
        InsertOutcome { fresh, evicted }
    }

    /// The deepest stored key that is a **strict** prefix of `query`
    /// (shorter than it), with its payload bytes; refreshes LRU recency.
    pub fn longest_prefix(&mut self, query: &[u8]) -> Option<(Vec<u8>, &[u8])> {
        let depth = best_depth(&self.root, query, 0)?;
        self.tick += 1;
        let tick = self.tick;
        let payload = payload_at(&mut self.root, &query[..depth]).expect("best_depth found it");
        payload.tick = tick;
        Some((query[..depth].to_vec(), payload.bytes.as_slice()))
    }

    /// Does the trie hold a payload at exactly `key`? (No recency touch.)
    pub fn contains(&mut self, key: &[u8]) -> bool {
        payload_at(&mut self.root, key).is_some()
    }

    /// Remove the payload at `key` (pruning/merging emptied nodes);
    /// returns whether anything was stored there.
    pub fn remove(&mut self, key: &[u8]) -> bool {
        match remove_in(&mut self.root, key) {
            Some(old) => {
                self.resident_bytes -= old.bytes.len();
                self.entries -= 1;
                true
            }
            None => false,
        }
    }

    /// The least-recently-used stored key.
    fn lru_key(&self) -> Option<Vec<u8>> {
        let mut best: Option<(u64, Vec<u8>)> = None;
        visit(&self.root, &mut Vec::new(), &mut |key, p| {
            if best.as_ref().map_or(true, |(t, _)| p.tick < *t) {
                best = Some((p.tick, key.to_vec()));
            }
        });
        best.map(|(_, k)| k)
    }

    /// All stored keys (ascending by key) — test/diagnostic surface.
    pub fn keys(&self) -> Vec<Vec<u8>> {
        let mut out = vec![];
        visit(&self.root, &mut Vec::new(), &mut |key, _| out.push(key.to_vec()));
        out.sort();
        out
    }

    /// Verify every structural invariant; returns a description of the
    /// first violation.  Used by the property tests after every operation.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut entries = 0usize;
        let mut bytes = 0usize;
        check_node(&self.root, true, &mut entries, &mut bytes)?;
        if entries != self.entries {
            return Err(format!("entry accounting: counted {entries}, stored {}", self.entries));
        }
        if bytes != self.resident_bytes {
            return Err(format!("byte accounting: counted {bytes}, stored {}", self.resident_bytes));
        }
        if self.resident_bytes > self.budget {
            return Err(format!("budget exceeded: {} > {}", self.resident_bytes, self.budget));
        }
        Ok(())
    }
}

/// Insert `payload` at `key` under `node`; returns the replaced payload.
fn insert_in(node: &mut Node, key: &[u8], payload: Payload) -> Option<Payload> {
    if key.is_empty() {
        return node.payload.replace(payload);
    }
    let first = key[0];
    let Some(child) = node.children.get_mut(&first) else {
        node.children.insert(
            first,
            Node { edge: key.to_vec(), children: HashMap::new(), payload: Some(payload) },
        );
        return None;
    };
    let lcp = common_prefix(&child.edge, key);
    if lcp == child.edge.len() {
        return insert_in(child, &key[lcp..], payload);
    }
    // split the edge: child becomes a grandchild of a new interior node
    let mut old = node.children.remove(&first).expect("child exists");
    let shared = old.edge[..lcp].to_vec();
    let old_rest = old.edge[lcp..].to_vec();
    old.edge = old_rest;
    let mut mid = Node { edge: shared, children: HashMap::new(), payload: None };
    mid.children.insert(old.edge[0], old);
    if key.len() == lcp {
        mid.payload = Some(payload);
    } else {
        let rest = key[lcp..].to_vec();
        mid.children.insert(
            rest[0],
            Node { edge: rest, children: HashMap::new(), payload: Some(payload) },
        );
    }
    node.children.insert(first, mid);
    None
}

/// Depth (in tokens) of the deepest payload-bearing node whose key is a
/// strict prefix of `query`.
fn best_depth(node: &Node, remaining: &[u8], depth: usize) -> Option<usize> {
    let mut best = match (&node.payload, remaining.is_empty()) {
        // strict: a payload at the full query depth is NOT a hit
        (Some(_), false) => Some(depth),
        _ => None,
    };
    if !remaining.is_empty() {
        if let Some(child) = node.children.get(&remaining[0]) {
            if remaining.len() >= child.edge.len() && remaining.starts_with(&child.edge) {
                if let Some(d) =
                    best_depth(child, &remaining[child.edge.len()..], depth + child.edge.len())
                {
                    best = Some(best.map_or(d, |b| b.max(d)));
                }
            }
        }
    }
    best
}

/// Mutable payload at exactly `key`.
fn payload_at<'a>(node: &'a mut Node, key: &[u8]) -> Option<&'a mut Payload> {
    if key.is_empty() {
        return node.payload.as_mut();
    }
    let child = node.children.get_mut(&key[0])?;
    if key.len() < child.edge.len() || !key.starts_with(&child.edge) {
        return None;
    }
    let edge_len = child.edge.len();
    payload_at(child, &key[edge_len..])
}

/// Remove the payload at `key`, pruning/merging the emptied path.
fn remove_in(node: &mut Node, key: &[u8]) -> Option<Payload> {
    if key.is_empty() {
        return node.payload.take();
    }
    let first = key[0];
    let child = node.children.get_mut(&first)?;
    if key.len() < child.edge.len() || !key.starts_with(&child.edge) {
        return None;
    }
    let edge_len = child.edge.len();
    let removed = remove_in(child, &key[edge_len..]);
    if removed.is_some() && child.payload.is_none() {
        match child.children.len() {
            // a bare leaf: drop it
            0 => {
                node.children.remove(&first);
            }
            // path re-compression: merge the only grandchild up
            1 => {
                let child = node.children.get_mut(&first).expect("still there");
                let (_, mut gc) = child.children.drain().next().expect("len checked");
                let mut edge = child.edge.clone();
                edge.extend_from_slice(&gc.edge);
                gc.edge = edge;
                node.children.insert(first, gc);
            }
            // live descendants on both sides: the node must survive
            _ => {}
        }
    }
    removed
}

fn common_prefix(a: &[u8], b: &[u8]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Visit every stored payload with its full key.
fn visit<'a>(node: &'a Node, prefix: &mut Vec<u8>, f: &mut impl FnMut(&[u8], &'a Payload)) {
    prefix.extend_from_slice(&node.edge);
    if let Some(p) = &node.payload {
        f(prefix, p);
    }
    for child in node.children.values() {
        visit(child, prefix, f);
    }
    prefix.truncate(prefix.len() - node.edge.len());
}

fn check_node(
    node: &Node,
    is_root: bool,
    entries: &mut usize,
    bytes: &mut usize,
) -> Result<(), String> {
    if is_root {
        if !node.edge.is_empty() {
            return Err("root must have an empty edge".into());
        }
    } else {
        if node.edge.is_empty() {
            return Err("non-root node with an empty edge".into());
        }
        if node.payload.is_none() && node.children.len() < 2 {
            return Err(format!(
                "payload-less non-root node with {} child(ren) survived pruning",
                node.children.len()
            ));
        }
    }
    if let Some(p) = &node.payload {
        *entries += 1;
        *bytes += p.bytes.len();
    }
    for (&k, child) in &node.children {
        if child.edge.first() != Some(&k) {
            return Err(format!("child keyed {k} but edge starts {:?}", child.edge.first()));
        }
        check_node(child, false, entries, bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::HashMap as Map;

    fn payload(tag: u8, n: usize) -> Vec<u8> {
        vec![tag; n]
    }

    #[test]
    fn insert_lookup_remove_basics() {
        let mut t = RadixTrie::new(1 << 20);
        assert!(t.is_empty());
        assert!(t.longest_prefix(b"anything").is_none());
        assert!(t.insert(b"sys", payload(1, 8)).fresh);
        assert!(t.insert(b"system prompt", payload(2, 8)).fresh);
        // shared-edge split happened under the hood
        t.check_invariants().unwrap();
        assert_eq!(t.len(), 2);

        // deepest strict prefix wins
        let (key, bytes) = t.longest_prefix(b"system prompt + user turn").unwrap();
        assert_eq!(key, b"system prompt");
        assert_eq!(bytes, payload(2, 8));
        // a query equal to a stored key must fall back to the shallower
        // boundary: the result is a STRICT prefix
        let (key, _) = t.longest_prefix(b"system prompt").unwrap();
        assert_eq!(key, b"sys");
        assert!(t.longest_prefix(b"sys").is_none(), "no strict prefix of the shortest key");
        assert!(t.longest_prefix(b"other").is_none());

        assert!(t.remove(b"sys"));
        assert!(!t.remove(b"sys"));
        t.check_invariants().unwrap();
        assert_eq!(t.len(), 1);
        assert!(t.longest_prefix(b"system prompt").is_none());
    }

    #[test]
    fn replacing_a_key_swaps_bytes_without_double_count() {
        let mut t = RadixTrie::new(100);
        assert!(t.insert(b"abc", payload(1, 40)).fresh);
        let out = t.insert(b"abc", payload(2, 60));
        assert!(!out.fresh, "same key is a refresh, not a new entry");
        assert_eq!(out.evicted, 0);
        assert_eq!(t.len(), 1);
        assert_eq!(t.nbytes(), 60);
        t.check_invariants().unwrap();
    }

    #[test]
    fn oversize_payload_is_rejected_not_thrashed() {
        let mut t = RadixTrie::new(64);
        t.insert(b"keep", payload(1, 32));
        let out = t.insert(b"huge", payload(2, 65));
        assert!(!out.fresh);
        assert_eq!(out.evicted, 0, "a hopeless insert must not evict residents");
        assert_eq!(t.len(), 1);
        assert!(t.longest_prefix(b"keep it").is_some());
    }

    #[test]
    fn lru_eviction_respects_recency() {
        let mut t = RadixTrie::new(100);
        t.insert(b"aa", payload(1, 40));
        t.insert(b"bb", payload(2, 40));
        // touch aa so bb becomes LRU
        assert!(t.longest_prefix(b"aaX").is_some());
        let out = t.insert(b"cc", payload(3, 40));
        assert_eq!(out.evicted, 1);
        assert_eq!(t.keys(), vec![b"aa".to_vec(), b"cc".to_vec()]);
        t.check_invariants().unwrap();
    }

    /// Property test: the trie against a brute-force shadow map oracle —
    /// random inserts/lookups/removes over a tiny alphabet (forcing deep
    /// shared prefixes and edge splits), with every structural invariant
    /// checked after every operation.  Budget is unbounded here so the
    /// oracle stays exact; eviction behavior has its own property below.
    #[test]
    fn property_matches_shadow_map_oracle() {
        let mut rng = Rng::new(0xCAFE);
        let mut t = RadixTrie::new(usize::MAX);
        let mut shadow: Map<Vec<u8>, Vec<u8>> = Map::new();
        let key = |rng: &mut Rng| -> Vec<u8> {
            let n = rng.range(1, 12);
            (0..n).map(|_| rng.below(3) as u8).collect()
        };
        for step in 0..600 {
            match rng.below(10) {
                0..=4 => {
                    let k = key(&mut rng);
                    let v = payload(rng.below(256) as u8, rng.range(1, 16));
                    let out = t.insert(&k, v.clone());
                    assert_eq!(out.fresh, !shadow.contains_key(&k), "step {step}");
                    shadow.insert(k, v);
                }
                5..=7 => {
                    let q = key(&mut rng);
                    // oracle: the longest stored strict prefix of q
                    let want = shadow
                        .iter()
                        .filter(|(k, _)| k.len() < q.len() && q.starts_with(k))
                        .max_by_key(|(k, _)| k.len());
                    match (t.longest_prefix(&q), want) {
                        (None, None) => {}
                        (Some((k, b)), Some((wk, wb))) => {
                            assert_eq!(&k, wk, "step {step}: wrong prefix for {q:?}");
                            assert_eq!(b, wb.as_slice(), "step {step}");
                            assert!(k.len() < q.len(), "step {step}: lookup not strict");
                        }
                        (got, want) => {
                            panic!("step {step}: got {got:?}, oracle {want:?}")
                        }
                    }
                }
                _ => {
                    let k = key(&mut rng);
                    assert_eq!(t.remove(&k), shadow.remove(&k).is_some(), "step {step}");
                }
            }
            t.check_invariants().unwrap_or_else(|e| panic!("step {step}: {e}"));
            assert_eq!(t.len(), shadow.len(), "step {step}");
            assert_eq!(
                t.nbytes(),
                shadow.values().map(Vec::len).sum::<usize>(),
                "step {step}"
            );
        }
        assert!(!t.is_empty(), "the walk should leave residue");
    }

    /// Property test: under a tight budget, the byte budget is never
    /// exceeded, the most-recently-touched key always survives eviction,
    /// and pruning/merging never violates the structure invariants.
    #[test]
    fn property_eviction_under_byte_budget() {
        let mut rng = Rng::new(0xBEEF);
        let budget = 200usize;
        let mut t = RadixTrie::new(budget);
        let mut last_touched: Option<Vec<u8>> = None;
        for step in 0..400 {
            let n = rng.range(1, 10);
            let k: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
            let size = rng.range(8, 64);
            let out = t.insert(&k, payload(step as u8, size));
            if size <= budget {
                assert!(t.contains(&k), "step {step}: fitting insert must land");
            }
            last_touched = Some(k);
            if rng.bool(0.3) {
                let q: Vec<u8> = (0..rng.range(2, 12)).map(|_| rng.below(4) as u8).collect();
                if let Some((hit, _)) = t.longest_prefix(&q) {
                    assert!(q.starts_with(&hit) && hit.len() < q.len(), "step {step}");
                    last_touched = Some(hit);
                }
            }
            t.check_invariants().unwrap_or_else(|e| panic!("step {step}: {e}"));
            assert!(t.nbytes() <= budget, "step {step}: {} > {budget}", t.nbytes());
            if let Some(lt) = &last_touched {
                assert!(
                    out.evicted == 0 || t.contains(lt),
                    "step {step}: most-recently-used key was evicted"
                );
            }
        }
    }
}
