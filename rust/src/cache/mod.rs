//! Shared-prefix radix cache: constant-size HLA prefix states reused
//! across requests.
//!
//! HLA summarizes an entire prefix in a constant-size tuple of sufficient
//! statistics (Theorem 3.1), which makes *any* token boundary a resumable
//! point.  Serving traffic is dominated by shared prefixes — one system
//! prompt or few-shot preamble fanning out into thousands of per-request
//! suffixes — so that prefix should be prefill-scanned **once** per
//! replica, not once per request.  This module is the cache that makes it
//! so:
//!
//! * [`PrefixCache`] — a [`trie::RadixTrie`] keyed on token prefixes,
//!   holding CRC-checksummed snapshots (the [`crate::session::codec`]
//!   wire format) of the post-prefix model state at **chunk-aligned**
//!   boundaries, LRU-evicted under a byte budget.
//! * [`crate::prefill::Prefiller::ingest_lane_cached`] — the consumer:
//!   admission seeds the chunked scan from the longest cached strict
//!   prefix of the prompt and inserts the fresh boundary states it
//!   computes on the way to the end of the prompt.
//!
//! Exactness contract (pinned by `rust/tests/prefix_cache_differential.rs`):
//! because the cache-aware ingest *always* cuts its scan at the same
//! chunk-aligned boundaries — warm or cold — the state stored at boundary
//! `b` is a deterministic function of `tokens[..b]` alone.  A warm hit
//! therefore lands bit-identical floats to the cold path, and the emitted
//! token stream is byte-identical, greedy and seeded alike.  Snapshots
//! are checksummed on the way in and verified on the way out; a corrupt
//! entry is dropped and the lookup falls back to the next-shallower
//! boundary (degrading toward a cold scan, never into a wrong state).
//!
//! Sessions and speculative decode compose for free: a *resumed* lane's
//! state already encodes its private history, so resumes bypass the cache
//! (keys are prefixes from the zero state); a *speculative* lane only
//! diverges from the batched path after its prompt is ingested and its
//! first token sampled, both of which sit downstream of the cache seed.

pub mod trie;

use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::metrics::{hit_rate, Counter};
use crate::session::codec::{Reader, Writer};
use crate::tensor::Tensor;
pub use trie::{InsertOutcome, RadixTrie};

/// Snapshot wire magic: "HLAC" little-endian (cache entries are not
/// session snapshots — different header, same codec substrate).
pub const MAGIC: u32 = u32::from_le_bytes(*b"HLAC");

/// Entry format version (readers reject unknown).
pub const FORMAT_VERSION: u32 = 1;

/// Cache sizing knobs (the `serve --prefix-cache-mb/--prefix-cache-chunk`
/// flags).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixCacheCfg {
    /// Byte budget for resident snapshots (LRU-evicted past it).
    pub budget_bytes: usize,
    /// Snapshot boundary stride in tokens: states are stored (and scans
    /// are cut) at multiples of this — the exactness anchor (see module
    /// docs).  Clamped to ≥ 1.
    pub chunk: usize,
}

impl PrefixCacheCfg {
    pub fn new(budget_bytes: usize, chunk: usize) -> PrefixCacheCfg {
        PrefixCacheCfg { budget_bytes: budget_bytes.max(1), chunk: chunk.max(1) }
    }

    /// Budget in whole mebibytes (the CLI flag's unit).
    pub fn megabytes(mb: usize, chunk: usize) -> PrefixCacheCfg {
        PrefixCacheCfg::new(mb.max(1) << 20, chunk)
    }
}

/// Point-in-time counter view (bench/CLI/`ServeStats` reporting).
#[derive(Debug, Default, Clone)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    /// Prompt tokens skipped by warm hits (the work the cache saved).
    pub hit_tokens: u64,
    /// Entries dropped for failing their checksum on the way out.
    pub corrupt: u64,
    /// Snapshots currently resident.
    pub resident: usize,
    /// Bytes of snapshots currently resident.
    pub resident_bytes: usize,
}

impl CacheStats {
    /// Fraction of lookups that found a reusable boundary.
    pub fn hit_rate(&self) -> f64 {
        hit_rate(self.hits, self.misses)
    }
}

/// Thread-safe shared-prefix state cache: one per engine replica (cached
/// states are functions of the replica's weights), shared between its
/// admission path and any diagnostics readers.  Counters are lock-free so
/// stats reads never contend with admissions.
pub struct PrefixCache {
    trie: Mutex<RadixTrie>,
    chunk: usize,
    pub hits: Counter,
    pub misses: Counter,
    pub inserts: Counter,
    pub evictions: Counter,
    pub hit_tokens: Counter,
    pub corrupt: Counter,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheCfg) -> PrefixCache {
        PrefixCache {
            trie: Mutex::new(RadixTrie::new(cfg.budget_bytes.max(1))),
            chunk: cfg.chunk.max(1),
            hits: Counter::new(),
            misses: Counter::new(),
            inserts: Counter::new(),
            evictions: Counter::new(),
            hit_tokens: Counter::new(),
            corrupt: Counter::new(),
        }
    }

    /// The snapshot boundary stride in tokens.
    pub fn chunk(&self) -> usize {
        self.chunk
    }

    pub fn len(&self) -> usize {
        self.trie.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn nbytes(&self) -> usize {
        self.trie.lock().unwrap().nbytes()
    }

    /// The deepest cached boundary that is a strict, chunk-aligned prefix
    /// of `query` (the serving path passes the full prompt: strictness
    /// then guarantees the lane keeps at least its final token), decoded
    /// and checksum-verified.  A corrupt entry is evicted and the lookup
    /// retries at the next-shallower boundary, so the worst outcome of
    /// corruption is extra cold work, never a wrong state.  Counts one
    /// hit or miss per call.
    pub fn lookup(&self, query: &[u8]) -> Option<(usize, Vec<Tensor>)> {
        let mut trie = self.trie.lock().unwrap();
        loop {
            let Some((key, bytes)) = trie.longest_prefix(query) else {
                self.misses.incr();
                return None;
            };
            match decode(bytes) {
                Ok((n_tokens, parts)) if n_tokens == key.len() => {
                    self.hits.incr();
                    self.hit_tokens.add(key.len() as u64);
                    return Some((key.len(), parts));
                }
                Ok((n_tokens, _)) => {
                    log::warn!(
                        "prefix cache: entry at depth {} claims {n_tokens} tokens; dropping",
                        key.len()
                    );
                    trie.remove(&key);
                    self.corrupt.incr();
                }
                Err(e) => {
                    log::warn!("prefix cache: corrupt entry at depth {}: {e}", key.len());
                    trie.remove(&key);
                    self.corrupt.incr();
                }
            }
        }
    }

    /// Store the post-`prefix` state components at a chunk-aligned
    /// boundary.  Returns whether a fresh entry landed (refreshes and
    /// over-budget rejections return false).
    pub fn insert(&self, prefix: &[u8], parts: &[Tensor]) -> Result<bool> {
        ensure!(!prefix.is_empty(), "empty prefix has nothing to cache");
        ensure!(
            prefix.len() % self.chunk == 0,
            "prefix of {} tokens is not aligned to the {}-token boundary stride",
            prefix.len(),
            self.chunk
        );
        let bytes = encode(prefix.len(), parts);
        let mut trie = self.trie.lock().unwrap();
        let out = trie.insert(prefix, bytes);
        drop(trie);
        if out.fresh {
            self.inserts.incr();
        }
        self.evictions.add(out.evicted as u64);
        Ok(out.fresh)
    }

    /// Drop every resident snapshot (weights changed; counters survive).
    pub fn clear(&self) {
        let mut trie = self.trie.lock().unwrap();
        let budget = trie.budget();
        *trie = RadixTrie::new(budget);
    }

    pub fn stats(&self) -> CacheStats {
        let trie = self.trie.lock().unwrap();
        CacheStats {
            hits: self.hits.get(),
            misses: self.misses.get(),
            inserts: self.inserts.get(),
            evictions: self.evictions.get(),
            hit_tokens: self.hit_tokens.get(),
            corrupt: self.corrupt.get(),
            resident: trie.len(),
            resident_bytes: trie.nbytes(),
        }
    }
}

/// Serialize state components: magic + version + token count + tensors +
/// CRC-32 (the session codec's framing discipline).
fn encode(n_tokens: usize, parts: &[Tensor]) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(MAGIC);
    w.u32(FORMAT_VERSION);
    w.u64(n_tokens as u64);
    w.u32(parts.len() as u32);
    for t in parts {
        w.u32(t.shape.len() as u32);
        for &d in &t.shape {
            w.u32(d as u32);
        }
        w.f32_slice(&t.data);
    }
    w.finish_with_crc()
}

/// Checksum-verify and decode an entry back into state components.
fn decode(bytes: &[u8]) -> Result<(usize, Vec<Tensor>)> {
    let mut r = Reader::with_crc(bytes)?;
    let magic = r.u32()?;
    ensure!(magic == MAGIC, "not a prefix-cache entry (magic {magic:#010x})");
    let version = r.u32()?;
    ensure!(
        version == FORMAT_VERSION,
        "prefix-cache entry v{version} unsupported (this build reads v{FORMAT_VERSION})"
    );
    let n_tokens = r.u64()? as usize;
    let n = r.u32()? as usize;
    let mut parts = Vec::with_capacity(n);
    for _ in 0..n {
        let rank = r.u32()? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(r.u32()? as usize);
        }
        let data = r.f32_slice()?;
        ensure!(
            data.len() == shape.iter().product::<usize>(),
            "entry tensor payload {} != shape {shape:?}",
            data.len()
        );
        parts.push(Tensor::from_vec(&shape, data));
    }
    ensure!(r.remaining() == 0, "{} trailing bytes after entry", r.remaining());
    Ok((n_tokens, parts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn with_suffix(p: &[u8]) -> Vec<u8> {
        let mut v = p.to_vec();
        v.push(b'x');
        v
    }

    fn parts(seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        let mut a = Tensor::zeros(&[2, 1, 2, 4, 4]);
        let mut b = Tensor::zeros(&[2, 1, 2, 4]);
        rng.fill_normal(&mut a.data, 1.0);
        rng.fill_normal(&mut b.data, 1.0);
        vec![a, b]
    }

    #[test]
    fn entry_roundtrip_is_exact() {
        let want = parts(3);
        let bytes = encode(16, &want);
        let (n, got) = decode(&bytes).unwrap();
        assert_eq!(n, 16);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.shape, b.shape);
            // bit-exact floats: the cache must not perturb a state
            let ab: Vec<u32> = a.data.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn lookup_hit_miss_and_alignment() {
        let cache = PrefixCache::new(PrefixCacheCfg::new(1 << 20, 8));
        assert_eq!(cache.chunk(), 8);
        let prefix: Vec<u8> = (0..16).collect();
        cache.insert(&prefix, &parts(1)).unwrap();
        // misaligned inserts are a bug upstream: refuse loudly
        assert!(cache.insert(&prefix[..13], &parts(1)).is_err());
        assert!(cache.insert(&[], &parts(1)).is_err());

        let mut query = prefix.clone();
        query.extend_from_slice(b"suffix");
        let (n, got) = cache.lookup(&query).unwrap();
        assert_eq!(n, 16);
        assert_eq!(got[0].shape, vec![2, 1, 2, 4, 4]);
        // strict: the full prefix alone cannot hit its own entry
        assert!(cache.lookup(&prefix).is_none());
        assert!(cache.lookup(b"unrelated").is_none());
        let st = cache.stats();
        assert_eq!((st.hits, st.misses, st.inserts), (1, 2, 1));
        assert_eq!(st.hit_tokens, 16);
        assert!((st.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(st.resident, 1);
        assert!(st.resident_bytes > 0);
    }

    #[test]
    fn corrupt_entry_degrades_to_shallower_boundary() {
        let cache = PrefixCache::new(PrefixCacheCfg::new(1 << 20, 4));
        let prefix: Vec<u8> = (0..12).collect();
        cache.insert(&prefix[..4], &parts(1)).unwrap();
        cache.insert(&prefix, &parts(2)).unwrap();
        // corrupt the deep entry in place
        {
            let mut trie = cache.trie.lock().unwrap();
            let (_, bytes) = trie.longest_prefix(&with_suffix(&prefix)).unwrap();
            let mut evil = bytes.to_vec();
            let mid = evil.len() / 2;
            evil[mid] ^= 0xFF;
            trie.insert(&prefix, evil);
        }
        let mut query = prefix.clone();
        query.push(99);
        let (n, _) = cache.lookup(&query).unwrap();
        assert_eq!(n, 4, "corrupt deep entry must fall back to the shallow boundary");
        let st = cache.stats();
        assert_eq!(st.corrupt, 1);
        assert_eq!(st.resident, 1, "the corrupt entry was dropped");
    }

    #[test]
    fn budget_evicts_lru_and_clear_resets() {
        let one = encode(4, &parts(1)).len();
        let cache = PrefixCache::new(PrefixCacheCfg::new(2 * one, 4));
        let keys: Vec<Vec<u8>> = (0..3u8).map(|t| vec![t; 4]).collect();
        cache.insert(&keys[0], &parts(1)).unwrap();
        cache.insert(&keys[1], &parts(2)).unwrap();
        // touch key 0 so key 1 is the LRU victim
        assert!(cache.lookup(&with_suffix(&keys[0])).is_some());
        cache.insert(&keys[2], &parts(3)).unwrap();
        let st = cache.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.resident, 2);
        assert!(st.resident_bytes <= 2 * one);
        assert!(cache.lookup(&with_suffix(&keys[1])).is_none(), "LRU victim gone");
        assert!(cache.lookup(&with_suffix(&keys[2])).is_some());

        cache.clear();
        assert!(cache.is_empty());
        assert!(cache.stats().evictions >= 1, "counters survive clear");
    }

    #[test]
    fn megabytes_cfg_and_clamps() {
        let cfg = PrefixCacheCfg::megabytes(2, 0);
        assert_eq!(cfg.budget_bytes, 2 << 20);
        assert_eq!(cfg.chunk, 1);
        let tiny = PrefixCacheCfg::new(0, 0);
        assert_eq!((tiny.budget_bytes, tiny.chunk), (1, 1));
    }
}
