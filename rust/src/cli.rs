//! CLI: `hla <command> [--flags]` — the framework launcher.
//!
//! Commands:
//!   info       print artifact/config inventory
//!   selftest   decode-step artifact vs pure-Rust model numerics
//!   train      run the AOT train_step loop (E10 driver)
//!   generate   one-shot generation through the coordinator
//!   serve      TCP serving frontend over N engine replicas
//!   router     cluster front-end over N `hla serve` replica processes
//!   top        poll a serving fleet's live stats (the "stats" request)
//!   trace-stitch  pull span rings over the wire, emit one fleet trace
//!   sessions   list/inspect/evict spilled session snapshots

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::cache::PrefixCacheCfg;
use crate::config::RunConfig;
use crate::coordinator::router::Router;
use crate::coordinator::{
    collect_tokens, spawn_engine_full, BucketCfg, BucketSpec, EngineOpts, GenRequest,
};
use crate::metrics::trace::write_chrome_trace;
use crate::metrics::{LiveStats, TraceCfg, Tracer};
use crate::model::sampler::SamplerCfg;
use crate::prefill::PrefillCfg;
use crate::runtime::Engine;
use crate::server::ServeObs;
use crate::spec::SpecCfg;
use crate::session::{spill_file, spill_sessions, SessionStore, StoreCfg};
use crate::train::{train, LrSchedule, TrainOpts};
use crate::util::human_bytes;

pub const USAGE: &str = "\
hla — Higher-order Linear Attention runtime
usage: hla <info|selftest|train|generate|serve|router|top|trace-stitch|sessions> [--flags]
common flags: --artifacts DIR --model NAME --seed N --config FILE.json
train:    --steps N --lr F --warmup N --checkpoint PATH
generate: --prompt STR --max-tokens N --temperature F [--checkpoint PATH]
          --spec true [--spec-k N --spec-drafter ngram|model|model:<cfg>]
          --decode-threads N  (persistent decode worker pool; 0 = one per
          core, 1 = serial; byte-identical either way)
          --trace-out PATH.json  (Chrome trace of the engine cycle)
serve:    --addr HOST:PORT --replicas N --sched POLICY --route POLICY
          [--checkpoint PATH]  (trained weights; default is seeded init)
          --session-capacity N --spill-dir DIR
          --prefill-chunk N --prefill-threads N  (0 0 = decode-as-prefill)
          --prefill-budget N  (prompt tokens per engine cycle spent on
          parked prefills; interleaves long prompts with decode steps,
          0 = monolithic admission-time scan; needs --prefill-chunk)
          --admit-per-cycle N  (admissions per cycle on top of --sched's
          allowance; bounds burst-admission stalls, 0 = policy default)
          --max-queue N  (in-flight cap; beyond it requests get the typed
          overloaded reply instead of queueing, 0 = unbounded)
          --decode-threads N  (persistent per-engine decode pool for the
          host-side paths: fixture engines and model drafters; 0 = auto)
          --batch-buckets off|pow2|w1,w2,...  --bucket-shrink-after K
          (occupancy-adaptive decode width; grows on admission, shrinks
          after K under-occupied steps; needs bucketed decode artifacts)
          --prefix-cache-mb N --prefix-cache-chunk N  (shared-prefix
          cache, per replica; needs --prefill-chunk; requests opt out
          with \"no_cache\": true on the wire)
          --spec-k N --spec-drafter D  (spec engine; requests opt in
          with \"spec\": true on the wire)
          --trace-out PATH.json --trace-sample P  (request-span tracing;
          P in [0,1] picks which requests record spans, default 1)
          --fixture true  (artifact-free fixture model with full session
          support — the cluster-mode replica; share --seed across the
          fleet so failover replays are byte-identical)
router:   --addr HOST:PORT --replicas H:P,H:P,...  (the replica fleet)
          --route POLICY --health-interval SECS  (probe period; 3 missed
          probes mark a replica dead and its sessions re-home)
          --drain H:P  (evacuate that replica's sessions at startup)
          --trace-out PATH.json  (mint trace ids, record relay spans, and
          re-export a stitched fleet trace every 60s)
          --event-log PATH.jsonl  (append the structured cluster event
          journal; the in-memory ring answers {\"events\": N} regardless)
top:      --addr HOST:PORT --interval SECS --count N  (0 = forever; a
          router endpoint adds per-replica rows and the router section)
trace-stitch: --replicas H:P,H:P,...  (router first for pid 0; each
          endpoint answers the trace_export control verb)
          --trace-out PATH.json  (default stitched_trace.json)
sessions: <list|inspect|evict> --spill-dir DIR [--session-id N]";

pub fn run(args: Vec<String>) -> Result<()> {
    let Some((cmd, rest)) = args.split_first() else {
        println!("{USAGE}");
        return Ok(());
    };
    if cmd == "sessions" {
        return cmd_sessions(rest);
    }
    let cfg = RunConfig::from_args(rest)?;
    match cmd.as_str() {
        "info" => info(&cfg),
        "selftest" => selftest(&cfg),
        "train" => cmd_train(&cfg),
        "generate" => cmd_generate(&cfg),
        "serve" => cmd_serve(&cfg),
        "router" => cmd_router(&cfg),
        "top" => cmd_top(&cfg),
        "trace-stitch" => cmd_trace_stitch(&cfg),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn info(cfg: &RunConfig) -> Result<()> {
    let engine = Engine::open(&cfg.artifacts)?;
    println!("artifacts: {} ({} programs)", cfg.artifacts, engine.manifest.artifacts.len());
    let mut table = crate::metrics::Table::new(&[
        "config", "mixer", "params", "layers", "d_model", "heads", "state/seq", "kv@4k",
    ]);
    for (name, mc) in &engine.manifest.configs {
        table.row(&[
            name.clone(),
            mc.mixer.clone(),
            format!("{:.2}M", mc.n_params as f64 / 1e6),
            mc.n_layers.to_string(),
            mc.d_model.to_string(),
            mc.n_heads.to_string(),
            human_bytes(mc.state_nbytes_per_seq()),
            human_bytes(mc.kv_cache_nbytes(4096)),
        ]);
    }
    print!("{}", table.render());
    Ok(())
}

/// Compare one decode step of the AOT artifact against the pure-Rust model.
fn selftest(cfg: &RunConfig) -> Result<()> {
    use crate::model::{ModelState, RustModel};
    use crate::runtime::literal::literal_to_tensor;
    use crate::tensor::TensorI32;

    let engine = Engine::open(&cfg.artifacts)?;
    let mc = engine.model_cfg(&cfg.model)?.clone();
    let params = engine.init_params(&cfg.model, cfg.seed as i32)?;
    let tensors: Vec<_> =
        params.iter().map(literal_to_tensor).collect::<Result<_>>()?;
    let rust = RustModel::from_tensors(&mc, &tensors)?;
    println!("model {} ({} params), mixer {}", mc.name, rust.n_params(), mc.mixer);

    // run 8 decode steps both ways on the same token stream
    let b = mc.decode_batch;
    let toks: Vec<u8> = b"It was ".iter().copied().cycle().take(8).collect();
    let exe = engine.load(&format!("decode_step_{}", cfg.model))?;
    let mut state_lits: Vec<xla::Literal> = mc
        .state_paths
        .iter()
        .map(|(_, shape)| {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let n: usize = shape.iter().product();
            Ok(xla::Literal::vec1(&vec![0f32; n]).reshape(&dims)?)
        })
        .collect::<Result<_>>()?;
    let mut rust_state = ModelState::new(&mc);
    let mut worst = 0f32;
    for &tok in &toks {
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(|p| {
                let s = p.array_shape()?;
                Ok(xla::Literal::vec1(&p.to_vec::<f32>()?).reshape(s.dims())?)
            })
            .collect::<Result<_>>()?;
        inputs.append(&mut state_lits);
        let tvec = vec![tok as i32; b];
        inputs.push(crate::runtime::literal::tokens_to_literal(&TensorI32::from_vec(
            &[b],
            tvec,
        ))?);
        let outs = exe.run(&inputs)?;
        let logits = literal_to_tensor(&outs[0])?;
        state_lits = outs.into_iter().skip(1).collect();
        let rust_logits = rust.decode_step(&mut rust_state, tok);
        let vocab = mc.vocab;
        for (a, bb) in logits.data[..vocab].iter().zip(&rust_logits) {
            worst = worst.max((a - bb).abs());
        }
    }
    println!("max |artifact - rust| logit diff over {} steps: {worst:.3e}", toks.len());
    if worst > 2e-2 {
        bail!("selftest FAILED (diff {worst})");
    }
    println!("selftest OK");
    Ok(())
}

fn cmd_train(cfg: &RunConfig) -> Result<()> {
    let engine = Engine::open(&cfg.artifacts)?;
    let opts = TrainOpts {
        cfg_name: cfg.model.clone(),
        steps: cfg.steps,
        lr: LrSchedule {
            peak: cfg.lr,
            warmup: cfg.warmup,
            total: cfg.steps,
            floor: cfg.lr * 0.1,
        },
        seed: cfg.seed,
        log_every: (cfg.steps / 30).max(1),
        checkpoint: cfg.checkpoint.clone(),
        ..Default::default()
    };
    println!("training {} for {} steps (uniform-loss baseline {:.3})",
        cfg.model, cfg.steps, crate::train::uniform_loss(engine.model_cfg(&cfg.model)?.vocab));
    let (curve, params) = train(&engine, &opts)?;
    let mut table = crate::metrics::Table::new(&["step", "loss", "lr", "tok/s"]);
    for p in &curve {
        table.row(&[
            p.step.to_string(),
            format!("{:.4}", p.loss),
            format!("{:.2e}", p.lr),
            format!("{:.0}", p.tokens_per_sec),
        ]);
    }
    print!("{}", table.render());
    let eval = crate::train::evaluate(&engine, &cfg.model, &params, 4, cfg.seed + 999)?;
    println!("held-out loss: {eval:.4}");
    Ok(())
}

/// `--prefill-chunk N` (N > 0) turns on scan prefill for the serving path.
fn prefill_cfg(cfg: &RunConfig) -> Option<PrefillCfg> {
    (cfg.prefill_chunk > 0).then(|| PrefillCfg::scan(cfg.prefill_chunk, cfg.prefill_threads))
}

/// `--decode-threads N` resolved: 0 means one worker per available core
/// (uncapped, like `--prefill-threads 0`); anything else passes through.
/// `1` keeps the serial decode path ([`crate::model::pool::DecodePool`]
/// spawns no workers).
fn decode_threads(cfg: &RunConfig) -> usize {
    if cfg.decode_threads == 0 {
        crate::util::auto_threads()
    } else {
        cfg.decode_threads
    }
}

/// `--prefix-cache-mb N` (N > 0) attaches the shared-prefix cache (one
/// per replica — cached states are functions of the replica's weights).
fn prefix_cache_cfg(cfg: &RunConfig) -> Option<PrefixCacheCfg> {
    (cfg.prefix_cache_mb > 0)
        .then(|| PrefixCacheCfg::megabytes(cfg.prefix_cache_mb, cfg.prefix_cache_chunk))
}

/// `--batch-buckets pow2|w1,w2,...` turns on occupancy-adaptive decode
/// bucketing; `--bucket-shrink-after K` sets the shrink hysteresis.  The
/// ladder string was validated at parse time.
fn bucket_cfg(cfg: &RunConfig) -> Option<BucketCfg> {
    let spec = BucketSpec::parse(&cfg.batch_buckets).expect("validated by RunConfig::apply");
    if spec == BucketSpec::Off {
        return None;
    }
    Some(BucketCfg { spec, shrink_after: cfg.bucket_shrink_after })
}

/// `--spec true` / `--spec-k N` attach the speculative decoding engine;
/// k stays adaptive ([`crate::spec::AdaptiveK`]) with `--spec-k` as the
/// starting draft length.  The drafter string was validated at parse time.
fn spec_cfg(cfg: &RunConfig) -> Option<SpecCfg> {
    (cfg.spec || cfg.spec_k > 0).then(|| {
        let defaults = SpecCfg::default();
        SpecCfg {
            k: if cfg.spec_k > 0 { cfg.spec_k } else { defaults.k },
            drafter: crate::spec::DrafterKind::parse(&cfg.spec_drafter)
                .expect("validated by RunConfig::apply"),
            ..defaults
        }
    })
}

/// `--trace-out PATH` attaches a span recorder; `--trace-sample P` picks
/// which requests record spans (engine-scoped spans always record).
fn tracer_cfg(cfg: &RunConfig) -> Option<Arc<Tracer>> {
    cfg.trace_out
        .as_ref()
        .map(|_| Arc::new(Tracer::new(&TraceCfg { sample: cfg.trace_sample, ..TraceCfg::default() })))
}

/// Export one Chrome trace file covering every replica's recorder.
fn export_trace(path: &str, tracers: &[Arc<Tracer>]) {
    let pairs: Vec<(usize, &Tracer)> =
        tracers.iter().enumerate().map(|(i, t)| (i, &**t)).collect();
    match write_chrome_trace(std::path::Path::new(path), &pairs) {
        Ok(()) => {
            let n: usize = tracers.iter().map(|t| t.recorded().min(t.capacity() as u64) as usize).sum();
            println!("[trace: {n} span(s) -> {path} (load in Perfetto / chrome://tracing)]");
        }
        Err(e) => eprintln!("[trace: writing {path} failed: {e}]"),
    }
}

fn cmd_generate(cfg: &RunConfig) -> Result<()> {
    let spec = spec_cfg(cfg);
    let stats = Arc::new(LiveStats::new());
    let tracer = tracer_cfg(cfg);
    let (tx, handle) = spawn_engine_full(
        cfg.artifacts.clone(),
        cfg.model.clone(),
        EngineOpts {
            policy: Some(cfg.sched),
            seed: cfg.seed as i32,
            checkpoint: cfg.checkpoint.clone(),
            store: None,
            prefill: prefill_cfg(cfg),
            prefix_cache: None,
            spec: spec.clone(),
            buckets: bucket_cfg(cfg),
            stats: Some(stats.clone()),
            tracer: tracer.clone(),
            decode_threads: decode_threads(cfg),
            prefill_budget: cfg.prefill_budget,
            admit_per_cycle: cfg.admit_per_cycle,
        },
    );
    let (etx, erx) = std::sync::mpsc::channel();
    let mut req = GenRequest::new(
        1,
        cfg.prompt.as_bytes().to_vec(),
        cfg.max_tokens,
        SamplerCfg { temperature: cfg.temperature, top_k: 40, seed: cfg.seed },
        etx,
    );
    if spec.is_some() {
        req = req.with_spec();
    }
    tx.send(req).ok();
    drop(tx);
    let (tokens, finish) = collect_tokens(&erx);
    println!("{}{}", cfg.prompt, String::from_utf8_lossy(&tokens));
    println!("[finish: {finish:?}]");
    let stats = handle.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))??;
    println!("[{}]", stats.summary_line());
    if let (Some(path), Some(t)) = (&cfg.trace_out, &tracer) {
        export_trace(path, std::slice::from_ref(t));
    }
    Ok(())
}

fn cmd_serve(cfg: &RunConfig) -> Result<()> {
    if cfg.fixture {
        return cmd_serve_fixture(cfg);
    }
    // fail fast on a bad --checkpoint: the replicas load it inside their
    // own threads, where an error would only surface at join (i.e. at
    // shutdown) while the listener keeps accepting doomed requests.
    // Header-only read — the tensor payload is deserialized once per
    // replica thread (literals are !Send, so each engine owns its copy).
    if let Some(path) = &cfg.checkpoint {
        let meta = crate::train::checkpoint::load_meta(path)
            .map_err(|e| anyhow!("checkpoint {path}: {e}"))?;
        if meta.config != cfg.model {
            bail!(
                "checkpoint {path} was trained for config {:?}, serving {:?}",
                meta.config,
                cfg.model
            );
        }
    }
    // one shared store across all replicas: any replica can resume any
    // session, so rebalancing a conversation is just routing
    let store = Arc::new(SessionStore::new(StoreCfg {
        capacity: cfg.session_capacity,
        spill_dir: cfg.spill_dir.clone().map(std::path::PathBuf::from),
    }));
    let mut senders = vec![];
    let mut handles = vec![];
    let mut registries = vec![];
    let mut tracers = vec![];
    for r in 0..cfg.replicas {
        let stats = Arc::new(LiveStats::new());
        let tracer = tracer_cfg(cfg);
        let (tx, handle) = spawn_engine_full(
            cfg.artifacts.clone(),
            cfg.model.clone(),
            EngineOpts {
                policy: Some(cfg.sched),
                seed: cfg.seed as i32 + r as i32,
                checkpoint: cfg.checkpoint.clone(),
                store: Some(store.clone()),
                prefill: prefill_cfg(cfg),
                prefix_cache: prefix_cache_cfg(cfg),
                spec: spec_cfg(cfg),
                buckets: bucket_cfg(cfg),
                stats: Some(stats.clone()),
                tracer: tracer.clone(),
                decode_threads: decode_threads(cfg),
                prefill_budget: cfg.prefill_budget,
                admit_per_cycle: cfg.admit_per_cycle,
            },
        );
        senders.push(tx);
        handles.push(handle);
        registries.push(stats);
        tracers.extend(tracer);
    }
    let router = Arc::new(Router::new(senders, cfg.route));
    router.set_capacity(cfg.max_queue);
    let stop = Arc::new(AtomicBool::new(false));
    println!("serving {} ({} replica(s)) on {}", cfg.model, cfg.replicas, cfg.addr);
    match &cfg.checkpoint {
        Some(p) => println!("weights: checkpoint {p}"),
        None => println!("weights: seeded init (pass --checkpoint PATH to serve trained weights)"),
    }
    // both thread counts print *resolved* (0 = auto already expanded to
    // the core count) so the operator sees what actually runs
    match prefill_cfg(cfg) {
        Some(p) => println!("prefill: chunked scan (w={}, {} thread(s))", p.chunk, p.threads),
        None => println!("prefill: decode-as-prefill (enable with --prefill-chunk N)"),
    }
    match cfg.prefill_budget {
        0 => println!("interleave: monolithic prefill (enable with --prefill-budget N)"),
        b => {
            println!(
                "interleave: parked prefills spend <= {b} prompt token(s) per cycle \
                 between decode steps"
            );
            if prefill_cfg(cfg).is_none() {
                println!("  (inert without --prefill-chunk: admissions never scan on the host twin)");
            }
        }
    }
    if cfg.admit_per_cycle > 0 {
        println!("admissions: capped at {} per cycle (burst fairness)", cfg.admit_per_cycle);
    }
    match cfg.max_queue {
        0 => println!("admission queue: unbounded (bound with --max-queue N)"),
        n => println!("admission queue: {n} in-flight cap — beyond it the typed overloaded reply"),
    }
    match decode_threads(cfg) {
        t if t > 1 => println!(
            "decode pool: {t} persistent worker(s) per engine (host-side paths: \
             model drafters; byte-identical to serial)"
        ),
        _ => println!("decode pool: serial (enable with --decode-threads N, 0 = auto)"),
    }
    match prefix_cache_cfg(cfg) {
        Some(c) => {
            println!(
                "prefix cache: {} per replica, boundary stride {} tokens — requests opt out with \"no_cache\": true",
                human_bytes(c.budget_bytes),
                c.chunk
            );
            if prefill_cfg(cfg).is_none() {
                println!("  (inert without --prefill-chunk: admissions never scan on the host twin)");
            }
        }
        None => println!("prefix cache: off (enable with --prefix-cache-mb N)"),
    }
    match bucket_cfg(cfg) {
        Some(b) => println!(
            "decode bucketing: {} (shrink after {} under-occupied step(s)) — \
             widths without artifacts are dropped at spawn",
            cfg.batch_buckets, b.shrink_after
        ),
        None => println!("decode bucketing: off (enable with --batch-buckets pow2)"),
    }
    match spec_cfg(cfg) {
        Some(s) => println!(
            "speculative decode: k={} (adaptive {}..{}), drafter {} — requests opt in with \"spec\": true",
            s.k,
            s.k_min,
            s.k_max,
            s.drafter.label()
        ),
        None => println!("speculative decode: off (enable with --spec-k N)"),
    }
    match &cfg.trace_out {
        Some(p) => println!(
            "tracing: spans -> {p} (sample {:.2}, re-exported every 60s) — inspect in Perfetto",
            cfg.trace_sample
        ),
        None => println!("tracing: off (enable with --trace-out PATH.json)"),
    }
    println!("stats: live registry on — poll with `hla top --addr {}` or a \"stats\" request", cfg.addr);
    // the serve loop only exits on kill, so report the fleet's live stats
    // and the session-store counters periodically from a daemon thread
    // (it dies with the process), and keep the trace file fresh
    {
        let store = store.clone();
        let registries = registries.clone();
        let tracers = tracers.clone();
        let trace_out = cfg.trace_out.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
            println!("[{}]", LiveStats::merged(&registries).summary_line());
            let st = store.stats();
            if st.snapshots > 0 {
                println!(
                    "sessions: {} snapshots, {} restores, resume hit-rate {:.2}, {} forks, {} spills, {} resident ({})",
                    st.snapshots,
                    st.restores,
                    st.hit_rate(),
                    st.forks,
                    st.spills,
                    st.resident,
                    human_bytes(st.resident_bytes),
                );
            }
            if let Some(path) = &trace_out {
                let pairs: Vec<(usize, &Tracer)> =
                    tracers.iter().enumerate().map(|(i, t)| (i, &**t)).collect();
                if let Err(e) = write_chrome_trace(std::path::Path::new(path), &pairs) {
                    eprintln!("[trace: writing {path} failed: {e}]");
                }
            }
        });
    }
    let obs = Arc::new(ServeObs { stats: registries, tracers: tracers.clone() });
    crate::server::serve_full(&cfg.addr, router, Some(store), Some(obs), stop, |addr| {
        println!("listening on {addr}");
    })?;
    if let Some(path) = &cfg.trace_out {
        export_trace(path, &tracers);
    }
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// `hla serve --fixture true` — the cluster-mode replica: the pure-Rust
/// fixture model behind the full wire protocol (sessions, stats, and the
/// control-plane verbs), no artifact directory needed.  Every fleet
/// member must share `--seed` so a failover replay on a different
/// process continues the stream byte-for-byte.
fn cmd_serve_fixture(cfg: &RunConfig) -> Result<()> {
    use crate::cluster::{fixture_identity, spawn_fixture_engine_pooled};
    use crate::testing::fixtures::{build_model_full, ModelShape};

    let store = Arc::new(SessionStore::new(StoreCfg {
        capacity: cfg.session_capacity,
        spill_dir: cfg.spill_dir.clone().map(std::path::PathBuf::from),
    }));
    let shape = ModelShape::default();
    let mut senders = vec![];
    let mut handles = vec![];
    let mut registries = vec![];
    let mut tracers = vec![];
    let mut identity = None;
    for _ in 0..cfg.replicas.max(1) {
        // identical weights in every engine (same seed): a failover
        // replay may land on any of them and must continue the stream
        let model = build_model_full("hla2", &shape, cfg.seed);
        if identity.is_none() {
            identity = Some(Arc::new(fixture_identity(&model)));
        }
        let stats = Arc::new(LiveStats::new());
        let tracer = tracer_cfg(cfg);
        let (tx, handle) = spawn_fixture_engine_pooled(
            model,
            store.clone(),
            stats.clone(),
            tracer.clone(),
            decode_threads(cfg),
        );
        senders.push(tx);
        handles.push(handle);
        registries.push(stats);
        tracers.extend(tracer);
    }
    let identity = identity.expect("at least one engine spawns");
    let router = Arc::new(Router::new(senders, cfg.route));
    router.set_capacity(cfg.max_queue);
    let stop = Arc::new(AtomicBool::new(false));
    println!(
        "serving fixture model on {} ({} engine(s), cfg {}, fingerprint {:016x}, {} state/session)",
        cfg.addr,
        cfg.replicas.max(1),
        identity.cfg_name,
        identity.cfg_fingerprint,
        human_bytes(identity.state_bytes),
    );
    match decode_threads(cfg) {
        t if t > 1 => println!(
            "decode pool: {t} persistent worker(s) per engine (byte-identical to serial)"
        ),
        _ => println!("decode pool: serial (enable with --decode-threads N, 0 = auto)"),
    }
    match &cfg.trace_out {
        Some(_) => println!(
            "tracing: replica spans on (sample {:.2}) — pull the ring with the \
             trace_export verb or `hla trace-stitch`",
            cfg.trace_sample
        ),
        None => println!("tracing: off (enable with --trace-out PATH.json)"),
    }
    let obs = Arc::new(ServeObs { stats: registries, tracers });
    crate::server::serve_cluster(
        &cfg.addr,
        router,
        Some(store),
        Some(obs),
        Some(identity),
        stop,
        |addr| println!("listening on {addr}"),
    )?;
    for h in handles {
        let _ = h.join();
    }
    Ok(())
}

/// `hla router` — the cluster front-end: speaks the client protocol on
/// `--addr`, routes across the `--replicas` fleet, holds end-of-turn
/// session snapshots, and fails streams over mid-generation when a
/// replica dies.
fn cmd_router(cfg: &RunConfig) -> Result<()> {
    use crate::cluster::{serve_frontend, EventLog, Frontend, FrontendCfg};

    if cfg.replica_addrs.is_empty() {
        bail!("router: --replicas host:port,host:port,... is required\n{USAGE}");
    }
    let tracer = tracer_cfg(cfg);
    let events = match &cfg.event_log {
        Some(p) => Some(
            EventLog::with_journal(std::path::Path::new(p))
                .map_err(|e| anyhow!("router: --event-log {p}: {e}"))?,
        ),
        None => None,
    };
    let fe = Arc::new(
        Frontend::new(FrontendCfg {
            replica_addrs: cfg.replica_addrs.clone(),
            policy: cfg.route,
            health_interval: std::time::Duration::from_secs_f64(cfg.health_interval),
            ..FrontendCfg::default()
        })
        .with_observability(tracer, events),
    );
    println!(
        "routing across {} replica(s): {} (probe every {}s, 3 misses = dead)",
        cfg.replica_addrs.len(),
        cfg.replica_addrs.join(", "),
        cfg.health_interval,
    );
    match &cfg.trace_out {
        Some(p) => println!(
            "tracing: minting trace ids, relay spans on — stitched fleet trace \
             re-exported to {p} every 60s (inspect in Perfetto)"
        ),
        None => println!("tracing: off (enable with --trace-out PATH.json)"),
    }
    match &cfg.event_log {
        Some(p) => println!("events: journaling to {p}; poll the ring with {{\"events\": N}}"),
        None => println!("events: ring only (journal with --event-log PATH.jsonl)"),
    }
    if let Some(path) = cfg.trace_out.clone() {
        let fe = fe.clone();
        std::thread::spawn(move || loop {
            std::thread::sleep(std::time::Duration::from_secs(60));
            stitch_fleet(&fe, &path);
        });
    }
    if let Some(target) = &cfg.drain {
        let idx = cfg
            .replica_addrs
            .iter()
            .position(|a| a == target)
            .ok_or_else(|| anyhow!("drain: {target} is not in --replicas"))?;
        // register first so the drained sessions have live destinations
        fe.register_all()?;
        let moved = fe.drain_replica(idx)?;
        println!("drained {moved} session(s) off {target}");
    }
    let stop = Arc::new(AtomicBool::new(false));
    serve_frontend(&cfg.addr, fe, stop, |addr| println!("listening on {addr}"))
}

/// One stitched-trace export: the router's own ring (pid 0) plus every
/// live replica's `trace_export` ring, rebased onto one timeline.
fn stitch_fleet(fe: &crate::cluster::Frontend, path: &str) {
    use crate::metrics::stitch::{write_stitched, ProcessTrace};
    let Some(t) = &fe.tracer else { return };
    let mut procs = vec![ProcessTrace::from_tracer("router", t)];
    for i in fe.registry.alive_indices() {
        let addr = fe.registry.replicas[i].addr.clone();
        let pulled = fe
            .control(i)
            .and_then(|mut c| c.trace_export())
            .and_then(|j| ProcessTrace::from_export(&j));
        match pulled {
            Ok(mut p) => {
                p.name = format!("replica {addr}");
                procs.push(p);
            }
            // a replica serving without --trace-out answers with a typed
            // error: it just contributes no pid to the stitched view
            Err(e) => log::warn!("trace: replica {addr} contributed no ring: {e}"),
        }
    }
    if let Err(e) = write_stitched(std::path::Path::new(path), &procs) {
        eprintln!("[trace: writing {path} failed: {e}]");
    }
}

/// `hla trace-stitch` — pull the span ring of every listed endpoint over
/// the wire (the `trace_export` control verb; routers answer it too) and
/// write one stitched Chrome trace.  List the router first: `procs[0]`
/// becomes pid 0 by convention.
fn cmd_trace_stitch(cfg: &RunConfig) -> Result<()> {
    use crate::metrics::stitch::{write_stitched, ProcessTrace};
    use crate::server::client::Client;
    if cfg.replica_addrs.is_empty() {
        bail!("trace-stitch: --replicas host:port,host:port,... is required\n{USAGE}");
    }
    let out = cfg.trace_out.clone().unwrap_or_else(|| "stitched_trace.json".to_string());
    let mut procs = Vec::new();
    for addr in &cfg.replica_addrs {
        let export = Client::connect(addr)
            .and_then(|mut c| c.trace_export())
            .map_err(|e| anyhow!("trace-stitch: {addr}: {e}"))?;
        let mut p = ProcessTrace::from_export(&export)
            .map_err(|e| anyhow!("trace-stitch: {addr}: {e}"))?;
        p.name = format!("{} ({addr})", p.name);
        println!("pulled {} span(s) from {addr}", p.spans.len());
        procs.push(p);
    }
    write_stitched(std::path::Path::new(&out), &procs)?;
    println!(
        "stitched {} process(es) -> {out} (load in Perfetto / chrome://tracing)",
        procs.len()
    );
    Ok(())
}

/// `hla top` — poll a live server's `"stats"` request and print one
/// merged summary line per tick (a `top`-style view of the fleet).
/// Against a cluster front-end the reply also carries the `"router"`
/// section and the fleet roster, rendered as per-replica rows.
fn cmd_top(cfg: &RunConfig) -> Result<()> {
    use crate::metrics::ServeStats;
    use crate::server::client::Client;
    use crate::util::json::Json;
    let mut client = Client::connect(&cfg.addr)
        .map_err(|e| anyhow!("top: connecting {}: {e} (is `hla serve` running?)", cfg.addr))?;
    let mut tick = 0usize;
    loop {
        let reply = client.stats_reply().map_err(|e| anyhow!("top: {e}"))?;
        let merged = reply.get("stats").map(ServeStats::from_json).unwrap_or_default();
        println!("[{}]", merged.summary_line());
        if let Some(router) = reply.get("router") {
            render_router_section(router, &reply);
        }
        tick += 1;
        if cfg.count > 0 && tick >= cfg.count {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(cfg.interval));
    }
}

/// The front-end half of a `hla top` tick: router health on one line,
/// then one row per replica in the fleet roster.
fn render_router_section(router: &crate::util::json::Json, reply: &crate::util::json::Json) {
    use crate::util::json::Json;
    let n = |path: &str| router.path(path).and_then(Json::as_f64).unwrap_or(0.0);
    println!(
        "[router: {} relay(s) p50 {:.0}us overhead p50 {:.0}us | {} failover(s) \
         {} line(s) suppressed | {} strike(s) {} revival(s) | desk {}]",
        n("relays"),
        n("relay_us.p50"),
        n("overhead_us.p50"),
        n("failovers"),
        n("replayed_suppressed"),
        n("strikes"),
        n("revivals"),
        n("desk_sessions"),
    );
    if let Some(rows) = router.get("per_replica").and_then(Json::as_arr) {
        for r in rows {
            let s = |k: &str| r.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
            let f = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            let alive = match r.get("alive").and_then(Json::as_bool) {
                Some(true) => "alive",
                Some(false) => "DEAD",
                None => "?",
            };
            println!(
                "  {} {alive}: {} in flight, {} relay(s), ttft p50 {:.0}us",
                s("addr"),
                f("in_flight"),
                f("relays"),
                f("ttft_us_p50"),
            );
        }
    }
    if let Some(skipped) = reply.get("skipped").and_then(Json::as_arr) {
        for sk in skipped {
            let addr = sk.get("addr").and_then(Json::as_str).unwrap_or("?");
            let err = sk.get("error").and_then(Json::as_str).unwrap_or("?");
            println!("  {addr} SKIPPED: {err}");
        }
    }
}

/// `hla sessions <list|inspect|evict>` — operate on a spill directory (the
/// disk tier is the only cross-process view of a session store).
fn cmd_sessions(rest: &[String]) -> Result<()> {
    let Some((action, flags)) = rest.split_first() else {
        bail!("sessions: expected <list|inspect|evict>\n{USAGE}");
    };
    let cfg = RunConfig::from_args(flags)?;
    let dir = std::path::PathBuf::from(
        cfg.spill_dir.ok_or_else(|| anyhow!("sessions: --spill-dir DIR is required"))?,
    );
    match action.as_str() {
        "list" => {
            let snaps = spill_sessions(&dir)?;
            let mut table = crate::metrics::Table::new(&[
                "session", "config", "tokens", "state", "components",
            ]);
            for s in &snaps {
                table.row(&[
                    s.id.to_string(),
                    s.cfg_name.clone(),
                    s.tokens_generated.to_string(),
                    human_bytes(s.state_nbytes()),
                    s.state.len().to_string(),
                ]);
            }
            print!("{}", table.render());
            println!("{} spilled session(s) in {}", snaps.len(), dir.display());
            Ok(())
        }
        "inspect" => {
            let id = cfg.session_id.ok_or_else(|| anyhow!("inspect: --session-id N required"))?;
            let path = spill_file(&dir, id);
            let bytes = std::fs::read(&path)
                .map_err(|e| anyhow!("unknown session {id} ({}: {e})", path.display()))?;
            let s = crate::session::SessionSnapshot::from_bytes(&bytes)?;
            println!("session {} (config {}, checksum OK)", s.id, s.cfg_name);
            println!("  tokens generated: {}", s.tokens_generated);
            println!("  last token:       {} ({:?})", s.last_token, s.last_token as char);
            println!(
                "  sampler:          temp {} top_k {} seed {} rng {:#018x}",
                s.sampler.temperature, s.sampler.top_k, s.sampler.seed, s.sampler.rng_state
            );
            println!("  state:            {} ({} components)", human_bytes(s.state_nbytes()), s.state.len());
            for (i, t) in s.state.iter().enumerate() {
                println!("    [{i}] shape {:?} ({})", t.shape, human_bytes(t.nbytes()));
            }
            Ok(())
        }
        "evict" => {
            let id = cfg.session_id.ok_or_else(|| anyhow!("evict: --session-id N required"))?;
            let path = spill_file(&dir, id);
            std::fs::remove_file(&path)
                .map_err(|e| anyhow!("unknown session {id} ({}: {e})", path.display()))?;
            println!("evicted session {id}");
            Ok(())
        }
        other => bail!("sessions: unknown action {other:?}\n{USAGE}"),
    }
}
