//! Cross-process trace stitching: merge the router's span ring and N
//! replica rings (pulled over the wire via the `trace_export` control
//! verb) into one Chrome trace-event JSON the whole fleet shares.
//!
//! Each process traces against its own private monotonic epoch, so raw
//! `start_us` values from two processes are not comparable.  The export
//! form ([`Tracer::export_json`]) therefore carries `anchor_unix_us` —
//! the epoch expressed as unix microseconds — and the stitcher rebases
//! every span onto one timeline: `ts = (anchor - min_anchor) + start_us`.
//! Process 0 is the router by convention (pid 0), replicas follow in
//! order (pid i).  A request that traversed router → replica →
//! failover → survivor shows up as one trace id across three pids, with
//! flow arrows from the router's `relay` span to each replica `admission`
//! span that shares its trace id, and failovers/migrations rendered as
//! instant events on the router track.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::trace::{SpanEvent, Stage, Tracer, TRACE_EXPORT_SCHEMA};

/// One process's contribution to a stitched trace: its name, its epoch
/// as unix microseconds, and its decoded span ring.
#[derive(Debug, Clone)]
pub struct ProcessTrace {
    pub name: String,
    pub anchor_unix_us: u64,
    pub spans: Vec<SpanEvent>,
}

impl ProcessTrace {
    /// Decode a `trace_export` payload (the [`Tracer::export_json`] wire
    /// form).  Unparseable spans are skipped, a missing anchor or schema
    /// mismatch is an error — silently stitching rings from two layouts
    /// would misplace every span.
    pub fn from_export(j: &Json) -> Result<ProcessTrace> {
        let schema = j
            .get("schema")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace export: missing \"schema\""))?;
        if schema != TRACE_EXPORT_SCHEMA {
            return Err(anyhow!("trace export: schema {schema:?}, want {TRACE_EXPORT_SCHEMA:?}"));
        }
        let anchor = j
            .get("anchor_unix_us")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("trace export: missing \"anchor_unix_us\""))?;
        let name = j.get("name").and_then(Json::as_str).unwrap_or("unnamed").to_string();
        let spans = j
            .get("spans")
            .and_then(Json::as_arr)
            .map(|v| v.iter().filter_map(SpanEvent::from_json).collect())
            .unwrap_or_default();
        Ok(ProcessTrace { name, anchor_unix_us: anchor as u64, spans })
    }

    /// Local shortcut: snapshot an in-process tracer (the router stitching
    /// its own ring alongside the replicas' wire exports).
    pub fn from_tracer(name: &str, t: &Tracer) -> ProcessTrace {
        // round-trip through the export form so the local path and the
        // wire path can never diverge
        Self::from_export(&t.export_json(name)).expect("own export is always well-formed")
    }
}

/// Merge process traces into one Chrome trace-event document.  `procs[0]`
/// becomes pid 0 (the router by convention), `procs[i]` pid i.
pub fn stitch(procs: &[ProcessTrace]) -> Json {
    let base = procs.iter().map(|p| p.anchor_unix_us).min().unwrap_or(0);
    let mut events = Vec::new();
    // flow arrows bind by trace id: the router's relay span starts the
    // flow, every same-id admission span on another pid terminates it
    let mut flow_starts: Vec<(u64, u64)> = Vec::new(); // (request, rebased ts)
    for (pid, p) in procs.iter().enumerate() {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as u32)),
            ("args", Json::obj(vec![("name", Json::str(p.name.clone()))])),
        ]));
        let mut tids_seen = vec![];
        for e in &p.spans {
            let tid = e.lane.map_or(0, |l| l + 1);
            let ts = (p.anchor_unix_us - base) + e.start_us;
            if !tids_seen.contains(&tid) {
                tids_seen.push(tid);
                let tname =
                    if tid == 0 { "engine".to_string() } else { format!("lane {}", tid - 1) };
                events.push(Json::obj(vec![
                    ("name", Json::str("thread_name")),
                    ("ph", Json::str("M")),
                    ("pid", Json::num(pid as u32)),
                    ("tid", Json::num(tid as u32)),
                    ("args", Json::obj(vec![("name", Json::str(tname))])),
                ]));
            }
            let args = Json::obj(vec![
                ("request", Json::str(format!("{:016x}", e.request))),
                ("detail", Json::num(e.detail)),
            ]);
            let mut fields = vec![
                ("name", Json::str(e.stage.name())),
                ("cat", Json::str(if e.lane.is_some() { "request" } else { "engine" })),
                ("ph", Json::str(if e.instant() { "i" } else { "X" })),
                ("ts", Json::num(ts as f64)),
                ("pid", Json::num(pid as u32)),
                ("tid", Json::num(tid as u32)),
                ("args", args),
            ];
            if e.instant() {
                fields.push(("s", Json::str("t")));
            } else {
                fields.push(("dur", Json::num(e.dur_us as f64)));
            }
            events.push(Json::obj(fields));
            if pid == 0 && e.stage == Stage::Relay {
                flow_starts.push((e.request, ts));
                events.push(flow_event("s", e.request, 0, tid, ts));
            }
        }
    }
    // terminate each flow at every same-id admission span on a replica pid
    for (pid, p) in procs.iter().enumerate().skip(1) {
        for e in &p.spans {
            if e.stage != Stage::Admission {
                continue;
            }
            if flow_starts.iter().any(|(req, _)| *req == e.request) {
                let ts = (p.anchor_unix_us - base) + e.start_us;
                let tid = e.lane.map_or(0, |l| l + 1);
                events.push(flow_event("f", e.request, pid, tid, ts));
            }
        }
    }
    Json::obj(vec![("traceEvents", Json::Arr(events)), ("displayTimeUnit", Json::str("ms"))])
}

fn flow_event(ph: &str, request: u64, pid: usize, tid: usize, ts: u64) -> Json {
    let mut fields = vec![
        ("name", Json::str("request")),
        ("cat", Json::str("flow")),
        ("ph", Json::str(ph)),
        ("id", Json::str(format!("{request:016x}"))),
        ("ts", Json::num(ts as f64)),
        ("pid", Json::num(pid as u32)),
        ("tid", Json::num(tid as u32)),
    ];
    if ph == "f" {
        fields.push(("bp", Json::str("e"))); // bind to the enclosing slice
    }
    Json::obj(fields)
}

/// Stitch and write atomically (tmp + rename), same contract as
/// [`write_chrome_trace`](super::trace::write_chrome_trace).
pub fn write_stitched(path: &Path, procs: &[ProcessTrace]) -> Result<()> {
    let doc = stitch(procs);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc.to_string()).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::trace::TraceCfg;
    use std::time::Instant;

    fn traced(name: &str, f: impl Fn(&Tracer)) -> ProcessTrace {
        let t = Tracer::new(&TraceCfg { sample: 1.0, capacity: 64 });
        f(&t);
        ProcessTrace::from_tracer(name, &t)
    }

    /// Every stitched document must satisfy what Perfetto's loader needs:
    /// known phases, durations on complete events, pids everywhere.
    fn assert_perfetto_parses(doc: &Json) -> Vec<String> {
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let mut names = vec![];
        for e in events {
            let ph = e.get("ph").and_then(Json::as_str).unwrap();
            assert!(["X", "i", "M", "s", "f"].contains(&ph), "unknown phase {ph}");
            if ph == "X" {
                assert!(e.get("dur").and_then(Json::as_f64).is_some());
            }
            if ph == "s" || ph == "f" {
                assert!(e.get("id").and_then(Json::as_str).is_some(), "flows need ids");
            }
            assert!(e.get("pid").and_then(Json::as_f64).is_some());
            names.push(e.get("name").and_then(Json::as_str).unwrap().to_string());
        }
        names
    }

    #[test]
    fn stitches_router_and_replicas_onto_one_timeline() {
        let trace_id = 0xfeed_face_0000_0001u64;
        let start = Instant::now();
        let router = traced("router", |t| {
            t.span(Stage::Relay, trace_id, 0, start, 9);
            t.instant_event(Stage::Failover, trace_id, 0, 0);
        });
        let rep_a = traced("replica 127.0.0.1:7001", |t| {
            t.span(Stage::Admission, trace_id, 0, start, 5);
            t.span(Stage::Prefill, trace_id, 0, start, 5);
        });
        let rep_b = traced("replica 127.0.0.1:7002", |t| {
            t.span(Stage::Admission, trace_id, 1, start, 5);
        });
        let doc = stitch(&[router, rep_a, rep_b]);
        let names = assert_perfetto_parses(&doc);
        for want in ["relay", "failover", "admission", "process_name"] {
            assert!(names.iter().any(|n| n == want), "missing {want}");
        }
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        // one flow start on pid 0, one flow finish per replica admission
        let flows = |ph: &str| {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
                .collect::<Vec<_>>()
        };
        assert_eq!(flows("s").len(), 1);
        assert_eq!(flows("f").len(), 2, "both replicas admitted the trace id");
        for f in flows("f") {
            assert_eq!(
                f.get("id").and_then(Json::as_str),
                Some(format!("{trace_id:016x}").as_str())
            );
            assert!(f.get("pid").and_then(Json::as_f64).unwrap() > 0.0);
        }
        // the failover rides pid 0 as an instant event
        let failover = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("failover"))
            .unwrap();
        assert_eq!(failover.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(failover.get("pid").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn anchors_rebase_onto_the_earliest_process() {
        let start = Instant::now();
        let mut early = traced("router", |t| t.span(Stage::Relay, 1, 0, start, 0));
        let mut late = traced("replica", |t| t.span(Stage::Admission, 1, 0, start, 0));
        // force a known 500us anchor gap regardless of wall-clock jitter
        late.anchor_unix_us = early.anchor_unix_us + 500;
        early.spans[0].start_us = 100;
        late.spans[0].start_us = 100;
        let doc = stitch(&[early, late]);
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let ts_of = |name: &str| {
            events
                .iter()
                .find(|e| {
                    e.get("name").and_then(Json::as_str) == Some(name)
                        && e.get("ph").and_then(Json::as_str) != Some("s")
                })
                .and_then(|e| e.get("ts"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(ts_of("relay"), 100.0);
        assert_eq!(ts_of("admission"), 600.0, "later process shifts by the anchor gap");
    }

    #[test]
    fn write_is_atomic_and_reparseable() {
        let dir = std::env::temp_dir().join(format!("hla_stitch_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stitched.json");
        let start = Instant::now();
        let p = traced("router", |t| t.span(Stage::Relay, 3, 0, start, 1));
        write_stitched(&path, &[p]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_perfetto_parses(&doc);
        assert!(!dir.join("stitched.json.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
