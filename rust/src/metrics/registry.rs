//! Live metrics registry: the shared, always-current serving counters.
//!
//! PRs 1–5 accumulated metrics as private fields on the engine loop,
//! visible only as the [`ServeStats`] value returned when the loop
//! *exits* — useless for a server that exits on SIGKILL.  [`LiveStats`]
//! inverts that: the engine updates a shared registry of lock-free
//! [`Counter`]s and lock-guarded [`SharedHistogram`]s **in place**, and
//! any thread can take a consistent [`LiveStats::snapshot`] at any time —
//! the `"stats"` admin request on the wire protocol, the `hla top`
//! polling view, the 60s serve heartbeat.  Multi-replica deployments
//! merge per-replica registries with [`LiveStats::merged`]: counters add,
//! histograms merge bucket-wise (exactly — see the merge property test in
//! the parent module), occupancy merges as a ratio of summed tallies.
//!
//! [`ServeStats`] itself (the snapshot type, its wire JSON form, the
//! Prometheus text form, and the one-line [`ServeStats::summary_line`]
//! every CLI surface prints) lives here too; `coordinator` re-exports it,
//! so existing `hla::coordinator::ServeStats` imports still hold.

use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;

use super::{hit_rate, Counter, Histogram, SharedHistogram, Table};

/// Aggregated serving metrics, snapshotted for benches/CLI/the wire.
///
/// TTFT (submission → first token) splits into queue-wait (submission →
/// admission), prefill (admission-time prompt ingestion) and first-decode
/// (decode steps until the first sampled token) — the three knobs a
/// serving operator can actually turn (batch width, prefill threads,
/// scheduler policy respectively).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub completed: u64,
    pub tokens_out: u64,
    pub steps: u64,
    pub elapsed_s: f64,
    pub step_us_p50: f64,
    pub step_us_p99: f64,
    pub ttft_us_p50: f64,
    pub ttft_us_p95: f64,
    pub ttft_us_p99: f64,
    pub queue_us_p50: f64,
    pub queue_us_p95: f64,
    pub queue_us_p99: f64,
    pub prefill_us_p50: f64,
    pub prefill_us_p95: f64,
    pub prefill_us_p99: f64,
    pub first_decode_us_p50: f64,
    pub first_decode_us_p95: f64,
    pub first_decode_us_p99: f64,
    /// Lanes whose prompt went through the scan prefill engine.
    pub prefills: u64,
    /// Prompt tokens ingested by the prefill engine (vs decode steps).
    pub prefilled_tokens: u64,
    /// Budgeted prefill window advances run (`--prefill-budget`; 0 =
    /// monolithic admission scans).
    pub prefill_chunks: u64,
    /// Requests waiting at the engine at snapshot time (gauge — the
    /// admission backpressure signal, reported in `overloaded` replies).
    pub queue_depth: u64,
    /// Gap between consecutive batched decode steps while decode-ready
    /// lanes existed — the head-of-line stall that monolithic admission
    /// scans inflict on in-flight decodes and `--prefill-budget` bounds
    /// (bench E22's headline).
    pub decode_stall_us_p50: f64,
    pub decode_stall_us_p99: f64,
    /// Prefix-cache lookups that seeded a prefill from a cached boundary
    /// / that found nothing reusable.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Boundary snapshots inserted / LRU-evicted under the byte budget.
    pub cache_inserts: u64,
    pub cache_evictions: u64,
    /// Prompt tokens skipped by warm hits (work the cache saved).
    pub cache_hit_tokens: u64,
    /// Bytes of cached boundary snapshots resident at snapshot time.
    pub cache_resident_bytes: usize,
    /// TTFT split by cache outcome: lanes seeded from a cached prefix
    /// (warm) vs lanes that scanned their whole prompt (cold) — the
    /// headline the shared-prefix workload buys (bench E16).
    pub ttft_warm_us_p50: f64,
    pub ttft_warm_us_p95: f64,
    pub ttft_warm_us_p99: f64,
    pub ttft_cold_us_p50: f64,
    pub ttft_cold_us_p95: f64,
    pub ttft_cold_us_p99: f64,
    pub latency_us_p50: f64,
    pub latency_us_p95: f64,
    pub latency_us_p99: f64,
    pub tokens_per_sec: f64,
    pub state_bytes: usize,
    pub lane_occupancy: f64,
    /// Bucket-layout grows (admission bursts) / shrinks (sustained
    /// under-occupancy) — both 0 when bucketing is off or never fired.
    pub bucket_grows: u64,
    pub bucket_shrinks: u64,
    /// Exact state repacks run (one per bucket switch) and their cost —
    /// the overhead side of the E17 trade.
    pub repacks: u64,
    pub repack_us_p50: f64,
    pub repack_us_p99: f64,
    /// Mean width of the batched decode steps actually executed
    /// (== `decode_batch` when bucketing is off).  Lower than the batch
    /// width at low occupancy is the bucketing win (bench E17).
    pub step_width_mean: f64,
    /// Speculative draft/verify rounds run across all lanes.
    pub spec_rounds: u64,
    /// Draft tokens proposed / accepted (acceptance rate = ratio).
    pub spec_drafted: u64,
    pub spec_accepted: u64,
    /// Rounds that restored the pre-draft O(state) snapshot.
    pub spec_rollbacks: u64,
    /// Tokens emitted by speculative rounds (vs. 1 per batched step).
    pub spec_tokens: u64,
}

/// Schema tag on the wire JSON form (bump on breaking field changes).
pub const STATS_SCHEMA: &str = "hla-stats/1";

impl ServeStats {
    /// Mean draft tokens accepted per speculative verify step (0 when no
    /// speculative rounds ran).  The serial baseline emits exactly 1
    /// token per step, so `accepted_per_step + 1` ≈ the per-step speedup
    /// surface.
    pub fn accepted_per_step(&self) -> f64 {
        if self.spec_rounds == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_rounds as f64
        }
    }

    /// Fraction of drafted tokens accepted (0 when nothing was drafted).
    pub fn spec_accept_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// Fraction of prefix-cache lookups that seeded a prefill (0 when the
    /// cache was off or never consulted).
    pub fn cache_hit_rate(&self) -> f64 {
        hit_rate(self.cache_hits, self.cache_misses)
    }

    /// Total bucket switches (grows + shrinks).  Under a healthy
    /// hysteresis setting this stays far below `steps`; a ratio near 1
    /// means the shrink debounce is too aggressive for the admission
    /// churn (raise `--bucket-shrink-after`).
    pub fn bucket_switches(&self) -> u64 {
        self.bucket_grows + self.bucket_shrinks
    }

    /// The TTFT breakdown as a [`Table`] (the reporter benches/CLI print).
    pub fn ttft_table(&self) -> Table {
        let mut t = Table::new(&["phase", "p50 ms", "p95 ms", "p99 ms"]);
        let mut row = |name: &str, p50: f64, p95: f64, p99: f64| {
            t.row(&[
                name.to_string(),
                format!("{:.2}", p50 / 1e3),
                format!("{:.2}", p95 / 1e3),
                format!("{:.2}", p99 / 1e3),
            ]);
        };
        row("queue-wait", self.queue_us_p50, self.queue_us_p95, self.queue_us_p99);
        row("prefill", self.prefill_us_p50, self.prefill_us_p95, self.prefill_us_p99);
        row(
            "first-decode",
            self.first_decode_us_p50,
            self.first_decode_us_p95,
            self.first_decode_us_p99,
        );
        row("ttft (e2e)", self.ttft_us_p50, self.ttft_us_p95, self.ttft_us_p99);
        row("ttft (warm-hit)", self.ttft_warm_us_p50, self.ttft_warm_us_p95, self.ttft_warm_us_p99);
        row("ttft (cold)", self.ttft_cold_us_p50, self.ttft_cold_us_p95, self.ttft_cold_us_p99);
        t
    }

    /// The one-line rollup every CLI surface prints — `generate`'s
    /// end-of-run line, `serve`'s heartbeat, each `hla top` poll.
    /// Optional subsystems (cache, spec, buckets) only appear once they
    /// have fired, so the line stays short on a plain engine and counters
    /// added later get a consumer by extending this one method.
    pub fn summary_line(&self) -> String {
        let mut s = format!(
            "{} req | {} tok | {:.1} tok/s | step p50/p99 {:.2}/{:.2} ms | \
             ttft p50 {:.1} ms | occ {:.2}",
            self.completed,
            self.tokens_out,
            self.tokens_per_sec,
            self.step_us_p50 / 1e3,
            self.step_us_p99 / 1e3,
            self.ttft_us_p50 / 1e3,
            self.lane_occupancy,
        );
        if self.cache_hits + self.cache_misses > 0 {
            s.push_str(&format!(
                " | cache {:.0}% hit ({} tok saved)",
                self.cache_hit_rate() * 100.0,
                self.cache_hit_tokens
            ));
        }
        if self.spec_rounds > 0 {
            s.push_str(&format!(
                " | spec {:.2} acc/step ({:.0}% rate)",
                self.accepted_per_step(),
                self.spec_accept_rate() * 100.0
            ));
        }
        if self.bucket_switches() > 0 {
            s.push_str(&format!(
                " | width {:.2} ({}g/{}s, repack p50 {:.0} us)",
                self.step_width_mean,
                self.bucket_grows,
                self.bucket_shrinks,
                self.repack_us_p50
            ));
        }
        s
    }

    /// The wire JSON form (the `"stats"` admin reply's payload): every
    /// struct field flat under its own name, plus the derived rates and
    /// the [`STATS_SCHEMA`] tag.
    pub fn to_json(&self) -> Json {
        let u = |v: u64| Json::num(v as f64);
        Json::obj(vec![
            ("schema", Json::str(STATS_SCHEMA)),
            ("completed", u(self.completed)),
            ("tokens_out", u(self.tokens_out)),
            ("steps", u(self.steps)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("step_us_p50", Json::num(self.step_us_p50)),
            ("step_us_p99", Json::num(self.step_us_p99)),
            ("ttft_us_p50", Json::num(self.ttft_us_p50)),
            ("ttft_us_p95", Json::num(self.ttft_us_p95)),
            ("ttft_us_p99", Json::num(self.ttft_us_p99)),
            ("queue_us_p50", Json::num(self.queue_us_p50)),
            ("queue_us_p95", Json::num(self.queue_us_p95)),
            ("queue_us_p99", Json::num(self.queue_us_p99)),
            ("prefill_us_p50", Json::num(self.prefill_us_p50)),
            ("prefill_us_p95", Json::num(self.prefill_us_p95)),
            ("prefill_us_p99", Json::num(self.prefill_us_p99)),
            ("first_decode_us_p50", Json::num(self.first_decode_us_p50)),
            ("first_decode_us_p95", Json::num(self.first_decode_us_p95)),
            ("first_decode_us_p99", Json::num(self.first_decode_us_p99)),
            ("prefills", u(self.prefills)),
            ("prefilled_tokens", u(self.prefilled_tokens)),
            ("prefill_chunks", u(self.prefill_chunks)),
            ("queue_depth", u(self.queue_depth)),
            ("decode_stall_us_p50", Json::num(self.decode_stall_us_p50)),
            ("decode_stall_us_p99", Json::num(self.decode_stall_us_p99)),
            ("cache_hits", u(self.cache_hits)),
            ("cache_misses", u(self.cache_misses)),
            ("cache_inserts", u(self.cache_inserts)),
            ("cache_evictions", u(self.cache_evictions)),
            ("cache_hit_tokens", u(self.cache_hit_tokens)),
            ("cache_resident_bytes", u(self.cache_resident_bytes as u64)),
            ("ttft_warm_us_p50", Json::num(self.ttft_warm_us_p50)),
            ("ttft_warm_us_p95", Json::num(self.ttft_warm_us_p95)),
            ("ttft_warm_us_p99", Json::num(self.ttft_warm_us_p99)),
            ("ttft_cold_us_p50", Json::num(self.ttft_cold_us_p50)),
            ("ttft_cold_us_p95", Json::num(self.ttft_cold_us_p95)),
            ("ttft_cold_us_p99", Json::num(self.ttft_cold_us_p99)),
            ("latency_us_p50", Json::num(self.latency_us_p50)),
            ("latency_us_p95", Json::num(self.latency_us_p95)),
            ("latency_us_p99", Json::num(self.latency_us_p99)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("state_bytes", u(self.state_bytes as u64)),
            ("lane_occupancy", Json::num(self.lane_occupancy)),
            ("bucket_grows", u(self.bucket_grows)),
            ("bucket_shrinks", u(self.bucket_shrinks)),
            ("repacks", u(self.repacks)),
            ("repack_us_p50", Json::num(self.repack_us_p50)),
            ("repack_us_p99", Json::num(self.repack_us_p99)),
            ("step_width_mean", Json::num(self.step_width_mean)),
            ("spec_rounds", u(self.spec_rounds)),
            ("spec_drafted", u(self.spec_drafted)),
            ("spec_accepted", u(self.spec_accepted)),
            ("spec_rollbacks", u(self.spec_rollbacks)),
            ("spec_tokens", u(self.spec_tokens)),
            // derived, for consumers that don't want to recompute
            ("cache_hit_rate", Json::num(self.cache_hit_rate())),
            ("spec_accept_rate", Json::num(self.spec_accept_rate())),
            ("accepted_per_step", Json::num(self.accepted_per_step())),
        ])
    }

    /// Rebuild a snapshot from its wire JSON form (`hla top`, the test
    /// client).  Missing fields read as 0 — a newer server may add
    /// fields, an older one lack them; neither should break the reader.
    pub fn from_json(j: &Json) -> ServeStats {
        let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let u = |k: &str| f(k) as u64;
        ServeStats {
            completed: u("completed"),
            tokens_out: u("tokens_out"),
            steps: u("steps"),
            elapsed_s: f("elapsed_s"),
            step_us_p50: f("step_us_p50"),
            step_us_p99: f("step_us_p99"),
            ttft_us_p50: f("ttft_us_p50"),
            ttft_us_p95: f("ttft_us_p95"),
            ttft_us_p99: f("ttft_us_p99"),
            queue_us_p50: f("queue_us_p50"),
            queue_us_p95: f("queue_us_p95"),
            queue_us_p99: f("queue_us_p99"),
            prefill_us_p50: f("prefill_us_p50"),
            prefill_us_p95: f("prefill_us_p95"),
            prefill_us_p99: f("prefill_us_p99"),
            first_decode_us_p50: f("first_decode_us_p50"),
            first_decode_us_p95: f("first_decode_us_p95"),
            first_decode_us_p99: f("first_decode_us_p99"),
            prefills: u("prefills"),
            prefilled_tokens: u("prefilled_tokens"),
            prefill_chunks: u("prefill_chunks"),
            queue_depth: u("queue_depth"),
            decode_stall_us_p50: f("decode_stall_us_p50"),
            decode_stall_us_p99: f("decode_stall_us_p99"),
            cache_hits: u("cache_hits"),
            cache_misses: u("cache_misses"),
            cache_inserts: u("cache_inserts"),
            cache_evictions: u("cache_evictions"),
            cache_hit_tokens: u("cache_hit_tokens"),
            cache_resident_bytes: u("cache_resident_bytes") as usize,
            ttft_warm_us_p50: f("ttft_warm_us_p50"),
            ttft_warm_us_p95: f("ttft_warm_us_p95"),
            ttft_warm_us_p99: f("ttft_warm_us_p99"),
            ttft_cold_us_p50: f("ttft_cold_us_p50"),
            ttft_cold_us_p95: f("ttft_cold_us_p95"),
            ttft_cold_us_p99: f("ttft_cold_us_p99"),
            latency_us_p50: f("latency_us_p50"),
            latency_us_p95: f("latency_us_p95"),
            latency_us_p99: f("latency_us_p99"),
            tokens_per_sec: f("tokens_per_sec"),
            state_bytes: u("state_bytes") as usize,
            lane_occupancy: f("lane_occupancy"),
            bucket_grows: u("bucket_grows"),
            bucket_shrinks: u("bucket_shrinks"),
            repacks: u("repacks"),
            repack_us_p50: f("repack_us_p50"),
            repack_us_p99: f("repack_us_p99"),
            step_width_mean: f("step_width_mean"),
            spec_rounds: u("spec_rounds"),
            spec_drafted: u("spec_drafted"),
            spec_accepted: u("spec_accepted"),
            spec_rollbacks: u("spec_rollbacks"),
            spec_tokens: u("spec_tokens"),
        }
    }

    /// Merge wire-form snapshots from independent replica *processes*
    /// into one fleet view — the cluster front-end's `"stats"` fan-out.
    /// Unlike [`LiveStats::merged`] (which merges the live histograms
    /// bucket-exactly), only each process's percentile summaries survive
    /// the wire, so percentile fields merge as weighted means: request-
    /// phase percentiles weight by completed requests, step-level ones by
    /// engine steps — approximate, but monotone and unit-correct.
    /// Counters sum, elapsed takes the longest-lived replica, throughput
    /// and occupancy recompute from the summed tallies.
    pub fn merge(snaps: &[ServeStats]) -> ServeStats {
        fn wmean(
            snaps: &[ServeStats],
            v: impl Fn(&ServeStats) -> f64,
            w: impl Fn(&ServeStats) -> f64,
        ) -> f64 {
            let total: f64 = snaps.iter().map(&w).sum();
            if total <= 0.0 {
                return 0.0;
            }
            snaps.iter().map(|s| v(s) * w(s)).sum::<f64>() / total
        }
        let by_req = |v: fn(&ServeStats) -> f64| wmean(snaps, v, |s| s.completed as f64);
        let by_step = |v: fn(&ServeStats) -> f64| wmean(snaps, v, |s| s.steps as f64);
        let mut out = ServeStats::default();
        for s in snaps {
            out.completed += s.completed;
            out.tokens_out += s.tokens_out;
            out.steps += s.steps;
            out.prefills += s.prefills;
            out.prefilled_tokens += s.prefilled_tokens;
            out.prefill_chunks += s.prefill_chunks;
            out.queue_depth += s.queue_depth;
            out.cache_hits += s.cache_hits;
            out.cache_misses += s.cache_misses;
            out.cache_inserts += s.cache_inserts;
            out.cache_evictions += s.cache_evictions;
            out.cache_hit_tokens += s.cache_hit_tokens;
            out.cache_resident_bytes += s.cache_resident_bytes;
            out.state_bytes += s.state_bytes;
            out.bucket_grows += s.bucket_grows;
            out.bucket_shrinks += s.bucket_shrinks;
            out.repacks += s.repacks;
            out.spec_rounds += s.spec_rounds;
            out.spec_drafted += s.spec_drafted;
            out.spec_accepted += s.spec_accepted;
            out.spec_rollbacks += s.spec_rollbacks;
            out.spec_tokens += s.spec_tokens;
            out.elapsed_s = out.elapsed_s.max(s.elapsed_s);
        }
        out.tokens_per_sec = out.tokens_out as f64 / out.elapsed_s.max(1e-9);
        out.step_us_p50 = by_step(|s| s.step_us_p50);
        out.step_us_p99 = by_step(|s| s.step_us_p99);
        out.repack_us_p50 = by_step(|s| s.repack_us_p50);
        out.repack_us_p99 = by_step(|s| s.repack_us_p99);
        out.decode_stall_us_p50 = by_step(|s| s.decode_stall_us_p50);
        out.decode_stall_us_p99 = by_step(|s| s.decode_stall_us_p99);
        out.lane_occupancy = by_step(|s| s.lane_occupancy);
        out.step_width_mean = by_step(|s| s.step_width_mean);
        out.ttft_us_p50 = by_req(|s| s.ttft_us_p50);
        out.ttft_us_p95 = by_req(|s| s.ttft_us_p95);
        out.ttft_us_p99 = by_req(|s| s.ttft_us_p99);
        out.queue_us_p50 = by_req(|s| s.queue_us_p50);
        out.queue_us_p95 = by_req(|s| s.queue_us_p95);
        out.queue_us_p99 = by_req(|s| s.queue_us_p99);
        out.prefill_us_p50 = by_req(|s| s.prefill_us_p50);
        out.prefill_us_p95 = by_req(|s| s.prefill_us_p95);
        out.prefill_us_p99 = by_req(|s| s.prefill_us_p99);
        out.first_decode_us_p50 = by_req(|s| s.first_decode_us_p50);
        out.first_decode_us_p95 = by_req(|s| s.first_decode_us_p95);
        out.first_decode_us_p99 = by_req(|s| s.first_decode_us_p99);
        out.ttft_warm_us_p50 = by_req(|s| s.ttft_warm_us_p50);
        out.ttft_warm_us_p95 = by_req(|s| s.ttft_warm_us_p95);
        out.ttft_warm_us_p99 = by_req(|s| s.ttft_warm_us_p99);
        out.ttft_cold_us_p50 = by_req(|s| s.ttft_cold_us_p50);
        out.ttft_cold_us_p95 = by_req(|s| s.ttft_cold_us_p95);
        out.ttft_cold_us_p99 = by_req(|s| s.ttft_cold_us_p99);
        out.latency_us_p50 = by_req(|s| s.latency_us_p50);
        out.latency_us_p95 = by_req(|s| s.latency_us_p95);
        out.latency_us_p99 = by_req(|s| s.latency_us_p99);
        out
    }

    /// Prometheus text exposition of the snapshot (`{"stats":
    /// "prometheus"}` on the wire; travels as a JSON string so the
    /// protocol stays line-JSON).  Counters as `_total`, gauges plain,
    /// histogram percentiles as `{quantile="..."}` series.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut counter = |name: &str, v: u64| {
            out.push_str(&format!("# TYPE hla_{name}_total counter\nhla_{name}_total {v}\n"));
        };
        counter("requests_completed", self.completed);
        counter("tokens_out", self.tokens_out);
        counter("engine_steps", self.steps);
        counter("prefills", self.prefills);
        counter("prefilled_tokens", self.prefilled_tokens);
        counter("prefill_chunks", self.prefill_chunks);
        counter("cache_hits", self.cache_hits);
        counter("cache_misses", self.cache_misses);
        counter("cache_inserts", self.cache_inserts);
        counter("cache_evictions", self.cache_evictions);
        counter("cache_hit_tokens", self.cache_hit_tokens);
        counter("bucket_grows", self.bucket_grows);
        counter("bucket_shrinks", self.bucket_shrinks);
        counter("repacks", self.repacks);
        counter("spec_rounds", self.spec_rounds);
        counter("spec_drafted", self.spec_drafted);
        counter("spec_accepted", self.spec_accepted);
        counter("spec_rollbacks", self.spec_rollbacks);
        counter("spec_tokens", self.spec_tokens);
        let mut gauge = |name: &str, v: f64| {
            out.push_str(&format!("# TYPE hla_{name} gauge\nhla_{name} {v}\n"));
        };
        gauge("elapsed_seconds", self.elapsed_s);
        gauge("tokens_per_sec", self.tokens_per_sec);
        gauge("lane_occupancy", self.lane_occupancy);
        gauge("step_width_mean", self.step_width_mean);
        gauge("state_bytes", self.state_bytes as f64);
        gauge("cache_resident_bytes", self.cache_resident_bytes as f64);
        gauge("queue_depth", self.queue_depth as f64);
        let mut quant = |name: &str, series: &[(&str, f64)]| {
            out.push_str(&format!("# TYPE hla_{name}_us summary\n"));
            for (q, v) in series {
                out.push_str(&format!("hla_{name}_us{{quantile=\"{q}\"}} {v}\n"));
            }
        };
        quant("step", &[("0.5", self.step_us_p50), ("0.99", self.step_us_p99)]);
        quant(
            "ttft",
            &[("0.5", self.ttft_us_p50), ("0.95", self.ttft_us_p95), ("0.99", self.ttft_us_p99)],
        );
        quant(
            "queue",
            &[("0.5", self.queue_us_p50), ("0.95", self.queue_us_p95), ("0.99", self.queue_us_p99)],
        );
        quant(
            "prefill",
            &[
                ("0.5", self.prefill_us_p50),
                ("0.95", self.prefill_us_p95),
                ("0.99", self.prefill_us_p99),
            ],
        );
        quant(
            "first_decode",
            &[
                ("0.5", self.first_decode_us_p50),
                ("0.95", self.first_decode_us_p95),
                ("0.99", self.first_decode_us_p99),
            ],
        );
        quant(
            "ttft_warm",
            &[
                ("0.5", self.ttft_warm_us_p50),
                ("0.95", self.ttft_warm_us_p95),
                ("0.99", self.ttft_warm_us_p99),
            ],
        );
        quant(
            "ttft_cold",
            &[
                ("0.5", self.ttft_cold_us_p50),
                ("0.95", self.ttft_cold_us_p95),
                ("0.99", self.ttft_cold_us_p99),
            ],
        );
        quant(
            "latency",
            &[
                ("0.5", self.latency_us_p50),
                ("0.95", self.latency_us_p95),
                ("0.99", self.latency_us_p99),
            ],
        );
        quant("repack", &[("0.5", self.repack_us_p50), ("0.99", self.repack_us_p99)]);
        quant(
            "decode_stall",
            &[("0.5", self.decode_stall_us_p50), ("0.99", self.decode_stall_us_p99)],
        );
        out
    }
}

/// The live registry one engine replica writes into: lock-free counters
/// for the tallies, lock-guarded histograms for the latency phases, and
/// two mirrored gauges (`batch_lanes`, `state_bytes`) the occupancy and
/// footprint derivations need.  All fields are public — the engine loop
/// updates them directly on its hot path (an atomic add per event), and
/// artifact-free tests drive them without an engine.
#[derive(Debug)]
pub struct LiveStats {
    pub started: Instant,
    /// Batch width of the owning replica (occupancy denominator).
    pub batch_lanes: Counter,
    pub completed: Counter,
    pub tokens_out: Counter,
    /// Engine cycles that served at least one lane.
    pub steps: Counter,
    /// Sum over steps of live lanes served (occupancy numerator).
    pub occupied_lanes: Counter,
    /// Batched decode steps executed / sum of their widths.
    pub batched_steps: Counter,
    pub width_steps: Counter,
    pub prefills: Counter,
    pub prefilled_tokens: Counter,
    /// Budgeted prefill window advances (one per cursor visit).
    pub prefill_chunks: Counter,
    /// Waiting requests at the engine (gauge — set once per cycle).
    pub queue_depth: Counter,
    pub bucket_grows: Counter,
    pub bucket_shrinks: Counter,
    // gauges mirrored from subsystems that own their accounting
    pub state_bytes: Counter,
    pub cache_hits: Counter,
    pub cache_misses: Counter,
    pub cache_inserts: Counter,
    pub cache_evictions: Counter,
    pub cache_hit_tokens: Counter,
    pub cache_resident_bytes: Counter,
    pub spec_rounds: Counter,
    pub spec_drafted: Counter,
    pub spec_accepted: Counter,
    pub spec_rollbacks: Counter,
    pub spec_tokens: Counter,
    // latency phases
    pub step_hist: SharedHistogram,
    pub ttft_hist: SharedHistogram,
    pub latency_hist: SharedHistogram,
    pub queue_hist: SharedHistogram,
    pub prefill_hist: SharedHistogram,
    pub first_decode_hist: SharedHistogram,
    pub ttft_warm_hist: SharedHistogram,
    pub ttft_cold_hist: SharedHistogram,
    pub repack_hist: SharedHistogram,
    /// Gap between consecutive batched decode steps while decode-ready
    /// lanes existed (the interleaving headline — bench E22).
    pub decode_stall_hist: SharedHistogram,
}

impl Default for LiveStats {
    fn default() -> Self {
        Self::new()
    }
}

impl LiveStats {
    pub fn new() -> LiveStats {
        LiveStats {
            started: Instant::now(),
            batch_lanes: Counter::new(),
            completed: Counter::new(),
            tokens_out: Counter::new(),
            steps: Counter::new(),
            occupied_lanes: Counter::new(),
            batched_steps: Counter::new(),
            width_steps: Counter::new(),
            prefills: Counter::new(),
            prefilled_tokens: Counter::new(),
            prefill_chunks: Counter::new(),
            queue_depth: Counter::new(),
            bucket_grows: Counter::new(),
            bucket_shrinks: Counter::new(),
            state_bytes: Counter::new(),
            cache_hits: Counter::new(),
            cache_misses: Counter::new(),
            cache_inserts: Counter::new(),
            cache_evictions: Counter::new(),
            cache_hit_tokens: Counter::new(),
            cache_resident_bytes: Counter::new(),
            spec_rounds: Counter::new(),
            spec_drafted: Counter::new(),
            spec_accepted: Counter::new(),
            spec_rollbacks: Counter::new(),
            spec_tokens: Counter::new(),
            step_hist: SharedHistogram::new(),
            ttft_hist: SharedHistogram::new(),
            latency_hist: SharedHistogram::new(),
            queue_hist: SharedHistogram::new(),
            prefill_hist: SharedHistogram::new(),
            first_decode_hist: SharedHistogram::new(),
            ttft_warm_hist: SharedHistogram::new(),
            ttft_cold_hist: SharedHistogram::new(),
            repack_hist: SharedHistogram::new(),
            decode_stall_hist: SharedHistogram::new(),
        }
    }

    /// A consistent-enough snapshot as of now.  Counters are read
    /// individually (each is exact; cross-counter skew is bounded by one
    /// engine cycle), histograms snapshot under their lock.
    pub fn snapshot(&self) -> ServeStats {
        Self::assemble(&[self])
    }

    /// Merge per-replica registries into one fleet-wide snapshot:
    /// counters add, histograms merge bucket-wise, occupancy and mean
    /// width merge as ratios of the summed tallies (never as averages of
    /// averages), elapsed is the longest-lived replica's.
    pub fn merged(replicas: &[Arc<LiveStats>]) -> ServeStats {
        let refs: Vec<&LiveStats> = replicas.iter().map(|r| r.as_ref()).collect();
        Self::assemble(&refs)
    }

    fn assemble(rs: &[&LiveStats]) -> ServeStats {
        fn sum(rs: &[&LiveStats], f: impl Fn(&LiveStats) -> &Counter) -> u64 {
            rs.iter().map(|r| f(r).get()).sum()
        }
        fn hist(rs: &[&LiveStats], f: impl Fn(&LiveStats) -> &SharedHistogram) -> Histogram {
            let mut h = Histogram::new();
            for r in rs {
                h.merge(&f(r).snapshot());
            }
            h
        }
        let step = hist(rs, |r| &r.step_hist);
        let ttft = hist(rs, |r| &r.ttft_hist);
        let latency = hist(rs, |r| &r.latency_hist);
        let queue = hist(rs, |r| &r.queue_hist);
        let prefill = hist(rs, |r| &r.prefill_hist);
        let first_decode = hist(rs, |r| &r.first_decode_hist);
        let warm = hist(rs, |r| &r.ttft_warm_hist);
        let cold = hist(rs, |r| &r.ttft_cold_hist);
        let repack = hist(rs, |r| &r.repack_hist);
        let stall = hist(rs, |r| &r.decode_stall_hist);
        let elapsed_s = rs
            .iter()
            .map(|r| r.started.elapsed().as_secs_f64())
            .fold(0.0, f64::max);
        let tokens_out = sum(rs, |r| &r.tokens_out);
        let steps = sum(rs, |r| &r.steps);
        // occupancy: each replica's denominator is its own steps × width
        let occ_den: u64 = rs.iter().map(|r| r.steps.get() * r.batch_lanes.get()).sum();
        let occ_num = sum(rs, |r| &r.occupied_lanes);
        let batched_steps = sum(rs, |r| &r.batched_steps);
        let width_steps = sum(rs, |r| &r.width_steps);
        ServeStats {
            completed: sum(rs, |r| &r.completed),
            tokens_out,
            steps,
            elapsed_s,
            step_us_p50: step.percentile_us(50.0),
            step_us_p99: step.percentile_us(99.0),
            ttft_us_p50: ttft.percentile_us(50.0),
            ttft_us_p95: ttft.percentile_us(95.0),
            ttft_us_p99: ttft.percentile_us(99.0),
            queue_us_p50: queue.percentile_us(50.0),
            queue_us_p95: queue.percentile_us(95.0),
            queue_us_p99: queue.percentile_us(99.0),
            prefill_us_p50: prefill.percentile_us(50.0),
            prefill_us_p95: prefill.percentile_us(95.0),
            prefill_us_p99: prefill.percentile_us(99.0),
            first_decode_us_p50: first_decode.percentile_us(50.0),
            first_decode_us_p95: first_decode.percentile_us(95.0),
            first_decode_us_p99: first_decode.percentile_us(99.0),
            prefills: sum(rs, |r| &r.prefills),
            prefilled_tokens: sum(rs, |r| &r.prefilled_tokens),
            prefill_chunks: sum(rs, |r| &r.prefill_chunks),
            queue_depth: sum(rs, |r| &r.queue_depth),
            decode_stall_us_p50: stall.percentile_us(50.0),
            decode_stall_us_p99: stall.percentile_us(99.0),
            cache_hits: sum(rs, |r| &r.cache_hits),
            cache_misses: sum(rs, |r| &r.cache_misses),
            cache_inserts: sum(rs, |r| &r.cache_inserts),
            cache_evictions: sum(rs, |r| &r.cache_evictions),
            cache_hit_tokens: sum(rs, |r| &r.cache_hit_tokens),
            cache_resident_bytes: sum(rs, |r| &r.cache_resident_bytes) as usize,
            ttft_warm_us_p50: warm.percentile_us(50.0),
            ttft_warm_us_p95: warm.percentile_us(95.0),
            ttft_warm_us_p99: warm.percentile_us(99.0),
            ttft_cold_us_p50: cold.percentile_us(50.0),
            ttft_cold_us_p95: cold.percentile_us(95.0),
            ttft_cold_us_p99: cold.percentile_us(99.0),
            latency_us_p50: latency.percentile_us(50.0),
            latency_us_p95: latency.percentile_us(95.0),
            latency_us_p99: latency.percentile_us(99.0),
            tokens_per_sec: tokens_out as f64 / elapsed_s.max(1e-9),
            state_bytes: sum(rs, |r| &r.state_bytes) as usize,
            lane_occupancy: if occ_den == 0 { 0.0 } else { occ_num as f64 / occ_den as f64 },
            bucket_grows: sum(rs, |r| &r.bucket_grows),
            bucket_shrinks: sum(rs, |r| &r.bucket_shrinks),
            repacks: repack.count(),
            repack_us_p50: repack.percentile_us(50.0),
            repack_us_p99: repack.percentile_us(99.0),
            step_width_mean: if batched_steps == 0 {
                0.0
            } else {
                width_steps as f64 / batched_steps as f64
            },
            spec_rounds: sum(rs, |r| &r.spec_rounds),
            spec_drafted: sum(rs, |r| &r.spec_drafted),
            spec_accepted: sum(rs, |r| &r.spec_accepted),
            spec_rollbacks: sum(rs, |r| &r.spec_rollbacks),
            spec_tokens: sum(rs, |r| &r.spec_tokens),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled() -> Arc<LiveStats> {
        let s = Arc::new(LiveStats::new());
        s.batch_lanes.set(4);
        s.completed.add(3);
        s.tokens_out.add(120);
        s.steps.add(50);
        s.occupied_lanes.add(100);
        s.batched_steps.add(50);
        s.width_steps.add(150);
        s.prefills.add(3);
        s.prefilled_tokens.add(90);
        s.prefill_chunks.add(12);
        s.queue_depth.set(5);
        s.cache_hits.add(2);
        s.cache_misses.add(1);
        s.cache_hit_tokens.add(64);
        s.spec_rounds.add(10);
        s.spec_drafted.add(40);
        s.spec_accepted.add(30);
        s.spec_tokens.add(40);
        s.bucket_grows.add(2);
        s.bucket_shrinks.add(1);
        s.state_bytes.set(4096);
        for i in 1..=50u64 {
            s.step_hist.record_us(100.0 + i as f64);
            s.repack_hist.record_us(40.0);
        }
        for i in 0..3u64 {
            s.ttft_hist.record_us(5_000.0 + 1_000.0 * i as f64);
            s.latency_hist.record_us(50_000.0);
            s.queue_hist.record_us(200.0);
            s.prefill_hist.record_us(3_000.0);
            s.first_decode_hist.record_us(1_000.0);
            s.ttft_cold_hist.record_us(6_000.0);
            s.decode_stall_hist.record_us(700.0);
        }
        s
    }

    #[test]
    fn snapshot_reflects_live_counters() {
        let live = filled();
        let s = live.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.tokens_out, 120);
        assert_eq!(s.steps, 50);
        assert_eq!(s.prefilled_tokens, 90);
        assert_eq!(s.prefill_chunks, 12);
        assert_eq!(s.queue_depth, 5);
        assert!(s.decode_stall_us_p50 > 0.0, "stall histogram surfaces");
        assert!((s.lane_occupancy - 100.0 / 200.0).abs() < 1e-12);
        assert!((s.step_width_mean - 3.0).abs() < 1e-12);
        assert_eq!(s.repacks, 50);
        assert!(s.step_us_p50 > 100.0 && s.step_us_p50 < 160.0);
        assert!(s.elapsed_s >= 0.0 && s.tokens_per_sec > 0.0);
        // live: more events move the snapshot
        live.tokens_out.incr();
        assert_eq!(live.snapshot().tokens_out, 121);
    }

    #[test]
    fn merged_sums_counters_and_merges_histograms() {
        let a = filled();
        let b = filled();
        b.ttft_hist.record_us(100_000.0); // one slow outlier on replica b
        let m = LiveStats::merged(&[a.clone(), b.clone()]);
        assert_eq!(m.completed, 6);
        assert_eq!(m.tokens_out, 240);
        assert_eq!(m.steps, 100);
        assert_eq!(m.spec_drafted, 80);
        // occupancy is a ratio of summed tallies, unchanged for twins
        assert!((m.lane_occupancy - 0.5).abs() < 1e-12);
        // the merged p99 sees replica b's outlier
        assert!(m.ttft_us_p99 > 50_000.0, "p99 {}", m.ttft_us_p99);
        assert!(m.ttft_us_p50 < 10_000.0, "p50 {}", m.ttft_us_p50);
        // single-replica merge == snapshot (modulo elapsed jitter)
        let one = LiveStats::merged(&[a.clone()]);
        assert_eq!(one.tokens_out, a.snapshot().tokens_out);
    }

    #[test]
    fn wire_merge_sums_counters_and_weights_percentiles() {
        let a = ServeStats {
            completed: 3,
            tokens_out: 120,
            steps: 50,
            elapsed_s: 2.0,
            ttft_us_p50: 1_000.0,
            step_us_p50: 100.0,
            lane_occupancy: 0.5,
            state_bytes: 4096,
            ..Default::default()
        };
        let b = ServeStats {
            completed: 1,
            tokens_out: 40,
            steps: 150,
            elapsed_s: 5.0,
            ttft_us_p50: 5_000.0,
            step_us_p50: 300.0,
            lane_occupancy: 0.9,
            state_bytes: 4096,
            ..Default::default()
        };
        let m = ServeStats::merge(&[a, b]);
        assert_eq!(m.completed, 4);
        assert_eq!(m.tokens_out, 160);
        assert_eq!(m.steps, 200);
        assert_eq!(m.state_bytes, 8192, "fleet footprint sums");
        assert!((m.elapsed_s - 5.0).abs() < 1e-12, "longest-lived replica wins");
        assert!((m.tokens_per_sec - 160.0 / 5.0).abs() < 1e-9, "throughput recomputes");
        // request-phase percentiles weight by completed: (3*1000 + 1*5000)/4
        assert!((m.ttft_us_p50 - 2_000.0).abs() < 1e-9, "{}", m.ttft_us_p50);
        // step-level ones weight by steps: (50*100 + 150*300)/200
        assert!((m.step_us_p50 - 250.0).abs() < 1e-9, "{}", m.step_us_p50);
        assert!((m.lane_occupancy - (50.0 * 0.5 + 150.0 * 0.9) / 200.0).abs() < 1e-9);
        // degenerate inputs stay finite
        let empty = ServeStats::merge(&[]);
        assert_eq!(empty.completed, 0);
        assert_eq!(empty.ttft_us_p50, 0.0);
        let idle = ServeStats::merge(&[ServeStats::default()]);
        assert_eq!(idle.step_us_p50, 0.0, "zero weight never divides by zero");
    }

    #[test]
    fn wire_json_round_trips_every_field() {
        let s = filled().snapshot();
        let j = s.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(STATS_SCHEMA));
        let back = ServeStats::from_json(&j);
        // the JSON forms must agree exactly — every field survived
        assert_eq!(back.to_json().to_string(), j.to_string());
        // and a reparse of the serialized line also survives
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(ServeStats::from_json(&reparsed).to_json().to_string(), j.to_string());
        // missing fields read as zero, not as an error
        let sparse = ServeStats::from_json(&Json::parse(r#"{"tokens_out": 7}"#).unwrap());
        assert_eq!(sparse.tokens_out, 7);
        assert_eq!(sparse.completed, 0);
    }

    #[test]
    fn summary_line_grows_with_active_subsystems() {
        let plain = ServeStats { completed: 2, tokens_out: 80, ..Default::default() };
        let line = plain.summary_line();
        assert!(line.contains("2 req"), "{line}");
        assert!(!line.contains("cache"), "inactive cache must not clutter: {line}");
        assert!(!line.contains("spec"), "{line}");
        assert!(!line.contains("width"), "{line}");
        let full = filled().snapshot().summary_line();
        for seg in ["cache", "tok saved", "spec", "acc/step", "width", "repack"] {
            assert!(full.contains(seg), "missing {seg}: {full}");
        }
    }

    #[test]
    fn prometheus_form_exposes_counters_and_quantiles() {
        let text = filled().snapshot().to_prometheus();
        assert!(text.contains("hla_tokens_out_total 120"), "{text}");
        assert!(text.contains("hla_requests_completed_total 3"), "{text}");
        assert!(text.contains("hla_ttft_us{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("# TYPE hla_lane_occupancy gauge"), "{text}");
        // every line is either a comment or `name value`
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.splitn(2, ' ').count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }
}
