//! Metrics substrate: log-bucketed latency histograms with percentile
//! queries, throughput meters, lock-free event counters and a table
//! reporter — replaces hdrhistogram/prometheus for the serving benches
//! (E8/E13/E18) and the CLI.
//!
//! Submodules extend this into the live observability layer:
//! [`registry`] holds the shared [`registry::LiveStats`] the engine loop
//! updates in place (and the [`registry::ServeStats`] snapshot it exports),
//! [`trace`] holds the lock-free span ring and Chrome-trace exporter,
//! [`stitch`] merges span rings from N processes (router + replicas,
//! pulled over the wire via `trace_export`) into one fleet-wide trace.

pub mod registry;
pub mod stitch;
pub mod trace;

pub use registry::{LiveStats, ServeStats};
pub use stitch::ProcessTrace;
pub use trace::{Stage, TraceCfg, Tracer};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Lock-free monotonically increasing event counter, shareable across
/// threads behind an `Arc` (e.g. the session store's snapshot/restore/
/// hit-rate accounting read concurrently by server handlers and the CLI).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Increment by one; returns the new value.
    pub fn incr(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Undo one increment (compensating entry, e.g. a claim that had to be
    /// rolled back).  Caller guarantees a matching `incr` happened.
    pub fn decr(&self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Overwrite the current value.  For gauges mirrored from a source of
    /// truth owned elsewhere (e.g. the engine republishing `SpecStats` or
    /// `CacheStats` totals into the live registry each cycle) — not for
    /// event counting, where `incr`/`add` compose across writers.
    pub fn set(&self, n: u64) {
        self.0.store(n, Ordering::Relaxed);
    }
}

/// `hits / (hits + misses)`, or 0 when nothing was recorded.
pub fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

/// Log-bucketed histogram over microsecond latencies.
///
/// Buckets grow geometrically (~4.6% width) from 1us to ~1100s, giving
/// percentile error well under the measurement jitter of the benches.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
    dropped: u64,
}

const BUCKETS: usize = 460;
const GROWTH: f64 = 1.046;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; BUCKETS],
            count: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
            dropped: 0,
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= 1.0 {
            return 0;
        }
        (us.ln() / GROWTH.ln()).floor().min((BUCKETS - 1) as f64) as usize
    }

    fn bucket_value(i: usize) -> f64 {
        GROWTH.powi(i as i32) * (1.0 + GROWTH) / 2.0
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        // A NaN or negative sample (clock skew, a subtraction that went the
        // wrong way upstream) must not corrupt bucket 0 / mean / min: drop
        // it and count the drop so the corruption is visible, not silent.
        if !us.is_finite() || us < 0.0 {
            self.dropped += 1;
            return;
        }
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
        self.dropped += other.dropped;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Samples rejected by the `record_us` finite/non-negative guard.
    pub fn dropped_samples(&self) -> u64 {
        self.dropped
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// p in [0, 100].
    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count,
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(95.0),
            self.percentile_us(99.0),
            self.max_us
        )
    }
}

/// A [`Histogram`] shareable across threads behind an `Arc`: writers
/// record under a short critical section, readers take whole-histogram
/// [`SharedHistogram::snapshot`]s which merge cleanly across replicas.
///
/// A `Mutex` (not per-bucket atomics) keeps `{count, sum, min, max,
/// buckets}` mutually consistent — a snapshot is always *some* prefix of
/// the sample stream, never a torn mix.  The lock is uncontended in
/// practice (one engine-loop writer, occasional `"stats"` reader) and a
/// poisoned lock degrades to the inner value rather than panicking the
/// serving thread.
#[derive(Debug, Default)]
pub struct SharedHistogram(Mutex<Histogram>);

impl SharedHistogram {
    pub fn new() -> Self {
        SharedHistogram(Mutex::new(Histogram::new()))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Histogram> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn record(&self, d: Duration) {
        self.lock().record(d);
    }

    pub fn record_us(&self, us: f64) {
        self.lock().record_us(us);
    }

    pub fn count(&self) -> u64 {
        self.lock().count()
    }

    /// A consistent copy of the histogram as of now.
    pub fn snapshot(&self) -> Histogram {
        self.lock().clone()
    }
}

/// Events-per-second meter over a wall-clock window.
#[derive(Debug)]
pub struct Meter {
    start: Instant,
    events: u64,
    units: u64,
}

impl Default for Meter {
    fn default() -> Self {
        Self::new()
    }
}

impl Meter {
    pub fn new() -> Self {
        Meter { start: Instant::now(), events: 0, units: 0 }
    }

    /// Record one event carrying `units` work items (e.g. tokens).
    pub fn tick(&mut self, units: u64) {
        self.events += 1;
        self.units += units;
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn units_per_sec(&self) -> f64 {
        self.units as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }

    pub fn events(&self) -> u64 {
        self.events
    }

    pub fn units(&self) -> u64 {
        self.units
    }
}

/// Fixed-width ASCII table writer for the bench harnesses (criterion-less).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{:>width$}", c, width = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_us(i as f64);
        }
        let p50 = h.percentile_us(50.0);
        let p95 = h.percentile_us(95.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // within bucket resolution of the true values
        assert!((p50 - 500.0).abs() / 500.0 < 0.08, "{p50}");
        assert!((p99 - 990.0).abs() / 990.0 < 0.08, "{p99}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record_us(10.0);
        b.record_us(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.percentile_us(99.0) > 500.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["n", "tput"]);
        t.row(&["1024".into(), "3.5".into()]);
        t.row(&["64".into(), "12.25".into()]);
        let s = t.render();
        assert!(s.contains("1024"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn counter_concurrent_increments() {
        use std::sync::Arc;
        let c = Arc::new(Counter::new());
        let mut handles = vec![];
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    c.incr();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        c.add(5);
        assert_eq!(c.get(), 4005);
    }

    #[test]
    fn hit_rate_bounds() {
        assert_eq!(hit_rate(0, 0), 0.0);
        assert_eq!(hit_rate(3, 1), 0.75);
        assert_eq!(hit_rate(0, 7), 0.0);
        assert_eq!(hit_rate(7, 0), 1.0);
    }

    /// Property: merge(a, b) must be indistinguishable from recording all
    /// samples into a single histogram — count, dropped, sum (exact: both
    /// sides add the same finite f64s, just in a different grouping order
    /// within each histogram's own sequential sum), min/max, and every
    /// percentile.  100 random splits of random sample sets.
    #[test]
    fn prop_merge_equals_recording_into_one() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x4d45524745);
        for _ in 0..100 {
            let n = 1 + rng.below(400);
            let samples: Vec<f64> = (0..n)
                .map(|_| {
                    // span the bucket range, include hostile samples
                    match rng.below(20) {
                        0 => f64::NAN,
                        1 => -(rng.f64() * 100.0) - 0.001,
                        2 => f64::INFINITY,
                        _ => rng.f64() * 10f64.powi(rng.below(8) as i32),
                    }
                })
                .collect();
            let split = rng.below(n + 1);
            let (mut a, mut b, mut whole) = (Histogram::new(), Histogram::new(), Histogram::new());
            for (i, &s) in samples.iter().enumerate() {
                if i < split {
                    a.record_us(s);
                } else {
                    b.record_us(s);
                }
                whole.record_us(s);
            }
            a.merge(&b);
            assert_eq!(a.count(), whole.count());
            assert_eq!(a.dropped_samples(), whole.dropped_samples());
            assert_eq!(a.min_us, whole.min_us, "split {split} of {n}");
            assert_eq!(a.max_us, whole.max_us);
            let tol = 1e-9 * whole.sum_us.abs().max(1.0);
            assert!((a.sum_us - whole.sum_us).abs() <= tol, "{} vs {}", a.sum_us, whole.sum_us);
            for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                assert_eq!(a.percentile_us(p), whole.percentile_us(p), "p{p}");
            }
        }
    }

    /// Property: p50 <= p95 <= p99 <= max over random sample sets (and
    /// percentile_us is monotone in p generally).
    #[test]
    fn prop_percentiles_monotone_in_p() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x504354);
        for _ in 0..100 {
            let mut h = Histogram::new();
            for _ in 0..1 + rng.below(300) {
                h.record_us(rng.f64() * 10f64.powi(rng.below(7) as i32));
            }
            let mut prev = 0.0;
            for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                let v = h.percentile_us(p);
                assert!(v >= prev, "p{p}: {v} < {prev}");
                prev = v;
            }
            assert!(prev <= h.max_us, "p100 {prev} exceeds max {}", h.max_us);
            assert!(
                h.percentile_us(50.0) <= h.percentile_us(95.0)
                    && h.percentile_us(95.0) <= h.percentile_us(99.0)
                    && h.percentile_us(99.0) <= h.max_us
            );
        }
    }

    #[test]
    fn record_us_guards_nan_and_negative() {
        let mut h = Histogram::new();
        h.record_us(5.0);
        h.record_us(f64::NAN);
        h.record_us(-1.0);
        h.record_us(f64::NEG_INFINITY);
        h.record_us(f64::INFINITY);
        assert_eq!(h.count(), 1, "bad samples must not count");
        assert_eq!(h.dropped_samples(), 4);
        assert_eq!(h.mean_us(), 5.0, "mean must not absorb NaN/negative");
        assert_eq!(h.min_us, 5.0);
        assert_eq!(h.max_us, 5.0);
        // zero is a legal sample (bucket 0), not a drop
        h.record_us(0.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.min_us, 0.0);
    }

    #[test]
    fn shared_histogram_concurrent_recording_snapshots_consistently() {
        use std::sync::Arc;
        let h = Arc::new(SharedHistogram::new());
        let mut handles = vec![];
        for t in 0..4u64 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    h.record_us((t * 500 + i) as f64);
                }
            }));
        }
        // reader races the writers: snapshots are internally consistent
        for _ in 0..50 {
            let s = h.snapshot();
            assert_eq!(s.count() + s.dropped_samples(), s.count(), "no drops expected");
            if s.count() > 0 {
                assert!(s.min_us <= s.max_us);
                assert!(s.percentile_us(50.0) <= s.percentile_us(99.0));
            }
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 2000);
        let s = h.snapshot();
        assert_eq!(s.min_us, 0.0);
        assert_eq!(s.max_us, 1999.0);
    }

    #[test]
    fn counter_set_overwrites() {
        let c = Counter::new();
        c.add(10);
        c.set(3);
        assert_eq!(c.get(), 3);
        c.incr();
        assert_eq!(c.get(), 4);
    }

    #[test]
    fn meter_counts() {
        let mut m = Meter::new();
        m.tick(10);
        m.tick(20);
        assert_eq!(m.events(), 2);
        assert_eq!(m.units(), 30);
        assert!(m.units_per_sec() > 0.0);
    }
}
