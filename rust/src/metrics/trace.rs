//! Request-span tracing: a lock-free ring of fixed-size span records the
//! engine loop writes on its hot path, exportable as Chrome trace-event
//! JSON (`chrome://tracing` / [Perfetto](https://ui.perfetto.dev)).
//!
//! ## Design
//!
//! The writer is the engine thread (plus, rarely, server threads); the
//! reader is whoever exports — `generate --trace-out`, the `serve`
//! flush daemon, a test.  Requirements: recording must cost nanoseconds
//! and never block, and a reader racing the writer must never see a torn
//! record.  The ring is a seqlock per slot over plain atomics — no
//! `unsafe`, no locks:
//!
//! - a writer claims a slot by `fetch_add` on a global ticket, then
//!   stores `2*ticket+1` (odd: in progress) into the slot's `seq`,
//!   writes the four payload words, and stores `2*ticket+2` (even:
//!   committed, generation-stamped);
//! - a reader loads `seq`, skips odd/zero, reads the payload, re-loads
//!   `seq`, and discards the record if it changed underneath it.
//!
//! Every cell is an `AtomicU64`, so a race is at worst a *discarded*
//! record, never undefined behavior.  When the ring wraps, the oldest
//! spans are overwritten — a trace is a window onto the tail of the run,
//! sized by [`TraceCfg::capacity`].
//!
//! Per-request sampling hashes the request id through a SplitMix64
//! finalizer and compares against `sample * 2^64`: a request is either
//! fully traced or fully untraced (spans from one request never
//! disappear mid-life), and sampling costs one multiply-free hash on the
//! untraced path.  Engine-scoped spans (decode steps, repacks) ignore
//! sampling — there is one per step, not one per request-token.

use std::fs;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Span taxonomy: one variant per engine-cycle stage worth seeing on a
/// timeline.  The discriminant is packed into the ring record, so keep
/// variants dense from 0 and append-only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request admitted to a lane (includes session restore if resuming).
    Admission = 0,
    /// Prefix-cache probe during admission (instant event; detail = hit tokens).
    CacheLookup = 1,
    /// Prompt ingestion — serial or chunked scan (detail = tokens consumed).
    Prefill = 2,
    /// One batched decode step across all lanes (detail = batch width).
    DecodeStep = 3,
    /// One speculative draft/verify round on a lane (detail = tokens emitted).
    SpecRound = 4,
    /// Bucket switch: state repack to a new batch width (detail = new width).
    Repack = 5,
    /// Session snapshot on lane retirement (detail = tokens generated).
    Detach = 6,
    /// Front-end relay of one generation (detail = reply lines relayed).
    Relay = 7,
    /// Mid-stream failover (instant; detail = index of the dead replica).
    Failover = 8,
    /// Session migrated between replicas (instant; detail = new home).
    Migrate = 9,
    /// One budgeted window of a parked prompt ingestion (detail = tokens
    /// consumed this window).  Budget mode emits these instead of one
    /// aggregate [`Stage::Prefill`] span, so a timeline shows the scan
    /// interleaving with decode steps.
    PrefillChunk = 10,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::CacheLookup => "cache_lookup",
            Stage::Prefill => "prefill",
            Stage::DecodeStep => "decode_step",
            Stage::SpecRound => "spec_round",
            Stage::Repack => "repack",
            Stage::Detach => "detach",
            Stage::Relay => "relay",
            Stage::Failover => "failover",
            Stage::Migrate => "migrate",
            Stage::PrefillChunk => "prefill_chunk",
        }
    }

    fn from_u8(v: u8) -> Option<Stage> {
        Some(match v {
            0 => Stage::Admission,
            1 => Stage::CacheLookup,
            2 => Stage::Prefill,
            3 => Stage::DecodeStep,
            4 => Stage::SpecRound,
            5 => Stage::Repack,
            6 => Stage::Detach,
            7 => Stage::Relay,
            8 => Stage::Failover,
            9 => Stage::Migrate,
            10 => Stage::PrefillChunk,
            _ => return None,
        })
    }

    fn from_name(s: &str) -> Option<Stage> {
        [
            Stage::Admission,
            Stage::CacheLookup,
            Stage::Prefill,
            Stage::DecodeStep,
            Stage::SpecRound,
            Stage::Repack,
            Stage::Detach,
            Stage::Relay,
            Stage::Failover,
            Stage::Migrate,
            Stage::PrefillChunk,
        ]
        .into_iter()
        .find(|v| v.name() == s)
    }
}

/// One decoded span, times in microseconds since the tracer's epoch.
/// `lane` is `None` for engine-scoped spans (whole-batch decode steps,
/// repacks); `dur_us == 0` with [`SpanEvent::instant`] marks an instant
/// event (cache lookups) rather than a zero-length slice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanEvent {
    pub stage: Stage,
    /// Request id, 0 for engine-scoped spans.
    pub request: u64,
    pub lane: Option<usize>,
    pub start_us: u64,
    pub dur_us: u64,
    /// Stage-specific payload (see [`Stage`] docs); saturates at `u32::MAX`.
    pub detail: u32,
    instant: bool,
}

impl SpanEvent {
    pub fn instant(&self) -> bool {
        self.instant
    }

    /// Wire form of one span (the `trace_export` reply payload).  The
    /// request id ships as a 16-hex-digit string — trace ids use the full
    /// 64-bit space and would not survive the f64 round-trip JSON numbers
    /// take (same discipline as the `register` fingerprint).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("stage", Json::str(self.stage.name())),
            ("request", Json::str(format!("{:016x}", self.request))),
            ("lane", self.lane.map_or(Json::Null, |l| Json::num(l as u32))),
            ("start_us", Json::num(self.start_us as f64)),
            ("dur_us", Json::num(self.dur_us as f64)),
            ("detail", Json::num(self.detail)),
            ("instant", Json::Bool(self.instant)),
        ])
    }

    /// Decode the wire form; `None` on a missing/mistyped field (a reader
    /// fed garbage skips the span rather than panicking).
    pub fn from_json(j: &Json) -> Option<SpanEvent> {
        let stage = Stage::from_name(j.get("stage")?.as_str()?)?;
        let request = u64::from_str_radix(j.get("request")?.as_str()?, 16).ok()?;
        let lane = match j.get("lane") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_usize()?),
        };
        Some(SpanEvent {
            stage,
            request,
            lane,
            start_us: j.get("start_us")?.as_f64()? as u64,
            dur_us: j.get("dur_us")?.as_f64()? as u64,
            detail: j.get("detail")?.as_f64()? as u32,
            instant: j.get("instant")?.as_bool()?,
        })
    }
}

// meta word layout: stage(8) | lane_plus1(16) | instant(1) | detail(32 high)
const LANE_SHIFT: u32 = 8;
const INSTANT_BIT: u64 = 1 << 24;
const DETAIL_SHIFT: u32 = 32;

struct Slot {
    seq: AtomicU64,
    request: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    meta: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            request: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
            meta: AtomicU64::new(0),
        }
    }
}

/// Schema tag on the `trace_export` wire form (bump on layout changes).
pub const TRACE_EXPORT_SCHEMA: &str = "hla-trace/1";

/// Tracing knobs (`--trace-sample`, ring size).
#[derive(Debug, Clone)]
pub struct TraceCfg {
    /// Fraction of requests traced, in `[0, 1]`.  Engine-scoped spans are
    /// always recorded while a tracer is attached.
    pub sample: f64,
    /// Ring capacity in spans; rounded up to a power of two.  At 5 spans
    /// per request-token the default (64Ki) holds the tail ~10k tokens.
    pub capacity: usize,
}

impl Default for TraceCfg {
    fn default() -> Self {
        TraceCfg { sample: 1.0, capacity: 1 << 16 }
    }
}

/// The span recorder: a [`TraceCfg`]-sized seqlock ring plus the sampling
/// threshold and the epoch all timestamps are relative to.  Share behind
/// an `Arc`; recording takes `&self`.
pub struct Tracer {
    slots: Vec<Slot>,
    mask: u64,
    next: AtomicU64,
    threshold: u64,
    epoch: Instant,
}

fn splitmix_hash(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The SplitMix64 finalizer the sampler hashes request ids through,
/// exported so trace-id *minting* (the cluster front-end) uses the same
/// mixing discipline: ids minted from a counter stay uniformly spread
/// under per-request sampling.
pub fn splitmix64(z: u64) -> u64 {
    splitmix_hash(z)
}

impl Tracer {
    pub fn new(cfg: &TraceCfg) -> Tracer {
        let cap = cfg.capacity.max(64).next_power_of_two();
        let sample = cfg.sample.clamp(0.0, 1.0);
        let threshold = if sample >= 1.0 {
            u64::MAX
        } else {
            // sample * 2^64, computed without overflow at the top end
            (sample * 2f64.powi(64)).min(u64::MAX as f64) as u64
        };
        Tracer {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            mask: (cap - 1) as u64,
            next: AtomicU64::new(0),
            threshold,
            epoch: Instant::now(),
        }
    }

    /// Is this request in the sampled set?  Deterministic per id, so all
    /// spans of a request share one fate.
    pub fn sampled(&self, request: u64) -> bool {
        self.threshold == u64::MAX || splitmix_hash(request) < self.threshold
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans written over the tracer's lifetime (>= capacity means the
    /// ring wrapped and the oldest were overwritten).
    pub fn recorded(&self) -> u64 {
        self.next.load(Ordering::Relaxed)
    }

    pub fn overwritten(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn write(&self, stage: Stage, request: u64, lane: Option<usize>, start_us: u64, dur_us: u64, instant: bool, detail: u64) {
        let ticket = self.next.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(ticket & self.mask) as usize];
        let lane_plus1 = lane.map_or(0, |l| (l + 1).min(u16::MAX as usize)) as u64;
        let meta = (stage as u64)
            | (lane_plus1 << LANE_SHIFT)
            | if instant { INSTANT_BIT } else { 0 }
            | (detail.min(u32::MAX as u64) << DETAIL_SHIFT);
        slot.seq.store(2 * ticket + 1, Ordering::Release); // odd: in progress
        slot.request.store(request, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        slot.meta.store(meta, Ordering::Relaxed);
        slot.seq.store(2 * ticket + 2, Ordering::Release); // even: committed
    }

    /// Record a request-scoped span that began at `start`; no-op unless
    /// the request is sampled.
    pub fn span(&self, stage: Stage, request: u64, lane: usize, start: Instant, detail: u64) {
        if !self.sampled(request) {
            return;
        }
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        let dur_us = self.now_us().saturating_sub(start_us);
        self.write(stage, request, Some(lane), start_us, dur_us, false, detail);
    }

    /// Record an engine-scoped span (always recorded while attached).
    pub fn engine_span(&self, stage: Stage, start: Instant, detail: u64) {
        let start_us = start.saturating_duration_since(self.epoch).as_micros() as u64;
        let dur_us = self.now_us().saturating_sub(start_us);
        self.write(stage, 0, None, start_us, dur_us, false, detail);
    }

    /// Record a request-scoped instant event (a point, not a slice).
    pub fn instant_event(&self, stage: Stage, request: u64, lane: usize, detail: u64) {
        if !self.sampled(request) {
            return;
        }
        self.write(stage, request, Some(lane), self.now_us(), 0, true, detail);
    }

    /// Decode every committed, untorn record, oldest first.
    pub fn events(&self) -> Vec<SpanEvent> {
        let mut out: Vec<(u64, SpanEvent)> = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let s0 = slot.seq.load(Ordering::Acquire);
            if s0 == 0 || s0 % 2 == 1 {
                continue; // never written, or write in progress
            }
            let request = slot.request.load(Ordering::Relaxed);
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let dur_us = slot.dur_us.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != s0 {
                continue; // torn: a writer lapped us mid-read
            }
            let Some(stage) = Stage::from_u8((meta & 0xff) as u8) else {
                continue;
            };
            let lane_plus1 = ((meta >> LANE_SHIFT) & 0xffff) as usize;
            out.push((
                s0,
                SpanEvent {
                    stage,
                    request,
                    lane: lane_plus1.checked_sub(1),
                    start_us,
                    dur_us,
                    detail: (meta >> DETAIL_SHIFT) as u32,
                    instant: meta & INSTANT_BIT != 0,
                },
            ));
        }
        out.sort_by_key(|(seq, _)| *seq);
        out.into_iter().map(|(_, e)| e).collect()
    }

    /// Wire export of the whole ring: the decoded spans plus a wall-clock
    /// anchor — the tracer's (process-private, monotonic) epoch expressed
    /// as unix microseconds.  `anchor_unix_us + span.start_us` places every
    /// span from every process on one shared timeline, which is what lets
    /// the stitcher merge rings from N processes into a single trace.
    /// Anchor skew between processes is wall-clock skew (one NTP-displined
    /// host: microseconds), not monotonic-epoch skew.
    pub fn export_json(&self, name: &str) -> Json {
        export_rings_json(name, &[self])
    }

    /// This ring's epoch expressed as unix microseconds — the anchor the
    /// export form ships.  Skew between two processes' anchors is wall-
    /// clock skew (one NTP-disciplined host: microseconds), not
    /// monotonic-epoch skew.
    pub fn anchor_unix_us(&self) -> u64 {
        let unix_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_micros() as u64)
            .unwrap_or(0);
        unix_us.saturating_sub(self.now_us())
    }

    /// Chrome trace-event objects for this tracer under process id `pid`
    /// (one pid per replica).  Engine-scoped spans land on tid 0, lane
    /// spans on tid lane+1, so Perfetto renders one track per lane.
    pub fn chrome_events(&self, pid: usize) -> Vec<Json> {
        let mut events = vec![Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid as u32)),
            ("args", Json::obj(vec![("name", Json::str(format!("replica {pid}")))])),
        ])];
        let mut tids_seen = vec![];
        for e in self.events() {
            let tid = e.lane.map_or(0, |l| l + 1);
            if !tids_seen.contains(&tid) {
                tids_seen.push(tid);
                let tname = if tid == 0 { "engine".to_string() } else { format!("lane {}", tid - 1) };
                events.push(Json::obj(vec![
                    ("name", Json::str("thread_name")),
                    ("ph", Json::str("M")),
                    ("pid", Json::num(pid as u32)),
                    ("tid", Json::num(tid as u32)),
                    ("args", Json::obj(vec![("name", Json::str(tname))])),
                ]));
            }
            let args = Json::obj(vec![
                ("request", Json::num(e.request as f64)),
                ("detail", Json::num(e.detail as f64)),
            ]);
            let mut fields = vec![
                ("name", Json::str(e.stage.name())),
                ("cat", Json::str(if e.lane.is_some() { "request" } else { "engine" })),
                ("ph", Json::str(if e.instant { "i" } else { "X" })),
                ("ts", Json::num(e.start_us as f64)),
                ("pid", Json::num(pid as u32)),
                ("tid", Json::num(tid as u32)),
                ("args", args),
            ];
            if e.instant {
                fields.push(("s", Json::str("t"))); // thread-scoped instant
            } else {
                fields.push(("dur", Json::num(e.dur_us as f64)));
            }
            events.push(Json::obj(fields));
        }
        events
    }
}

/// One `trace_export` payload covering several in-process rings (a server
/// running N engine replicas answers with a single merged ring): every
/// span is rebased onto the earliest ring's epoch, so the payload is
/// indistinguishable from one process-wide tracer's export.
pub fn export_rings_json(name: &str, rings: &[&Tracer]) -> Json {
    let anchors: Vec<u64> = rings.iter().map(|t| t.anchor_unix_us()).collect();
    let base = anchors.iter().copied().min().unwrap_or(0);
    let mut spans: Vec<Json> = Vec::new();
    for (t, &anchor) in rings.iter().zip(&anchors) {
        for mut e in t.events() {
            e.start_us += anchor - base;
            spans.push(e.to_json());
        }
    }
    Json::obj(vec![
        ("schema", Json::str(TRACE_EXPORT_SCHEMA)),
        ("name", Json::str(name)),
        // unix us ~ 1.7e15 < 2^53: exact as a JSON number
        ("anchor_unix_us", Json::num(base as f64)),
        ("spans", Json::Arr(spans)),
    ])
}

/// Assemble `{pid, tracer}` pairs into one Chrome trace-event JSON file,
/// written atomically (tmp + rename) so a live flush never leaves a
/// half-written file for Perfetto to choke on.
pub fn write_chrome_trace(path: &Path, tracers: &[(usize, &Tracer)]) -> Result<()> {
    let mut events = vec![];
    for (pid, t) in tracers {
        events.extend(t.chrome_events(*pid));
    }
    let doc = Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ]);
    let tmp = path.with_extension("json.tmp");
    fs::write(&tmp, doc.to_string()).with_context(|| format!("write {}", tmp.display()))?;
    fs::rename(&tmp, path).with_context(|| format!("rename to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(sample: f64, capacity: usize) -> Tracer {
        Tracer::new(&TraceCfg { sample, capacity })
    }

    #[test]
    fn spans_round_trip_through_the_ring() {
        let t = tracer(1.0, 256);
        let start = Instant::now();
        t.span(Stage::Prefill, 7, 2, start, 33);
        t.engine_span(Stage::DecodeStep, start, 4);
        t.instant_event(Stage::CacheLookup, 7, 2, 12);
        let evs = t.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].stage, Stage::Prefill);
        assert_eq!(evs[0].request, 7);
        assert_eq!(evs[0].lane, Some(2));
        assert_eq!(evs[0].detail, 33);
        assert!(!evs[0].instant());
        assert_eq!(evs[1].stage, Stage::DecodeStep);
        assert_eq!(evs[1].lane, None);
        assert_eq!(evs[1].detail, 4);
        assert_eq!(evs[2].stage, Stage::CacheLookup);
        assert!(evs[2].instant());
        assert_eq!(evs[2].dur_us, 0);
    }

    #[test]
    fn ring_wraps_keeping_the_newest_spans() {
        let t = tracer(1.0, 64); // min capacity clamps to 64
        let start = Instant::now();
        for i in 0..200u64 {
            t.engine_span(Stage::DecodeStep, start, i);
        }
        let evs = t.events();
        assert_eq!(evs.len(), 64);
        assert_eq!(t.recorded(), 200);
        assert_eq!(t.overwritten(), 200 - 64);
        // oldest-first order, covering exactly the tail
        let details: Vec<u32> = evs.iter().map(|e| e.detail).collect();
        assert_eq!(details, (136..200).map(|i| i as u32).collect::<Vec<_>>());
    }

    #[test]
    fn sampling_is_deterministic_and_proportional() {
        let t0 = tracer(0.0, 64);
        let t1 = tracer(1.0, 64);
        let th = tracer(0.5, 64);
        let mut hits = 0;
        for id in 0..1000u64 {
            assert!(!t0.sampled(id));
            assert!(t1.sampled(id));
            if th.sampled(id) {
                hits += 1;
            }
        }
        assert!((350..=650).contains(&hits), "half-sampling hit {hits}/1000");
        // unsampled requests record nothing
        let start = Instant::now();
        t0.span(Stage::Prefill, 5, 0, start, 1);
        t0.instant_event(Stage::CacheLookup, 5, 0, 1);
        assert_eq!(t0.events().len(), 0);
        // engine spans ignore sampling
        t0.engine_span(Stage::DecodeStep, start, 1);
        assert_eq!(t0.events().len(), 1);
    }

    #[test]
    fn chrome_export_is_valid_trace_event_json() {
        let t = tracer(1.0, 64);
        let start = Instant::now();
        t.span(Stage::Admission, 3, 0, start, 0);
        t.span(Stage::Prefill, 3, 0, start, 16);
        t.engine_span(Stage::DecodeStep, start, 2);
        t.instant_event(Stage::CacheLookup, 3, 0, 8);
        let dir = std::env::temp_dir().join(format!("hla_trace_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &[(0, &t)]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let mut names = vec![];
        for e in events {
            let ph = e.get("ph").and_then(Json::as_str).unwrap();
            assert!(["X", "i", "M"].contains(&ph), "{ph}");
            if ph == "X" {
                assert!(e.get("dur").and_then(Json::as_f64).is_some());
            }
            assert!(e.get("pid").and_then(Json::as_f64).is_some());
            names.push(e.get("name").and_then(Json::as_str).unwrap().to_string());
        }
        for want in ["admission", "prefill", "decode_step", "cache_lookup", "process_name"] {
            assert!(names.iter().any(|n| n == want), "missing {want}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wire_export_round_trips_spans_with_an_anchor() {
        let t = tracer(1.0, 64);
        let start = Instant::now();
        // a full-64-bit trace id must survive the wire (hex, not f64)
        let big = 0xdead_beef_cafe_f00du64;
        t.span(Stage::Relay, big, 0, start, 3);
        t.instant_event(Stage::Failover, big, 1, 2);
        t.engine_span(Stage::DecodeStep, start, 4);
        let j = t.export_json("router");
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(TRACE_EXPORT_SCHEMA));
        assert_eq!(j.get("name").and_then(Json::as_str), Some("router"));
        let anchor = j.get("anchor_unix_us").and_then(Json::as_f64).unwrap();
        assert!(anchor > 0.0 && anchor < 9e15, "anchor must be f64-exact: {anchor}");
        // round-trip through the serialized line, as the wire would
        let j2 = Json::parse(&j.to_string()).unwrap();
        let spans: Vec<SpanEvent> = j2
            .get("spans")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|s| SpanEvent::from_json(s).unwrap())
            .collect();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].stage, Stage::Relay);
        assert_eq!(spans[0].request, big);
        assert_eq!(spans[0].lane, Some(0));
        assert_eq!(spans[0].detail, 3);
        assert!(spans[1].instant());
        assert_eq!(spans[1].stage, Stage::Failover);
        assert_eq!(spans[2].lane, None, "engine spans keep their null lane");
        // garbage degrades to None, never a panic
        assert!(SpanEvent::from_json(&Json::parse(r#"{"stage":"nope"}"#).unwrap()).is_none());
        assert!(SpanEvent::from_json(&Json::parse(r#"{"request":12}"#).unwrap()).is_none());
    }

    #[test]
    fn concurrent_writers_never_produce_torn_stages() {
        use std::sync::Arc;
        let t = Arc::new(tracer(1.0, 1 << 10));
        let mut handles = vec![];
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                let start = Instant::now();
                for i in 0..5000u64 {
                    t.span(Stage::SpecRound, w * 10_000 + i, w as usize, start, i);
                }
            }));
        }
        // reader races the writers; every decoded record must be coherent
        for _ in 0..20 {
            for e in t.events() {
                assert_eq!(e.stage, Stage::SpecRound);
                assert!(e.lane.unwrap() < 4);
                assert_eq!(e.request / 10_000, e.lane.unwrap() as u64);
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.recorded(), 20_000);
        assert_eq!(t.events().len(), 1 << 10);
    }
}
