//! Small shared substrates: PRNG, JSON, time helpers.

pub mod b64;
pub mod json;
pub mod rng;

use std::time::Instant;

/// One worker per available core — the shared `0 = auto` resolution for
/// `--prefill-threads` and `--decode-threads`.
///
/// Deliberately uncapped: the old prefill-private copy did `.min(8)`, which
/// silently pinned `--prefill-threads 0` to 8 workers on larger boxes.  The
/// resolved count is printed at serve startup so there is no silent cap to
/// rediscover.
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Time a closure, returning (result, seconds).
pub fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let start = Instant::now();
    let r = f();
    (r, start.elapsed().as_secs_f64())
}

/// Format a byte count human-readably.
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn human_bytes_units() {
        assert_eq!(super::human_bytes(512), "512 B");
        assert_eq!(super::human_bytes(2048), "2.00 KiB");
        assert_eq!(super::human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
