//! Minimal JSON substrate (parser + writer) — replaces serde_json, which is
//! unavailable offline.  Supports the full JSON grammar the project needs:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//!
//! Used by: `runtime::artifact` (manifest.json), `config` (model/runtime
//! configs), the `server` line protocol, and checkpoint metadata.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are ordered (BTreeMap) so serialization is
/// deterministic — handy for golden tests and checkpoint diffs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Shape helper: `[2, 3, 4]` -> `vec![2, 3, 4]`.
    pub fn as_shape(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- parse / serialize ----
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        for src in [
            "null",
            "true",
            "false",
            "0",
            "-12.5",
            "\"hi\"",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(src).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{src}");
        }
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let src = r#"{"configs": {"micro": {"d_model": 64, "paths": [["['a']", [2, 3]]]}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path("configs.micro.d_model").unwrap().as_usize(), Some(64));
        let shape = v
            .path("configs.micro.paths")
            .unwrap()
            .idx(0)
            .unwrap()
            .idx(1)
            .unwrap()
            .as_shape()
            .unwrap();
        assert_eq!(shape, vec![2, 3]);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
        let round = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn scientific_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64(), Some(-0.025));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }
}
