//! Deterministic PRNG substrate (no external `rand` available offline).
//!
//! `SplitMix64` core with helper distributions used across the workload
//! generators, property tests and samplers: uniform, normal (Box–Muller),
//! exponential, Poisson (Knuth / PTRS for large lambda), zipf and
//! categorical sampling.

/// SplitMix64: tiny, fast, statistically solid for non-crypto use.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (for per-thread / per-lane generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Capture the full generator state (session snapshot / exact resume).
    pub fn parts(&self) -> (u64, Option<f64>) {
        (self.state, self.spare)
    }

    /// Rebuild a generator from [`Rng::parts`] — continues the exact stream.
    pub fn from_parts(state: u64, spare: Option<f64>) -> Rng {
        Rng { state, spare }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson; Knuth for small lambda, normal approximation above 64.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 64.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.normal();
            x.max(0.0).round() as u64
        }
    }

    /// Zipf over [0, n) with exponent `s` (inverse-CDF on precomputed table
    /// is overkill here; rejection-free cumulative walk for modest n).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        let norm: f64 = (1..=n).map(|i| (i as f64).powf(-s)).sum();
        let mut u = self.f64() * norm;
        for i in 1..=n {
            u -= (i as f64).powf(-s);
            if u <= 0.0 {
                return i - 1;
            }
        }
        n - 1
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w.max(0.0) as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fill a slice with scaled standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for x in out.iter_mut() {
            *x = self.normal() as f32 * scale;
        }
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(3);
        for lambda in [0.5, 4.0, 100.0] {
            let n = 20_000;
            let mean = (0..n).map(|_| r.poisson(lambda)).sum::<u64>() as f64 / n as f64;
            assert!((mean - lambda).abs() < lambda.max(1.0) * 0.1, "lambda {lambda} mean {mean}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4] && counts[4] > counts[9]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [0.0f32, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 2 * counts[2]);
    }

    #[test]
    fn parts_roundtrip_continues_stream() {
        let mut a = Rng::new(9);
        let _ = a.normal(); // populate the Box–Muller spare
        let (state, spare) = a.parts();
        let mut b = Rng::from_parts(state, spare);
        for _ in 0..20 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the cached spare is part of the state
        let mut c = Rng::new(9);
        let _ = c.normal();
        let (state, spare) = c.parts();
        let mut d = Rng::from_parts(state, spare);
        assert_eq!(c.normal(), d.normal());
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(7);
        let mut b = a.fork(1);
        let mut c = a.fork(2);
        assert_ne!(b.next_u64(), c.next_u64());
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>());
    }
}
