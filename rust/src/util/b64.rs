//! Minimal standard base64 (RFC 4648, with padding) — carries binary
//! session-snapshot frames inside the line-JSON control plane without
//! pulling in a dependency.  The CRC lives inside the snapshot frame, so
//! this layer only has to be reversible, not self-checking.

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Encode bytes as standard padded base64.
pub fn encode(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len().div_ceil(3) * 4);
    for chunk in bytes.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let word = (b0 << 16) | (b1 << 8) | b2;
        out.push(ALPHABET[(word >> 18) as usize & 63] as char);
        out.push(ALPHABET[(word >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { ALPHABET[(word >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { ALPHABET[word as usize & 63] as char } else { '=' });
    }
    out
}

/// Decode standard base64 (padded or unpadded).  Rejects characters
/// outside the alphabet and impossible lengths.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    fn val(c: u8) -> Result<u32, String> {
        match c {
            b'A'..=b'Z' => Ok((c - b'A') as u32),
            b'a'..=b'z' => Ok((c - b'a') as u32 + 26),
            b'0'..=b'9' => Ok((c - b'0') as u32 + 52),
            b'+' => Ok(62),
            b'/' => Ok(63),
            _ => Err(format!("invalid base64 byte {c:#04x}")),
        }
    }
    let stripped: &[u8] = s.as_bytes();
    let stripped = match stripped {
        [rest @ .., b'=', b'='] => rest,
        [rest @ .., b'='] => rest,
        _ => stripped,
    };
    if stripped.len() % 4 == 1 {
        return Err(format!("impossible base64 length {}", stripped.len()));
    }
    let mut out = Vec::with_capacity(stripped.len() * 3 / 4);
    for chunk in stripped.chunks(4) {
        let mut word: u32 = 0;
        for &c in chunk {
            word = (word << 6) | val(c)?;
        }
        match chunk.len() {
            4 => {
                out.push((word >> 16) as u8);
                out.push((word >> 8) as u8);
                out.push(word as u8);
            }
            3 => {
                word <<= 6;
                out.push((word >> 16) as u8);
                out.push((word >> 8) as u8);
            }
            2 => {
                word <<= 12;
                out.push((word >> 16) as u8);
            }
            _ => unreachable!("length % 4 == 1 rejected above"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc4648_vectors() {
        for (plain, enc) in [
            ("", ""),
            ("f", "Zg=="),
            ("fo", "Zm8="),
            ("foo", "Zm9v"),
            ("foob", "Zm9vYg=="),
            ("fooba", "Zm9vYmE="),
            ("foobar", "Zm9vYmFy"),
        ] {
            assert_eq!(encode(plain.as_bytes()), enc);
            assert_eq!(decode(enc).unwrap(), plain.as_bytes());
        }
    }

    #[test]
    fn binary_roundtrip_all_lengths() {
        let mut rng = crate::util::rng::Rng::new(0xB64);
        for len in 0..200 {
            let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let enc = encode(&bytes);
            assert_eq!(decode(&enc).unwrap(), bytes, "len {len}");
            // unpadded form decodes too
            assert_eq!(decode(enc.trim_end_matches('=')).unwrap(), bytes, "len {len}");
        }
    }

    #[test]
    fn garbage_rejected_not_panicked() {
        assert!(decode("a\nb").is_err());
        assert!(decode("ab cd").is_err());
        assert!(decode("a").is_err());
        assert!(decode("{json}").is_err());
    }
}
