//! Bucketed decode-step executable ladder: one compiled `decode_step`
//! artifact per batch width, resolved by name and cached through the
//! engine's compile cache.
//!
//! The AOT pipeline (`python/compile/aot.py`) emits the full-width
//! program as `decode_step_<cfg>` (the pre-bucketing name, kept for
//! compatibility) and narrower variants as `decode_step_<cfg>_b<W>` at
//! power-of-two widths below `decode_batch`.  Parameters are
//! batch-independent, and the state inputs are the same components at
//! batch width W — so switching buckets is purely a state-repack plus a
//! different executable, never a weight reload.
//!
//! This module is the *mechanism* half of occupancy-adaptive decode:
//! discovery (which widths actually have artifacts — a ladder entry the
//! manifest cannot back is silently dropped, so an old artifact
//! directory degrades to fixed-width serving instead of erroring) and
//! name resolution.  The *policy* half (hysteresis, when to switch)
//! lives in [`crate::coordinator::bucket`].

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::Result;

use super::{Engine, Executable, Manifest};

/// The name a decode-step artifact of width `w` carries for config
/// `cfg`: the bare `decode_step_<cfg>` at full width, `_b<w>` otherwise.
pub fn decode_artifact_name(cfg: &str, width: usize, full_width: usize) -> String {
    if width == full_width {
        format!("decode_step_{cfg}")
    } else {
        format!("decode_step_{cfg}_b{width}")
    }
}

impl Manifest {
    /// Decode-step rungs available for `cfg`: batch width → the artifact
    /// *actually holding* that width, for the full-width
    /// `decode_step_<cfg>` (when present) and every bucketed
    /// `decode_step_<cfg>_b<W>` variant.  Widths are taken from the
    /// artifact's token-input shape (`[W] i32`, the last input), not the
    /// name suffix, so a mislabelled artifact still registers under its
    /// *real* width (and is loaded by its real name, not a reconstructed
    /// one).  On a width collision the canonically-named artifact wins.
    pub fn decode_rungs(&self, cfg: &str, full_width: usize) -> BTreeMap<usize, String> {
        let full = format!("decode_step_{cfg}");
        let bucket_prefix = format!("decode_step_{cfg}_b");
        let mut rungs: BTreeMap<usize, String> = BTreeMap::new();
        for (name, spec) in &self.artifacts {
            // the manifest's own config tag is the authority: a sibling
            // config whose *name* collides (e.g. "t_b4", whose full-width
            // artifact is "decode_step_t_b4") must not leak its program
            // into config "t"'s ladder.  Empty tags (older manifests)
            // fall through to the name filters below.
            if !(spec.config.is_empty() || spec.config == cfg) {
                continue;
            }
            let named_ok = *name == full
                || name
                    .strip_prefix(&bucket_prefix)
                    // all-digit suffix only, so a config named
                    // "t_bucketed" cannot leak into config "t"'s ladder
                    .is_some_and(|w| !w.is_empty() && w.bytes().all(|b| b.is_ascii_digit()));
            if !named_ok {
                continue;
            }
            let Some(tokens) = spec.inputs.last() else { continue };
            if tokens.shape.len() != 1 {
                continue;
            }
            let w = tokens.shape[0];
            let canonical = decode_artifact_name(cfg, w, full_width);
            match rungs.entry(w) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(name.clone());
                }
                std::collections::btree_map::Entry::Occupied(mut e) => {
                    if *name == canonical {
                        e.insert(name.clone());
                    }
                }
            }
        }
        rungs
    }

    /// The widths of [`Manifest::decode_rungs`], sorted ascending
    /// (diagnostics surface; `full_width` only disambiguates naming).
    pub fn decode_widths(&self, cfg: &str) -> Vec<usize> {
        let full = self
            .configs
            .get(cfg)
            .map(|c| c.decode_batch)
            .unwrap_or(usize::MAX);
        self.decode_rungs(cfg, full).into_keys().collect()
    }
}

/// A validated ladder of decode widths for one config, every rung bound
/// to the manifest artifact that actually holds it.
#[derive(Debug, Clone)]
pub struct DecodeBuckets {
    cfg_name: String,
    full_width: usize,
    /// (width, artifact name), sorted ascending by width.
    rungs: Vec<(usize, String)>,
    widths: Vec<usize>,
}

impl DecodeBuckets {
    /// Intersect the requested ladder with the rungs the manifest can
    /// back, keeping each rung bound to its real artifact name — so one
    /// mislabelled artifact costs at most its own rung, never the whole
    /// feature.  `full_width` (the config's `decode_batch`) is always
    /// included under the bare name — the engine force-compiled that
    /// artifact at spawn.
    pub fn discover(
        manifest: &Manifest,
        cfg_name: &str,
        requested: &[usize],
        full_width: usize,
    ) -> DecodeBuckets {
        let available = manifest.decode_rungs(cfg_name, full_width);
        let mut rungs: Vec<(usize, String)> = requested
            .iter()
            .filter(|&&w| w != full_width)
            .filter_map(|w| available.get(w).map(|name| (*w, name.clone())))
            .collect();
        rungs.push((full_width, decode_artifact_name(cfg_name, full_width, full_width)));
        rungs.sort_by_key(|(w, _)| *w);
        rungs.dedup_by_key(|r| r.0);
        let widths = rungs.iter().map(|(w, _)| *w).collect();
        DecodeBuckets { cfg_name: cfg_name.to_string(), full_width, rungs, widths }
    }

    /// The validated ladder, sorted ascending (always ends in the full
    /// width).  A single-entry ladder means bucketing has nothing to
    /// switch between — callers keep fixed-width decode.
    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// The artifact name serving width `w`: the manifest-bound rung when
    /// one exists, the canonical [`decode_artifact_name`] otherwise.
    pub fn artifact_name(&self, width: usize) -> String {
        self.rungs
            .iter()
            .find(|(w, _)| *w == width)
            .map(|(_, name)| name.clone())
            .unwrap_or_else(|| decode_artifact_name(&self.cfg_name, width, self.full_width))
    }

    /// Compile-and-cache every rung up front so a bucket switch on the
    /// serving path never pays compile latency.  Returns the executables
    /// in ladder order (kept alive by the engine's cache regardless).
    pub fn warm(&self, engine: &Engine) -> Result<Vec<Rc<Executable>>> {
        self.rungs.iter().map(|(_, name)| engine.load(name)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A manifest with decode_step artifacts at widths 8 (full), 4, 2
    /// for config "t" — plus a mislabelled `_b16` whose real token shape
    /// is `[4]`, and another config's bucket, neither of which may
    /// perturb "t"'s ladder.
    fn bucketed_manifest() -> Manifest {
        let json = r#"{
          "configs": {},
          "artifacts": {
            "decode_step_t": {"file": "a.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [256, 64], "dtype": "f32"}, {"shape": [8], "dtype": "int32"}],
              "outputs": [{"shape": [8, 256], "dtype": "f32"}]},
            "decode_step_t_b4": {"file": "b.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [256, 64], "dtype": "f32"}, {"shape": [4], "dtype": "int32"}],
              "outputs": [{"shape": [4, 256], "dtype": "f32"}]},
            "decode_step_t_b2": {"file": "c.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [256, 64], "dtype": "f32"}, {"shape": [2], "dtype": "int32"}],
              "outputs": [{"shape": [2, 256], "dtype": "f32"}]},
            "decode_step_t_b16": {"file": "e.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [256, 64], "dtype": "f32"}, {"shape": [4], "dtype": "int32"}],
              "outputs": [{"shape": [4, 256], "dtype": "f32"}]},
            "decode_step_t_b9": {"file": "f.hlo.txt", "kind": "decode_step", "config": "t_b9",
              "inputs": [{"shape": [9], "dtype": "int32"}],
              "outputs": [{"shape": [9, 256], "dtype": "f32"}]},
            "decode_step_other_b1": {"file": "d.hlo.txt", "kind": "decode_step", "config": "other",
              "inputs": [{"shape": [1], "dtype": "int32"}],
              "outputs": [{"shape": [1, 256], "dtype": "f32"}]}
          }
        }"#;
        Manifest::parse(json).unwrap()
    }

    #[test]
    fn widths_come_from_token_shapes_not_names() {
        let m = bucketed_manifest();
        // the mislabelled _b16 (token shape [4]) merges into width 4
        // instead of inventing a phantom width 16, and the sibling
        // config "t_b9" — whose full-width artifact name collides with
        // "t"'s bucket naming — is excluded by its manifest config tag
        assert_eq!(m.decode_widths("t"), vec![2, 4, 8]);
        // other configs' buckets don't leak in, and "t_b9" sees its own
        assert_eq!(m.decode_widths("other"), vec![1]);
        assert_eq!(m.decode_widths("t_b9"), vec![9]);
        assert_eq!(m.decode_widths("absent"), Vec::<usize>::new());
    }

    #[test]
    fn discovery_intersects_request_with_artifacts() {
        let m = bucketed_manifest();
        // requested 1 has no artifact: dropped; 2/4 backed; 8 is full
        let b = DecodeBuckets::discover(&m, "t", &[1, 2, 4, 8], 8);
        assert_eq!(b.widths(), &[2, 4, 8]);
        assert_eq!(b.artifact_name(8), "decode_step_t");
        // width-4 collision (real b4 vs mislabelled b16): canonical wins
        assert_eq!(b.artifact_name(4), "decode_step_t_b4");
        // an empty/unbackable request degrades to fixed-width, not error
        let fixed = DecodeBuckets::discover(&m, "t", &[1], 8);
        assert_eq!(fixed.widths(), &[8]);
        let no_arts = DecodeBuckets::discover(&m, "absent", &[1, 2, 4], 4);
        assert_eq!(no_arts.widths(), &[4]);
    }

    #[test]
    fn mislabelled_rung_is_loaded_by_its_real_name() {
        // only a mislabelled artifact backs width 4 (named _b16, token
        // shape [4]): the rung must bind to the REAL name so warm()
        // loads it instead of failing on a reconstructed "_b4" — and a
        // bad rung can never cost more than itself
        let json = r#"{
          "configs": {},
          "artifacts": {
            "decode_step_t": {"file": "a.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [8], "dtype": "int32"}],
              "outputs": [{"shape": [8, 256], "dtype": "f32"}]},
            "decode_step_t_b16": {"file": "e.hlo.txt", "kind": "decode_step", "config": "t",
              "inputs": [{"shape": [4], "dtype": "int32"}],
              "outputs": [{"shape": [4, 256], "dtype": "f32"}]}
          }
        }"#;
        let m = Manifest::parse(json).unwrap();
        let b = DecodeBuckets::discover(&m, "t", &[1, 2, 4, 8], 8);
        assert_eq!(b.widths(), &[4, 8]);
        assert_eq!(b.artifact_name(4), "decode_step_t_b16");
        assert_eq!(b.artifact_name(8), "decode_step_t");
    }
}
