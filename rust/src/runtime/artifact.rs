//! Manifest parsing: `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) describes every AOT artifact's I/O signature and
//! every model config's layout (parameter/state tree-flatten order).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// dtype + shape of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            shape: j.get("shape").and_then(Json::as_shape).ok_or_else(|| anyhow!("shape"))?,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("dtype"))?
                .to_string(),
        })
    }
}

/// One AOT artifact (an HLO-text program).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub kind: String,
    pub config: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A model configuration (mirrors `model.HlaConfig` + shapes).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub kv_heads: usize,
    pub mixer: String,
    pub chunk: usize,
    pub gamma: f64,
    pub lam: f64,
    pub norm_mode: String,
    pub eps: f64,
    pub multi_query: bool,
    pub n_params: usize,
    pub n_param_tensors: usize,
    pub n_state_tensors: usize,
    /// (name, shape) in tree-flatten order.
    pub param_paths: Vec<(String, Vec<usize>)>,
    pub state_paths: Vec<(String, Vec<usize>)>,
    pub train_batch: usize,
    pub train_seq: usize,
    pub decode_batch: usize,
    pub prefill_len: usize,
}

impl ModelCfg {
    /// Bytes of recurrent state for the whole decode batch.
    pub fn state_nbytes(&self) -> usize {
        self.state_paths.iter().map(|(_, s)| s.iter().product::<usize>() * 4).sum()
    }

    /// Bytes of recurrent state per sequence (one decode lane).
    pub fn state_nbytes_per_seq(&self) -> usize {
        self.state_nbytes() / self.decode_batch.max(1)
    }

    /// Softmax-baseline KV-cache bytes per sequence at context length n.
    pub fn kv_cache_nbytes(&self, n: usize) -> usize {
        2 * n * self.n_layers * self.kv_heads * self.head_dim * 4
    }

    fn from_json(name: &str, j: &Json) -> Result<ModelCfg> {
        let us =
            |k: &str| j.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("cfg field {k}"));
        let fl = |k: &str| j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("cfg field {k}"));
        let st = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("cfg field {k}"))
        };
        let paths = |k: &str| -> Result<Vec<(String, Vec<usize>)>> {
            j.get(k)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("cfg field {k}"))?
                .iter()
                .map(|e| {
                    let name = e.idx(0).and_then(Json::as_str).ok_or_else(|| anyhow!("path"))?;
                    let shape = e.idx(1).and_then(Json::as_shape).ok_or_else(|| anyhow!("shape"))?;
                    Ok((name.to_string(), shape))
                })
                .collect()
        };
        Ok(ModelCfg {
            name: name.to_string(),
            vocab: us("vocab")?,
            d_model: us("d_model")?,
            n_layers: us("n_layers")?,
            n_heads: us("n_heads")?,
            head_dim: us("head_dim")?,
            d_ffn: us("d_ffn")?,
            kv_heads: us("kv_heads")?,
            mixer: st("mixer")?,
            chunk: us("chunk")?,
            gamma: fl("gamma")?,
            lam: fl("lam")?,
            norm_mode: st("norm_mode")?,
            eps: fl("eps")?,
            multi_query: j.get("multi_query").and_then(Json::as_bool).unwrap_or(false),
            n_params: us("n_params")?,
            n_param_tensors: us("n_param_tensors")?,
            n_state_tensors: us("n_state_tensors")?,
            param_paths: paths("param_paths")?,
            state_paths: paths("state_paths")?,
            train_batch: us("train_batch")?,
            train_seq: us("train_seq")?,
            decode_batch: us("decode_batch")?,
            prefill_len: us("prefill_len")?,
        })
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub configs: BTreeMap<String, ModelCfg>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut m = Manifest::default();
        if let Some(cfgs) = j.get("configs").and_then(Json::as_obj) {
            for (name, cj) in cfgs {
                m.configs.insert(name.clone(), ModelCfg::from_json(name, cj)?);
            }
        }
        if let Some(arts) = j.get("artifacts").and_then(Json::as_obj) {
            for (name, aj) in arts {
                let get_specs = |k: &str| -> Result<Vec<TensorSpec>> {
                    aj.get(k)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("artifact {name}: {k}"))?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect()
                };
                m.artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        file: aj
                            .get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("file"))?
                            .to_string(),
                        kind: aj
                            .get("kind")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        config: aj
                            .get("config")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        inputs: get_specs("inputs")?,
                        outputs: get_specs("outputs")?,
                    },
                );
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "configs": {
        "t": {"vocab": 256, "d_model": 64, "n_layers": 2, "n_heads": 2,
              "head_dim": 32, "d_ffn": 160, "kv_heads": 2, "mixer": "hla2",
              "chunk": 16, "gamma": 0.99, "lam": 0.0, "norm_mode": "abs",
              "eps": 1e-6, "multi_query": false, "n_params": 110000,
              "n_param_tensors": 20, "n_state_tensors": 5,
              "param_paths": [["['embed']", [256, 64]]],
              "state_paths": [["['c']", [2, 2, 2, 32, 32]]],
              "train_batch": 2, "train_seq": 32, "decode_batch": 2,
              "prefill_len": 16, "ffn_mult": 2.6667, "name": "t"}
      },
      "artifacts": {
        "fwd_t": {"file": "fwd_t.hlo.txt", "kind": "fwd", "config": "t",
                   "inputs": [{"shape": [2, 32], "dtype": "int32"}],
                   "outputs": [{"shape": [2, 32, 256], "dtype": "float32"}]}
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let cfg = &m.configs["t"];
        assert_eq!(cfg.d_model, 64);
        assert_eq!(cfg.param_paths[0].0, "['embed']");
        assert_eq!(cfg.state_paths[0].1, vec![2, 2, 2, 32, 32]);
        let a = &m.artifacts["fwd_t"];
        assert_eq!(a.inputs[0].shape, vec![2, 32]);
        assert_eq!(a.outputs[0].dtype, "float32");
    }

    #[test]
    fn state_memory_accounting() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let cfg = &m.configs["t"];
        assert_eq!(cfg.state_nbytes(), 2 * 2 * 2 * 32 * 32 * 4);
        assert_eq!(cfg.state_nbytes_per_seq(), cfg.state_nbytes() / 2);
        // KV cache grows with n, state does not
        assert!(cfg.kv_cache_nbytes(100_000) > 100 * cfg.state_nbytes_per_seq());
    }

    #[test]
    fn real_manifest_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.configs.contains_key("micro"));
            let micro = &m.configs["micro"];
            assert_eq!(micro.n_state_tensors, micro.state_paths.len());
            assert!(m.artifacts.contains_key("decode_step_micro"));
        }
    }
}
