//! Host tensor ⇄ `xla::Literal` conversions (f32 and i32 payloads).

use anyhow::{anyhow, Result};

use crate::tensor::{Tensor, TensorI32};

/// A host-side input value for `Engine::run_host`.
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Tensor),
    I32(TensorI32),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl HostValue {
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostValue::F32(t) => tensor_to_literal(t),
            HostValue::I32(t) => tokens_to_literal(t),
            HostValue::ScalarF32(v) => Ok(xla::Literal::scalar(*v)),
            HostValue::ScalarI32(v) => Ok(xla::Literal::scalar(*v)),
        }
    }
}

impl From<Tensor> for HostValue {
    fn from(t: Tensor) -> Self {
        HostValue::F32(t)
    }
}

impl From<TensorI32> for HostValue {
    fn from(t: TensorI32) -> Self {
        HostValue::I32(t)
    }
}

/// f32 tensor -> literal (rank 0 handled via scalar).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if t.shape.is_empty() {
        return Ok(xla::Literal::scalar(t.data[0]));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// i32 tensor -> literal.
pub fn tokens_to_literal(t: &TensorI32) -> Result<xla::Literal> {
    if t.shape.is_empty() {
        return Ok(xla::Literal::scalar(t.data[0]));
    }
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&t.data).reshape(&dims)?)
}

/// literal -> f32 tensor (errors on non-f32 payloads).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(|e| anyhow!("literal_to_tensor: {e}"))?;
    Ok(Tensor::from_vec(&dims, data))
}

/// literal -> i32 tensor.
pub fn literal_to_tokens(lit: &xla::Literal) -> Result<TensorI32> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<i32>().map_err(|e| anyhow!("literal_to_tokens: {e}"))?;
    Ok(TensorI32::from_vec(&dims, data))
}
