//! Runtime: load AOT artifacts (HLO text) and execute them on the PJRT CPU
//! client — the only place the `xla` crate is touched.
//!
//! Threading: the xla wrapper types hold raw pointers and are not `Send`;
//! the [`Engine`] therefore lives on exactly one thread (the coordinator's
//! engine loop, the trainer main thread, or a bench).  Cross-thread access
//! goes through `coordinator`'s message channels.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;
pub mod bucket;
pub mod literal;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::metrics::Histogram;
use crate::tensor::{Tensor, TensorI32};
pub use artifact::{ArtifactSpec, Manifest, ModelCfg, TensorSpec};
pub use bucket::{decode_artifact_name, DecodeBuckets};
pub use literal::{literal_to_tensor, tensor_to_literal, tokens_to_literal, HostValue};

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    pub name: String,
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host literals; returns untupled output literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with *borrowed* literals — the decode hot path: callers keep
    /// params/state alive across steps and pass references, so nothing is
    /// deep-copied per step (rust/DESIGN.md §Perf item 2).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let out = self.exe.execute(inputs)?;
        let tuple = out[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Execute with device-resident buffers (the decode hot path): inputs
    /// stay on device, outputs come back as device buffers (untupled when
    /// PJRT returns a flattened row, otherwise via one host round-trip).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let out = self.exe.execute_b(inputs)?;
        let mut row = out.into_iter().next().ok_or_else(|| anyhow!("no replica output"))?;
        if row.len() == 1 && self.spec.outputs.len() > 1 {
            // single tuple buffer: round-trip through a literal to untuple
            let lit = row[0].to_literal_sync()?;
            let parts = lit.to_tuple()?;
            let client = self.exe.client();
            let device = client.devices().into_iter().next().ok_or_else(|| anyhow!("no device"))?;
            return parts
                .iter()
                .map(|l| Ok(client.buffer_from_host_literal(Some(&device), l)?))
                .collect();
        }
        Ok(row.drain(..).collect())
    }
}

/// PJRT CPU engine: artifact registry + executable cache (single-threaded).
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// compile + execute timing, for the perf log
    pub compile_hist: RefCell<Histogram>,
    pub exec_hist: RefCell<Histogram>,
}

impl Engine {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_hist: RefCell::new(Histogram::new()),
            exec_hist: RefCell::new(Histogram::new()),
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_hist.borrow_mut().record(start.elapsed());
        let exec = Rc::new(Executable { name: name.to_string(), spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Host-tensor convenience execute (copies in and out), timed.
    pub fn run_host(&self, name: &str, inputs: &[HostValue]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|h| h.to_literal()).collect::<Result<_>>()?;
        let start = Instant::now();
        let outs = exe.run(&lits)?;
        self.exec_hist.borrow_mut().record(start.elapsed());
        outs.iter().map(literal_to_tensor).collect()
    }

    /// Upload a literal to the device.
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let device = self.client.devices().into_iter().next().ok_or_else(|| anyhow!("no device"))?;
        Ok(self.client.buffer_from_host_literal(Some(&device), lit)?)
    }

    /// Upload a host tensor to the device.
    pub fn tensor_to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.to_device(&tensor_to_literal(t)?)
    }

    pub fn tokens_to_device(&self, t: &TensorI32) -> Result<xla::PjRtBuffer> {
        self.to_device(&tokens_to_literal(t)?)
    }

    /// Run `init_<cfg>` and return the parameter literals (host side).
    pub fn init_params(&self, cfg: &str, seed: i32) -> Result<Vec<xla::Literal>> {
        let exe = self.load(&format!("init_{cfg}"))?;
        exe.run(&[xla::Literal::scalar(seed)])
    }

    pub fn model_cfg(&self, name: &str) -> Result<&ModelCfg> {
        self.manifest.configs.get(name).ok_or_else(|| anyhow!("config {name:?} not in manifest"))
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.manifest.artifacts.keys().cloned().collect();
        v.sort();
        v
    }
}
