//! Training driver: runs the AOT `train_step_<cfg>` artifact in a loop —
//! Rust owns the schedule, data pipeline, logging and checkpoints; all
//! gradient math lives in the lowered HLO (L2's jax.value_and_grad).

pub mod checkpoint;
pub mod corpus;
pub mod data;

use anyhow::Result;

use crate::runtime::{literal, Engine};
use crate::tensor::TensorI32;

/// Learning-rate schedule: linear warmup then cosine decay.
#[derive(Debug, Clone, Copy)]
pub struct LrSchedule {
    pub peak: f32,
    pub warmup: usize,
    pub total: usize,
    pub floor: f32,
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        if step < self.warmup {
            return self.peak * (step + 1) as f32 / self.warmup as f32;
        }
        let progress =
            (step - self.warmup) as f32 / (self.total.saturating_sub(self.warmup)).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress.min(1.0)).cos());
        self.floor + (self.peak - self.floor) * cos
    }
}

/// Options for a training run.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub cfg_name: String,
    pub steps: usize,
    pub lr: LrSchedule,
    pub seed: u64,
    pub log_every: usize,
    pub checkpoint: Option<String>,
    /// corpus size in bytes (synthesized deterministically)
    pub corpus_bytes: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            cfg_name: "tiny".into(),
            steps: 300,
            lr: LrSchedule { peak: 3e-3, warmup: 20, total: 300, floor: 3e-4 },
            seed: 0,
            log_every: 10,
            checkpoint: None,
            corpus_bytes: 1 << 20,
        }
    }
}

/// One logged point of the loss curve.
#[derive(Debug, Clone, Copy)]
pub struct LossPoint {
    pub step: usize,
    pub loss: f32,
    pub lr: f32,
    pub tokens_per_sec: f64,
}

/// Run training; returns the loss curve and leaves final params on `engine`
/// as literals (also checkpointed if requested).
pub fn train(engine: &Engine, opts: &TrainOpts) -> Result<(Vec<LossPoint>, Vec<xla::Literal>)> {
    let cfg = engine.model_cfg(&opts.cfg_name)?.clone();
    let (b, t) = (cfg.train_batch, cfg.train_seq);
    let step_exe = engine.load(&format!("train_step_{}", opts.cfg_name))?;
    let n_params = cfg.n_param_tensors;

    // init params + zeroed Adam moments
    let mut params = engine.init_params(&opts.cfg_name, opts.seed as i32)?;
    let mut mu = zeros_like(&params)?;
    let mut nu = zeros_like(&params)?;

    let corpus = corpus::build_corpus(opts.corpus_bytes, opts.seed ^ 0xC0FFEE);
    let mut batches = data::Batches::new(&corpus, b, t + 1, opts.seed);

    let mut curve = Vec::new();
    let started = std::time::Instant::now();
    let mut tokens_done = 0u64;
    let mut last_loss = f32::NAN;
    for step in 0..opts.steps {
        let lr = opts.lr.at(step);
        let tokens = batches.next_batch();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(3 * n_params + 3);
        inputs.append(&mut params);
        inputs.append(&mut mu);
        inputs.append(&mut nu);
        inputs.push(xla::Literal::scalar(step as f32));
        inputs.push(literal::tokens_to_literal(&TensorI32::from_vec(&[b, t + 1], tokens))?);
        inputs.push(xla::Literal::scalar(lr));
        let mut outs = step_exe.run(&inputs)?;
        let loss_lit = outs.pop().expect("train_step returns loss last");
        last_loss = loss_lit.to_vec::<f32>()?[0];
        nu = outs.split_off(2 * n_params);
        mu = outs.split_off(n_params);
        params = outs;
        tokens_done += (b * t) as u64;
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            let tps = tokens_done as f64 / started.elapsed().as_secs_f64();
            curve.push(LossPoint { step, loss: last_loss, lr, tokens_per_sec: tps });
            log::info!("step {step:>5}  loss {last_loss:.4}  lr {lr:.2e}  {tps:.0} tok/s");
        }
        if !last_loss.is_finite() {
            anyhow::bail!("loss diverged at step {step}");
        }
    }
    if let Some(path) = &opts.checkpoint {
        checkpoint::save(path, &cfg, &params, opts.steps, last_loss)?;
    }
    Ok((curve, params))
}

fn zeros_like(params: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    params
        .iter()
        .map(|p| {
            let shape = p.array_shape()?;
            let n: i64 = shape.dims().iter().product();
            Ok(xla::Literal::vec1(&vec![0f32; n as usize]).reshape(shape.dims())?)
        })
        .collect()
}

/// Evaluate mean loss of `params` on held-out batches via `loss_<cfg>`.
pub fn evaluate(
    engine: &Engine,
    cfg_name: &str,
    params: &[xla::Literal],
    n_batches: usize,
    seed: u64,
) -> Result<f32> {
    let cfg = engine.model_cfg(cfg_name)?.clone();
    let (b, t) = (cfg.train_batch, cfg.train_seq);
    let exe = engine.load(&format!("loss_{cfg_name}"))?;
    let corpus = corpus::build_corpus(1 << 18, seed ^ 0xEAA1);
    let mut batches = data::Batches::new(&corpus, b, t + 1, seed);
    let mut total = 0.0f32;
    for _ in 0..n_batches {
        let tokens = batches.next_batch();
        let mut inputs: Vec<xla::Literal> = params
            .iter()
            .map(|p| {
                let shape = p.array_shape()?;
                let data = p.to_vec::<f32>()?;
                Ok(xla::Literal::vec1(&data).reshape(shape.dims())?)
            })
            .collect::<Result<_>>()?;
        inputs.push(literal::tokens_to_literal(&TensorI32::from_vec(&[b, t + 1], tokens))?);
        let outs = exe.run(&inputs)?;
        total += outs[0].to_vec::<f32>()?[0];
    }
    Ok(total / n_batches as f32)
}

/// A random-model baseline loss: ln(vocab) for a uniform predictor.
pub fn uniform_loss(vocab: usize) -> f32 {
    (vocab as f32).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let s = LrSchedule { peak: 1.0, warmup: 10, total: 110, floor: 0.1 };
        assert!(s.at(0) < s.at(9));
        assert!((s.at(9) - 1.0).abs() < 0.11);
        assert!(s.at(50) < 1.0);
        assert!(s.at(109) >= 0.1 - 1e-6);
        assert!(s.at(109) < s.at(50));
    }

    #[test]
    fn uniform_loss_value() {
        assert!((uniform_loss(256) - 5.545).abs() < 0.01);
    }
}
