//! Checkpoint format: a small self-describing binary container.
//!
//! Layout: magic "HLACKPT1" | meta-JSON length (u32 LE) | meta JSON |
//! per-tensor: rank (u32) | dims (u32 each) | f32 payload (LE).
//! Meta records config name, step, loss and tensor count for validation.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::ModelCfg;
use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"HLACKPT1";

/// Checkpoint metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct Meta {
    pub config: String,
    pub step: usize,
    pub loss: f32,
    pub n_tensors: usize,
}

/// Save parameter literals with metadata.
pub fn save(
    path: impl AsRef<Path>,
    cfg: &ModelCfg,
    params: &[xla::Literal],
    step: usize,
    loss: f32,
) -> Result<()> {
    let tensors: Vec<Tensor> = params
        .iter()
        .map(crate::runtime::literal::literal_to_tensor)
        .collect::<Result<_>>()?;
    save_tensors(path, &cfg.name, &tensors, step, loss)
}

/// Save host tensors with metadata.
pub fn save_tensors(
    path: impl AsRef<Path>,
    config: &str,
    tensors: &[Tensor],
    step: usize,
    loss: f32,
) -> Result<()> {
    let mut w = BufWriter::new(File::create(path.as_ref()).context("creating checkpoint")?);
    w.write_all(MAGIC)?;
    let meta = Json::obj(vec![
        ("config", Json::str(config)),
        ("step", Json::num(step as f64)),
        ("loss", Json::num(loss as f64)),
        ("n_tensors", Json::num(tensors.len() as f64)),
    ])
    .to_string();
    w.write_all(&(meta.len() as u32).to_le_bytes())?;
    w.write_all(meta.as_bytes())?;
    for t in tensors {
        w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read just the header of an open checkpoint stream (magic + meta).
fn read_meta(r: &mut impl Read) -> Result<Meta> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an HLA checkpoint (bad magic)");
    }
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let mut meta_buf = vec![0u8; u32::from_le_bytes(len4) as usize];
    r.read_exact(&mut meta_buf)?;
    let meta_json = Json::parse(std::str::from_utf8(&meta_buf)?)
        .map_err(|e| anyhow!("checkpoint meta: {e}"))?;
    Ok(Meta {
        config: meta_json.get("config").and_then(Json::as_str).unwrap_or("").to_string(),
        step: meta_json.get("step").and_then(Json::as_usize).unwrap_or(0),
        loss: meta_json.get("loss").and_then(Json::as_f64).unwrap_or(f64::NAN) as f32,
        n_tensors: meta_json.get("n_tensors").and_then(Json::as_usize).unwrap_or(0),
    })
}

/// Load only the metadata header — cheap validation for callers that
/// just need to know what the file claims to hold (e.g. `hla serve
/// --checkpoint` fails fast on a typo'd path or wrong config without
/// deserializing the tensor payload).
pub fn load_meta(path: impl AsRef<Path>) -> Result<Meta> {
    let mut r = BufReader::new(File::open(path.as_ref()).context("opening checkpoint")?);
    read_meta(&mut r)
}

/// Load a checkpoint (tensors + metadata).
pub fn load(path: impl AsRef<Path>) -> Result<(Meta, Vec<Tensor>)> {
    let mut r = BufReader::new(File::open(path.as_ref()).context("opening checkpoint")?);
    let meta = read_meta(&mut r)?;
    let mut len4 = [0u8; 4];
    let mut tensors = Vec::with_capacity(meta.n_tensors);
    for _ in 0..meta.n_tensors {
        r.read_exact(&mut len4)?;
        let rank = u32::from_le_bytes(len4) as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            r.read_exact(&mut len4)?;
            shape.push(u32::from_le_bytes(len4) as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut buf = vec![0u8; numel * 4];
        r.read_exact(&mut buf)?;
        for (x, b4) in data.iter_mut().zip(buf.chunks_exact(4)) {
            *x = f32::from_le_bytes([b4[0], b4[1], b4[2], b4[3]]);
        }
        tensors.push(Tensor::from_vec(&shape, data));
    }
    Ok((meta, tensors))
}

/// Convert loaded tensors back to literals for the engine.
pub fn tensors_to_literals(tensors: &[Tensor]) -> Result<Vec<xla::Literal>> {
    tensors.iter().map(crate::runtime::literal::tensor_to_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("hla-ckpt-{}", std::process::id()));
        let tensors = vec![
            Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            Tensor::from_vec(&[4], vec![-1.0, 0.5, 0.25, 0.0]),
            Tensor::scalar(7.5),
        ];
        save_tensors(&dir, "tiny", &tensors, 42, 1.23).unwrap();
        let (meta, back) = load(&dir).unwrap();
        assert_eq!(meta.config, "tiny");
        assert_eq!(meta.step, 42);
        assert!((meta.loss - 1.23).abs() < 1e-6);
        assert_eq!(back, tensors);
        // header-only read agrees with the full load (the serve
        // fail-fast validation path)
        assert_eq!(load_meta(&dir).unwrap(), meta);
        std::fs::remove_file(dir).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("hla-bad-{}", std::process::id()));
        std::fs::write(&dir, b"NOTACKPTxxxx").unwrap();
        assert!(load(&dir).is_err());
        std::fs::remove_file(dir).unwrap();
    }
}
