//! Training corpora: a small embedded public-domain text plus synthetic
//! generators (pattern language, key-value recall) — the data substrate for
//! the end-to-end training run (E10) and the recall probe (E11).

use crate::util::rng::Rng;

/// Public-domain seed text (Dickens, *A Tale of Two Cities*, 1859, opening;
/// + *The Gutenberg* non-copyright boilerplate trimmed).  Byte-level models
/// train on repetitions of this plus synthetic augmentation.
pub const SEED_TEXT: &str = "\
It was the best of times, it was the worst of times, it was the age of \
wisdom, it was the age of foolishness, it was the epoch of belief, it was \
the epoch of incredulity, it was the season of Light, it was the season of \
Darkness, it was the spring of hope, it was the winter of despair, we had \
everything before us, we had nothing before us, we were all going direct to \
Heaven, we were all going direct the other way - in short, the period was \
so far like the present period, that some of its noisiest authorities \
insisted on its being received, for good or for evil, in the superlative \
degree of comparison only. There were a king with a large jaw and a queen \
with a plain face, on the throne of England; there were a king with a large \
jaw and a queen with a fair face, on the throne of France. In both \
countries it was clearer than crystal to the lords of the State preserves \
of loaves and fishes, that things in general were settled for ever. It was \
the year of Our Lord one thousand seven hundred and seventy-five. Spiritual \
revelations were conceded to England at that favoured period, as at this. ";

/// Build a byte corpus of at least `min_len` bytes by cycling the seed text
/// and interleaving synthetic pattern sentences (so the LM has both natural
/// text statistics and learnable regularities).
pub fn build_corpus(min_len: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(min_len + 1024);
    while out.len() < min_len {
        out.extend_from_slice(SEED_TEXT.as_bytes());
        out.extend_from_slice(pattern_sentence(&mut rng).as_bytes());
    }
    out
}

const SUBJECTS: [&str; 8] =
    ["the model", "the kernel", "the scan", "a monoid", "the state", "the chunk", "a query", "the key"];
const VERBS: [&str; 8] =
    ["updates", "composes", "attends to", "streams", "decays", "normalizes", "projects", "masks"];
const OBJECTS: [&str; 8] = [
    "the prefix", "the summary", "the carry", "the output", "the moment", "the sequence",
    "the value", "the metric",
];

/// A grammatical synthetic sentence — compressible structure for the LM.
pub fn pattern_sentence(rng: &mut Rng) -> String {
    format!(
        "{} {} {} and {} {} {}. ",
        SUBJECTS[rng.below(8)],
        VERBS[rng.below(8)],
        OBJECTS[rng.below(8)],
        SUBJECTS[rng.below(8)],
        VERBS[rng.below(8)],
        OBJECTS[rng.below(8)],
    )
}

/// Associative-recall sequence (E11): `k1:v1 k2:v2 ... ? ki` should be
/// continued with `vi`.  Keys/values are single letters; the probe key is
/// drawn from the emitted pairs.  Returns (sequence, expected_value_byte).
pub fn recall_sequence(n_pairs: usize, rng: &mut Rng) -> (Vec<u8>, u8) {
    let mut keys: Vec<u8> = (b'a'..=b'z').collect();
    rng.shuffle(&mut keys);
    let keys = &keys[..n_pairs.min(26)];
    let vals: Vec<u8> = (0..keys.len()).map(|_| b'0' + rng.below(10) as u8).collect();
    let mut seq = Vec::new();
    for (k, v) in keys.iter().zip(&vals) {
        seq.push(*k);
        seq.push(b':');
        seq.push(*v);
        seq.push(b' ');
    }
    let probe = rng.below(keys.len());
    seq.push(b'?');
    seq.push(keys[probe]);
    seq.push(b':');
    (seq, vals[probe])
}

/// An entire recall-task corpus: many recall sequences with answers, used
/// to *train* the recall probe models.
pub fn recall_corpus(n_sequences: usize, n_pairs: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..n_sequences {
        let (mut seq, answer) = recall_sequence(n_pairs, &mut rng);
        seq.push(answer);
        seq.push(b'\n');
        out.extend_from_slice(&seq);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_reaches_length() {
        let c = build_corpus(10_000, 1);
        assert!(c.len() >= 10_000);
        // contains both natural text and synthetic patterns
        let s = String::from_utf8_lossy(&c);
        assert!(s.contains("best of times"));
        assert!(s.contains(". "));
    }

    #[test]
    fn recall_sequences_are_answerable() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let (seq, answer) = recall_sequence(5, &mut rng);
            let s = String::from_utf8_lossy(&seq).to_string();
            // the probe key appears earlier with the expected value
            let probe_key = seq[seq.len() - 2] as char;
            let needle = format!("{probe_key}:{}", answer as char);
            assert!(s.contains(&needle), "{s} missing {needle}");
        }
    }

    #[test]
    fn recall_corpus_lines_end_with_answers() {
        let c = recall_corpus(10, 4, 3);
        let s = String::from_utf8_lossy(&c);
        for line in s.lines() {
            let bytes = line.as_bytes();
            assert!(bytes[bytes.len() - 2] == b':');
            assert!(bytes[bytes.len() - 1].is_ascii_digit());
        }
    }
}
