//! Batch iterator over a byte corpus: random contiguous windows, i32 token
//! rows of length `seq` (which includes the shifted target position).

use crate::util::rng::Rng;

pub struct Batches<'a> {
    corpus: &'a [u8],
    batch: usize,
    seq: usize,
    rng: Rng,
}

impl<'a> Batches<'a> {
    pub fn new(corpus: &'a [u8], batch: usize, seq: usize, seed: u64) -> Batches<'a> {
        assert!(corpus.len() > seq, "corpus shorter than one window");
        Batches { corpus, batch, seq, rng: Rng::new(seed) }
    }

    /// The next `[batch * seq]` token buffer (row-major).
    pub fn next_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let start = self.rng.below(self.corpus.len() - self.seq);
            out.extend(self.corpus[start..start + self.seq].iter().map(|&b| b as i32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_right_shape_and_content() {
        let corpus: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let mut b = Batches::new(&corpus, 3, 17, 1);
        let x = b.next_batch();
        assert_eq!(x.len(), 3 * 17);
        assert!(x.iter().all(|&t| (0..256).contains(&t)));
        // windows are contiguous runs of the corpus
        for row in x.chunks(17) {
            for w in row.windows(2) {
                assert_eq!((w[0] + 1) % 256, w[1] % 256);
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let corpus: Vec<u8> = (0..200u8).cycle().take(2048).collect();
        let a = Batches::new(&corpus, 2, 9, 1).next_batch();
        let b = Batches::new(&corpus, 2, 9, 2).next_batch();
        assert_ne!(a, b);
    }
}
