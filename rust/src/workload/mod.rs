//! Synthetic serving/training workloads: arrival processes, length
//! distributions, session mixes, corpus generators and trace
//! record/replay.  Substitutes for production traces per the reproduction
//! rules (see `rust/DESIGN.md`).

use crate::util::rng::Rng;

/// Open-loop arrival process.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Poisson with `rate` requests/sec.
    Poisson { rate: f64 },
    /// Fixed inter-arrival gap.
    Uniform { rate: f64 },
    /// Everything at t = 0 (closed burst).
    Burst,
}

impl Arrivals {
    /// Generate `n` arrival offsets in seconds, sorted ascending.
    pub fn times(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match *self {
            Arrivals::Poisson { rate } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(rate);
                        t
                    })
                    .collect()
            }
            Arrivals::Uniform { rate } => (0..n).map(|i| i as f64 / rate).collect(),
            Arrivals::Burst => vec![0.0; n],
        }
    }
}

/// Prompt/output length distribution (log-normal, clamped).  `sigma` is
/// the tail knob: 0.5 reproduces the historical traces; larger values
/// fatten the right tail (the long-prompt scenarios of E8c/E14 use ~1.0,
/// where p99 prompts run several times the median).
#[derive(Debug, Clone, Copy)]
pub struct Lengths {
    pub mean_prompt: usize,
    pub mean_output: usize,
    pub min: usize,
    pub max: usize,
    /// log-normal shape parameter (tail heaviness)
    pub sigma: f64,
}

impl Default for Lengths {
    fn default() -> Self {
        Lengths { mean_prompt: 32, mean_output: 32, min: 4, max: 256, sigma: 0.5 }
    }
}

impl Lengths {
    /// A heavy-tailed long-prompt distribution: median well below the
    /// mean, p99 near `max` — the regime where scan prefill pays off.
    pub fn long_prompts(mean_prompt: usize, sigma: f64, max: usize) -> Lengths {
        Lengths { mean_prompt, mean_output: 32, min: 16, max, sigma }
    }

    fn sample(&self, mean: usize, rng: &mut Rng) -> usize {
        // log-normal with E[x] = mean: mu = ln(mean) - sigma^2/2
        let mu = (mean as f64).ln() - self.sigma * self.sigma / 2.0;
        let x = (mu + self.sigma * rng.normal()).exp();
        (x.round() as usize).clamp(self.min, self.max)
    }

    pub fn prompt(&self, rng: &mut Rng) -> usize {
        self.sample(self.mean_prompt, rng)
    }

    pub fn output(&self, rng: &mut Rng) -> usize {
        self.sample(self.mean_output, rng)
    }
}

/// Session-behavior knobs for synthetic traces: how many distinct
/// conversations the traffic spreads over, and how often a request to an
/// already-seen session asks the coordinator to resume its snapshot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionMix {
    pub n_sessions: usize,
    /// P(resume) for a request whose session has appeared before.
    pub resume_prob: f64,
}

impl Default for SessionMix {
    fn default() -> Self {
        SessionMix { n_sessions: 16, resume_prob: 0.0 }
    }
}

/// One synthetic request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceItem {
    pub at_s: f64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub session: Option<u64>,
    /// Resume the session's snapshot (multi-turn continuation).
    pub resume: bool,
}

/// A reproducible request trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub items: Vec<TraceItem>,
    /// The session mix the trace was synthesized with (serialized in the
    /// replay file's meta line so replays are self-describing).
    pub mix: SessionMix,
}

impl Trace {
    /// Synthesize a trace: arrivals + lengths + corpus-sampled prompts,
    /// with the default session mix (16 sessions, no resumes).
    pub fn synthesize(
        n: usize,
        arrivals: Arrivals,
        lengths: Lengths,
        corpus: &[u8],
        seed: u64,
    ) -> Trace {
        Self::synthesize_sessions(n, arrivals, lengths, corpus, seed, SessionMix::default())
    }

    /// [`Trace::synthesize`] with explicit session-count / resume-probability
    /// knobs.  A request can only resume a session that already appeared
    /// earlier in the trace (there must be a snapshot to restore).
    pub fn synthesize_sessions(
        n: usize,
        arrivals: Arrivals,
        lengths: Lengths,
        corpus: &[u8],
        seed: u64,
        mix: SessionMix,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let times = arrivals.times(n, &mut rng);
        let mut seen = std::collections::HashSet::new();
        let items = times
            .into_iter()
            .map(|at_s| {
                // draw order (plen, start, output, session) matches the
                // pre-session-mix generator, so existing seeds reproduce
                // the exact same traces when resume_prob is 0
                let plen = lengths.prompt(&mut rng);
                let start = rng.below(corpus.len().saturating_sub(plen).max(1));
                let prompt = corpus[start..(start + plen).min(corpus.len())].to_vec();
                let max_new_tokens = lengths.output(&mut rng);
                let session = rng.below(mix.n_sessions.max(1)) as u64;
                let resume =
                    seen.contains(&session) && mix.resume_prob > 0.0 && rng.bool(mix.resume_prob);
                seen.insert(session);
                TraceItem { at_s, prompt, max_new_tokens, session: Some(session), resume }
            })
            .collect();
        Trace { items, mix }
    }

    /// The long-prompt scenario (E8c / E14): heavy-tailed log-normal
    /// prompt lengths with a knob-controlled tail (`sigma`), short
    /// outputs — prompt ingestion dominates, which is exactly where
    /// decode-as-prefill's O(prompt) TTFT hurts and the chunked scan
    /// prefill pays.  Prompts wrap around the corpus so the tail is not
    /// silently clipped by corpus length.
    pub fn synthesize_long_prompts(
        n: usize,
        arrivals: Arrivals,
        mean_prompt: usize,
        sigma: f64,
        max_prompt: usize,
        corpus: &[u8],
        seed: u64,
    ) -> Trace {
        let lengths = Lengths::long_prompts(mean_prompt, sigma, max_prompt);
        let mut rng = Rng::new(seed);
        let times = arrivals.times(n, &mut rng);
        let items = times
            .into_iter()
            .map(|at_s| {
                let plen = lengths.prompt(&mut rng);
                let start = rng.below(corpus.len().max(1));
                let prompt: Vec<u8> =
                    corpus.iter().cycle().skip(start).take(plen).copied().collect();
                TraceItem {
                    at_s,
                    prompt,
                    max_new_tokens: lengths.output(&mut rng),
                    session: None,
                    resume: false,
                }
            })
            .collect();
        Trace { items, mix: SessionMix { n_sessions: 0, resume_prob: 0.0 } }
    }

    /// The speculative-decoding acceptance scenario (E15): an
    /// acceptance-rate-diverse request mix.  A `repeat_frac` fraction of
    /// requests carry *repetitive* prompts — a short corpus motif of
    /// `motif` bytes tiled to the prompt length, the regime where suffix
    /// drafters and small draft models land almost every guess — and the
    /// rest carry *high-entropy* prompts of uniform random bytes below
    /// `vocab`, where almost nothing is predictable and an adaptive-k
    /// controller should collapse toward serial decode.  Outputs follow
    /// `lengths.output`; requests are stateless (no sessions).
    #[allow(clippy::too_many_arguments)]
    pub fn synthesize_spec_mix(
        n: usize,
        arrivals: Arrivals,
        lengths: Lengths,
        repeat_frac: f64,
        motif: usize,
        vocab: usize,
        corpus: &[u8],
        seed: u64,
    ) -> Trace {
        let motif = motif.max(1);
        let vocab = vocab.max(2);
        let mut rng = Rng::new(seed);
        let times = arrivals.times(n, &mut rng);
        let items = times
            .into_iter()
            .map(|at_s| {
                let plen = lengths.prompt(&mut rng);
                let prompt: Vec<u8> = if rng.bool(repeat_frac) {
                    let start = rng.below(corpus.len().saturating_sub(motif).max(1));
                    let pattern = &corpus[start..(start + motif).min(corpus.len())];
                    pattern.iter().cycle().take(plen).copied().collect()
                } else {
                    (0..plen).map(|_| rng.below(vocab) as u8).collect()
                };
                TraceItem {
                    at_s,
                    prompt,
                    max_new_tokens: lengths.output(&mut rng),
                    session: None,
                    resume: false,
                }
            })
            .collect();
        Trace { items, mix: SessionMix { n_sessions: 0, resume_prob: 0.0 } }
    }

    /// The shared-prefix scenario (E16): a few long "system prompts"
    /// fanned out across many requests.  Each request's prompt is one of
    /// `n_prefixes` fixed `prefix_len`-byte corpus windows followed by a
    /// per-request suffix drawn from `lengths.prompt` — the traffic shape
    /// where a prefix cache turns O(prompt) cold prefills into O(suffix)
    /// warm ones.  Requests are stateless (no sessions): prefix reuse is
    /// *cross-request* sharing, which is exactly what sessions cannot
    /// capture.
    #[allow(clippy::too_many_arguments)]
    pub fn synthesize_shared_prefix(
        n: usize,
        arrivals: Arrivals,
        n_prefixes: usize,
        prefix_len: usize,
        lengths: Lengths,
        corpus: &[u8],
        seed: u64,
    ) -> Trace {
        let n_prefixes = n_prefixes.max(1);
        let mut rng = Rng::new(seed);
        // the shared preambles: fixed corpus windows, drawn once up front
        // (wrap-around so short corpora still yield full-length prefixes)
        let prefixes: Vec<Vec<u8>> = (0..n_prefixes)
            .map(|_| {
                let start = rng.below(corpus.len().max(1));
                corpus.iter().cycle().skip(start).take(prefix_len).copied().collect()
            })
            .collect();
        let times = arrivals.times(n, &mut rng);
        let items = times
            .into_iter()
            .map(|at_s| {
                let pfx = &prefixes[rng.below(n_prefixes)];
                let slen = lengths.prompt(&mut rng);
                let start = rng.below(corpus.len().max(1));
                let mut prompt = pfx.clone();
                prompt.extend(corpus.iter().cycle().skip(start).take(slen));
                TraceItem {
                    at_s,
                    prompt,
                    max_new_tokens: lengths.output(&mut rng),
                    session: None,
                    resume: false,
                }
            })
            .collect();
        Trace { items, mix: SessionMix { n_sessions: 0, resume_prob: 0.0 } }
    }

    /// A multi-turn-conversation scenario: `n_sessions` conversations of
    /// `turns` requests each.  Turn 1 starts fresh; every later turn
    /// resumes the session's snapshot (mean `think_s` seconds of "user
    /// think time" after the previous turn).  Arrival order interleaves
    /// the conversations, so resumes land while other sessions hold lanes
    /// — the snapshot/restore path under realistic contention.
    pub fn synthesize_multiturn(
        n_sessions: usize,
        turns: usize,
        arrivals: Arrivals,
        lengths: Lengths,
        corpus: &[u8],
        seed: u64,
        think_s: f64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let starts = arrivals.times(n_sessions, &mut rng);
        let mut items = vec![];
        for (sid, t0) in starts.into_iter().enumerate() {
            let mut at_s = t0;
            for turn in 0..turns {
                let plen = lengths.prompt(&mut rng);
                let start = rng.below(corpus.len().saturating_sub(plen).max(1));
                let prompt = corpus[start..(start + plen).min(corpus.len())].to_vec();
                items.push(TraceItem {
                    at_s,
                    prompt,
                    max_new_tokens: lengths.output(&mut rng),
                    session: Some(sid as u64),
                    resume: turn > 0,
                });
                at_s += rng.exponential(1.0 / think_s.max(1e-9));
            }
        }
        // interleave conversations by arrival time; per-session turn order
        // is preserved because each session's times are increasing
        items.sort_by(|a, b| a.at_s.partial_cmp(&b.at_s).unwrap());
        let resume_prob = if turns == 0 { 0.0 } else { (turns - 1) as f64 / turns as f64 };
        Trace { items, mix: SessionMix { n_sessions, resume_prob } }
    }

    /// Serialize as line-JSON for replay files: a self-describing meta
    /// line (the session-mix knobs) followed by one item per line.
    pub fn to_lines(&self) -> String {
        use crate::util::json::Json;
        let meta = Json::obj(vec![
            ("kind", Json::str("trace-meta")),
            ("n_sessions", Json::num(self.mix.n_sessions as f64)),
            ("resume_prob", Json::num(self.mix.resume_prob)),
        ])
        .to_string();
        std::iter::once(meta)
            .chain(self.items.iter().map(|it| {
                Json::obj(vec![
                    ("at_s", Json::num(it.at_s)),
                    ("prompt", Json::str(String::from_utf8_lossy(&it.prompt).to_string())),
                    ("max_new_tokens", Json::num(it.max_new_tokens as f64)),
                    ("session", it.session.map_or(Json::Null, |s| Json::num(s as f64))),
                    ("resume", Json::Bool(it.resume)),
                ])
                .to_string()
            }))
            .collect::<Vec<_>>()
            .join("\n")
    }

    pub fn from_lines(text: &str) -> anyhow::Result<Trace> {
        use crate::util::json::Json;
        let mut items = vec![];
        let mut mix = SessionMix::default();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line: {e}"))?;
            if j.get("kind").and_then(Json::as_str) == Some("trace-meta") {
                if let Some(n) = j.get("n_sessions").and_then(Json::as_usize) {
                    mix.n_sessions = n;
                }
                if let Some(p) = j.get("resume_prob").and_then(Json::as_f64) {
                    mix.resume_prob = p;
                }
                continue;
            }
            items.push(TraceItem {
                at_s: j.get("at_s").and_then(Json::as_f64).unwrap_or(0.0),
                prompt: j
                    .get("prompt")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .as_bytes()
                    .to_vec(),
                max_new_tokens: j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(16),
                session: j.get("session").and_then(Json::as_i64).map(|s| s as u64),
                resume: j.get("resume").and_then(Json::as_bool).unwrap_or(false),
            });
        }
        Ok(Trace { items, mix })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_holds() {
        let mut rng = Rng::new(1);
        let times = Arrivals::Poisson { rate: 50.0 }.times(5000, &mut rng);
        let span = times.last().unwrap() - times[0];
        let rate = 5000.0 / span;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn lengths_respect_bounds() {
        let mut rng = Rng::new(2);
        let l = Lengths { mean_prompt: 32, mean_output: 64, min: 8, max: 128, sigma: 0.5 };
        for _ in 0..500 {
            let p = l.prompt(&mut rng);
            assert!((8..=128).contains(&p), "{p}");
        }
    }

    #[test]
    fn sigma_knob_controls_the_prompt_tail() {
        let quantiles = |sigma: f64| -> (usize, usize) {
            let mut rng = Rng::new(3);
            let l = Lengths::long_prompts(256, sigma, 1 << 14);
            let mut xs: Vec<usize> = (0..2000).map(|_| l.prompt(&mut rng)).collect();
            xs.sort_unstable();
            (xs[xs.len() / 2], xs[xs.len() * 99 / 100])
        };
        let (med_light, p99_light) = quantiles(0.4);
        let (med_heavy, p99_heavy) = quantiles(1.2);
        let ratio_light = p99_light as f64 / med_light as f64;
        let ratio_heavy = p99_heavy as f64 / med_heavy as f64;
        assert!(
            ratio_heavy > 2.0 * ratio_light,
            "tail knob inert: {ratio_light:.2} vs {ratio_heavy:.2}"
        );
    }

    #[test]
    fn long_prompt_scenario_wraps_the_corpus() {
        let corpus = b"0123456789";
        let t = Trace::synthesize_long_prompts(
            50,
            Arrivals::Burst,
            64,
            1.0,
            512,
            corpus,
            11,
        );
        assert_eq!(t.items.len(), 50);
        // prompts can exceed the 10-byte corpus thanks to wrap-around
        assert!(t.items.iter().any(|it| it.prompt.len() > corpus.len()));
        assert!(t.items.iter().all(|it| it.prompt.len() >= 16 && it.prompt.len() <= 512));
        assert!(t.items.iter().all(|it| !it.resume && it.session.is_none()));
    }

    #[test]
    fn trace_roundtrip() {
        let corpus = b"the quick brown fox jumps over the lazy dog, repeatedly and often";
        let t = Trace::synthesize_sessions(
            10,
            Arrivals::Poisson { rate: 10.0 },
            Lengths::default(),
            corpus,
            3,
            SessionMix { n_sessions: 4, resume_prob: 0.8 },
        );
        assert_eq!(t.items.len(), 10);
        let text = t.to_lines();
        let back = Trace::from_lines(&text).unwrap();
        assert_eq!(back.items.len(), 10);
        assert_eq!(back.mix, t.mix, "session knobs survive the replay file");
        for (a, b) in t.items.iter().zip(&back.items) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.session, b.session);
            assert_eq!(a.resume, b.resume);
            assert!((a.at_s - b.at_s).abs() < 1e-9);
        }
    }

    #[test]
    fn session_mix_knobs_shape_the_trace() {
        let corpus = b"some corpus bytes for prompts, long enough to slice from";
        // one session, always resume after the first sighting
        let t = Trace::synthesize_sessions(
            20,
            Arrivals::Burst,
            Lengths::default(),
            corpus,
            5,
            SessionMix { n_sessions: 1, resume_prob: 1.0 },
        );
        assert!(t.items.iter().all(|it| it.session == Some(0)));
        assert!(!t.items[0].resume, "first sighting cannot resume");
        assert!(t.items[1..].iter().all(|it| it.resume));
        // resume_prob 0 reproduces the stateless default
        let t0 = Trace::synthesize(20, Arrivals::Burst, Lengths::default(), corpus, 5);
        assert!(t0.items.iter().all(|it| !it.resume));
        assert!(t0.items.iter().all(|it| it.session.unwrap() < 16));
    }

    #[test]
    fn spec_mix_balances_repetitive_and_high_entropy_prompts() {
        let corpus = b"the quick brown fox jumps over the lazy dog and keeps on jumping";
        let lengths = Lengths { mean_prompt: 48, mean_output: 16, min: 24, max: 96, sigma: 0.4 };
        let t = Trace::synthesize_spec_mix(
            200,
            Arrivals::Burst,
            lengths,
            0.5,
            8,
            64,
            corpus,
            13,
        );
        assert_eq!(t.items.len(), 200);
        assert!(t.items.iter().all(|it| it.session.is_none() && !it.resume));
        assert!(t.items.iter().all(|it| it.prompt.iter().all(|&b| (b as usize) < 128)));
        // a motif-tiled prompt is exactly periodic with period ≤ 8; a
        // 24+-byte uniform random prompt essentially never is
        let periodic = |p: &[u8]| {
            (1..=8).any(|m| m < p.len() && p.iter().enumerate().all(|(i, &b)| b == p[i % m]))
        };
        let reps = t.items.iter().filter(|it| periodic(&it.prompt)).count();
        assert!(
            (60..=140).contains(&reps),
            "repeat_frac 0.5 over 200 items gave {reps} repetitive prompts"
        );
        // the knob's extremes
        let all = Trace::synthesize_spec_mix(
            40, Arrivals::Burst, lengths, 1.0, 8, 64, corpus, 14,
        );
        assert!(all.items.iter().all(|it| periodic(&it.prompt)));
        let none = Trace::synthesize_spec_mix(
            40, Arrivals::Burst, lengths, 0.0, 8, 64, corpus, 15,
        );
        assert!(none.items.iter().all(|it| it.prompt.iter().all(|&b| (b as usize) < 64)));
    }

    #[test]
    fn shared_prefix_trace_reuses_a_few_preambles() {
        let corpus = b"a corpus with enough bytes to cut shared system prompts from it";
        let lengths = Lengths { mean_prompt: 24, mean_output: 8, min: 8, max: 64, sigma: 0.5 };
        let t = Trace::synthesize_shared_prefix(
            120,
            Arrivals::Burst,
            3,
            48,
            lengths,
            corpus,
            21,
        );
        assert_eq!(t.items.len(), 120);
        assert!(t.items.iter().all(|it| it.session.is_none() && !it.resume));
        // every prompt = one of exactly <= 3 distinct 48-byte prefixes + a suffix
        let mut seen: std::collections::HashSet<Vec<u8>> = std::collections::HashSet::new();
        for it in &t.items {
            assert!(it.prompt.len() > 48, "prefix plus a non-empty suffix");
            seen.insert(it.prompt[..48].to_vec());
        }
        assert!(seen.len() <= 3, "{} distinct prefixes", seen.len());
        // with 120 draws over <= 3 prefixes, each one is heavily reused
        for p in &seen {
            let uses = t.items.iter().filter(|it| it.prompt.starts_with(p)).count();
            assert!(uses >= 10, "prefix reused only {uses} times");
        }
        // determinism: the same seed reproduces the same trace
        let t2 = Trace::synthesize_shared_prefix(
            120,
            Arrivals::Burst,
            3,
            48,
            lengths,
            corpus,
            21,
        );
        assert_eq!(t, t2);
    }

    #[test]
    fn multiturn_trace_interleaves_but_preserves_turn_order() {
        let corpus = b"a corpus with enough material to cut prompt windows from it";
        let t = Trace::synthesize_multiturn(
            4,
            3,
            Arrivals::Poisson { rate: 20.0 },
            Lengths::default(),
            corpus,
            7,
            0.05,
        );
        assert_eq!(t.items.len(), 12);
        assert!(t.items.windows(2).all(|w| w[0].at_s <= w[1].at_s), "sorted by arrival");
        for sid in 0..4u64 {
            let turns: Vec<&TraceItem> =
                t.items.iter().filter(|it| it.session == Some(sid)).collect();
            assert_eq!(turns.len(), 3);
            assert!(!turns[0].resume, "session {sid}: first turn is fresh");
            assert!(turns[1].resume && turns[2].resume, "session {sid}: later turns resume");
        }
        assert_eq!(t.mix.n_sessions, 4);
    }
}
