//! Synthetic serving/training workloads: arrival processes, length
//! distributions, corpus generators and trace record/replay.  Substitutes
//! for production traces per the reproduction rules (DESIGN.md §3).

use crate::util::rng::Rng;

/// Open-loop arrival process.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Poisson with `rate` requests/sec.
    Poisson { rate: f64 },
    /// Fixed inter-arrival gap.
    Uniform { rate: f64 },
    /// Everything at t = 0 (closed burst).
    Burst,
}

impl Arrivals {
    /// Generate `n` arrival offsets in seconds, sorted ascending.
    pub fn times(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        match *self {
            Arrivals::Poisson { rate } => {
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exponential(rate);
                        t
                    })
                    .collect()
            }
            Arrivals::Uniform { rate } => (0..n).map(|i| i as f64 / rate).collect(),
            Arrivals::Burst => vec![0.0; n],
        }
    }
}

/// Prompt/output length distribution (log-normal-ish, clamped).
#[derive(Debug, Clone, Copy)]
pub struct Lengths {
    pub mean_prompt: usize,
    pub mean_output: usize,
    pub min: usize,
    pub max: usize,
}

impl Default for Lengths {
    fn default() -> Self {
        Lengths { mean_prompt: 32, mean_output: 32, min: 4, max: 256 }
    }
}

impl Lengths {
    fn sample(&self, mean: usize, rng: &mut Rng) -> usize {
        // log-normal with sigma 0.5 around the mean
        let mu = (mean as f64).ln() - 0.125;
        let x = (mu + 0.5 * rng.normal()).exp();
        (x.round() as usize).clamp(self.min, self.max)
    }

    pub fn prompt(&self, rng: &mut Rng) -> usize {
        self.sample(self.mean_prompt, rng)
    }

    pub fn output(&self, rng: &mut Rng) -> usize {
        self.sample(self.mean_output, rng)
    }
}

/// One synthetic request in a trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceItem {
    pub at_s: f64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub session: Option<u64>,
}

/// A reproducible request trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub items: Vec<TraceItem>,
}

impl Trace {
    /// Synthesize a trace: arrivals + lengths + corpus-sampled prompts.
    pub fn synthesize(
        n: usize,
        arrivals: Arrivals,
        lengths: Lengths,
        corpus: &[u8],
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let times = arrivals.times(n, &mut rng);
        let items = times
            .into_iter()
            .map(|at_s| {
                let plen = lengths.prompt(&mut rng);
                let start = rng.below(corpus.len().saturating_sub(plen).max(1));
                let prompt = corpus[start..(start + plen).min(corpus.len())].to_vec();
                TraceItem {
                    at_s,
                    prompt,
                    max_new_tokens: lengths.output(&mut rng),
                    session: Some(rng.below(16) as u64),
                }
            })
            .collect();
        Trace { items }
    }

    /// Serialize as line-JSON (one item per line) for replay files.
    pub fn to_lines(&self) -> String {
        use crate::util::json::Json;
        self.items
            .iter()
            .map(|it| {
                Json::obj(vec![
                    ("at_s", Json::num(it.at_s)),
                    ("prompt", Json::str(String::from_utf8_lossy(&it.prompt).to_string())),
                    ("max_new_tokens", Json::num(it.max_new_tokens as f64)),
                    ("session", it.session.map_or(Json::Null, |s| Json::num(s as f64))),
                ])
                .to_string()
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    pub fn from_lines(text: &str) -> anyhow::Result<Trace> {
        use crate::util::json::Json;
        let mut items = vec![];
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let j = Json::parse(line).map_err(|e| anyhow::anyhow!("trace line: {e}"))?;
            items.push(TraceItem {
                at_s: j.get("at_s").and_then(Json::as_f64).unwrap_or(0.0),
                prompt: j
                    .get("prompt")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .as_bytes()
                    .to_vec(),
                max_new_tokens: j.get("max_new_tokens").and_then(Json::as_usize).unwrap_or(16),
                session: j.get("session").and_then(Json::as_i64).map(|s| s as u64),
            });
        }
        Ok(Trace { items })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximately_holds() {
        let mut rng = Rng::new(1);
        let times = Arrivals::Poisson { rate: 50.0 }.times(5000, &mut rng);
        let span = times.last().unwrap() - times[0];
        let rate = 5000.0 / span;
        assert!((rate - 50.0).abs() < 5.0, "rate {rate}");
        assert!(times.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn lengths_respect_bounds() {
        let mut rng = Rng::new(2);
        let l = Lengths { mean_prompt: 32, mean_output: 64, min: 8, max: 128 };
        for _ in 0..500 {
            let p = l.prompt(&mut rng);
            assert!((8..=128).contains(&p), "{p}");
        }
    }

    #[test]
    fn trace_roundtrip() {
        let corpus = b"the quick brown fox jumps over the lazy dog, repeatedly and often";
        let t = Trace::synthesize(10, Arrivals::Poisson { rate: 10.0 }, Lengths::default(), corpus, 3);
        assert_eq!(t.items.len(), 10);
        let text = t.to_lines();
        let back = Trace::from_lines(&text).unwrap();
        assert_eq!(back.items.len(), 10);
        for (a, b) in t.items.iter().zip(&back.items) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert!((a.at_s - b.at_s).abs() < 1e-9);
        }
    }
}
