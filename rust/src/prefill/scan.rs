//! Per-head chunk-parallel mixer scans with a non-identity initial state —
//! the serving-path counterpart of `hla::chunk`'s training drivers.
//!
//! Hot-path layout (rust/DESIGN.md §Perf): chunk summaries are built by
//! serial rank-1 stepping (not per-token monoid materialization), the
//! exclusive Blelloch scan runs over the B_c summaries only, the lane's
//! incoming state is folded in as the scan's left-most segment (exact per
//! Thm 4.1 / Remark 4.2, including the decayed-carry erratum #2 — the
//! monoids already encode it), and each chunk then serial-steps from its
//! carried-in state.  Each function advances `st` to the post-sequence
//! state and returns the per-token head outputs `[n, dv]`.

use crate::attention::{LinearAttnState, LinearSeg};
use crate::hla::ahla::{AhlaState, SegA};
use crate::hla::chunk::parallel_chunks;
use crate::hla::monoid2::Seg2;
use crate::hla::monoid3::Seg3Decay;
use crate::hla::scan::{blelloch_exclusive, Monoid};
use crate::hla::state2::Hla2State;
use crate::hla::state3::Hla3State;
use crate::hla::HlaOptions;
use crate::tensor::{ops, Mat};

/// Split `out`'s rows into per-chunk bands paired with end-state slots.
fn bands<'a, S>(
    out: &'a mut Mat<f32>,
    ends: &'a mut [Option<S>],
    n: usize,
    chunk: usize,
    dv: usize,
) -> Vec<(usize, &'a mut [f32], &'a mut Option<S>)> {
    let nc = ends.len();
    let mut items = Vec::with_capacity(nc);
    let mut rest = out.data.as_mut_slice();
    for (c, end) in ends.iter_mut().enumerate() {
        let lo = c * chunk;
        let hi = (lo + chunk).min(n);
        let (band, tail) = rest.split_at_mut((hi - lo) * dv);
        items.push((c, band, end));
        rest = tail;
    }
    items
}

/// Chunk-parallel masked second-order prefill scan from `st`.
pub fn scan_hla2(
    st: &mut Hla2State<f32>,
    q: &Mat<f32>,
    k: &Mat<f32>,
    v: &Mat<f32>,
    opts: &HlaOptions<f32>,
    chunk: usize,
    threads: usize,
) -> Mat<f32> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);

    // phase 1: chunk summaries via serial stepping (rank-1 updates only)
    let mut summaries: Vec<Option<Seg2<f32>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = Hla2State::new(d, dv);
            let mut stp = Mat::zeros(d, d); // plain S-tilde
            let mut rho = 1f32;
            for t in lo..hi {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                stp.add_outer(1.0, k.row(t), k.row(t));
                rho *= opts.gamma;
            }
            **slot = Some(Seg2 { s: s.s, c: s.c, m: s.m, g: s.g, h: s.h, st: stp, rho });
        });
    }
    let summaries: Vec<Seg2<f32>> = summaries.into_iter().map(|s| s.unwrap()).collect();

    // phase 2: exclusive scan + fold the lane state in on the left
    let init = Seg2::from_state(st);
    let carries: Vec<Seg2<f32>> =
        blelloch_exclusive(&summaries).iter().map(|c| init.combine(c)).collect();

    // phase 3: per-chunk serial recurrence from the carried-in state
    let mut ends: Vec<Option<Hla2State<f32>>> = vec![None; nc];
    {
        let items = bands(&mut out, &mut ends, n, chunk, dv);
        parallel_chunks(items, threads, |_, (c, band, end)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let o = s.output(q.row(t), opts);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
            **end = Some(s);
        });
    }
    *st = ends.pop().unwrap().unwrap();
    out
}

/// Chunk-parallel AHLA prefill scan from `st`.
pub fn scan_ahla(
    st: &mut AhlaState<f32>,
    q: &Mat<f32>,
    k: &Mat<f32>,
    v: &Mat<f32>,
    opts: &HlaOptions<f32>,
    chunk: usize,
    threads: usize,
) -> Mat<f32> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);
    let mut summaries: Vec<Option<SegA<f32>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = AhlaState::new(d, dv);
            let mut r = Mat::zeros(d, d); // plain R^KQ
            let mut rho = 1f32;
            for t in lo..hi {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                r.add_outer(1.0, k.row(t), q.row(t));
                rho *= opts.gamma;
            }
            **slot = Some(SegA { r, p: s.p, m: s.m, e: s.e, n: s.n, rho });
        });
    }
    let summaries: Vec<SegA<f32>> = summaries.into_iter().map(|s| s.unwrap()).collect();
    let init = SegA::from_state(st);
    let carries: Vec<SegA<f32>> =
        blelloch_exclusive(&summaries).iter().map(|c| init.combine(c)).collect();
    let mut ends: Vec<Option<AhlaState<f32>>> = vec![None; nc];
    {
        let items = bands(&mut out, &mut ends, n, chunk, dv);
        parallel_chunks(items, threads, |_, (c, band, end)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let o = s.output(q.row(t), opts);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
            **end = Some(s);
        });
    }
    *st = ends.pop().unwrap().unwrap();
    out
}

/// Chunk-parallel canonical third-order prefill scan from `st` (any γ,
/// via the decayed [`Seg3Decay`] monoid).
pub fn scan_hla3(
    st: &mut Hla3State<f32>,
    q: &Mat<f32>,
    k: &Mat<f32>,
    v: &Mat<f32>,
    opts: &HlaOptions<f32>,
    chunk: usize,
    threads: usize,
) -> Mat<f32> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);
    let mut summaries: Vec<Option<Seg3Decay<f32>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = Hla3State::new(d, dv);
            let mut sq = Mat::zeros(d, d);
            let mut r = Mat::zeros(d, dv);
            let mut rv = vec![0f32; d];
            let mut nmat = Mat::zeros(d, d);
            let mut w = 1f32; // γ^j, j = 1-based position within the chunk
            for t in lo..hi {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let qt = q.row(t);
                w *= opts.gamma;
                sq.add_outer(w, qt, qt);
                // cross stats read the *local inclusive* state (post-step)
                let qp = s.p.t_matvec(qt);
                r.add_outer(1.0, qt, &qp);
                let qm = ops::dot(qt, &s.m);
                ops::axpy(qm, qt, &mut rv);
                let sqv = s.s.matvec(qt);
                nmat.add_outer(1.0, &sqv, qt);
            }
            **slot = Some(Seg3Decay {
                s: s.s,
                sq,
                p: s.p,
                m: s.m,
                f: s.f,
                eta: s.eta,
                r,
                rv,
                nmat,
                rho: w,
            });
        });
    }
    let summaries: Vec<Seg3Decay<f32>> = summaries.into_iter().map(|s| s.unwrap()).collect();
    let init = Seg3Decay::from_state(st);
    let carries: Vec<Seg3Decay<f32>> =
        blelloch_exclusive(&summaries).iter().map(|c| init.combine(c)).collect();
    let mut ends: Vec<Option<Hla3State<f32>>> = vec![None; nc];
    {
        let items = bands(&mut out, &mut ends, n, chunk, dv);
        parallel_chunks(items, threads, |_, (c, band, end)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let o = s.output(q.row(t), opts);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
            **end = Some(s);
        });
    }
    *st = ends.pop().unwrap().unwrap();
    out
}

/// Chunk-parallel first-order linear-attention prefill scan from `st`.
pub fn scan_linear(
    st: &mut LinearAttnState<f32>,
    q: &Mat<f32>,
    k: &Mat<f32>,
    v: &Mat<f32>,
    opts: &HlaOptions<f32>,
    chunk: usize,
    threads: usize,
) -> Mat<f32> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);
    let mut summaries: Vec<Option<LinearSeg<f32>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = LinearAttnState::new(d, dv);
            let mut rho = 1f32;
            for t in lo..hi {
                s.step(k.row(t), v.row(t), opts.gamma);
                rho *= opts.gamma;
            }
            **slot = Some(LinearSeg { p: s.p, m: s.m, rho });
        });
    }
    let summaries: Vec<LinearSeg<f32>> = summaries.into_iter().map(|s| s.unwrap()).collect();
    let init = LinearSeg::from_state(st);
    let carries: Vec<LinearSeg<f32>> =
        blelloch_exclusive(&summaries).iter().map(|c| init.combine(c)).collect();
    let mut ends: Vec<Option<LinearAttnState<f32>>> = vec![None; nc];
    {
        let items = bands(&mut out, &mut ends, n, chunk, dv);
        parallel_chunks(items, threads, |_, (c, band, end)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut s = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                s.step(k.row(t), v.row(t), opts.gamma);
                let o = s.output(q.row(t), opts.norm, opts.eps);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
            **end = Some(s);
        });
    }
    *st = ends.pop().unwrap().unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize) -> Mat<f32> {
        let mut m = Mat::zeros(n, d);
        let s = 1.0 / (d as f64).sqrt();
        for x in &mut m.data {
            *x = (rng.normal() * s) as f32;
        }
        m
    }

    fn close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let denom = 1f32.max(x.abs()).max(y.abs());
            assert!((x - y).abs() / denom < tol, "{what}: idx {i}: {x} vs {y}");
        }
    }

    #[test]
    fn hla2_scan_from_state_matches_serial_f32() {
        let mut rng = Rng::new(3);
        let (d, dv, hist, n) = (4, 4, 9, 37);
        let opts = HlaOptions::<f32>::default().with_gamma(0.97);
        let (hq, hk, hv) = (random(&mut rng, hist, d), random(&mut rng, hist, d), random(&mut rng, hist, dv));
        let (q, k, v) = (random(&mut rng, n, d), random(&mut rng, n, d), random(&mut rng, n, dv));
        let mut st = Hla2State::<f32>::new(d, dv);
        for t in 0..hist {
            st.step(hq.row(t), hk.row(t), hv.row(t), opts.gamma);
        }
        // serial reference from the same restored state
        let mut serial = st.clone();
        let mut want = Mat::zeros(n, dv);
        for t in 0..n {
            serial.step(q.row(t), k.row(t), v.row(t), opts.gamma);
            want.row_mut(t).copy_from_slice(&serial.output(q.row(t), &opts));
        }
        for chunk in [1usize, 5, 16, 64] {
            for threads in [1usize, 4] {
                let mut scanned = st.clone();
                let got = scan_hla2(&mut scanned, &q, &k, &v, &opts, chunk, threads);
                close(&got.data, &want.data, 1e-3, &format!("out w={chunk} th={threads}"));
                close(&scanned.s.data, &serial.s.data, 1e-3, "end S");
                close(&scanned.g.data, &serial.g.data, 1e-3, "end G");
            }
        }
    }
}
