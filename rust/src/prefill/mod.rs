//! Chunk-parallel prefill engine: scan-based prompt ingestion for the
//! serving path.
//!
//! The paper's chunk-parallel scheme (§4.2, Thm 4.1) reproduces the serial
//! recurrence exactly, so a prompt does not have to be fed one
//! `decode_step` at a time ("decode-as-prefill") — it can be ingested as
//! per-token monoid leaves, scanned with the two-level intra-/inter-chunk
//! driver, and the resulting *constant-size* state landed directly in a
//! lane.  TTFT then scales with `n / threads` instead of `n` (bench E14).
//!
//! Entry points, all sharing one prompt loop (no more hand-rolled
//! `decode_step` loops in `Model::forward` or the coordinator):
//!
//! * [`advance`] — push tokens through the state, no logits (the
//!   coordinator's admission-time prompt ingestion).
//! * [`ingest`] — ditto, returning the last position's logits.
//! * [`forward_logits`] — all positions' logits (the training-forward /
//!   teacher-forcing path behind [`RustModel::forward`]).
//! * [`Prefiller`] — the coordinator-facing wrapper: converts a lane's
//!   component-layout state tensors to a [`ModelState`], ingests all but
//!   the final prompt token, and converts back.  The final token stays
//!   with the lane so the first sampled token flows through the unchanged
//!   batched decode/sampling path.  [`Prefiller::ingest_lane_cached`] is
//!   the same landing through the shared-prefix radix cache
//!   ([`crate::cache`]): the scan seeds from the longest cached boundary
//!   and contributes the fresh boundaries it computes.
//! * [`PrefillCursor`] ([`cursor`]) — the same two landings split into
//!   budgeted, resumable window advances, so the engine can interleave a
//!   long prompt's ingestion with decode steps (`--prefill-budget`).
//!   Both `ingest_lane*` entry points drive a cursor to completion in
//!   one call, so the budgeted and monolithic paths cannot drift.
//!
//! Exactness: the per-head scans ([`scan`]) fold the lane's incoming state
//! in as the scan's left-most segment (resume-from-`SessionSnapshot` as
//! Remark 4.2's non-identity P_0), and the segment monoids already encode
//! the decayed-carry erratum (#2) — so scan prefill equals the serial
//! recurrence up to f32 reassociation (differential test:
//! `rust/tests/prefill_differential.rs`).  [`PrefillMode::Serial`] keeps
//! the step-by-step path as the differential-testing baseline.

pub mod cursor;
pub mod scan;

pub use cursor::PrefillCursor;

use anyhow::{ensure, Result};

use crate::cache::PrefixCache;
use crate::hla::chunk::parallel_chunks;
use crate::model::{mixer_opts, rmsnorm, silu, MixerState, ModelState, RustModel};
use crate::runtime::ModelCfg;
use crate::tensor::{Mat, Tensor};

/// How to run the prompt through the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefillMode {
    /// One `decode_step` per token — exact reference, O(n) serial.
    Serial,
    /// Two-level chunked scan per layer/head — same math, parallel.
    Scan,
}

/// Prefill configuration (chunk width w and worker threads).
#[derive(Debug, Clone, Copy)]
pub struct PrefillCfg {
    pub mode: PrefillMode,
    pub chunk: usize,
    pub threads: usize,
}

impl PrefillCfg {
    /// The serial decode-as-prefill baseline.
    pub fn serial() -> PrefillCfg {
        PrefillCfg { mode: PrefillMode::Serial, chunk: 1, threads: 1 }
    }

    /// Scan prefill with chunk width `chunk` (clamped to ≥ 1) and
    /// `threads` workers (0 = one per available core, uncapped — see
    /// [`crate::util::auto_threads`]).
    pub fn scan(chunk: usize, threads: usize) -> PrefillCfg {
        PrefillCfg {
            mode: PrefillMode::Scan,
            chunk: chunk.max(1),
            threads: if threads == 0 { crate::util::auto_threads() } else { threads },
        }
    }

    /// Scan with the model's training chunk width when the mixer supports
    /// it, serial otherwise (softmax has no segment monoid).
    pub fn auto(cfg: &ModelCfg) -> PrefillCfg {
        if supports_scan(&cfg.mixer) {
            PrefillCfg::scan(cfg.chunk.max(1), 0)
        } else {
            PrefillCfg::serial()
        }
    }

    fn resolved(&self, cfg: &ModelCfg) -> PrefillMode {
        if self.mode == PrefillMode::Scan && supports_scan(&cfg.mixer) {
            PrefillMode::Scan
        } else {
            PrefillMode::Serial
        }
    }
}

/// Does this mixer have a segment monoid (i.e. can its prompt be scanned)?
pub fn supports_scan(mixer: &str) -> bool {
    matches!(mixer, "hla2" | "ahla" | "hla3" | "linear")
}

/// Push `tokens` through `state` (no logits) — admission-time ingestion.
pub fn advance(model: &RustModel, state: &mut ModelState, tokens: &[u8], cfg: &PrefillCfg) {
    if tokens.is_empty() {
        return;
    }
    match cfg.resolved(&model.cfg) {
        PrefillMode::Serial => {
            for &tok in tokens {
                model.decode_step(state, tok);
            }
        }
        PrefillMode::Scan => {
            let _ = scan_hidden(model, state, tokens, cfg.chunk, cfg.threads);
        }
    }
}

/// Push `tokens` through `state`, returning the last position's logits.
pub fn ingest(model: &RustModel, state: &mut ModelState, tokens: &[u8], cfg: &PrefillCfg) -> Vec<f32> {
    assert!(!tokens.is_empty(), "ingest needs at least one token");
    match cfg.resolved(&model.cfg) {
        PrefillMode::Serial => {
            let mut logits = vec![];
            for &tok in tokens {
                logits = model.decode_step(state, tok);
            }
            logits
        }
        PrefillMode::Scan => {
            let hidden = scan_hidden(model, state, tokens, cfg.chunk, cfg.threads);
            model.embed.matvec(hidden.row(tokens.len() - 1))
        }
    }
}

/// Teacher-forced logits for every position `[n, vocab]` — the
/// training-forward path ([`RustModel::forward`] delegates here).
pub fn forward_logits(
    model: &RustModel,
    state: &mut ModelState,
    tokens: &[u8],
    cfg: &PrefillCfg,
) -> Mat<f32> {
    let n = tokens.len();
    let mut out = Mat::zeros(n, model.cfg.vocab);
    if n == 0 {
        return out;
    }
    match cfg.resolved(&model.cfg) {
        PrefillMode::Serial => {
            for (t, &tok) in tokens.iter().enumerate() {
                let logits = model.decode_step(state, tok);
                out.row_mut(t).copy_from_slice(&logits);
            }
        }
        PrefillMode::Scan => {
            let hidden = scan_hidden(model, state, tokens, cfg.chunk, cfg.threads);
            par_rowwise(&mut out, cfg.threads, |t, row| {
                row.copy_from_slice(&model.embed.matvec(hidden.row(t)));
            });
        }
    }
    out
}

/// Layer-by-layer chunk-parallel forward: every position-wise op is the
/// exact per-row op `decode_step` uses (bit-identical), and every mixer
/// runs the two-level scan from the lane's current state.  Returns the
/// final-rmsnormed hidden states `[n, d_model]`; `state` is advanced past
/// all `tokens`.
fn scan_hidden(
    model: &RustModel,
    state: &mut ModelState,
    tokens: &[u8],
    chunk: usize,
    threads: usize,
) -> Mat<f32> {
    let cfg = &model.cfg;
    let n = tokens.len();
    let d = cfg.d_model;
    let dh = cfg.head_dim;
    let scale = 1.0 / (dh as f32).sqrt();
    let opts = mixer_opts(cfg);

    // residual stream x: [n, d]
    let mut x = Mat::zeros(n, d);
    for (t, &tok) in tokens.iter().enumerate() {
        x.row_mut(t).copy_from_slice(model.embed.row(tok as usize));
    }
    for (li, layer) in model.layers.iter().enumerate() {
        // pre-norm + Q/K/V projections, position-parallel
        let mut h = Mat::zeros(n, d);
        par_rowwise(&mut h, threads, |t, row| rmsnorm(x.row(t), &layer.norm1, row));
        let mut qm = Mat::zeros(n, layer.wq.cols);
        par_rowwise(&mut qm, threads, |t, row| row.copy_from_slice(&layer.wq.t_matvec(h.row(t))));
        let mut km = Mat::zeros(n, layer.wk.cols);
        par_rowwise(&mut km, threads, |t, row| row.copy_from_slice(&layer.wk.t_matvec(h.row(t))));
        let mut vm = Mat::zeros(n, layer.wv.cols);
        par_rowwise(&mut vm, threads, |t, row| row.copy_from_slice(&layer.wv.t_matvec(h.row(t))));

        // per-head mixer scans (chunk-parallel inside each head)
        let mut heads_out = Mat::zeros(n, cfg.n_heads * dh);
        for hi in 0..cfg.n_heads {
            let kvh = if cfg.multi_query { 0 } else { hi };
            let mut qh = Mat::zeros(n, dh);
            let mut kh = Mat::zeros(n, dh);
            let mut vh = Mat::zeros(n, dh);
            for t in 0..n {
                for j in 0..dh {
                    qh[(t, j)] = qm[(t, hi * dh + j)] * scale;
                    kh[(t, j)] = km[(t, kvh * dh + j)] * scale;
                    vh[(t, j)] = vm[(t, kvh * dh + j)];
                }
            }
            let out_h = match &mut state.layers[li][hi] {
                MixerState::Hla2(s) => scan::scan_hla2(s, &qh, &kh, &vh, &opts, chunk, threads),
                MixerState::Ahla(s) => scan::scan_ahla(s, &qh, &kh, &vh, &opts, chunk, threads),
                MixerState::Hla3(s) => scan::scan_hla3(s, &qh, &kh, &vh, &opts, chunk, threads),
                MixerState::Linear(s) => scan::scan_linear(s, &qh, &kh, &vh, &opts, chunk, threads),
                MixerState::Softmax(_) => {
                    unreachable!("scan prefill requires a constant-state mixer (gated by supports_scan)")
                }
            };
            for t in 0..n {
                heads_out.row_mut(t)[hi * dh..(hi + 1) * dh].copy_from_slice(out_h.row(t));
            }
        }

        // attention output projection + residual
        let mut proj = Mat::zeros(n, d);
        par_rowwise(&mut proj, threads, |t, row| {
            row.copy_from_slice(&layer.wo.t_matvec(heads_out.row(t)));
        });
        x.add_scaled(1.0, &proj);

        // SwiGLU FFN + residual, position-parallel
        let mut delta = Mat::zeros(n, d);
        par_rowwise(&mut delta, threads, |t, row| {
            let mut ht = vec![0f32; d];
            rmsnorm(x.row(t), &layer.norm2, &mut ht);
            let gate = layer.w_gate.t_matvec(&ht);
            let up = layer.w_up.t_matvec(&ht);
            let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            row.copy_from_slice(&layer.w_down.t_matvec(&act));
        });
        x.add_scaled(1.0, &delta);
    }
    // final norm
    let mut out = Mat::zeros(n, d);
    par_rowwise(&mut out, threads, |t, row| rmsnorm(x.row(t), &model.norm_f, row));
    out
}

/// Run `f(row_index, out_row)` over `out`'s rows on up to `threads`
/// contiguous row bands (the position-wise counterpart of the per-chunk
/// partitioning in [`scan`]).
fn par_rowwise<F>(out: &mut Mat<f32>, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Send + Sync,
{
    let (n, cols) = (out.rows, out.cols);
    if n == 0 {
        return;
    }
    let threads = threads.max(1);
    let per = n.div_ceil(threads);
    let mut bands = Vec::with_capacity(threads);
    let mut rest = out.data.as_mut_slice();
    let mut start = 0usize;
    while start < n {
        let take = per.min(n - start);
        let (band, tail) = rest.split_at_mut(take * cols);
        bands.push((start, band));
        rest = tail;
        start += take;
    }
    parallel_chunks(bands, threads, |_, (start, band)| {
        for (i, row) in band.chunks_mut(cols).enumerate() {
            f(*start + i, row);
        }
    });
}

/// Coordinator-facing prefill runner: ingests a lane's prompt on the
/// pure-Rust twin of the artifact model and lands the state back in the
/// lane's component-layout tensors (`StatePool` / state-literal slices).
pub struct Prefiller {
    model: RustModel,
    cfg: PrefillCfg,
}

impl Prefiller {
    /// Validates up front that the mixer is scannable and that the model
    /// config's `state_paths` carry the mixer's full state (so lane
    /// round-trips are lossless) — a mismatch fails here, at attach time,
    /// instead of corrupting a lane at admission time.
    pub fn new(model: RustModel, cfg: PrefillCfg) -> Result<Prefiller> {
        ensure!(
            supports_scan(&model.cfg.mixer),
            "mixer {:?} has no segment monoid; keep decode-as-prefill",
            model.cfg.mixer
        );
        ModelState::new(&model.cfg).to_components(&model.cfg)?;
        Ok(Prefiller { model, cfg })
    }

    /// Build from the artifact's parameter tensors (the coordinator path).
    pub fn from_param_tensors(
        mc: &ModelCfg,
        tensors: &[Tensor],
        cfg: PrefillCfg,
    ) -> Result<Prefiller> {
        Prefiller::new(RustModel::from_tensors(mc, tensors)?, cfg)
    }

    pub fn model(&self) -> &RustModel {
        &self.model
    }

    pub fn cfg(&self) -> &PrefillCfg {
        &self.cfg
    }

    /// Ingest all but the final prompt token into a lane state (fresh, or
    /// restored from `resume` component tensors).  Returns the post-prompt
    /// component tensors and the number of tokens consumed; the caller
    /// advances the lane cursor by that count so the final token flows
    /// through the normal batched decode step (which samples the first
    /// token through the unchanged path).
    pub fn ingest_lane(
        &self,
        resume: Option<&[Tensor]>,
        prompt: &[u8],
    ) -> Result<(Vec<Tensor>, usize)> {
        // window >= prompt.len(): a single advance over prompt[..len-1],
        // the historical monolithic segmentation, now via the cursor
        let mut cur = self.cursor(resume, prompt, prompt.len())?;
        cur.advance_budget(self, None, usize::MAX)?;
        let (parts, consumed, _) = cur.finish(self)?;
        Ok((parts, consumed))
    }

    /// [`Prefiller::ingest_lane`] through the shared-prefix cache, for
    /// *fresh* lanes (resumed lanes bypass the cache: their incoming
    /// state already encodes private history, so the prompt is not a
    /// prefix from the zero state).
    ///
    /// The scan is seeded from the longest cached strict prefix of the
    /// *prompt* — strictness against the full prompt still leaves the
    /// final token with the lane, while letting an identical repeated
    /// prompt reuse a boundary stored at exactly its head length — and
    /// the boundary states computed past the hit point are inserted
    /// back.  Exactness anchor: the ingest *always* advances in
    /// `cache.chunk()`-aligned segments — warm or cold — so the state
    /// at boundary `b` is a deterministic function of `prompt[..b]` alone
    /// and a warm hit lands bit-identical floats to the cold path (the
    /// differential suite pins the streams byte-identical).
    pub fn ingest_lane_cached(
        &self,
        cache: &PrefixCache,
        prompt: &[u8],
    ) -> Result<(Vec<Tensor>, usize, CacheOutcome)> {
        let mut cur = self.cursor_cached(cache, prompt)?;
        while !cur.done() {
            cur.advance_budget(self, Some(cache), usize::MAX)?;
        }
        cur.finish(self)
    }
}

/// What the cache did for one [`Prefiller::ingest_lane_cached`] call.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CacheOutcome {
    /// Prompt tokens skipped by seeding from a cached boundary (0 = cold).
    pub hit_tokens: usize,
    /// Fresh boundary snapshots inserted on the way to the prompt end.
    pub inserted: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_normalizes_knobs() {
        let s = PrefillCfg::scan(0, 3);
        assert_eq!(s.chunk, 1);
        assert_eq!(s.threads, 3);
        let auto = PrefillCfg::scan(16, 0);
        assert!(auto.threads >= 1);
        assert_eq!(PrefillCfg::serial().mode, PrefillMode::Serial);
    }

    #[test]
    fn scan_support_by_mixer() {
        for m in ["hla2", "ahla", "hla3", "linear"] {
            assert!(supports_scan(m), "{m}");
        }
        assert!(!supports_scan("softmax"));
    }
}
