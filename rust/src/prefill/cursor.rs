//! Resumable prefill: the admission-time scan parked between engine
//! cycles and advanced in budgeted window cuts.
//!
//! A monolithic [`Prefiller::ingest_lane`] stalls every decode lane in
//! the replica for the length of the prompt.  A [`PrefillCursor`] splits
//! the same ingestion into *windows* — fixed, position-deterministic cuts
//! of the prompt — so the engine can consume `--prefill-budget` tokens of
//! prompt per cycle and give the batched decode step the rest of the
//! cycle back (Sarathi-style stall-free batching; the chunk monoids make
//! the partial-prompt state exact, so nothing is approximated).
//!
//! Exactness contract: the bit-exact end state of a scan ingestion
//! depends only on the *sequence of window cuts* fed to
//! [`advance`](super::advance) (the intra-window chunking is fixed by
//! `PrefillCfg::chunk`), not on how many windows run per engine cycle.
//! The cursor therefore fixes its cut quantum at creation:
//!
//! * [`Prefiller::cursor_cached`] — quantum = `cache.chunk()`, cuts at
//!   absolute chunk-aligned positions, fresh boundary states inserted on
//!   the way: *exactly* the segmentation [`Prefiller::ingest_lane_cached`]
//!   has always used, so a budgeted ingest is bit-identical to the
//!   monolithic one and warm stays byte-identical to cold by
//!   construction (both entry points now drive this cursor).
//! * [`Prefiller::cursor`] — uncached, quantum supplied by the caller
//!   (the engine passes the budget).  Different budgets are different
//!   segmentations of the same exact math — like the `no_cache` opt-out
//!   path, greedy streams are identical to the monolithic scan and
//!   seeded ones distribution-identical (f32 reassociation only;
//!   `rust/tests/interleave_differential.rs` pins both claims).
//!
//! The cursor owns its [`ModelState`] and bookkeeping only; each advance
//! borrows the [`Prefiller`] (and the cache, when attached), so a lane
//! can hold its cursor across cycles without borrowing the engine.

use anyhow::{ensure, Result};

use crate::cache::PrefixCache;
use crate::model::ModelState;
use crate::tensor::Tensor;

use super::{advance, CacheOutcome, Prefiller};

/// A partially-ingested prompt: scan state plus the window bookkeeping
/// needed to resume exactly where the last engine cycle stopped.
pub struct PrefillCursor {
    state: ModelState,
    prompt: Vec<u8>,
    /// Next prompt position to ingest (everything before it is folded
    /// into `state`).
    pos: usize,
    /// Ingestion target: `prompt.len() - 1`.  The final prompt token
    /// stays with the lane so the first sampled token flows through the
    /// unchanged batched decode path.
    consumed: usize,
    /// Fixed cut quantum: every advance stops at the next multiple of
    /// this (or at `consumed`), independent of the per-cycle budget.
    window: usize,
    /// Insert fresh `window`-aligned boundary states into the prefix
    /// cache as the scan passes them (the cached-segmentation mode).
    cached: bool,
    outcome: CacheOutcome,
    /// The final boundary's serialization, reused as the landing value
    /// when the ingestion target is itself window-aligned.
    final_parts: Option<Vec<Tensor>>,
}

impl std::fmt::Debug for PrefillCursor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PrefillCursor")
            .field("pos", &self.pos)
            .field("consumed", &self.consumed)
            .field("window", &self.window)
            .field("cached", &self.cached)
            .finish()
    }
}

impl Prefiller {
    /// Park a fresh (or snapshot-resumed) lane's prompt behind a cursor
    /// with caller-chosen window quantum — the uncached budget mode (the
    /// engine passes its `--prefill-budget`; `window >= prompt.len()`
    /// reproduces the monolithic single-advance segmentation exactly).
    pub fn cursor(
        &self,
        resume: Option<&[Tensor]>,
        prompt: &[u8],
        window: usize,
    ) -> Result<PrefillCursor> {
        ensure!(prompt.len() >= 2, "prompt of {} token(s): nothing to prefill", prompt.len());
        let mc = &self.model.cfg;
        let mut state = ModelState::new(mc);
        if let Some(parts) = resume {
            state.load_components(mc, parts)?;
        }
        Ok(PrefillCursor {
            state,
            prompt: prompt.to_vec(),
            pos: 0,
            consumed: prompt.len() - 1,
            window: window.max(1),
            cached: false,
            outcome: CacheOutcome::default(),
            final_parts: None,
        })
    }

    /// Park a fresh lane's prompt behind a cache-attached cursor: quantum
    /// = `cache.chunk()`, scan seeded from the longest cached strict
    /// prefix, fresh boundaries contributed as the windows complete —
    /// the identical segmentation (and therefore identical bits) as
    /// [`Prefiller::ingest_lane_cached`], which now drives this cursor
    /// to completion in one call.
    pub fn cursor_cached(&self, cache: &PrefixCache, prompt: &[u8]) -> Result<PrefillCursor> {
        ensure!(prompt.len() >= 2, "prompt of {} token(s): nothing to prefill", prompt.len());
        let mc = &self.model.cfg;
        let mut state = ModelState::new(mc);
        let mut pos = 0usize;
        let mut outcome = CacheOutcome::default();
        if let Some((depth, parts)) = cache.lookup(prompt) {
            state.load_components(mc, &parts)?;
            pos = depth;
            outcome.hit_tokens = depth;
        }
        Ok(PrefillCursor {
            state,
            prompt: prompt.to_vec(),
            pos,
            consumed: prompt.len() - 1,
            window: cache.chunk(),
            cached: true,
            outcome,
            final_parts: None,
        })
    }
}

impl PrefillCursor {
    /// Consume whole windows until at least `budget` tokens of prompt
    /// have been ingested this call (or the cursor is done).  Always
    /// makes progress: the first window runs even if it exceeds the
    /// budget, so a tiny budget still terminates.  Returns the number of
    /// prompt tokens consumed by this call.
    ///
    /// `cache` must be the cursor's creating cache for a
    /// [`Prefiller::cursor_cached`] cursor (boundary inserts land
    /// there); pass `None` for an uncached cursor.
    pub fn advance_budget(
        &mut self,
        pf: &Prefiller,
        cache: Option<&PrefixCache>,
        budget: usize,
    ) -> Result<usize> {
        let mc = &pf.model.cfg;
        let mut used = 0usize;
        while self.pos < self.consumed && (used == 0 || used < budget) {
            let next = ((self.pos / self.window + 1) * self.window).min(self.consumed);
            advance(&pf.model, &mut self.state, &self.prompt[self.pos..next], &pf.cfg);
            used += next - self.pos;
            self.pos = next;
            if self.cached && self.pos % self.window == 0 {
                // a boundary state fresh off the scan: share it forward
                let parts = self.state.to_components(mc)?;
                if let Some(cache) = cache {
                    if cache.insert(&self.prompt[..self.pos], &parts)? {
                        self.outcome.inserted += 1;
                    }
                }
                if self.pos == self.consumed {
                    self.final_parts = Some(parts);
                }
            }
        }
        Ok(used)
    }

    /// Has the full ingestion target been consumed?
    pub fn done(&self) -> bool {
        self.pos >= self.consumed
    }

    /// Next prompt position to ingest.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Total ingestion target (`prompt.len() - 1`).
    pub fn target(&self) -> usize {
        self.consumed
    }

    /// Prompt tokens still to ingest.
    pub fn remaining(&self) -> usize {
        self.consumed - self.pos
    }

    /// Prompt tokens skipped by the creating cache lookup (0 = cold or
    /// uncached) — known at creation, for the admission-time
    /// `cache_lookup` instant event.
    pub fn hit_tokens(&self) -> usize {
        self.outcome.hit_tokens
    }

    /// Land the finished ingestion: the post-prompt component tensors,
    /// the tokens consumed, and the cache outcome.  Errors if called
    /// before [`PrefillCursor::done`].
    pub fn finish(mut self, pf: &Prefiller) -> Result<(Vec<Tensor>, usize, CacheOutcome)> {
        ensure!(
            self.done(),
            "prefill cursor finished early at {}/{} tokens",
            self.pos,
            self.consumed
        );
        let parts = match self.final_parts.take() {
            Some(p) => p,
            None => self.state.to_components(&pf.model.cfg)?,
        };
        Ok((parts, self.consumed, self.outcome))
    }
}

#[cfg(test)]
mod tests {
    use super::super::PrefillCfg;
    use crate::testing::fixtures;

    #[test]
    fn budget_semantics_always_progress_and_stop_on_target() {
        let s = fixtures::ModelShape::default();
        let model = fixtures::build_model_full("hla2", &s, 11);
        let pf = super::Prefiller::new(model, PrefillCfg::scan(4, 1)).unwrap();
        let prompt: Vec<u8> = (0..23u8).collect();
        // window 8, budget 3: each call still consumes one whole window
        let mut cur = pf.cursor(None, &prompt, 8).unwrap();
        let mut cuts = vec![];
        while !cur.done() {
            let used = cur.advance_budget(&pf, None, 3).unwrap();
            assert!(used > 0, "every call makes progress");
            cuts.push(cur.position());
        }
        // cuts land at absolute window multiples, then the target
        assert_eq!(cuts, vec![8, 16, 22]);
        let (_, consumed, outcome) = cur.finish(&pf).unwrap();
        assert_eq!(consumed, prompt.len() - 1);
        assert_eq!(outcome.hit_tokens, 0);
        // a big budget crosses several windows in one call
        let mut cur = pf.cursor(None, &prompt, 4).unwrap();
        assert_eq!(cur.advance_budget(&pf, None, 9).unwrap(), 12);
        assert_eq!(cur.remaining(), 10);
    }

    #[test]
    fn whole_prompt_window_is_one_advance() {
        let s = fixtures::ModelShape::default();
        let model = fixtures::build_model_full("ahla", &s, 5);
        let pf = super::Prefiller::new(model, PrefillCfg::scan(8, 1)).unwrap();
        let prompt: Vec<u8> = (0..17u8).collect();
        let mut cur = pf.cursor(None, &prompt, prompt.len()).unwrap();
        assert_eq!(cur.advance_budget(&pf, None, usize::MAX).unwrap(), 16);
        assert!(cur.done());
        let (parts, consumed, _) = cur.finish(&pf).unwrap();
        let (mono, mono_consumed) = pf.ingest_lane(None, &prompt).unwrap();
        assert_eq!(consumed, mono_consumed);
        for (a, b) in parts.iter().zip(&mono) {
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.data.iter().map(|v| v.to_bits()).collect(),
                b.data.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "single-window cursor == monolithic ingest, bitwise");
        }
    }
}
