//! The session store: in-memory LRU tier + optional disk-spill tier.
//!
//! Sessions are small and constant-size (O(d² + d·d_v) per head), so the
//! store is a plain map of snapshots with tick-based LRU eviction; evicted
//! snapshots spill to `{spill_dir}/{id:016x}.hlas` when a spill directory
//! is configured, and a resume that misses memory falls through to disk.
//! All counters are lock-free ([`crate::metrics::Counter`]) so server
//! handler threads and the CLI can read hit rates without contending with
//! the engine loops.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use super::{SessionId, SessionSnapshot};
use crate::metrics::{hit_rate, Counter};

/// Store sizing/placement knobs.
#[derive(Debug, Clone)]
pub struct StoreCfg {
    /// Max snapshots resident in memory before LRU eviction.
    pub capacity: usize,
    /// Where evicted snapshots spill (None = evictions are dropped).
    pub spill_dir: Option<PathBuf>,
}

impl Default for StoreCfg {
    fn default() -> Self {
        StoreCfg { capacity: 1024, spill_dir: None }
    }
}

/// Point-in-time view of the store counters (CLI/bench reporting).
#[derive(Debug, Default, Clone)]
pub struct StoreStats {
    pub snapshots: u64,
    pub restores: u64,
    pub resume_hits: u64,
    pub resume_misses: u64,
    pub forks: u64,
    pub migrations: u64,
    pub evictions: u64,
    pub spills: u64,
    pub spill_loads: u64,
    /// Snapshots currently resident in memory.
    pub resident: usize,
    /// Bytes of state currently resident in memory.
    pub resident_bytes: usize,
}

impl StoreStats {
    /// Fraction of resume attempts served from the store (either tier).
    pub fn hit_rate(&self) -> f64 {
        hit_rate(self.resume_hits, self.resume_misses)
    }
}

struct Entry {
    snap: SessionSnapshot,
    tick: u64,
}

struct Inner {
    cfg: StoreCfg,
    map: HashMap<SessionId, Entry>,
    tick: u64,
}

/// Thread-safe snapshot store shared by engine replicas, server handlers
/// and the CLI.  Because every replica detaches into and restores from the
/// same store, moving a session between replicas is just routing — the
/// state follows through here (see [`super::migrate`] and
/// [`crate::coordinator::router::Router::pin_session`]).
pub struct SessionStore {
    inner: Mutex<Inner>,
    pub snapshots: Counter,
    pub restores: Counter,
    pub resume_hits: Counter,
    pub resume_misses: Counter,
    pub forks: Counter,
    pub migrations: Counter,
    pub evictions: Counter,
    pub spills: Counter,
    pub spill_loads: Counter,
}

/// The spill-tier file for a session id — the single source of the
/// on-disk naming convention (the `hla sessions` CLI reuses it).
pub fn spill_file(dir: &Path, id: SessionId) -> PathBuf {
    dir.join(format!("{id:016x}.hlas"))
}

impl SessionStore {
    pub fn new(cfg: StoreCfg) -> SessionStore {
        SessionStore {
            inner: Mutex::new(Inner { cfg, map: HashMap::new(), tick: 0 }),
            snapshots: Counter::new(),
            restores: Counter::new(),
            resume_hits: Counter::new(),
            resume_misses: Counter::new(),
            forks: Counter::new(),
            migrations: Counter::new(),
            evictions: Counter::new(),
            spills: Counter::new(),
            spill_loads: Counter::new(),
        }
    }

    /// Memory-only store with the given capacity.
    pub fn in_memory(capacity: usize) -> SessionStore {
        SessionStore::new(StoreCfg { capacity, spill_dir: None })
    }

    /// Detach a snapshot into the store (replacing any previous snapshot of
    /// the same session), evicting the least-recently-used entry past
    /// capacity — to disk when a spill dir is configured.
    pub fn put(&self, snap: SessionSnapshot) {
        self.snapshots.incr();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let id = snap.id;
        inner.map.insert(id, Entry { snap, tick });
        while inner.map.len() > inner.cfg.capacity.max(1) {
            // O(n) LRU scan: stores are small (thousands of entries) and
            // eviction is off the decode hot path
            let Some(&victim) =
                inner.map.iter().filter(|(&k, _)| k != id).min_by_key(|(_, e)| e.tick).map(|(k, _)| k)
            else {
                break;
            };
            let entry = inner.map.remove(&victim).expect("victim came from the map");
            self.evictions.incr();
            if let Some(dir) = inner.cfg.spill_dir.clone() {
                match Self::spill(&dir, &entry.snap) {
                    Ok(()) => {
                        self.spills.incr();
                    }
                    Err(e) => log::warn!("session {victim}: spill failed, dropping: {e}"),
                }
            }
        }
    }

    fn spill(dir: &Path, snap: &SessionSnapshot) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let path = spill_file(dir, snap.id);
        std::fs::write(&path, snap.to_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(())
    }

    /// Claim a session for resume: removes it from the store (the live lane
    /// becomes the one copy) and counts the resume hit/miss.  With
    /// `expect_cfg`, a snapshot from a different model config is left in
    /// place and counted as a miss rather than restored into a lane whose
    /// state layout it cannot match.
    pub fn claim(&self, id: SessionId, expect_cfg: Option<&str>) -> Option<SessionSnapshot> {
        let mut inner = self.inner.lock().unwrap();
        // memory tier
        if let Some(entry) = inner.map.get(&id) {
            if let Some(cfg) = expect_cfg {
                if entry.snap.cfg_name != cfg {
                    log::warn!(
                        "session {id}: snapshot is for config {:?}, not {cfg:?}",
                        entry.snap.cfg_name
                    );
                    self.resume_misses.incr();
                    return None;
                }
            }
            let entry = inner.map.remove(&id).expect("checked above");
            self.resume_hits.incr();
            self.restores.incr();
            return Some(entry.snap);
        }
        // disk tier — deliberately *under* the lock: claim is the "one
        // live copy" handoff, so a concurrent claim of the same spilled
        // session must observe the file already consumed (and a racing
        // put must not be missed); sessions are small, the IO is a few µs
        if let Some(dir) = inner.cfg.spill_dir.clone() {
            let path = spill_file(&dir, id);
            if let Ok(bytes) = std::fs::read(&path) {
                match SessionSnapshot::from_bytes(&bytes) {
                    Ok(snap) if expect_cfg.map_or(true, |c| snap.cfg_name == c) => {
                        let _ = std::fs::remove_file(&path);
                        self.spill_loads.incr();
                        self.resume_hits.incr();
                        self.restores.incr();
                        return Some(snap);
                    }
                    Ok(snap) => {
                        log::warn!(
                            "session {id}: spilled snapshot is for config {:?}",
                            snap.cfg_name
                        );
                    }
                    Err(e) => log::warn!("session {id}: spilled snapshot unreadable: {e}"),
                }
            }
            self.resume_misses.incr();
            return None;
        }
        self.resume_misses.incr();
        None
    }

    /// Re-insert a snapshot whose claim could not be applied (the lane
    /// rejected its state layout): the claim's hit/restore accounting is
    /// rolled back and the attempt recorded as a miss, so the headline
    /// hit-rate only counts resumes that actually reached a lane.  Does
    /// not count as a new snapshot.
    pub fn unclaim(&self, snap: SessionSnapshot) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(snap.id, Entry { snap, tick });
        drop(inner);
        self.resume_hits.decr();
        self.restores.decr();
        self.resume_misses.incr();
    }

    /// Clone a snapshot without removing it (fork source, CLI inspection).
    pub fn peek(&self, id: SessionId) -> Option<SessionSnapshot> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.map.get_mut(&id) {
            entry.tick = tick;
            return Some(entry.snap.clone());
        }
        // read the disk tier under the lock so a concurrent claim cannot
        // delete the file between our existence check and read
        let dir = inner.cfg.spill_dir.clone()?;
        let bytes = std::fs::read(spill_file(&dir, id)).ok()?;
        SessionSnapshot::from_bytes(&bytes).ok()
    }

    /// Is the session resident in either tier?
    pub fn contains(&self, id: SessionId) -> bool {
        let inner = self.inner.lock().unwrap();
        if inner.map.contains_key(&id) {
            return true;
        }
        match &inner.cfg.spill_dir {
            Some(dir) => spill_file(dir, id).exists(),
            None => false,
        }
    }

    /// Copy-on-snapshot fork: `child` continues from `parent`'s prefix
    /// state at O(state) cost; `reseed` gives the fork its own sampler
    /// stream so N forks of one shared prompt prefix diverge.
    pub fn fork(&self, parent: SessionId, child: SessionId, reseed: Option<u64>) -> Result<()> {
        let snap = self.peek(parent).ok_or_else(|| anyhow!("unknown session {parent}"))?;
        self.put(snap.fork(child, reseed));
        self.forks.incr();
        Ok(())
    }

    /// Drop a session from both tiers; returns whether anything existed.
    pub fn evict(&self, id: SessionId) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let in_mem = inner.map.remove(&id).is_some();
        let on_disk = match &inner.cfg.spill_dir {
            Some(dir) => std::fs::remove_file(spill_file(dir, id)).is_ok(),
            None => false,
        };
        if in_mem || on_disk {
            self.evictions.incr();
        }
        in_mem || on_disk
    }

    /// Memory-resident session ids (ascending).
    pub fn ids(&self) -> Vec<SessionId> {
        let inner = self.inner.lock().unwrap();
        let mut v: Vec<SessionId> = inner.map.keys().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> StoreStats {
        let inner = self.inner.lock().unwrap();
        StoreStats {
            snapshots: self.snapshots.get(),
            restores: self.restores.get(),
            resume_hits: self.resume_hits.get(),
            resume_misses: self.resume_misses.get(),
            forks: self.forks.get(),
            migrations: self.migrations.get(),
            evictions: self.evictions.get(),
            spills: self.spills.get(),
            spill_loads: self.spill_loads.get(),
            resident: inner.map.len(),
            resident_bytes: inner.map.values().map(|e| e.snap.state_nbytes()).sum(),
        }
    }
}

/// Enumerate the snapshots in a spill directory (the `hla sessions` CLI:
/// the disk tier is the only cross-process view of a store).
pub fn spill_sessions(dir: &Path) -> Result<Vec<SessionSnapshot>> {
    let mut out = vec![];
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading spill dir {}", dir.display()))?;
    for entry in entries {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("hlas") {
            continue;
        }
        let bytes = std::fs::read(&path)?;
        match SessionSnapshot::from_bytes(&bytes) {
            Ok(snap) => out.push(snap),
            Err(e) => log::warn!("{}: skipping unreadable snapshot: {e}", path.display()),
        }
    }
    out.sort_by_key(|s| s.id);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::snapshot::SamplerState;
    use crate::tensor::Tensor;

    fn snap(id: SessionId) -> SessionSnapshot {
        SessionSnapshot {
            id,
            cfg_name: "micro".into(),
            tokens_generated: id * 10,
            last_token: id as u8,
            sampler: SamplerState {
                temperature: 0.5,
                top_k: 0,
                seed: id,
                rng_state: id ^ 0xABCD,
                rng_spare: None,
            },
            state: vec![Tensor::from_vec(&[1, 1, 4], vec![id as f32; 4])],
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("hla-session-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn put_claim_roundtrip_and_counters() {
        let store = SessionStore::in_memory(8);
        store.put(snap(1));
        assert!(store.contains(1));
        assert_eq!(store.claim(1, Some("micro")).unwrap(), snap(1));
        assert!(!store.contains(1), "claim removes the snapshot");
        assert!(store.claim(1, None).is_none());
        let st = store.stats();
        assert_eq!((st.snapshots, st.resume_hits, st.resume_misses), (1, 1, 1));
        assert_eq!(st.hit_rate(), 0.5);
    }

    #[test]
    fn unclaim_restores_snapshot_and_rolls_back_accounting() {
        let store = SessionStore::in_memory(8);
        store.put(snap(1));
        let s = store.claim(1, Some("micro")).unwrap();
        store.unclaim(s);
        assert!(store.contains(1), "unclaim puts the one copy back");
        let st = store.stats();
        assert_eq!((st.resume_hits, st.restores, st.resume_misses), (0, 0, 1));
        assert_eq!(st.snapshots, 1, "unclaim is not a new snapshot");
        assert_eq!(store.claim(1, Some("micro")).unwrap(), snap(1), "claimable again");
    }

    #[test]
    fn cfg_mismatch_is_a_miss_and_preserves_snapshot() {
        let store = SessionStore::in_memory(8);
        store.put(snap(3));
        assert!(store.claim(3, Some("other-model")).is_none());
        assert!(store.contains(3), "mismatched claim must not destroy the snapshot");
        assert_eq!(store.stats().resume_misses, 1);
    }

    #[test]
    fn lru_eviction_spills_to_disk_and_loads_back() {
        let dir = temp_dir("spill");
        let store =
            SessionStore::new(StoreCfg { capacity: 2, spill_dir: Some(dir.clone()) });
        store.put(snap(1));
        store.put(snap(2));
        store.put(snap(3)); // evicts 1 (least recently used)
        assert_eq!(store.ids(), vec![2, 3]);
        assert!(store.contains(1), "evicted session lives on disk");
        assert_eq!(store.stats().spills, 1);

        let back = store.claim(1, Some("micro")).expect("disk-tier resume");
        assert_eq!(back, snap(1));
        assert_eq!(store.stats().spill_loads, 1);
        assert!(!store.contains(1), "claim consumes the spill file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recency_protects_hot_sessions() {
        let store = SessionStore::in_memory(2);
        store.put(snap(1));
        store.put(snap(2));
        let _ = store.peek(1); // touch 1 -> 2 becomes LRU
        store.put(snap(3));
        assert_eq!(store.ids(), vec![1, 3]);
    }

    #[test]
    fn corrupted_spill_file_is_a_miss() {
        let dir = temp_dir("corrupt");
        let store =
            SessionStore::new(StoreCfg { capacity: 1, spill_dir: Some(dir.clone()) });
        store.put(snap(1));
        store.put(snap(2)); // spills 1
        let path = dir.join(format!("{:016x}.hlas", 1u64));
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        assert!(store.claim(1, Some("micro")).is_none());
        assert_eq!(store.stats().resume_misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fork_and_evict() {
        let store = SessionStore::in_memory(8);
        store.put(snap(5));
        store.fork(5, 6, Some(999)).unwrap();
        assert!(store.contains(5) && store.contains(6));
        let child = store.peek(6).unwrap();
        assert_eq!(child.state, snap(5).state);
        assert_eq!(child.sampler.seed, 999);
        assert!(store.fork(404, 7, None).is_err(), "unknown parent");
        assert!(store.evict(5));
        assert!(!store.evict(5));
        assert_eq!(store.stats().forks, 1);
    }

    #[test]
    fn spill_listing_for_cli() {
        let dir = temp_dir("list");
        let store =
            SessionStore::new(StoreCfg { capacity: 1, spill_dir: Some(dir.clone()) });
        store.put(snap(9));
        store.put(snap(4)); // spills 9
        store.put(snap(2)); // spills 4
        let listed = spill_sessions(&dir).unwrap();
        assert_eq!(listed.iter().map(|s| s.id).collect::<Vec<_>>(), vec![4, 9]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
