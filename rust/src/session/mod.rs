//! Session state store: snapshot / resume / fork of the constant-size HLA
//! prefix state.
//!
//! HLA's defining serving property (Theorem 3.1) is that the entire
//! attention prefix is a compact, *constant-size* sufficient statistic —
//! O(d² + d·d_v) per head — rather than an O(context) KV-cache.  This
//! module turns that into a serving capability:
//!
//! * [`SessionSnapshot`] — a versioned, checksummed capture of one decode
//!   lane: every state component, the sampler's exact RNG position, the
//!   last sampled token, and the cumulative token count.  Fixed size no
//!   matter how long the conversation ran.
//! * [`SessionStore`] — an in-memory LRU tier with an optional disk-spill
//!   tier, shared by all engine replicas.  Detach on completion, restore
//!   on the next turn: a multi-turn conversation skips re-prefilling its
//!   whole history.
//! * [`SessionSnapshot::fork`] / [`SessionStore::fork`] — copy-on-snapshot:
//!   N continuations of one shared prompt prefix cost O(state) each, not
//!   O(context) each.
//! * [`migrate`] — cross-replica moves over the
//!   [`StatePool::read_lane`](crate::coordinator::StatePool::read_lane) /
//!   [`write_lane`](crate::coordinator::StatePool::write_lane) hooks.
//!
//! Wiring: the coordinator detaches a finished lane into the store when
//! the request carries a session id and restores it on `resume`; the TCP
//! protocol grows `session` / `resume` / `fork_of` fields (see
//! [`crate::server`]); `hla sessions` lists/inspects/evicts the spill
//! tier; bench E13 measures snapshot/restore/fork cost against a
//! simulated KV-cache checkpoint.

pub mod codec;
pub mod migrate;
pub mod snapshot;
pub mod store;

/// Durable conversation identifier (the TCP protocol's `"session"` field).
pub type SessionId = u64;

pub use migrate::{attach, detach, migrate_lane, migrate_via_store};
pub use snapshot::{
    cfg_state_fingerprint, shape_fingerprint, state_fingerprint, CfgMismatch, SamplerState,
    SessionSnapshot, FORMAT_VERSION,
};
pub use store::{spill_file, spill_sessions, SessionStore, StoreCfg, StoreStats};
