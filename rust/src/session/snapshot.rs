//! The versioned, checksummed session snapshot.
//!
//! A [`SessionSnapshot`] is everything needed to continue a generation
//! exactly where it stopped: the lane's recurrent state tensors (the
//! paper's constant-size sufficient statistic, O(d² + d·d_v) per head —
//! Theorem 3.1), the sampler's RNG stream position, the last sampled
//! token (the next step's input), and the cumulative token count.
//!
//! Because the state is constant-size, the snapshot is a fixed-size
//! memcpy regardless of how long the conversation has run — the property
//! that makes checkpoint/resume/fork O(state) instead of the O(context)
//! paging a softmax KV-cache needs (bench E13 quantifies the gap).

use anyhow::{ensure, Result};

use super::codec::{Reader, Writer};
use super::SessionId;
use crate::model::sampler::{Sampler, SamplerCfg};
use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

/// Binary format version (bump on layout change; readers reject unknown).
/// v2 added the config fingerprint to the header.
pub const FORMAT_VERSION: u32 = 2;

/// Magic prefix: "HLAS" little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"HLAS");

/// Typed rejection for a snapshot whose state layout does not match the
/// destination's (different shapes / layer count / component arity).
/// Attaching such a snapshot would silently corrupt the destination lane —
/// every attach path checks the fingerprint first and surfaces this error.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error(
    "session {id}: snapshot fingerprint {have:#018x} (cfg {cfg_name:?}) does not match \
     destination fingerprint {want:#018x} — refusing to attach"
)]
pub struct CfgMismatch {
    pub id: SessionId,
    pub cfg_name: String,
    /// Fingerprint of the snapshot's state layout.
    pub have: u64,
    /// Fingerprint the destination expects.
    pub want: u64,
}

/// FNV-1a over a state layout: per tensor its rank then every dim, plus the
/// tensor count.  Any shape or layer-count drift between two model configs
/// changes the state layout and therefore the fingerprint.
pub fn shape_fingerprint<'a>(shapes: impl IntoIterator<Item = &'a [usize]>) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |h: &mut u64, v: u64| {
        for b in v.to_le_bytes() {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    let mut n = 0u64;
    for s in shapes {
        n += 1;
        mix(&mut h, s.len() as u64);
        for &d in s {
            mix(&mut h, d as u64);
        }
    }
    mix(&mut h, n);
    h
}

/// Fingerprint of a concrete state tensor set (a snapshot's payload, or a
/// fresh `ModelState::to_tensors()` — both sides of an attach).
pub fn state_fingerprint(state: &[Tensor]) -> u64 {
    shape_fingerprint(state.iter().map(|t| t.shape.as_slice()))
}

/// The fingerprint a config's engine-path snapshots carry: the per-lane
/// slice of every `state_paths` component (batch dim collapsed to 1) —
/// exactly the shapes `StatePool::read_lane` produces.
pub fn cfg_state_fingerprint(cfg: &ModelCfg) -> u64 {
    let shapes: Vec<Vec<usize>> = cfg
        .state_paths
        .iter()
        .map(|(_, s)| {
            let mut s = s.clone();
            if s.len() > 1 {
                s[1] = 1;
            }
            s
        })
        .collect();
    shape_fingerprint(shapes.iter().map(|s| s.as_slice()))
}

/// Captured sampler: config plus the exact RNG stream position, so a
/// resumed generation draws the same tokens an uninterrupted one would.
#[derive(Debug, Clone, PartialEq)]
pub struct SamplerState {
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    pub rng_state: u64,
    pub rng_spare: Option<f64>,
}

impl SamplerState {
    pub fn capture(s: &Sampler) -> SamplerState {
        let (rng_state, rng_spare) = s.rng_parts();
        SamplerState {
            temperature: s.cfg.temperature,
            top_k: s.cfg.top_k,
            seed: s.cfg.seed,
            rng_state,
            rng_spare,
        }
    }

    /// Rebuild the sampler mid-stream.
    pub fn rebuild(&self) -> Sampler {
        let cfg = SamplerCfg { temperature: self.temperature, top_k: self.top_k, seed: self.seed };
        Sampler::from_parts(cfg, self.rng_state, self.rng_spare)
    }

    /// A fresh stream from `seed` (fork divergence point).
    pub fn reseeded(&self, seed: u64) -> SamplerState {
        let sampler = Sampler::new(SamplerCfg {
            temperature: self.temperature,
            top_k: self.top_k,
            seed,
        });
        SamplerState::capture(&sampler)
    }
}

/// One detached session: the full prefix state of a decode lane.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    pub id: SessionId,
    /// Model config the state belongs to; restore refuses a mismatch.
    pub cfg_name: String,
    /// Cumulative tokens generated across all turns of this session.
    pub tokens_generated: u64,
    /// Last sampled token — the first input token after resume.
    pub last_token: u8,
    pub sampler: SamplerState,
    /// One tensor per state component (the lane slice, batch dim = 1).
    pub state: Vec<Tensor>,
}

impl SessionSnapshot {
    /// Bytes of recurrent state carried (constant per session).
    pub fn state_nbytes(&self) -> usize {
        self.state.iter().map(Tensor::nbytes).sum()
    }

    /// Fingerprint of this snapshot's state layout (shapes + arity).  A
    /// pure function of the payload, so it cannot drift from the state it
    /// describes; `to_bytes` persists it in the header and `from_bytes`
    /// cross-checks header against payload.
    pub fn cfg_fingerprint(&self) -> u64 {
        state_fingerprint(&self.state)
    }

    /// The attach compatibility gate: refuse (typed) unless this
    /// snapshot's layout fingerprint matches what the destination expects.
    pub fn ensure_fingerprint(&self, want: u64) -> Result<(), CfgMismatch> {
        let have = self.cfg_fingerprint();
        if have != want {
            return Err(CfgMismatch {
                id: self.id,
                cfg_name: self.cfg_name.clone(),
                have,
                want,
            });
        }
        Ok(())
    }

    /// Copy-on-snapshot fork: a new session continuing from the same
    /// prefix state.  With `reseed`, the fork's sampler starts a fresh
    /// stream from that seed (so N forks of one prompt prefix diverge);
    /// without, it inherits the parent's exact stream position.
    pub fn fork(&self, child: SessionId, reseed: Option<u64>) -> SessionSnapshot {
        SessionSnapshot {
            id: child,
            sampler: match reseed {
                Some(seed) => self.sampler.reseeded(seed),
                None => self.sampler.clone(),
            },
            ..self.clone()
        }
    }

    /// Serialize: magic + version + fields + state tensors + CRC-32.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(MAGIC);
        w.u32(FORMAT_VERSION);
        w.u64(self.id);
        w.str(&self.cfg_name);
        w.u64(self.cfg_fingerprint());
        w.u64(self.tokens_generated);
        w.u8(self.last_token);
        w.f32(self.sampler.temperature);
        w.u64(self.sampler.top_k as u64);
        w.u64(self.sampler.seed);
        w.u64(self.sampler.rng_state);
        match self.sampler.rng_spare {
            Some(s) => {
                w.u8(1);
                w.f64(s);
            }
            None => {
                w.u8(0);
                w.f64(0.0);
            }
        }
        w.u32(self.state.len() as u32);
        for t in &self.state {
            w.u32(t.shape.len() as u32);
            for &d in &t.shape {
                w.u32(d as u32);
            }
            w.f32_slice(&t.data);
        }
        w.finish_with_crc()
    }

    /// Deserialize + verify checksum, magic and version.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionSnapshot> {
        let mut r = Reader::with_crc(bytes)?;
        let magic = r.u32()?;
        ensure!(magic == MAGIC, "not a session snapshot (magic {magic:#010x})");
        let version = r.u32()?;
        ensure!(
            version == FORMAT_VERSION,
            "snapshot format v{version} unsupported (this build reads v{FORMAT_VERSION})"
        );
        let id = r.u64()?;
        let cfg_name = r.str()?;
        let cfg_fingerprint = r.u64()?;
        let tokens_generated = r.u64()?;
        let last_token = r.u8()?;
        let temperature = r.f32()?;
        let top_k = r.u64()? as usize;
        let seed = r.u64()?;
        let rng_state = r.u64()?;
        let has_spare = r.u8()? != 0;
        let spare = r.f64()?;
        let n = r.u32()? as usize;
        let mut state = Vec::with_capacity(n);
        for _ in 0..n {
            let rank = r.u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u32()? as usize);
            }
            let data = r.f32_slice()?;
            ensure!(
                data.len() == shape.iter().product::<usize>(),
                "state tensor payload {} != shape {shape:?}",
                data.len()
            );
            state.push(Tensor::from_vec(&shape, data));
        }
        ensure!(r.remaining() == 0, "{} trailing bytes after snapshot", r.remaining());
        let computed = state_fingerprint(&state);
        ensure!(
            computed == cfg_fingerprint,
            "snapshot header fingerprint {cfg_fingerprint:#018x} does not match its state \
             layout ({computed:#018x})"
        );
        Ok(SessionSnapshot {
            id,
            cfg_name,
            tokens_generated,
            last_token,
            sampler: SamplerState {
                temperature,
                top_k,
                seed,
                rng_state,
                rng_spare: has_spare.then_some(spare),
            },
            state,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_snapshot(id: SessionId) -> SessionSnapshot {
        let mut rng = Rng::new(id);
        let mut t1 = Tensor::zeros(&[2, 1, 2, 4, 4]);
        let mut t2 = Tensor::zeros(&[2, 1, 2, 4]);
        rng.fill_normal(&mut t1.data, 1.0);
        rng.fill_normal(&mut t2.data, 1.0);
        SessionSnapshot {
            id,
            cfg_name: "micro".into(),
            tokens_generated: 123,
            last_token: 0x41,
            sampler: SamplerState {
                temperature: 0.8,
                top_k: 40,
                seed: 7,
                rng_state: 0x1234_5678_9ABC_DEF0,
                rng_spare: Some(-0.75),
            },
            state: vec![t1, t2],
        }
    }

    #[test]
    fn bytes_roundtrip_exact() {
        let snap = sample_snapshot(42);
        let bytes = snap.to_bytes();
        let back = SessionSnapshot::from_bytes(&bytes).unwrap();
        assert_eq!(back, snap);

        // None spare also roundtrips
        let mut snap2 = sample_snapshot(43);
        snap2.sampler.rng_spare = None;
        assert_eq!(SessionSnapshot::from_bytes(&snap2.to_bytes()).unwrap(), snap2);
    }

    #[test]
    fn corrupted_and_foreign_bytes_rejected() {
        let snap = sample_snapshot(1);
        let mut bytes = snap.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(SessionSnapshot::from_bytes(&bytes).is_err());
        assert!(SessionSnapshot::from_bytes(b"not a snapshot").is_err());
        assert!(SessionSnapshot::from_bytes(&[]).is_err());
    }

    #[test]
    fn fork_diverges_only_by_sampler() {
        let snap = sample_snapshot(7);
        let fork = snap.fork(99, Some(1234));
        assert_eq!(fork.id, 99);
        assert_eq!(fork.state, snap.state);
        assert_eq!(fork.last_token, snap.last_token);
        assert_eq!(fork.tokens_generated, snap.tokens_generated);
        assert_eq!(fork.sampler.temperature, snap.sampler.temperature);
        assert_eq!(fork.sampler.top_k, snap.sampler.top_k);
        assert_ne!(fork.sampler.rng_state, snap.sampler.rng_state);

        // no reseed: exact continuation of the parent's stream
        let twin = snap.fork(100, None);
        assert_eq!(twin.sampler, snap.sampler);
    }

    #[test]
    fn fingerprint_tracks_state_layout_only() {
        let a = sample_snapshot(1);
        let mut b = sample_snapshot(2);
        // different ids / values, same layout → same fingerprint
        b.tokens_generated = 999;
        b.state[0].data[0] += 1.0;
        assert_eq!(a.cfg_fingerprint(), b.cfg_fingerprint());
        // a layer-count (leading-dim) drift changes it
        let mut c = sample_snapshot(3);
        c.state[0] = Tensor::zeros(&[3, 1, 2, 4, 4]);
        assert_ne!(a.cfg_fingerprint(), c.cfg_fingerprint());
        // so does dropping a component
        let mut d = sample_snapshot(4);
        d.state.pop();
        assert_ne!(a.cfg_fingerprint(), d.cfg_fingerprint());
        // the gate is typed and carries both sides
        let err = c.ensure_fingerprint(a.cfg_fingerprint()).unwrap_err();
        assert_eq!(err.id, 3);
        assert_eq!(err.have, c.cfg_fingerprint());
        assert_eq!(err.want, a.cfg_fingerprint());
        assert!(err.to_string().contains("refusing to attach"), "{err}");
        a.ensure_fingerprint(b.cfg_fingerprint()).unwrap();
    }

    #[test]
    fn cfg_fingerprint_matches_lane_slice_of_state_paths() {
        // engine-path snapshots carry [L, 1, H, ...] lane slices of the
        // config's state_paths — cfg_state_fingerprint must agree
        let json = r#"{
          "configs": {"t": {"vocab": 16, "d_model": 8, "n_layers": 2,
            "n_heads": 2, "head_dim": 4, "d_ffn": 32, "kv_heads": 2,
            "mixer": "hla2", "chunk": 4, "gamma": 1.0, "lam": 0.0,
            "norm_mode": "abs", "eps": 1e-6, "n_params": 100,
            "n_param_tensors": 2, "n_state_tensors": 2,
            "param_paths": [["['embed']", [16, 8]]],
            "state_paths": [["['c']", [2, 3, 2, 4, 4]], ["['m']", [2, 3, 2, 4]]],
            "train_batch": 2, "train_seq": 8, "decode_batch": 3,
            "prefill_len": 4}},
          "artifacts": {}
        }"#;
        let cfg = crate::runtime::Manifest::parse(json).unwrap().configs["t"].clone();
        // sample_snapshot's layout is exactly this config's lane slice
        assert_eq!(sample_snapshot(1).cfg_fingerprint(), cfg_state_fingerprint(&cfg));
    }

    #[test]
    fn snapshot_size_is_state_dominated() {
        let snap = sample_snapshot(5);
        let bytes = snap.to_bytes();
        // header + checksum overhead stays under 128 bytes
        assert!(bytes.len() < snap.state_nbytes() + 128, "{}", bytes.len());
        assert!(bytes.len() > snap.state_nbytes());
    }
}
