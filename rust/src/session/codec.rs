//! Fixed little-endian binary codec for session snapshots.
//!
//! Deliberately tiny and dependency-free (no serde/bincode offline): a
//! byte writer/reader pair over primitive fields plus a CRC-32 trailer so
//! a snapshot that crossed a disk or the network is verifiably intact
//! before its bytes are written into a live decode lane.

use anyhow::{bail, ensure, Result};

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-less.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in bytes {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Append-only byte buffer with typed little-endian writes.
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 payload (the state tensors' data).
    pub fn f32_slice(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Append the CRC-32 of everything written so far and return the buffer.
    pub fn finish_with_crc(mut self) -> Vec<u8> {
        let crc = crc32(&self.buf);
        self.buf.extend_from_slice(&crc.to_le_bytes());
        self.buf
    }
}

/// Bounds-checked little-endian reader over a byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Verify the trailing CRC-32 and return a reader over the payload.
    pub fn with_crc(bytes: &'a [u8]) -> Result<Reader<'a>> {
        ensure!(bytes.len() >= 4, "snapshot too short for checksum ({} bytes)", bytes.len());
        let (payload, trailer) = bytes.split_at(bytes.len() - 4);
        let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
        let actual = crc32(payload);
        ensure!(
            stored == actual,
            "snapshot checksum mismatch (stored {stored:#010x}, computed {actual:#010x})"
        );
        Ok(Reader { buf: payload, pos: 0 })
    }

    pub fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { buf: bytes, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("snapshot truncated at byte {} (wanted {} more)", self.pos, n);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        Ok(String::from_utf8(b.to_vec())?)
    }

    pub fn f32_slice(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        // bound sanity before allocating: each element is 4 bytes
        ensure!(
            n <= (self.buf.len() - self.pos) / 4,
            "snapshot declares {n} f32s but only {} bytes remain",
            self.buf.len() - self.pos
        );
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_check_value() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut w = Writer::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f32(1.5);
        w.f64(-2.25);
        w.str("hla2-micro");
        w.f32_slice(&[0.0, -1.0, 3.5]);
        let bytes = w.finish_with_crc();

        let mut r = Reader::with_crc(&bytes).unwrap();
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.str().unwrap(), "hla2-micro");
        assert_eq!(r.f32_slice().unwrap(), vec![0.0, -1.0, 3.5]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn corruption_is_detected() {
        let mut w = Writer::new();
        w.u64(42);
        w.str("payload");
        let mut bytes = w.finish_with_crc();
        bytes[3] ^= 0x40;
        assert!(Reader::with_crc(&bytes).is_err());
    }

    #[test]
    fn truncation_is_detected() {
        let mut w = Writer::new();
        w.f32_slice(&[1.0; 16]);
        let bytes = w.finish_with_crc();
        // cutting the buffer breaks the CRC
        assert!(Reader::with_crc(&bytes[..bytes.len() - 8]).is_err());
        // and even without a CRC, reads past the end fail cleanly
        let mut r = Reader::new(&bytes[..10]);
        assert!(r.f32_slice().is_err());
    }
}
