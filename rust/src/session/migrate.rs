//! Detach / attach / cross-replica migration of lane state.
//!
//! Built on [`StatePool::read_lane`] / [`StatePool::write_lane`]: because a
//! lane's whole prefix is a constant-size tuple, moving a session between
//! replicas is a fixed-size copy — no O(context) KV-cache paging.  With a
//! shared [`super::SessionStore`], cross-replica migration is simply
//! "detach on replica A, restore on replica B"; rebalancing which replica
//! serves the session is a routing decision
//! ([`crate::coordinator::router::Router::pin_session`]).

use crate::coordinator::StatePool;
use crate::model::sampler::Sampler;

use super::snapshot::SamplerState;
use super::{SessionId, SessionSnapshot, SessionStore};

/// Detach one lane of a pool into a snapshot (the read_lane hook).
pub fn detach(
    pool: &StatePool,
    lane: usize,
    id: SessionId,
    cfg_name: &str,
    sampler: &Sampler,
    last_token: u8,
    tokens_generated: u64,
) -> SessionSnapshot {
    SessionSnapshot {
        id,
        cfg_name: cfg_name.to_string(),
        tokens_generated,
        last_token,
        sampler: SamplerState::capture(sampler),
        state: pool.read_lane(lane),
    }
}

/// Restore a snapshot's state into one lane of a pool (the write_lane
/// hook).  Refuses — typed, lane untouched — when the snapshot's state
/// layout does not match the pool's (a snapshot from a different model
/// config would silently corrupt the lane otherwise).
pub fn attach(
    snap: &SessionSnapshot,
    pool: &mut StatePool,
    lane: usize,
) -> Result<(), super::CfgMismatch> {
    snap.ensure_fingerprint(pool.lane_fingerprint())?;
    pool.write_lane(lane, &snap.state);
    Ok(())
}

/// Copy a lane's state directly between two pools (same state layout) —
/// the in-process fast path when both replicas are reachable.
pub fn migrate_lane(src: &StatePool, src_lane: usize, dst: &mut StatePool, dst_lane: usize) {
    let parts = src.read_lane(src_lane);
    dst.write_lane(dst_lane, &parts);
}

/// Move a session's snapshot through the store from one pool to another:
/// detach from `src`, restore into `dst`, counting the migration.  This is
/// the store-mediated path used when replicas do not share an address
/// space (the snapshot bytes are what would cross the wire).
#[allow(clippy::too_many_arguments)]
pub fn migrate_via_store(
    store: &SessionStore,
    id: SessionId,
    cfg_name: &str,
    src: &StatePool,
    src_lane: usize,
    sampler: &Sampler,
    last_token: u8,
    tokens_generated: u64,
    dst: &mut StatePool,
    dst_lane: usize,
) -> anyhow::Result<SessionSnapshot> {
    store.put(detach(src, src_lane, id, cfg_name, sampler, last_token, tokens_generated));
    let snap = store
        .claim(id, Some(cfg_name))
        .ok_or_else(|| anyhow::anyhow!("session {id} vanished mid-migration"))?;
    attach(&snap, dst, dst_lane)?;
    store.migrations.incr();
    Ok(snap)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sampler::SamplerCfg;
    use crate::runtime::{Manifest, ModelCfg};
    use crate::util::rng::Rng;

    fn test_cfg() -> ModelCfg {
        let json = r#"{
          "configs": {"t": {"vocab": 16, "d_model": 8, "n_layers": 2,
            "n_heads": 2, "head_dim": 4, "d_ffn": 32, "kv_heads": 2,
            "mixer": "hla2", "chunk": 4, "gamma": 1.0, "lam": 0.0,
            "norm_mode": "abs", "eps": 1e-6, "n_params": 100,
            "n_param_tensors": 2, "n_state_tensors": 2,
            "param_paths": [["['embed']", [16, 8]]],
            "state_paths": [["['c']", [2, 3, 2, 4, 4]], ["['m']", [2, 3, 2, 4]]],
            "train_batch": 2, "train_seq": 8, "decode_batch": 3,
            "prefill_len": 4}},
          "artifacts": {}
        }"#;
        Manifest::parse(json).unwrap().configs["t"].clone()
    }

    fn filled_pool(cfg: &ModelCfg, seed: u64) -> StatePool {
        let mut pool = StatePool::new(cfg);
        let mut rng = Rng::new(seed);
        for lane in 0..cfg.decode_batch {
            let mut parts = pool.read_lane(lane);
            for t in &mut parts {
                rng.fill_normal(&mut t.data, 1.0);
            }
            pool.write_lane(lane, &parts);
        }
        pool
    }

    #[test]
    fn migrate_lane_moves_exact_bytes() {
        let cfg = test_cfg();
        let src = filled_pool(&cfg, 1);
        let mut dst = StatePool::new(&cfg);
        migrate_lane(&src, 2, &mut dst, 0);
        assert_eq!(dst.read_lane(0), src.read_lane(2));
        // untouched destination lanes stay zero
        assert!(dst.read_lane(1).iter().all(|t| t.data.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn detach_attach_roundtrip() {
        let cfg = test_cfg();
        let pool = filled_pool(&cfg, 2);
        let sampler = Sampler::new(SamplerCfg { temperature: 0.7, top_k: 8, seed: 5 });
        let snap = detach(&pool, 1, 77, "t", &sampler, b'x', 42);
        assert_eq!(snap.state, pool.read_lane(1));
        assert_eq!(snap.state_nbytes(), cfg.state_nbytes_per_seq());

        let mut other = StatePool::new(&cfg);
        attach(&snap, &mut other, 2).unwrap();
        assert_eq!(other.read_lane(2), pool.read_lane(1));
    }

    #[test]
    fn attach_rejects_mismatched_config_typed_and_leaves_lane_untouched() {
        let cfg = test_cfg();
        let pool = filled_pool(&cfg, 4);
        let sampler = Sampler::new(SamplerCfg::greedy());
        let snap = detach(&pool, 0, 5, "t", &sampler, b'a', 1);

        // a destination with a different layer count / head_dim
        let other_json = r#"{
          "configs": {"u": {"vocab": 16, "d_model": 8, "n_layers": 3,
            "n_heads": 2, "head_dim": 8, "d_ffn": 32, "kv_heads": 2,
            "mixer": "hla2", "chunk": 4, "gamma": 1.0, "lam": 0.0,
            "norm_mode": "abs", "eps": 1e-6, "n_params": 100,
            "n_param_tensors": 2, "n_state_tensors": 2,
            "param_paths": [["['embed']", [16, 8]]],
            "state_paths": [["['c']", [3, 3, 2, 8, 8]], ["['m']", [3, 3, 2, 8]]],
            "train_batch": 2, "train_seq": 8, "decode_batch": 3,
            "prefill_len": 4}},
          "artifacts": {}
        }"#;
        let other_cfg = Manifest::parse(other_json).unwrap().configs["u"].clone();
        let mut dst = StatePool::new(&other_cfg);
        let err = attach(&snap, &mut dst, 1).unwrap_err();
        assert_eq!(err.id, 5);
        assert_eq!(err.have, snap.cfg_fingerprint());
        assert_eq!(err.want, dst.lane_fingerprint());
        // the lane was never written
        assert!(dst.read_lane(1).iter().all(|t| t.data.iter().all(|&x| x == 0.0)));
        // same-config destination still attaches
        let mut ok = StatePool::new(&cfg);
        attach(&snap, &mut ok, 1).unwrap();
        assert_eq!(ok.read_lane(1), pool.read_lane(0));
    }

    #[test]
    fn store_mediated_migration() {
        let cfg = test_cfg();
        let src = filled_pool(&cfg, 3);
        let mut dst = StatePool::new(&cfg);
        let store = SessionStore::in_memory(4);
        let sampler = Sampler::new(SamplerCfg::greedy());
        let snap =
            migrate_via_store(&store, 9, "t", &src, 0, &sampler, b'q', 11, &mut dst, 1)
                .unwrap();
        assert_eq!(dst.read_lane(1), src.read_lane(0));
        assert_eq!(snap.tokens_generated, 11);
        assert_eq!(store.stats().migrations, 1);
        assert!(!store.contains(9), "migration consumes the snapshot");
    }
}
