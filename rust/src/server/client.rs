//! Blocking client for the line-JSON serving protocol (examples, benches,
//! and the cluster front-end's control-plane calls).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::metrics::ServeStats;
use crate::util::json::Json;

/// One completed generation with client-side timing.
#[derive(Debug, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: Vec<u8>,
    pub finish: String,
    /// time to first token
    pub ttft: Duration,
    /// total request latency
    pub latency: Duration,
    /// did the server restore a session snapshot for this request?
    pub resumed: bool,
}

/// Request options for [`Client::generate_opts`] (the session-aware path).
#[derive(Debug, Clone)]
pub struct GenOpts {
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: Option<u64>,
    /// Session id: snapshot on completion / resume target / fork child id.
    pub session: Option<u64>,
    /// Restore `session`'s snapshot; the prompt is just the new turn.
    pub resume: bool,
    /// Fork this parent session's snapshot into `session` and resume it.
    pub fork_of: Option<u64>,
    /// Opt into speculative draft/verify/rollback decode (needs a server
    /// running with `--spec-k`; a no-op otherwise).  Lossless: greedy
    /// streams are identical, sampled streams come from the identical
    /// distributions (see the protocol notes in `server/mod.rs`).
    pub spec: bool,
    /// Opt out of the server's shared-prefix cache for this request
    /// (`"no_cache": true` on the wire; a no-op when the server runs
    /// without `--prefix-cache-mb`).  Greedy streams are identical either
    /// way; seeded streams draw from the identical distributions (the
    /// opt-out path scans with a different segmentation — see the
    /// protocol notes in `server/mod.rs`).
    pub no_cache: bool,
    /// Fleet-wide trace id to key the request's spans by (`"trace_id"` on
    /// the wire, shipped as 16 hex digits — full u64s do not survive the
    /// f64 round-trip).  Usually minted by the cluster front-end; set it
    /// here to correlate client-side calls with server spans.
    pub trace: Option<u64>,
    /// Per-token streaming (the default and the historical behavior).
    /// `false` sends `"stream": false`: the server buffers and the whole
    /// completion arrives on the single done line — same bytes, one
    /// read, no mid-stream state to resume if the connection drops.
    pub stream: bool,
}

/// The server refused admission with its typed `overloaded` reply
/// (`--max-queue` backpressure).  Carried inside the [`anyhow::Error`]
/// chain so callers can downcast and retry instead of treating it as a
/// hard failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverloadedError {
    /// In-flight requests the server observed when it refused.
    pub queue_depth: u64,
}

impl std::fmt::Display for OverloadedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server overloaded ({} requests in flight); retry later", self.queue_depth)
    }
}

impl std::error::Error for OverloadedError {}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts {
            max_tokens: 32,
            temperature: 0.0,
            top_k: 0,
            seed: None,
            session: None,
            resume: false,
            fork_of: None,
            spec: false,
            no_cache: false,
            trace: None,
            stream: true,
        }
    }
}

/// A persistent connection to the HLA server.
///
/// By default reads block forever (the historical behavior: a hung
/// replica stalls the caller indefinitely).  [`Client::connect_timeout`]
/// caps every read; a timed-out **admin** round-trip gets one retry on a
/// fresh connection after a backoff (admin requests are idempotent
/// single-line exchanges).  Generations are never retried — replaying a
/// non-idempotent request is the caller's decision (the cluster front-end
/// does it deliberately, with token-prefix suppression).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: String,
    timeout: Option<Duration>,
    backoff: Duration,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Self::from_stream(stream, addr, None)
    }

    /// Connect with `timeout` applied to the dial and to every subsequent
    /// read.  A read that exceeds it fails with a timeout error instead of
    /// hanging the caller forever.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        let sock = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow!("{addr}: no usable socket address"))?;
        let stream = TcpStream::connect_timeout(&sock, timeout)?;
        Self::from_stream(stream, addr, Some(timeout))
    }

    fn from_stream(stream: TcpStream, addr: &str, timeout: Option<Duration>) -> Result<Client> {
        stream.set_nodelay(true)?;
        stream.set_read_timeout(timeout)?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
            addr: addr.to_string(),
            timeout,
            backoff: Duration::from_millis(100),
        })
    }

    /// Change the read timeout (`None` = block forever).
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(timeout)?;
        self.timeout = timeout;
        Ok(())
    }

    /// Backoff slept before the single admin retry (default 100ms).
    pub fn set_retry_backoff(&mut self, backoff: Duration) {
        self.backoff = backoff;
    }

    /// Drop the (possibly wedged) connection and dial the same address
    /// again with the same timeout configuration.
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = match self.timeout {
            Some(t) => Client::connect_timeout(&self.addr, t)?,
            None => Client::connect(&self.addr)?,
        };
        self.reader = fresh.reader;
        self.writer = fresh.writer;
        Ok(())
    }

    /// Submit a prompt and stream the whole completion.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
        session: Option<u64>,
    ) -> Result<Completion> {
        self.generate_opts(
            prompt,
            &GenOpts { max_tokens, temperature, session, ..GenOpts::default() },
        )
    }

    /// Submit a prompt with full session options (resume / fork).
    pub fn generate_opts(&mut self, prompt: &str, opts: &GenOpts) -> Result<Completion> {
        let mut req = vec![
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(opts.max_tokens as f64)),
            ("temperature", Json::num(opts.temperature as f64)),
        ];
        if opts.top_k > 0 {
            req.push(("top_k", Json::num(opts.top_k as f64)));
        }
        if let Some(seed) = opts.seed {
            req.push(("seed", Json::num(seed as f64)));
        }
        if let Some(s) = opts.session {
            req.push(("session", Json::num(s as f64)));
        }
        if opts.resume {
            req.push(("resume", Json::Bool(true)));
        }
        if let Some(parent) = opts.fork_of {
            req.push(("fork_of", Json::num(parent as f64)));
        }
        if opts.spec {
            req.push(("spec", Json::Bool(true)));
        }
        if opts.no_cache {
            req.push(("no_cache", Json::Bool(true)));
        }
        if let Some(t) = opts.trace {
            req.push(("trace_id", Json::str(format!("{t:016x}"))));
        }
        if !opts.stream {
            req.push(("stream", Json::Bool(false)));
        }
        let start = Instant::now();
        writeln!(self.writer, "{}", Json::obj(req))?;

        let mut tokens = Vec::new();
        let mut ttft = None;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("server closed connection mid-response"));
            }
            let msg = Json::parse(&line).map_err(|e| anyhow!("bad server line: {e}"))?;
            if let Some(err) = msg.get("error").and_then(Json::as_str) {
                // the typed backpressure refusal rides the error line with
                // extra fields; surface it as a downcastable error
                if msg.get("overloaded").and_then(Json::as_bool) == Some(true) {
                    let depth =
                        msg.get("queue_depth").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                    return Err(OverloadedError { queue_depth: depth }.into());
                }
                return Err(anyhow!("server error: {err}"));
            }
            if let Some(tok) = msg.get("token").and_then(Json::as_i64) {
                if ttft.is_none() {
                    ttft = Some(start.elapsed());
                }
                tokens.push(tok as u8);
            }
            if msg.get("done").and_then(Json::as_bool) == Some(true) {
                // buffered mode: the done line carries the whole completion
                if let Some(arr) = msg.get("tokens").and_then(Json::as_arr) {
                    tokens = arr.iter().filter_map(Json::as_f64).map(|f| f as u8).collect();
                }
                let finish =
                    msg.get("finish").and_then(Json::as_str).unwrap_or("unknown").to_string();
                let resumed = msg.get("resumed").and_then(Json::as_bool).unwrap_or(false);
                return Ok(Completion {
                    text: String::from_utf8_lossy(&tokens).to_string(),
                    tokens,
                    finish,
                    ttft: ttft.unwrap_or_else(|| start.elapsed()),
                    latency: start.elapsed(),
                    resumed,
                });
            }
        }
    }

    /// Send one admin request line and read the single reply line.  With a
    /// read timeout configured, a timed-out exchange is retried exactly
    /// once on a fresh connection after [`Self::set_retry_backoff`]'s
    /// pause (admin exchanges are idempotent, so the resend is safe even
    /// if the hung server consumed the first request).
    fn admin(&mut self, req: Json) -> Result<Json> {
        match self.admin_once(&req) {
            Err(e) if self.timeout.is_some() && is_timeout(&e) => {
                std::thread::sleep(self.backoff);
                self.reconnect()?;
                self.admin_once(&req).map_err(|e2| {
                    anyhow!("server at {} unresponsive (timed out, retried once): {e2}", self.addr)
                })
            }
            other => other,
        }
    }

    fn admin_once(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed connection mid-response"));
        }
        let msg = Json::parse(&line).map_err(|e| anyhow!("bad server line: {e}"))?;
        if let Some(err) = msg.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(msg)
    }

    /// Fetch the server's live, fleet-merged stats snapshot (the `"stats"`
    /// admin request).  Safe to call mid-generation from a *separate*
    /// connection; on this connection, call it only between generations.
    pub fn stats(&mut self) -> Result<ServeStats> {
        let msg = self.admin(Json::obj(vec![("stats", Json::Bool(true))]))?;
        let stats = msg.get("stats").ok_or_else(|| anyhow!("stats reply missing \"stats\""))?;
        Ok(ServeStats::from_json(stats))
    }

    /// Fetch the whole one-line stats reply, untyped.  `hla top` uses this
    /// to see the sections a front-end router adds alongside the merged
    /// fleet snapshot (`"router"`, `"replicas"`, `"skipped"`) that the
    /// typed [`Self::stats`] accessor deliberately ignores.
    pub fn stats_reply(&mut self) -> Result<Json> {
        self.admin(Json::obj(vec![("stats", Json::Bool(true))]))
    }

    /// Fetch the stats snapshot rendered as Prometheus exposition text.
    pub fn stats_prometheus(&mut self) -> Result<String> {
        let msg = self.admin(Json::obj(vec![("stats", Json::str("prometheus"))]))?;
        msg.get("stats_text")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("stats reply missing \"stats_text\""))
    }

    // --- control plane (cluster mode; see PROTOCOL.md "Control plane") ---

    /// REGISTER: learn the replica's model identity.  Returns
    /// `(cfg_name, cfg_fingerprint)`.
    pub fn register(&mut self) -> Result<(String, u64)> {
        let msg = self.admin(Json::obj(vec![("control", Json::str("register"))]))?;
        let cfg = msg
            .get("cfg")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("register reply missing \"cfg\""))?
            .to_string();
        let fp = msg
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("register reply missing \"fingerprint\""))?;
        let fp = u64::from_str_radix(fp, 16)
            .map_err(|_| anyhow!("register reply: bad fingerprint {fp:?}"))?;
        Ok((cfg, fp))
    }

    /// HEALTH: liveness probe; returns the replica's in-flight count.
    pub fn health(&mut self) -> Result<u64> {
        let msg = self.admin(Json::obj(vec![("control", Json::str("health"))]))?;
        msg.get("in_flight")
            .and_then(Json::as_f64)
            .map(|f| f as u64)
            .ok_or_else(|| anyhow!("health reply missing \"in_flight\""))
    }

    /// DETACH_SESSION: pull a session's CRC-framed snapshot bytes off the
    /// replica.  With `keep` the replica retains its copy (a read-only
    /// export); without, the snapshot is consumed (a true detach).
    pub fn detach_session(&mut self, session: u64, keep: bool) -> Result<Vec<u8>> {
        let mut req = vec![
            ("control", Json::str("detach_session")),
            ("session", Json::num(session as f64)),
        ];
        if keep {
            req.push(("keep", Json::Bool(true)));
        }
        let msg = self.admin(Json::obj(req))?;
        let b64 = msg
            .get("snapshot")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("detach reply missing \"snapshot\""))?;
        crate::util::b64::decode(b64).map_err(|e| anyhow!("detach reply: {e}"))
    }

    /// ATTACH_SESSION: hand a snapshot frame to the replica.  The replica
    /// verifies CRC, format version and config fingerprint before its
    /// store accepts the session; returns the attached session id.
    pub fn attach_session(&mut self, snapshot: &[u8]) -> Result<u64> {
        let msg = self.admin(Json::obj(vec![
            ("control", Json::str("attach_session")),
            ("snapshot", Json::str(crate::util::b64::encode(snapshot))),
        ]))?;
        msg.get("session")
            .and_then(Json::as_f64)
            .map(|f| f as u64)
            .ok_or_else(|| anyhow!("attach reply missing \"session\""))
    }

    /// DRAIN: enumerate the sessions resident on the replica so the caller
    /// can evacuate them (detach each, attach elsewhere).
    pub fn drain(&mut self) -> Result<Vec<u64>> {
        let msg = self.admin(Json::obj(vec![("control", Json::str("drain"))]))?;
        let arr = msg
            .get("sessions")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("drain reply missing \"sessions\""))?;
        let mut ids = Vec::with_capacity(arr.len());
        for v in arr {
            match v.as_f64() {
                Some(f) => ids.push(f as u64),
                None => bail!("drain reply: non-numeric session id"),
            }
        }
        Ok(ids)
    }

    /// TRACE_EXPORT: pull the server's span ring (the stitcher's input).
    /// Returns the export payload (`hla-trace/1`: name, anchor, spans);
    /// works against replicas and front-end routers alike.
    pub fn trace_export(&mut self) -> Result<Json> {
        let msg = self.admin(Json::obj(vec![("control", Json::str("trace_export"))]))?;
        msg.get("trace")
            .cloned()
            .ok_or_else(|| anyhow!("trace_export reply missing \"trace\""))
    }

    /// Fetch the tail of a front-end router's structured event log
    /// (`{"events": n}` on the wire); returns the reply's `"events"`
    /// array.  Replicas do not keep an event log — this is router-only.
    pub fn events(&mut self, n: usize) -> Result<Vec<Json>> {
        let msg = self.admin(Json::obj(vec![("events", Json::num(n as f64))]))?;
        msg.get("events")
            .and_then(Json::as_arr)
            .map(<[Json]>::to_vec)
            .ok_or_else(|| anyhow!("events reply missing \"events\""))
    }
}

/// Does this error chain bottom out in a read timeout?
fn is_timeout(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>()
        .map(|io| {
            matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
        })
        .unwrap_or(false)
}
