//! Blocking client for the line-JSON serving protocol (examples + benches).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::metrics::ServeStats;
use crate::util::json::Json;

/// One completed generation with client-side timing.
#[derive(Debug, Clone)]
pub struct Completion {
    pub text: String,
    pub tokens: Vec<u8>,
    pub finish: String,
    /// time to first token
    pub ttft: Duration,
    /// total request latency
    pub latency: Duration,
    /// did the server restore a session snapshot for this request?
    pub resumed: bool,
}

/// Request options for [`Client::generate_opts`] (the session-aware path).
#[derive(Debug, Clone)]
pub struct GenOpts {
    pub max_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: Option<u64>,
    /// Session id: snapshot on completion / resume target / fork child id.
    pub session: Option<u64>,
    /// Restore `session`'s snapshot; the prompt is just the new turn.
    pub resume: bool,
    /// Fork this parent session's snapshot into `session` and resume it.
    pub fork_of: Option<u64>,
    /// Opt into speculative draft/verify/rollback decode (needs a server
    /// running with `--spec-k`; a no-op otherwise).  Lossless: greedy
    /// streams are identical, sampled streams come from the identical
    /// distributions (see the protocol notes in `server/mod.rs`).
    pub spec: bool,
    /// Opt out of the server's shared-prefix cache for this request
    /// (`"no_cache": true` on the wire; a no-op when the server runs
    /// without `--prefix-cache-mb`).  Greedy streams are identical either
    /// way; seeded streams draw from the identical distributions (the
    /// opt-out path scans with a different segmentation — see the
    /// protocol notes in `server/mod.rs`).
    pub no_cache: bool,
}

impl Default for GenOpts {
    fn default() -> Self {
        GenOpts {
            max_tokens: 32,
            temperature: 0.0,
            top_k: 0,
            seed: None,
            session: None,
            resume: false,
            fork_of: None,
            spec: false,
            no_cache: false,
        }
    }
}

/// A persistent connection to the HLA server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Submit a prompt and stream the whole completion.
    pub fn generate(
        &mut self,
        prompt: &str,
        max_tokens: usize,
        temperature: f32,
        session: Option<u64>,
    ) -> Result<Completion> {
        self.generate_opts(
            prompt,
            &GenOpts { max_tokens, temperature, session, ..GenOpts::default() },
        )
    }

    /// Submit a prompt with full session options (resume / fork).
    pub fn generate_opts(&mut self, prompt: &str, opts: &GenOpts) -> Result<Completion> {
        let mut req = vec![
            ("prompt", Json::str(prompt)),
            ("max_tokens", Json::num(opts.max_tokens as f64)),
            ("temperature", Json::num(opts.temperature as f64)),
        ];
        if opts.top_k > 0 {
            req.push(("top_k", Json::num(opts.top_k as f64)));
        }
        if let Some(seed) = opts.seed {
            req.push(("seed", Json::num(seed as f64)));
        }
        if let Some(s) = opts.session {
            req.push(("session", Json::num(s as f64)));
        }
        if opts.resume {
            req.push(("resume", Json::Bool(true)));
        }
        if let Some(parent) = opts.fork_of {
            req.push(("fork_of", Json::num(parent as f64)));
        }
        if opts.spec {
            req.push(("spec", Json::Bool(true)));
        }
        if opts.no_cache {
            req.push(("no_cache", Json::Bool(true)));
        }
        let start = Instant::now();
        writeln!(self.writer, "{}", Json::obj(req))?;

        let mut tokens = Vec::new();
        let mut ttft = None;
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(anyhow!("server closed connection mid-response"));
            }
            let msg = Json::parse(&line).map_err(|e| anyhow!("bad server line: {e}"))?;
            if let Some(err) = msg.get("error").and_then(Json::as_str) {
                return Err(anyhow!("server error: {err}"));
            }
            if let Some(tok) = msg.get("token").and_then(Json::as_i64) {
                if ttft.is_none() {
                    ttft = Some(start.elapsed());
                }
                tokens.push(tok as u8);
            }
            if msg.get("done").and_then(Json::as_bool) == Some(true) {
                let finish =
                    msg.get("finish").and_then(Json::as_str).unwrap_or("unknown").to_string();
                let resumed = msg.get("resumed").and_then(Json::as_bool).unwrap_or(false);
                return Ok(Completion {
                    text: String::from_utf8_lossy(&tokens).to_string(),
                    tokens,
                    finish,
                    ttft: ttft.unwrap_or_else(|| start.elapsed()),
                    latency: start.elapsed(),
                    resumed,
                });
            }
        }
    }

    /// Send one admin request line and read the single reply line.
    fn admin(&mut self, req: Json) -> Result<Json> {
        writeln!(self.writer, "{req}")?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed connection mid-response"));
        }
        let msg = Json::parse(&line).map_err(|e| anyhow!("bad server line: {e}"))?;
        if let Some(err) = msg.get("error").and_then(Json::as_str) {
            return Err(anyhow!("server error: {err}"));
        }
        Ok(msg)
    }

    /// Fetch the server's live, fleet-merged stats snapshot (the `"stats"`
    /// admin request).  Safe to call mid-generation from a *separate*
    /// connection; on this connection, call it only between generations.
    pub fn stats(&mut self) -> Result<ServeStats> {
        let msg = self.admin(Json::obj(vec![("stats", Json::Bool(true))]))?;
        let stats = msg.get("stats").ok_or_else(|| anyhow!("stats reply missing \"stats\""))?;
        Ok(ServeStats::from_json(stats))
    }

    /// Fetch the stats snapshot rendered as Prometheus exposition text.
    pub fn stats_prometheus(&mut self) -> Result<String> {
        let msg = self.admin(Json::obj(vec![("stats", Json::str("prometheus"))]))?;
        msg.get("stats_text")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow!("stats reply missing \"stats_text\""))
    }
}
