//! TCP serving frontend: newline-delimited JSON over a plain socket.
//!
//! Protocol (one JSON object per line):
//!   → `{"prompt": "...", "max_tokens": 32, "temperature": 0.8,
//!      "top_k": 40, "seed": 7, "session": 123}`
//!   ← `{"token": 104, "text": "h"}`           (streamed, one per token)
//!   ← `{"done": true, "finish": "length", "n": 32}`  (final)
//!
//! The listener accepts on a std TcpListener; each connection gets a
//! handler thread that submits to the [`Router`] and forwards token events
//! back down the socket.  `shutdown` drops the router (closing all engine
//! channels) so engine loops drain and exit.

pub mod client;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::router::Router;
use crate::coordinator::{FinishReason, GenRequest};
use crate::model::sampler::SamplerCfg;
use crate::util::json::Json;

/// Serve until `stop` is set.  Returns the bound address immediately via
/// the callback so tests can connect to an ephemeral port.
pub fn serve(
    addr: &str,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let router = router.clone();
                // handlers are detached: they exit when their client hangs
                // up (read_line returns 0), so shutdown never blocks on a
                // connection that is idle but still open.
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, &router);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, router: &Router) -> Result<()> {
    let peer = stream.peer_addr()?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match handle_request(&line, router, &mut writer) {
            Ok(()) => {}
            Err(e) => {
                let err = Json::obj(vec![("error", Json::str(e.to_string()))]);
                writeln!(writer, "{err}")?;
            }
        }
    }
    log::debug!("connection from {peer} closed");
    Ok(())
}

fn handle_request(line: &str, router: &Router, writer: &mut TcpStream) -> Result<()> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    let prompt = req.get("prompt").and_then(Json::as_str).unwrap_or("").as_bytes().to_vec();
    let max_tokens = req.get("max_tokens").and_then(Json::as_usize).unwrap_or(32).clamp(1, 4096);
    let sampler = SamplerCfg {
        temperature: req.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        top_k: req.get("top_k").and_then(Json::as_usize).unwrap_or(0),
        seed: req.get("seed").and_then(Json::as_i64).unwrap_or(0) as u64,
    };
    let session = req.get("session").and_then(Json::as_i64).map(|s| s as u64);

    let (tx, rx) = std::sync::mpsc::channel();
    let id = router.fresh_id();
    let replica = router.submit(GenRequest::new(id, prompt, max_tokens, sampler, tx), session)?;

    let mut n = 0usize;
    let mut finish = FinishReason::Aborted;
    while let Ok(ev) = rx.recv() {
        if let Some(tok) = ev.token {
            n += 1;
            let text = String::from_utf8_lossy(&[tok]).to_string();
            let msg = Json::obj(vec![
                ("token", Json::num(tok as f64)),
                ("text", Json::str(text)),
            ]);
            writeln!(writer, "{msg}")?;
        }
        if ev.done {
            finish = ev.finish.unwrap_or(FinishReason::Aborted);
            break;
        }
    }
    router.complete(replica);
    let fin = match finish {
        FinishReason::Length => "length",
        FinishReason::Eos => "eos",
        FinishReason::Aborted => "aborted",
    };
    let msg = Json::obj(vec![
        ("done", Json::Bool(true)),
        ("finish", Json::str(fin)),
        ("n", Json::num(n as f64)),
    ]);
    writeln!(writer, "{msg}")?;
    Ok(())
}
