//! TCP serving frontend: newline-delimited JSON over a plain socket.
//!
//! Protocol (one JSON object per line):
//!   → `{"prompt": "...", "max_tokens": 32, "temperature": 0.8,
//!      "top_k": 40, "seed": 7, "session": 123}`
//!   ← `{"token": 104, "text": "h"}`           (streamed, one per token)
//!   ← `{"done": true, "finish": "length", "n": 32,
//!      "session": 123, "resumed": false}`     (final; session fields only
//!                                              when a session id was sent)
//!
//! Streaming modes (`"stream"`, optional):
//!   * absent or `true` — per-token lines followed by the done line, as
//!     above (the historical wire behavior; existing clients and the
//!     cluster front-end relay are unaffected).  Streamed requests ride
//!     a *bounded* event channel: a client that stops reading (or hangs
//!     up) eventually fills it, the engine's non-blocking send fails,
//!     and the lane aborts instead of buffering without limit — one
//!     slow reader cannot stall the batch or grow the heap.
//!   * `false` — buffered: no per-token lines; the single done line
//!     additionally carries `"text"` (the full completion) and
//!     `"tokens"` (the byte values).  Same bytes, one write.
//!
//! Admission control: when the router is serving with a bounded queue
//! (`--max-queue N`), a request arriving with N requests already in
//! flight is refused with the one-line typed reply
//! `{"error": "...", "overloaded": true, "queue_depth": <n>}` and
//! nothing is generated.  Completions drain in-flight immediately
//! (drain-before-reject), so the refusal is momentary backpressure —
//! clients retry, ideally with jitter.
//!
//! Session extension (requires serving with a session store, see
//! [`serve_sessions`]; each field is optional):
//!   * `"session": <id>` — tag the request; on completion the lane's
//!     constant-size HLA state is snapshotted into the store under `<id>`.
//!   * `"resume": true` — restore `<id>`'s snapshot before generating, so
//!     `"prompt"` carries only the new turn's text (it may be empty or
//!     absent to continue generation in place).  The resumed sampler keeps
//!     the snapshot's config and exact RNG position: the token stream is
//!     identical to one uninterrupted generation.  Unknown `<id>` →
//!     `{"error": "unknown session <id>"}` and nothing is generated.
//!   * `"fork_of": <parent>` — copy-on-snapshot fork: `<parent>`'s state
//!     is duplicated under `"session"` (required) at O(state) cost and the
//!     request resumes the fork.  `"seed"` reseeds the fork's sampler so N
//!     forks of one shared prompt prefix diverge.  Unknown parent →
//!     `{"error": "unknown session <parent>"}`.
//!
//! Speculative decoding extension (requires serving with `--spec-k`):
//!   * `"spec": true` — opt this request into speculative
//!     draft/verify/rollback decode.  The acceptance rule is lossless:
//!     greedy requests emit the identical token stream, and sampled
//!     requests draw from the identical distributions — draw-for-draw
//!     identical under the serial verify backend, while the default
//!     chunked-scan verify (and the pure-Rust twin it samples on, vs.
//!     the artifact) can shift a draw at an f32 probability boundary
//!     without changing the distribution.  Without a spec engine
//!     attached the flag is a no-op, not an error.
//!
//! Prefix-cache extension (requires serving with `--prefix-cache-mb`):
//!   * `"no_cache": true` — opt this request out of the shared-prefix
//!     cache: its prompt is prefill-scanned cold and contributes no
//!     boundary snapshots (for prompts carrying per-user material a
//!     shared cache must not retain).  Exactness: warm and cold runs of
//!     the *cached* path are byte-identical, greedy and seeded alike;
//!     the opt-out path scans with a different segmentation, so vs. the
//!     cached path greedy streams are identical while a seeded draw at
//!     an f32 probability boundary can shift without changing the
//!     distribution — the same caveat as the chunked-scan verify
//!     backend (`rust/tests/prefix_cache_differential.rs`).  Without a
//!     cache attached the flag is a no-op, not an error.  Resumed
//!     sessions always bypass the cache (their restored state already
//!     encodes private history).
//!
//! Tracing extension:
//!   * `"trace_id": "<16 hex digits>"` — key this request's spans by a
//!     fleet-wide trace id (minted by the cluster front-end, or supplied
//!     by a client correlating its own calls) instead of the process-
//!     local request id, so `hla trace-stitch` can line the request up
//!     across router and replica processes.  Hex string, not a number: a
//!     full u64 does not survive the f64 round-trip.  Malformed values
//!     are rejected with a one-line error; without a tracer attached the
//!     field is validated and otherwise ignored.
//!
//! Stats extension (requires serving with a live registry, see
//! [`serve_full`]; an admin request, not a generation — no tokens flow):
//!   * `{"stats": true}` — one-line reply `{"stats": {...}, "replicas": N}`
//!     where the payload is the [`ServeStats`] wire JSON form
//!     ([`ServeStats::to_json`]), merged across every replica's live
//!     registry *as of now* — issue it mid-generation from a second
//!     connection and the counters are current, not end-of-run.
//!   * `{"stats": "prometheus"}` — same snapshot as Prometheus text
//!     exposition, carried in `{"stats_text": "...", "replicas": N}` so
//!     the protocol stays one JSON object per line.
//!
//! Control-plane extension (requires serving in cluster mode, see
//! [`serve_cluster`]; admin requests issued by the front-end router, not
//! by clients — documented in `docs/PROTOCOL.md` § Control plane):
//!   * `{"control": "register"}` — identity handshake: config name, the
//!     64-bit state-layout fingerprint (hex string — a u64 does not
//!     survive the f64 round-trip), and per-session state bytes.
//!   * `{"control": "health"}` — liveness probe; replies with the
//!     replica's total in-flight request count.
//!   * `{"control": "detach_session", "session": id, "keep": true}` —
//!     export `<id>`'s snapshot frame as base64 (`keep` peeks; omitted,
//!     the snapshot is consumed).
//!   * `{"control": "attach_session", "snapshot": "<b64>"}` — import a
//!     snapshot frame: CRC/version checked, fingerprint checked against
//!     this replica's config, then stored for the next `resume`.
//!   * `{"control": "drain"}` — list every resident session id so the
//!     front-end can detach them before retiring the replica.
//!   * `{"control": "trace_export"}` — ship the replica's span ring
//!     (decoded spans plus a unix-microsecond anchor) so the front-end
//!     can stitch one fleet-wide Chrome trace.  Unlike the other verbs
//!     this works on any traced server, cluster mode or not.
//!
//! Error replies are one-line objects: `{"error": "<reason>"}` — sent for
//! malformed JSON, resume/fork without a session store, `fork_of` without
//! a `"session"` id, unknown sessions, out-of-range ids, `stats`
//! requests against a server without a registry, and `control` requests
//! against a server not in cluster mode.  Session ids are JSON
//! numbers and must be integers in `[0, 2^53)` — larger values do not
//! survive the f64 round-trip and are rejected.
//!
//! The listener accepts on a std TcpListener; each connection gets a
//! handler thread that submits to the [`Router`] and forwards token events
//! back down the socket.  `shutdown` drops the router (closing all engine
//! channels) so engine loops drain and exit.

pub mod client;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::router::{Router, SubmitError};
use crate::coordinator::{EventSink, FinishReason, GenRequest, TokenEvent};
use crate::metrics::trace::{export_rings_json, Tracer};
use crate::metrics::{LiveStats, ServeStats};
use crate::model::sampler::SamplerCfg;
use crate::session::SessionStore;
use crate::util::json::Json;

/// Event-channel depth for streamed requests.  Generously sized so a
/// momentarily slow reader (GC pause, scheduler hiccup) never trips it,
/// yet bounded so a reader that has genuinely stopped draining turns
/// into a failed engine-side send — and an aborted lane — instead of an
/// unbounded heap of undelivered tokens.
const STREAM_EVENT_BUFFER: usize = 256;

/// The observability handles a server exposes: one live registry per
/// engine replica (index-aligned with the router's replicas).  The
/// `"stats"` admin request merges them into one fleet-wide snapshot.
pub struct ServeObs {
    pub stats: Vec<Arc<LiveStats>>,
    /// Span rings, one per traced engine replica (empty when serving
    /// without `--trace-out`).  The `trace_export` control verb merges
    /// them into one wire payload for cross-process stitching.
    pub tracers: Vec<Arc<Tracer>>,
}

impl ServeObs {
    /// Handles for an untraced server (stats only).
    pub fn stats_only(stats: Vec<Arc<LiveStats>>) -> ServeObs {
        ServeObs { stats, tracers: vec![] }
    }
}

/// What a replica tells the cluster front-end about itself on `register`:
/// enough to route compatible sessions to it and budget migrations.  The
/// fingerprint is [`crate::session::state_fingerprint`] over one lane's
/// state layout — two replicas attach each other's snapshots iff it
/// matches.
pub struct ReplicaIdentity {
    pub cfg_name: String,
    pub cfg_fingerprint: u64,
    /// Per-session snapshot payload size ([`crate::runtime::ModelCfg::state_nbytes_per_seq`]).
    pub state_bytes: usize,
}

/// Serve until `stop` is set (stateless: no session snapshot/resume).
/// Returns the bound address immediately via the callback so tests can
/// connect to an ephemeral port.
pub fn serve(
    addr: &str,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_sessions(addr, router, None, stop, on_bound)
}

/// [`serve`] with an optional session store enabling the `resume` /
/// `fork_of` protocol fields.  Pass the same store the engine replicas
/// were spawned with ([`crate::coordinator::spawn_engine_with_store`]).
pub fn serve_sessions(
    addr: &str,
    router: Arc<Router>,
    sessions: Option<Arc<SessionStore>>,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_full(addr, router, sessions, None, stop, on_bound)
}

/// [`serve_sessions`] with the observability handles: pass the replicas'
/// live registries ([`ServeObs`]) to enable the `"stats"` admin request.
pub fn serve_full(
    addr: &str,
    router: Arc<Router>,
    sessions: Option<Arc<SessionStore>>,
    obs: Option<Arc<ServeObs>>,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    serve_cluster(addr, router, sessions, obs, None, stop, on_bound)
}

/// [`serve_full`] plus a cluster identity: enables the `"control"` admin
/// verbs (`register` / `health` / `detach_session` / `attach_session` /
/// `drain`) so a [`crate::cluster`] front-end can health-check this
/// replica and move sessions on and off it over the wire.  A session
/// store is required for the session-moving verbs to succeed.
pub fn serve_cluster(
    addr: &str,
    router: Arc<Router>,
    sessions: Option<Arc<SessionStore>>,
    obs: Option<Arc<ServeObs>>,
    identity: Option<Arc<ReplicaIdentity>>,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let router = router.clone();
                let sessions = sessions.clone();
                let obs = obs.clone();
                let identity = identity.clone();
                // handlers are detached: they exit when their client hangs
                // up (read_line returns 0), so shutdown never blocks on a
                // connection that is idle but still open.
                std::thread::spawn(move || {
                    let _ = handle_conn(
                        stream,
                        &router,
                        sessions.as_deref(),
                        obs.as_deref(),
                        identity.as_deref(),
                    );
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn handle_conn(
    stream: TcpStream,
    router: &Router,
    sessions: Option<&SessionStore>,
    obs: Option<&ServeObs>,
    identity: Option<&ReplicaIdentity>,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match handle_request(&line, router, sessions, obs, identity, &mut writer) {
            Ok(()) => {}
            Err(e) => {
                let err = Json::obj(vec![("error", Json::str(e.to_string()))]);
                writeln!(writer, "{err}")?;
            }
        }
    }
    log::debug!("connection from {peer} closed");
    Ok(())
}

/// The `"stats"` admin request: merge every replica's live registry and
/// reply in the requested form.  One line out, no token stream.
fn handle_stats(fmt: &Json, obs: Option<&ServeObs>, writer: &mut TcpStream) -> Result<()> {
    let obs = obs.ok_or_else(|| anyhow!("stats: serving without a live metrics registry"))?;
    let merged: ServeStats = LiveStats::merged(&obs.stats);
    let replicas = Json::num(obs.stats.len() as f64);
    let msg = match fmt {
        Json::Bool(true) => {
            Json::obj(vec![("stats", merged.to_json()), ("replicas", replicas)])
        }
        Json::Str(s) if s == "json" => {
            Json::obj(vec![("stats", merged.to_json()), ("replicas", replicas)])
        }
        Json::Str(s) if s == "prometheus" => Json::obj(vec![
            ("stats_text", Json::str(merged.to_prometheus())),
            ("replicas", replicas),
        ]),
        other => return Err(anyhow!("stats: want true, \"json\" or \"prometheus\", got {other}")),
    };
    writeln!(writer, "{msg}")?;
    Ok(())
}

/// The `"control"` admin verbs: the cluster front-end's side-channel for
/// identity, liveness, and wire-level session migration.  Snapshot frames
/// travel base64-inside-JSON so the line protocol stays printable; the
/// frame's own CRC + the config fingerprint guard the payload, so a
/// corrupted or foreign snapshot is rejected before it can reach a lane.
fn handle_control(
    verb: &Json,
    req: &Json,
    router: &Router,
    sessions: Option<&SessionStore>,
    obs: Option<&ServeObs>,
    identity: Option<&ReplicaIdentity>,
    writer: &mut TcpStream,
) -> Result<()> {
    let verb = verb.as_str().ok_or_else(|| anyhow!("control: verb must be a string"))?;
    // trace_export needs the observability handles, not a cluster
    // identity: any traced server can hand its span ring over.
    if verb == "trace_export" {
        let rings: Vec<&Tracer> = obs.map_or(vec![], |o| {
            o.tracers.iter().map(|t| t.as_ref()).collect()
        });
        if rings.is_empty() {
            return Err(anyhow!("trace_export: serving without a tracer"));
        }
        let msg = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("trace", export_rings_json("replica", &rings)),
        ]);
        writeln!(writer, "{msg}")?;
        return Ok(());
    }
    let identity = identity
        .ok_or_else(|| anyhow!("control: not serving in cluster mode (no replica identity)"))?;
    let need_store = || {
        sessions.ok_or_else(|| anyhow!("control: {verb}: serving without a session store"))
    };
    let msg = match verb {
        "register" => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("cfg", Json::str(&identity.cfg_name)),
            // u64 fingerprints do not survive the f64 round-trip; ship hex
            ("fingerprint", Json::str(format!("{:016x}", identity.cfg_fingerprint))),
            ("state_bytes", Json::num(identity.state_bytes as f64)),
        ]),
        "health" => {
            let in_flight: usize =
                (0..router.n_replicas()).map(|i| router.in_flight(i)).sum();
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("in_flight", Json::num(in_flight as f64)),
            ])
        }
        "detach_session" => {
            let store = need_store()?;
            let sid = parse_session_id(req, "session")?
                .ok_or_else(|| anyhow!("detach_session requires a \"session\" id"))?;
            let keep = req.get("keep").and_then(Json::as_bool).unwrap_or(false);
            // keep=true copies the snapshot out (the front-end refreshing
            // its failover desk); without it the detach is a move and the
            // session no longer lives here.
            let snap = if keep { store.peek(sid) } else { store.claim(sid, None) }
                .ok_or_else(|| anyhow!("unknown session {sid}"))?;
            Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("session", Json::num(sid as f64)),
                ("snapshot", Json::str(crate::util::b64::encode(&snap.to_bytes()))),
            ])
        }
        "attach_session" => {
            let store = need_store()?;
            let b64 = req
                .get("snapshot")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("attach_session requires a \"snapshot\" payload"))?;
            let bytes = crate::util::b64::decode(b64)
                .map_err(|e| anyhow!("attach_session: bad base64: {e}"))?;
            let snap = crate::session::SessionSnapshot::from_bytes(&bytes)
                .map_err(|e| anyhow!("attach_session: bad snapshot frame: {e}"))?;
            snap.ensure_fingerprint(identity.cfg_fingerprint)?;
            let sid = snap.id;
            store.put(snap);
            Json::obj(vec![("ok", Json::Bool(true)), ("session", Json::num(sid as f64))])
        }
        "drain" => {
            let store = need_store()?;
            let ids: Vec<Json> =
                store.ids().into_iter().map(|id| Json::num(id as f64)).collect();
            Json::obj(vec![("ok", Json::Bool(true)), ("sessions", Json::Arr(ids))])
        }
        other => return Err(anyhow!("control: unknown verb {other:?}")),
    };
    writeln!(writer, "{msg}")?;
    Ok(())
}

/// Session ids ride in JSON numbers, so only integers below 2^53 survive
/// the f64 round-trip exactly; reject anything else rather than silently
/// storing a snapshot under a corrupted id.
fn parse_session_id(req: &Json, key: &str) -> Result<Option<u64>> {
    match req.get(key).and_then(Json::as_f64) {
        None => Ok(None),
        Some(s) if s >= 0.0 && s.fract() == 0.0 && s < 9_007_199_254_740_992.0 => {
            Ok(Some(s as u64))
        }
        Some(s) => Err(anyhow!("{key} must be an integer in [0, 2^53), got {s}")),
    }
}

fn handle_request(
    line: &str,
    router: &Router,
    sessions: Option<&SessionStore>,
    obs: Option<&ServeObs>,
    identity: Option<&ReplicaIdentity>,
    writer: &mut TcpStream,
) -> Result<()> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request: {e}"))?;
    // admin requests short-circuit before any generation fields parse
    if let Some(verb) = req.get("control") {
        return handle_control(verb, &req, router, sessions, obs, identity, writer);
    }
    if let Some(fmt) = req.get("stats") {
        return handle_stats(fmt, obs, writer);
    }
    let prompt = req.get("prompt").and_then(Json::as_str).unwrap_or("").as_bytes().to_vec();
    let max_tokens = req.get("max_tokens").and_then(Json::as_usize).unwrap_or(32).clamp(1, 4096);
    // seeds ride in JSON numbers like ids do, so they get the same exact-
    // integer validation (a rounded seed would silently collide forks)
    let seed = parse_session_id(&req, "seed")?;
    let sampler = SamplerCfg {
        temperature: req.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32,
        top_k: req.get("top_k").and_then(Json::as_usize).unwrap_or(0),
        seed: seed.unwrap_or(0),
    };
    let session = parse_session_id(&req, "session")?;
    let resume = req.get("resume").and_then(Json::as_bool).unwrap_or(false);
    let fork_of = parse_session_id(&req, "fork_of")?;

    // session-extension validation: fail fast with an error reply rather
    // than admitting a lane that cannot restore
    let mut resume_requested = false;
    if let Some(parent) = fork_of {
        let store = sessions.ok_or_else(|| anyhow!("fork_of: serving without a session store"))?;
        let child =
            session.ok_or_else(|| anyhow!("fork_of requires a \"session\" id for the fork"))?;
        store.fork(parent, child, seed).map_err(|_| anyhow!("unknown session {parent}"))?;
        resume_requested = true;
    } else if resume {
        let store = sessions.ok_or_else(|| anyhow!("resume: serving without a session store"))?;
        let sid = session.ok_or_else(|| anyhow!("resume requires a \"session\" id"))?;
        if !store.contains(sid) {
            return Err(anyhow!("unknown session {sid}"));
        }
        resume_requested = true;
    }

    // `"stream": false` opts into the buffered single-reply mode; absent
    // or true is the historical per-token wire behavior.
    let stream = req.get("stream").and_then(Json::as_bool).unwrap_or(true);
    // Streamed requests get a bounded event channel (slow-reader
    // backpressure: the engine aborts the lane rather than buffer for a
    // reader that cannot keep up).  Buffered requests keep an unbounded
    // channel — this thread drains it eagerly, no socket in the loop.
    let (sink, rx): (EventSink, std::sync::mpsc::Receiver<TokenEvent>) = if stream {
        let (tx, rx) = std::sync::mpsc::sync_channel(STREAM_EVENT_BUFFER);
        (tx.into(), rx)
    } else {
        let (tx, rx) = std::sync::mpsc::channel();
        (tx.into(), rx)
    };
    // the disconnect path: a failed socket write flips this flag and the
    // engine frees the lane at its next cycle (mid-prefill included)
    let cancel = Arc::new(AtomicBool::new(false));
    let id = router.fresh_id();
    let mut greq =
        GenRequest::new(id, prompt, max_tokens, sampler, sink).with_cancel(cancel.clone());
    if let Some(sid) = session {
        greq = greq.with_session(sid);
    }
    if resume_requested {
        greq = greq.resuming();
    }
    if req.get("spec").and_then(Json::as_bool).unwrap_or(false) {
        greq = greq.with_spec();
    }
    if req.get("no_cache").and_then(Json::as_bool).unwrap_or(false) {
        greq = greq.without_cache();
    }
    // the optional distributed trace id: 16 hex digits, because a full
    // u64 does not survive the f64 round-trip JSON numbers take (same
    // discipline as the register fingerprint)
    match req.get("trace_id") {
        None => {}
        Some(Json::Str(s)) if s.len() == 16 && s.bytes().all(|b| b.is_ascii_hexdigit()) => {
            let id = u64::from_str_radix(s, 16)
                .map_err(|e| anyhow!("trace_id: {e}"))?;
            greq = greq.with_trace(id);
        }
        Some(other) => {
            return Err(anyhow!("trace_id must be a 16-hex-digit string, got {other}"));
        }
    }
    let replica = match router.try_submit(greq, session) {
        Ok(idx) => idx,
        Err(SubmitError::Overloaded { queue_depth }) => {
            // typed backpressure, not a generic error: clients distinguish
            // "retry later" from "your request is malformed"
            let msg = Json::obj(vec![
                ("error", Json::str(format!(
                    "overloaded: {queue_depth} requests in flight"
                ))),
                ("overloaded", Json::Bool(true)),
                ("queue_depth", Json::num(queue_depth as f64)),
            ]);
            writeln!(writer, "{msg}")?;
            return Ok(());
        }
        Err(e @ SubmitError::ReplicaGone(_)) => return Err(e.into()),
    };

    let mut n = 0usize;
    let mut finish = FinishReason::Aborted;
    // ground truth from the engine: a requested resume can still degrade
    // to a fresh lane (snapshot evicted/incompatible by admission time)
    let mut resumed = false;
    let mut body: Vec<u8> = vec![];
    let mut client_gone = false;
    while let Ok(ev) = rx.recv() {
        if let Some(tok) = ev.token {
            n += 1;
            if !stream {
                body.push(tok);
            } else if !client_gone {
                let text = String::from_utf8_lossy(&[tok]).to_string();
                let msg = Json::obj(vec![
                    ("token", Json::num(tok as f64)),
                    ("text", Json::str(text)),
                ]);
                if writeln!(writer, "{msg}").is_err() {
                    // the client hung up mid-stream: cancel the lane (the
                    // engine frees it within a cycle) and keep draining the
                    // channel so the final event still arrives
                    cancel.store(true, Ordering::Relaxed);
                    client_gone = true;
                }
            }
        }
        if ev.done {
            finish = ev.finish.unwrap_or(FinishReason::Aborted);
            resumed = ev.resumed;
            break;
        }
    }
    router.complete(replica);
    if client_gone {
        // nobody is listening for the done line; the accounting above is
        // what mattered
        return Ok(());
    }
    let fin = match finish {
        FinishReason::Length => "length",
        FinishReason::Eos => "eos",
        FinishReason::Aborted => "aborted",
    };
    let mut done = vec![
        ("done", Json::Bool(true)),
        ("finish", Json::str(fin)),
        ("n", Json::num(n as f64)),
    ];
    if !stream {
        // buffered mode: the whole completion rides the done line
        done.push(("text", Json::str(String::from_utf8_lossy(&body).to_string())));
        done.push(("tokens", Json::Arr(body.iter().map(|&b| Json::num(b as f64)).collect())));
    }
    if let Some(sid) = session {
        done.push(("session", Json::num(sid as f64)));
        done.push(("resumed", Json::Bool(resumed)));
    }
    let msg = Json::obj(done);
    writeln!(writer, "{msg}")?;
    Ok(())
}
