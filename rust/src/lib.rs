//! # hla — Higher-order Linear Attention, reproduced as a serving/training framework
//!
//! A production-shaped reproduction of *Higher-order Linear Attention*
//! (Zhang, Qin, Wang, Gu, 2025) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas chunk kernels, AOT-lowered.
//! * **L2** (`python/compile/model.py`) — JAX HLA transformer (fwd/bwd,
//!   prefill, decode), exported as HLO text artifacts.
//! * **L3** (this crate) — the runtime and coordinator: PJRT execution of
//!   the artifacts, continuous-batching decode with constant-size HLA
//!   state at an occupancy-adaptive batch width (`coordinator::bucket` /
//!   `coordinator::repack`), a chunk-parallel prompt-ingestion engine
//!   (`prefill`), a session snapshot/resume/fork store (`session`), a
//!   shared-prefix radix cache reusing constant-size prefix states
//!   across requests (`cache`), a speculative decoding engine with
//!   draft/verify/rollback over the constant-size state (`spec`), a
//!   live observability layer (`metrics`: shared stats registry,
//!   request-span tracing, persisted perf trajectory), a cluster
//!   front-end routing the wire protocol across replica processes with
//!   wire-level session migration and mid-stream failover (`cluster`),
//!   a training driver, plus a from-scratch reimplementation of the
//!   paper's full algebra (`hla`) used for verification and CPU
//!   baselines.
//!
//! See `rust/DESIGN.md` for the system inventory, the `rust/benches/`
//! E-series (E1–E19) for the paper-claim ↔ measurement map,
//! `rust/docs/ARCHITECTURE.md` for one request walked end to end through
//! the serving stack, and `rust/docs/PROTOCOL.md` for the wire format.

pub mod attention;
pub mod bench;
pub mod cache;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod hla;
pub mod model;
pub mod prefill;
pub mod runtime;
pub mod server;
pub mod session;
pub mod spec;
pub mod train;
pub mod workload;
pub mod metrics;
pub mod tensor;
pub mod testing;
pub mod util;
