//! Token sampling policies for generation.

use crate::tensor::ops;
use crate::util::rng::Rng;

/// Sampling configuration for a generation request.
#[derive(Debug, Clone)]
pub struct SamplerCfg {
    pub temperature: f32,
    /// 0 disables top-k truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg { temperature: 1.0, top_k: 0, seed: 0 }
    }
}

impl SamplerCfg {
    pub fn greedy() -> Self {
        SamplerCfg { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// Stateful sampler (owns its RNG stream).
#[derive(Debug, Clone)]
pub struct Sampler {
    pub cfg: SamplerCfg,
    rng: Rng,
}

impl Sampler {
    pub fn new(cfg: SamplerCfg) -> Sampler {
        let rng = Rng::new(cfg.seed);
        Sampler { cfg, rng }
    }

    /// Capture the RNG stream position (session snapshot / exact resume).
    pub fn rng_parts(&self) -> (u64, Option<f64>) {
        self.rng.parts()
    }

    /// Rebuild a sampler mid-stream from [`Sampler::rng_parts`]; sampling
    /// continues exactly where the captured sampler left off.
    pub fn from_parts(cfg: SamplerCfg, state: u64, spare: Option<f64>) -> Sampler {
        Sampler { cfg, rng: Rng::from_parts(state, spare) }
    }

    /// Sample a token id from raw logits.
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        if self.cfg.temperature <= 0.0 {
            return argmax(logits);
        }
        let mut probs: Vec<f32> =
            logits.iter().map(|&l| l / self.cfg.temperature).collect();
        if self.cfg.top_k > 0 && self.cfg.top_k < probs.len() {
            // mask everything below the k-th largest logit
            let mut sorted: Vec<f32> = probs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let cutoff = sorted[self.cfg.top_k - 1];
            for p in probs.iter_mut() {
                if *p < cutoff {
                    *p = f32::NEG_INFINITY;
                }
            }
        }
        ops::softmax_inplace(&mut probs);
        self.rng.categorical(&probs)
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplerCfg::greedy());
        assert_eq!(s.sample(&[0.1, 5.0, -2.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(SamplerCfg { temperature: 1.0, top_k: 2, seed: 7 });
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        for _ in 0..50 {
            let t = s.sample(&logits);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn rng_parts_resume_exact() {
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, seed: 11 };
        let mut a = Sampler::new(cfg.clone());
        let logits = vec![1.0f32, 0.5, 0.2, 0.9];
        for _ in 0..7 {
            a.sample(&logits);
        }
        let (state, spare) = a.rng_parts();
        let mut b = Sampler::from_parts(cfg, state, spare);
        for _ in 0..32 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn temperature_flattens() {
        let logits = vec![2.0, 0.0];
        let mut hot = Sampler::new(SamplerCfg { temperature: 10.0, top_k: 0, seed: 1 });
        let mut cold = Sampler::new(SamplerCfg { temperature: 0.05, top_k: 0, seed: 1 });
        let count = |s: &mut Sampler| (0..500).filter(|_| s.sample(&logits) == 1).count();
        let hot_minor = count(&mut hot);
        let cold_minor = count(&mut cold);
        assert!(hot_minor > 100, "{hot_minor}");
        assert!(cold_minor < 10, "{cold_minor}");
    }
}
