//! Token sampling policies for generation.
//!
//! Besides drawing tokens, the sampler exposes the draft-vs-target
//! acceptance primitives speculative decoding needs ([`Sampler::prob_of`],
//! [`Sampler::u01`], [`Sampler::sample_residual`]) and the exact RNG
//! stream capture/restore that keeps speculative rollback and session
//! resume in lockstep with uninterrupted decode
//! ([`Sampler::rng_parts`]/[`Sampler::from_parts`]).

use crate::tensor::ops;
use crate::util::rng::Rng;

/// Sampling configuration for a generation request.
#[derive(Debug, Clone)]
pub struct SamplerCfg {
    pub temperature: f32,
    /// 0 disables top-k truncation.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplerCfg {
    fn default() -> Self {
        SamplerCfg { temperature: 1.0, top_k: 0, seed: 0 }
    }
}

impl SamplerCfg {
    pub fn greedy() -> Self {
        SamplerCfg { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

/// The decision a sampler config induces over one logits row: greedy /
/// temperature-0 collapses to a point mass, everything else to a softmax
/// distribution.  [`Sampler::sample`], [`Sampler::prob_of`] and
/// [`Sampler::sample_residual`] all branch on this one value, so the
/// greedy and stochastic paths share a single code path and cannot drift.
enum Decision {
    Point(usize),
    Probs(Vec<f32>),
}

/// Stateful sampler (owns its RNG stream).
#[derive(Debug, Clone)]
pub struct Sampler {
    pub cfg: SamplerCfg,
    rng: Rng,
}

impl Sampler {
    pub fn new(cfg: SamplerCfg) -> Sampler {
        let rng = Rng::new(cfg.seed);
        Sampler { cfg, rng }
    }

    /// Capture the RNG stream position (session snapshot / exact resume).
    pub fn rng_parts(&self) -> (u64, Option<f64>) {
        self.rng.parts()
    }

    /// Rebuild a sampler mid-stream from [`Sampler::rng_parts`]; sampling
    /// continues exactly where the captured sampler left off.
    pub fn from_parts(cfg: SamplerCfg, state: u64, spare: Option<f64>) -> Sampler {
        Sampler { cfg, rng: Rng::from_parts(state, spare) }
    }

    fn decision(&self, logits: &[f32]) -> Decision {
        if self.cfg.temperature <= 0.0 {
            return Decision::Point(argmax(logits));
        }
        let mut probs: Vec<f32> =
            logits.iter().map(|&l| l / self.cfg.temperature).collect();
        if self.cfg.top_k > 0 && self.cfg.top_k < probs.len() {
            // mask everything below the k-th largest logit
            let mut sorted: Vec<f32> = probs.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let cutoff = sorted[self.cfg.top_k - 1];
            for p in probs.iter_mut() {
                if *p < cutoff {
                    *p = f32::NEG_INFINITY;
                }
            }
        }
        ops::softmax_inplace(&mut probs);
        Decision::Probs(probs)
    }

    /// Sample a token id from raw logits.  Greedy consumes no randomness;
    /// otherwise exactly one uniform draw is spent per call — the
    /// invariant speculative verification relies on (one draw per
    /// *emitted* token, in stream order).
    pub fn sample(&mut self, logits: &[f32]) -> usize {
        match self.decision(logits) {
            Decision::Point(i) => i,
            Decision::Probs(p) => self.rng.categorical(&p),
        }
    }

    /// Probability this sampler assigns `token` under its temperature /
    /// top-k distribution over `logits` — the target side of the
    /// draft-vs-target acceptance test.  Consumes no randomness.
    pub fn prob_of(&self, logits: &[f32], token: usize) -> f32 {
        match self.decision(logits) {
            Decision::Point(i) => {
                if i == token {
                    1.0
                } else {
                    0.0
                }
            }
            Decision::Probs(p) => p.get(token).copied().unwrap_or(0.0),
        }
    }

    /// One seeded uniform draw in [0, 1) from the sampler's own stream —
    /// the acceptance coin of the two-draw rejection-sampling rule.
    pub fn u01(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Sample from the renormalized residual `max(0, p − δ_rejected)` —
    /// the resample half of the lossless rejection-sampling rule for a
    /// point-mass draft distribution (Chen et al., 2023).
    pub fn sample_residual(&mut self, logits: &[f32], rejected: usize) -> usize {
        match self.decision(logits) {
            // the residual of one point mass minus another is the point
            // mass itself (the rule only rejects when they differ)
            Decision::Point(i) => i,
            Decision::Probs(mut p) => {
                if rejected < p.len() {
                    p[rejected] = 0.0;
                }
                self.rng.categorical(&p)
            }
        }
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplerCfg::greedy());
        assert_eq!(s.sample(&[0.1, 5.0, -2.0]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = Sampler::new(SamplerCfg { temperature: 1.0, top_k: 2, seed: 7 });
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        for _ in 0..50 {
            let t = s.sample(&logits);
            assert!(t < 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn rng_parts_resume_exact() {
        let cfg = SamplerCfg { temperature: 1.0, top_k: 0, seed: 11 };
        let mut a = Sampler::new(cfg.clone());
        let logits = vec![1.0f32, 0.5, 0.2, 0.9];
        for _ in 0..7 {
            a.sample(&logits);
        }
        let (state, spare) = a.rng_parts();
        let mut b = Sampler::from_parts(cfg, state, spare);
        for _ in 0..32 {
            assert_eq!(a.sample(&logits), b.sample(&logits));
        }
    }

    #[test]
    fn temperature_flattens() {
        let logits = vec![2.0, 0.0];
        let mut hot = Sampler::new(SamplerCfg { temperature: 10.0, top_k: 0, seed: 1 });
        let mut cold = Sampler::new(SamplerCfg { temperature: 0.05, top_k: 0, seed: 1 });
        let count = |s: &mut Sampler| (0..500).filter(|_| s.sample(&logits) == 1).count();
        let hot_minor = count(&mut hot);
        let cold_minor = count(&mut cold);
        assert!(hot_minor > 100, "{hot_minor}");
        assert!(cold_minor < 10, "{cold_minor}");
    }

    #[test]
    fn prob_of_is_a_distribution_and_matches_masking() {
        let logits = vec![2.0f32, 1.0, 0.5, -3.0];
        // greedy: point mass on the argmax
        let g = Sampler::new(SamplerCfg::greedy());
        assert_eq!(g.prob_of(&logits, 0), 1.0);
        assert_eq!(g.prob_of(&logits, 1), 0.0);
        assert_eq!(g.prob_of(&logits, 99), 0.0, "out-of-range token has probability 0");
        // stochastic: sums to 1, monotone in the logits, respects top-k
        let s = Sampler::new(SamplerCfg { temperature: 0.7, top_k: 2, seed: 3 });
        let total: f32 = (0..logits.len()).map(|t| s.prob_of(&logits, t)).sum();
        assert!((total - 1.0).abs() < 1e-5, "{total}");
        assert!(s.prob_of(&logits, 0) > s.prob_of(&logits, 1));
        assert_eq!(s.prob_of(&logits, 2), 0.0, "token below the top-k cutoff");
        assert_eq!(s.prob_of(&logits, 3), 0.0);
    }

    #[test]
    fn residual_never_returns_the_rejected_token() {
        let logits = vec![3.0f32, 2.9, -1.0, -1.0];
        let mut s = Sampler::new(SamplerCfg { temperature: 1.0, top_k: 0, seed: 5 });
        for _ in 0..100 {
            assert_ne!(s.sample_residual(&logits, 0), 0);
        }
        // greedy residual is the argmax itself (rule only fires on mismatch)
        let mut g = Sampler::new(SamplerCfg::greedy());
        assert_eq!(g.sample_residual(&logits, 1), 0);
    }

    #[test]
    fn from_parts_roundtrips_mid_stream() {
        // speculative rollback + session resume both rebuild samplers via
        // from_parts(rng_parts()) mid-stream; a desync here would silently
        // fork resumed token streams, so: property-test it across configs,
        // stream positions and interleavings of every draw primitive
        crate::testing::quick("sampler-from-parts-roundtrip", 48, |rng, _| {
            let temps = [0.0f32, 0.5, 1.0, 2.0];
            let ks = [0usize, 1, 3, 8];
            let cfg = SamplerCfg {
                temperature: temps[rng.below(temps.len())],
                top_k: ks[rng.below(ks.len())],
                seed: rng.next_u64(),
            };
            let mut logits = vec![0f32; 16];
            let mut a = Sampler::new(cfg.clone());
            for _ in 0..rng.below(20) {
                rng.fill_normal(&mut logits, 2.0);
                let _ = a.sample(&logits);
                if rng.bool(0.3) {
                    let _ = a.u01();
                }
            }
            let (state, spare) = a.rng_parts();
            let mut b = Sampler::from_parts(cfg, state, spare);
            for step in 0..32 {
                rng.fill_normal(&mut logits, 2.0);
                if a.sample(&logits) != b.sample(&logits) {
                    return Err(format!("sample stream diverged at step {step}"));
                }
                if a.u01() != b.u01() {
                    return Err(format!("u01 stream diverged at step {step}"));
                }
                if a.prob_of(&logits, 3) != b.prob_of(&logits, 3) {
                    return Err(format!("prob_of diverged at step {step}"));
                }
            }
            Ok(())
        });
    }
}
