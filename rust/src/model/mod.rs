//! Pure-Rust HLA transformer (reference + CPU serving baseline).
//!
//! Mirrors `python/compile/model.py` exactly: same parameter layout (via the
//! manifest's tree-flatten order), same RMSNorm/SwiGLU/tied-head block, same
//! mixer semantics (delegating to `crate::hla`).  Used to
//! * verify the AOT HLO path end-to-end (integration test: Rust forward ==
//!   `fwd_<cfg>` artifact logits), and
//! * serve as the no-XLA CPU decode baseline in benches.

pub mod params;
pub mod pool;
pub mod sampler;

use anyhow::{bail, ensure, Result};

use crate::attention::{KvCache, LinearAttnState};
use crate::hla::ahla::AhlaState;
use crate::hla::state2::Hla2State;
use crate::hla::state3::Hla3State;
use crate::hla::{HlaOptions, NormMode};
use crate::runtime::ModelCfg;
use crate::tensor::{ops, Mat, Tensor};
pub use params::RustModel;

/// Per-head recurrent mixer state (the serving state).
#[derive(Debug, Clone)]
pub enum MixerState {
    Hla2(Hla2State<f32>),
    Ahla(AhlaState<f32>),
    Hla3(Hla3State<f32>),
    Linear(LinearAttnState<f32>),
    /// Softmax baseline: the KV-cache grows with context length.
    Softmax(KvCache),
}

impl MixerState {
    pub fn new(mixer: &str, dh: usize) -> MixerState {
        match mixer {
            "hla2" => MixerState::Hla2(Hla2State::new(dh, dh)),
            "ahla" => MixerState::Ahla(AhlaState::new(dh, dh)),
            "hla3" => MixerState::Hla3(Hla3State::new(dh, dh)),
            "linear" => MixerState::Linear(LinearAttnState::new(dh, dh)),
            "softmax" => MixerState::Softmax(KvCache::new()),
            other => panic!("unknown mixer {other:?}"),
        }
    }

    pub fn nbytes(&self) -> usize {
        match self {
            MixerState::Hla2(s) => s.nbytes(),
            MixerState::Ahla(s) => s.nbytes(),
            MixerState::Hla3(s) => s.nbytes(),
            MixerState::Linear(s) => s.nbytes(),
            MixerState::Softmax(c) => c.nbytes(),
        }
    }

    /// Flatten to one contiguous f32 vector — the session-snapshot carrier
    /// (fields in declaration order).  Errors on the softmax baseline: its
    /// KV-cache grows with context, which is exactly the cost HLA's
    /// constant-size state lets snapshot/resume avoid.
    pub fn state_vec(&self) -> Result<Vec<f32>> {
        let mut out = vec![];
        match self {
            MixerState::Hla2(s) => {
                out.extend_from_slice(&s.s.data);
                out.extend_from_slice(&s.c.data);
                out.extend_from_slice(&s.m);
                out.extend_from_slice(&s.g.data);
                out.extend_from_slice(&s.h);
            }
            MixerState::Ahla(s) => {
                out.extend_from_slice(&s.p.data);
                out.extend_from_slice(&s.m);
                out.extend_from_slice(&s.e.data);
                out.extend_from_slice(&s.n);
            }
            MixerState::Hla3(s) => {
                out.extend_from_slice(&s.s.data);
                out.extend_from_slice(&s.p.data);
                out.extend_from_slice(&s.m);
                out.extend_from_slice(&s.f.data);
                out.extend_from_slice(&s.eta);
            }
            MixerState::Linear(s) => {
                out.extend_from_slice(&s.p.data);
                out.extend_from_slice(&s.m);
            }
            MixerState::Softmax(_) => {
                bail!("softmax KV-cache is O(context); it has no constant-size snapshot")
            }
        }
        Ok(out)
    }

    /// Restore from a [`MixerState::state_vec`] flat vector (shapes come
    /// from the receiver, which must have been built for the same config).
    pub fn load_state_vec(&mut self, mut data: &[f32]) -> Result<()> {
        fn take<'a>(data: &mut &'a [f32], dst: &mut [f32]) -> Result<()> {
            ensure!(data.len() >= dst.len(), "state vector too short");
            let (a, b) = data.split_at(dst.len());
            dst.copy_from_slice(a);
            *data = b;
            Ok(())
        }
        match self {
            MixerState::Hla2(s) => {
                take(&mut data, &mut s.s.data)?;
                take(&mut data, &mut s.c.data)?;
                take(&mut data, &mut s.m)?;
                take(&mut data, &mut s.g.data)?;
                take(&mut data, &mut s.h)?;
            }
            MixerState::Ahla(s) => {
                take(&mut data, &mut s.p.data)?;
                take(&mut data, &mut s.m)?;
                take(&mut data, &mut s.e.data)?;
                take(&mut data, &mut s.n)?;
            }
            MixerState::Hla3(s) => {
                take(&mut data, &mut s.s.data)?;
                take(&mut data, &mut s.p.data)?;
                take(&mut data, &mut s.m)?;
                take(&mut data, &mut s.f.data)?;
                take(&mut data, &mut s.eta)?;
            }
            MixerState::Linear(s) => {
                take(&mut data, &mut s.p.data)?;
                take(&mut data, &mut s.m)?;
            }
            MixerState::Softmax(_) => {
                bail!("softmax KV-cache is O(context); it has no constant-size snapshot")
            }
        }
        ensure!(data.is_empty(), "{} trailing floats in state vector", data.len());
        Ok(())
    }

    /// Borrow one named state component (the manifest's `state_paths`
    /// field names, e.g. `"s"`/`"c"`/`"m"`/`"g"`/`"h"` for hla2) — the
    /// glue between this per-head state and the artifact's stacked
    /// `[L, B, H, ...]` component tensors.
    pub fn component(&self, name: &str) -> Result<&[f32]> {
        let slice: Option<&[f32]> = match (self, name) {
            (MixerState::Hla2(s), "s") => Some(&s.s.data),
            (MixerState::Hla2(s), "c") => Some(&s.c.data),
            (MixerState::Hla2(s), "m") => Some(&s.m),
            (MixerState::Hla2(s), "g") => Some(&s.g.data),
            (MixerState::Hla2(s), "h") => Some(&s.h),
            (MixerState::Ahla(s), "p") => Some(&s.p.data),
            (MixerState::Ahla(s), "m") => Some(&s.m),
            (MixerState::Ahla(s), "e") => Some(&s.e.data),
            (MixerState::Ahla(s), "n") => Some(&s.n),
            (MixerState::Hla3(s), "s") => Some(&s.s.data),
            (MixerState::Hla3(s), "p") => Some(&s.p.data),
            (MixerState::Hla3(s), "m") => Some(&s.m),
            (MixerState::Hla3(s), "f") => Some(&s.f.data),
            (MixerState::Hla3(s), "eta") => Some(&s.eta),
            (MixerState::Linear(s), "p") => Some(&s.p.data),
            (MixerState::Linear(s), "m") => Some(&s.m),
            _ => None,
        };
        slice.ok_or_else(|| anyhow::anyhow!("mixer has no state component {name:?}"))
    }

    /// Mutable twin of [`MixerState::component`].
    pub fn component_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        let slice: Option<&mut [f32]> = match (self, name) {
            (MixerState::Hla2(s), "s") => Some(&mut s.s.data),
            (MixerState::Hla2(s), "c") => Some(&mut s.c.data),
            (MixerState::Hla2(s), "m") => Some(&mut s.m),
            (MixerState::Hla2(s), "g") => Some(&mut s.g.data),
            (MixerState::Hla2(s), "h") => Some(&mut s.h),
            (MixerState::Ahla(s), "p") => Some(&mut s.p.data),
            (MixerState::Ahla(s), "m") => Some(&mut s.m),
            (MixerState::Ahla(s), "e") => Some(&mut s.e.data),
            (MixerState::Ahla(s), "n") => Some(&mut s.n),
            (MixerState::Hla3(s), "s") => Some(&mut s.s.data),
            (MixerState::Hla3(s), "p") => Some(&mut s.p.data),
            (MixerState::Hla3(s), "m") => Some(&mut s.m),
            (MixerState::Hla3(s), "f") => Some(&mut s.f.data),
            (MixerState::Hla3(s), "eta") => Some(&mut s.eta),
            (MixerState::Linear(s), "p") => Some(&mut s.p.data),
            (MixerState::Linear(s), "m") => Some(&mut s.m),
            _ => None,
        };
        slice.ok_or_else(|| anyhow::anyhow!("mixer has no state component {name:?}"))
    }

    /// One token through one head: update state, produce the head output.
    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], opts: &HlaOptions<f32>) -> Vec<f32> {
        match self {
            MixerState::Hla2(s) => {
                s.step(q, k, v, opts.gamma);
                s.output(q, opts)
            }
            MixerState::Ahla(s) => {
                s.step(q, k, v, opts.gamma);
                s.output(q, opts)
            }
            MixerState::Hla3(s) => {
                s.step(q, k, v, opts.gamma);
                s.output(q, opts)
            }
            MixerState::Linear(s) => {
                s.step(k, v, opts.gamma);
                s.output(q, opts.norm, opts.eps)
            }
            MixerState::Softmax(c) => c.step(q, k, v, 1.0),
        }
    }
}

/// Whole-model recurrent state: `[n_layers][n_heads]`.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub layers: Vec<Vec<MixerState>>,
}

impl ModelState {
    pub fn new(cfg: &ModelCfg) -> ModelState {
        ModelState {
            layers: (0..cfg.n_layers)
                .map(|_| (0..cfg.n_heads).map(|_| MixerState::new(&cfg.mixer, cfg.head_dim)).collect())
                .collect(),
        }
    }

    pub fn nbytes(&self) -> usize {
        self.layers.iter().flatten().map(|s| s.nbytes()).sum()
    }

    /// Serialize as one tensor per (layer, head) — the carrier format of
    /// [`crate::session::SessionSnapshot`] for the pure-Rust decode path.
    pub fn to_tensors(&self) -> Result<Vec<Tensor>> {
        self.layers
            .iter()
            .flatten()
            .map(|m| {
                let v = m.state_vec()?;
                Ok(Tensor::from_vec(&[v.len()], v))
            })
            .collect()
    }

    /// Restore from [`ModelState::to_tensors`] parts (receiver must be a
    /// fresh state for the same config).
    pub fn load_tensors(&mut self, parts: &[Tensor]) -> Result<()> {
        let n: usize = self.layers.iter().map(|l| l.len()).sum();
        ensure!(parts.len() == n, "state arity mismatch: {} tensors for {n} heads", parts.len());
        for (m, part) in self.layers.iter_mut().flatten().zip(parts) {
            m.load_state_vec(&part.data)?;
        }
        Ok(())
    }

    /// Serialize in the *artifact's* component layout: one tensor per
    /// `state_paths` entry, shaped `[L, 1, H, ...]` (a single decode
    /// lane's slice) — the format `StatePool::read_lane`/`write_lane` and
    /// the coordinator's state literals speak.  Fails if the manifest's
    /// components do not cover the mixer's full state, so a lossy
    /// round-trip is impossible.
    pub fn to_components(&self, cfg: &ModelCfg) -> Result<Vec<Tensor>> {
        let (l, h) = (cfg.n_layers, cfg.n_heads);
        let mut total = 0usize;
        let parts = cfg
            .state_paths
            .iter()
            .map(|(path, shape)| {
                let name = parse_state_path(path)?;
                ensure!(
                    shape.len() >= 3 && shape[0] == l && shape[2] == h,
                    "state component {path}: shape {shape:?} is not [L, B, H, ...]"
                );
                let rest: usize = shape[3..].iter().product();
                let mut out_shape = shape.clone();
                out_shape[1] = 1;
                let mut out = Tensor::zeros(&out_shape);
                for (li, layer) in self.layers.iter().enumerate() {
                    for (hi, head) in layer.iter().enumerate() {
                        let src = head.component(&name)?;
                        ensure!(
                            src.len() == rest,
                            "state component {path}: {} floats per head, shape wants {rest}",
                            src.len()
                        );
                        let dst = (li * h + hi) * rest;
                        out.data[dst..dst + rest].copy_from_slice(src);
                        total += rest;
                    }
                }
                Ok(out)
            })
            .collect::<Result<Vec<Tensor>>>()?;
        let want: usize =
            self.layers.iter().flatten().map(|m| m.state_vec().map(|v| v.len())).sum::<Result<usize>>()?;
        ensure!(
            total == want,
            "state_paths cover {total} floats but the mixer state holds {want}"
        );
        Ok(parts)
    }

    /// Restore from [`ModelState::to_components`]-layout tensors (also the
    /// layout of coordinator session snapshots).
    pub fn load_components(&mut self, cfg: &ModelCfg, parts: &[Tensor]) -> Result<()> {
        ensure!(
            parts.len() == cfg.state_paths.len(),
            "component arity mismatch: {} tensors for {} state paths",
            parts.len(),
            cfg.state_paths.len()
        );
        let h = cfg.n_heads;
        for ((path, shape), part) in cfg.state_paths.iter().zip(parts) {
            let name = parse_state_path(path)?;
            ensure!(
                shape.len() >= 3,
                "state component {path}: shape {shape:?} is not [L, B, H, ...]"
            );
            let rest: usize = shape[3..].iter().product();
            ensure!(
                part.data.len() == cfg.n_layers * h * rest,
                "state component {path}: {} floats for a lane slice of {}",
                part.data.len(),
                cfg.n_layers * h * rest
            );
            for (li, layer) in self.layers.iter_mut().enumerate() {
                for (hi, head) in layer.iter_mut().enumerate() {
                    let dst = head.component_mut(&name)?;
                    let src = (li * h + hi) * rest;
                    dst.copy_from_slice(&part.data[src..src + rest]);
                }
            }
        }
        Ok(())
    }
}

/// Copy one lane's slice between batched `[L, B, ...]` component tensors
/// whose batch widths may differ — the single primitive behind
/// [`crate::coordinator::StatePool`] lane reads/writes and the
/// coordinator's occupancy-adaptive state repack.  Bytes move verbatim
/// (`copy_from_slice` on the f32 payload), so a lane carried through any
/// chain of copies is bit-identical to the original: the exactness anchor
/// of `tests/bucketing_differential.rs`.
///
/// Panics (debug) on rank/shape mismatch; lanes must be in range.
pub fn copy_component_lane(src: &Tensor, src_lane: usize, dst: &mut Tensor, dst_lane: usize) {
    let l = src.shape[0];
    let (bs, bd) = (src.shape[1], dst.shape[1]);
    let rest: usize = src.shape[2..].iter().product();
    debug_assert_eq!(dst.shape[0], l, "layer-count mismatch");
    debug_assert_eq!(&dst.shape[2..], &src.shape[2..], "per-lane shape mismatch");
    assert!(src_lane < bs && dst_lane < bd, "lane out of range ({src_lane}/{bs}, {dst_lane}/{bd})");
    for li in 0..l {
        let s = (li * bs + src_lane) * rest;
        let d = (li * bd + dst_lane) * rest;
        dst.data[d..d + rest].copy_from_slice(&src.data[s..s + rest]);
    }
}

/// Zero one lane's slice of a batched `[L, B, ...]` component tensor
/// (admission reset; other lanes untouched).
pub fn zero_component_lane(comp: &mut Tensor, lane: usize) {
    let l = comp.shape[0];
    let batch = comp.shape[1];
    let rest: usize = comp.shape[2..].iter().product();
    assert!(lane < batch, "lane {lane} out of range (batch {batch})");
    for li in 0..l {
        let off = (li * batch + lane) * rest;
        comp.data[off..off + rest].fill(0.0);
    }
}

/// Extract lane `lane` of every batched component into `[L, 1, ...]`
/// parts — the session-snapshot / spec-activation read path.
pub fn slice_components(comps: &[Tensor], lane: usize) -> Vec<Tensor> {
    comps
        .iter()
        .map(|comp| {
            let mut shape = comp.shape.clone();
            shape[1] = 1;
            let mut out = Tensor::zeros(&shape);
            copy_component_lane(comp, lane, &mut out, 0);
            out
        })
        .collect()
}

/// Write `[L, 1, ...]` parts into lane `lane` of every batched component —
/// the session-restore / prefill-landing write path.  Panics on arity
/// mismatch (callers validate against the manifest first).
pub fn splice_components(comps: &mut [Tensor], lane: usize, parts: &[Tensor]) {
    assert_eq!(parts.len(), comps.len(), "component arity mismatch");
    for (comp, part) in comps.iter_mut().zip(parts) {
        copy_component_lane(part, 0, comp, lane);
    }
}

/// Parse a `state_paths` name like `"['eta']"` into `eta`.
fn parse_state_path(path: &str) -> Result<String> {
    let parts: Vec<&str> = path
        .split(['[', ']'])
        .filter(|s| !s.is_empty())
        .map(|s| s.trim_matches('\''))
        .collect();
    match parts.as_slice() {
        [field] => Ok(field.to_string()),
        _ => bail!("unparseable state path {path:?}"),
    }
}

pub fn rmsnorm(x: &[f32], w: &[f32], out: &mut [f32]) {
    let ms = ops::dot(x, x) / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w) {
        *o = xi * inv * wi;
    }
}

pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Mixer options derived from a model config.
pub fn mixer_opts(cfg: &ModelCfg) -> HlaOptions<f32> {
    HlaOptions {
        gamma: cfg.gamma as f32,
        lambda: cfg.lam as f32,
        norm: NormMode::parse(&cfg.norm_mode).unwrap_or(NormMode::Abs),
        eps: cfg.eps as f32,
        masked: true,
    }
}

impl RustModel {
    /// One decode step for a single sequence: token -> logits, state updated
    /// in place.  This is the O(1)-memory serving path (except softmax).
    pub fn decode_step(&self, state: &mut ModelState, token: u8) -> Vec<f32> {
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let dh = cfg.head_dim;
        let scale = 1.0 / (dh as f32).sqrt();
        let opts = mixer_opts(cfg);
        let mut x = self.embed.row(token as usize).to_vec();
        let mut h = vec![0f32; d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&x, &layer.norm1, &mut h);
            let q = layer.wq.t_matvec(&h);
            let k = layer.wk.t_matvec(&h);
            let v = layer.wv.t_matvec(&h);
            let mut heads_out = vec![0f32; cfg.n_heads * dh];
            for hi in 0..cfg.n_heads {
                let kvh = if cfg.multi_query { 0 } else { hi };
                let qh: Vec<f32> = q[hi * dh..(hi + 1) * dh].iter().map(|&x| x * scale).collect();
                let kh: Vec<f32> =
                    k[kvh * dh..(kvh + 1) * dh].iter().map(|&x| x * scale).collect();
                let vh = &v[kvh * dh..(kvh + 1) * dh];
                let o = state.layers[li][hi].step(&qh, &kh, vh, &opts);
                heads_out[hi * dh..(hi + 1) * dh].copy_from_slice(&o);
            }
            let proj = layer.wo.t_matvec(&heads_out);
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += pi;
            }
            rmsnorm(&x, &layer.norm2, &mut h);
            let gate = layer.w_gate.t_matvec(&h);
            let up = layer.w_up.t_matvec(&h);
            let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let down = layer.w_down.t_matvec(&act);
            for (xi, di) in x.iter_mut().zip(&down) {
                *xi += di;
            }
        }
        rmsnorm(&x.clone(), &self.norm_f, &mut x);
        // tied LM head: logits = embed @ x
        self.embed.matvec(&x)
    }

    /// Full forward over a token sequence (teacher-forced), returning the
    /// logits matrix [n, vocab].  Routed through the chunk-parallel
    /// prefill engine (`crate::prefill`), which equals the streaming path
    /// exactly up to f32 reassociation (Theorem 4.1); softmax mixers fall
    /// back to the serial path automatically.
    pub fn forward(&self, tokens: &[u8]) -> Mat<f32> {
        let mut state = ModelState::new(&self.cfg);
        let cfg = crate::prefill::PrefillCfg::auto(&self.cfg);
        crate::prefill::forward_logits(self, &mut state, tokens, &cfg)
    }

    /// Serial reference forward (one `decode_step` per token) — kept as
    /// the differential-testing baseline for the scan prefill path.
    pub fn forward_serial(&self, tokens: &[u8]) -> Mat<f32> {
        let mut state = ModelState::new(&self.cfg);
        crate::prefill::forward_logits(
            self,
            &mut state,
            tokens,
            &crate::prefill::PrefillCfg::serial(),
        )
    }

    /// Mean next-token cross entropy over a sequence.
    pub fn loss(&self, tokens: &[u8]) -> f32 {
        assert!(tokens.len() >= 2);
        let logits = self.forward(&tokens[..tokens.len() - 1]);
        let mut total = 0.0;
        for t in 0..tokens.len() - 1 {
            let row = logits.row(t);
            let lse = ops::logsumexp(row);
            total += lse - row[tokens[t + 1] as usize];
        }
        total / (tokens.len() - 1) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, 4.0];
        let w = vec![1.0f32, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &w, &mut out);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 2.0;
        assert!((ms - 1.0).abs() < 1e-4, "{ms}");
    }

    #[test]
    fn silu_values() {
        assert!((silu(0.0)).abs() < 1e-7);
        assert!(silu(10.0) > 9.9);
        assert!(silu(-10.0) > -1e-3);
    }

    #[test]
    fn state_vec_roundtrip_all_constant_size_mixers() {
        let opts = HlaOptions::<f32>::default();
        for mixer in ["hla2", "ahla", "hla3", "linear"] {
            let mut s = MixerState::new(mixer, 8);
            let mut rng = crate::util::rng::Rng::new(3);
            let mut q = vec![0f32; 8];
            let mut k = vec![0f32; 8];
            let mut v = vec![0f32; 8];
            for _ in 0..5 {
                rng.fill_normal(&mut q, 1.0);
                rng.fill_normal(&mut k, 1.0);
                rng.fill_normal(&mut v, 1.0);
                s.step(&q, &k, &v, &opts);
            }
            let vec = s.state_vec().unwrap();
            assert_eq!(vec.len() * 4, s.nbytes(), "{mixer}");
            let mut fresh = MixerState::new(mixer, 8);
            fresh.load_state_vec(&vec).unwrap();
            assert_eq!(fresh.state_vec().unwrap(), vec, "{mixer}");
            assert!(fresh.load_state_vec(&vec[..vec.len() - 1]).is_err(), "{mixer}: short");
        }
        // softmax is the contrast case: no constant-size snapshot exists
        assert!(MixerState::new("softmax", 8).state_vec().is_err());
    }

    #[test]
    fn component_layout_roundtrip_and_coverage_check() {
        use crate::runtime::Manifest;
        let json = r#"{
          "configs": {"t": {"vocab": 16, "d_model": 8, "n_layers": 2,
            "n_heads": 2, "head_dim": 4, "d_ffn": 16, "kv_heads": 2,
            "mixer": "hla2", "chunk": 4, "gamma": 0.98, "lam": 0.0,
            "norm_mode": "abs", "eps": 1e-6, "n_params": 100,
            "n_param_tensors": 1, "n_state_tensors": 5,
            "param_paths": [["['embed']", [16, 8]]],
            "state_paths": [
              ["['s']", [2, 3, 2, 4, 4]],
              ["['c']", [2, 3, 2, 4, 4]],
              ["['m']", [2, 3, 2, 4]],
              ["['g']", [2, 3, 2, 4, 4]],
              ["['h']", [2, 3, 2, 4]]],
            "train_batch": 1, "train_seq": 8, "decode_batch": 3,
            "prefill_len": 4}},
          "artifacts": {}
        }"#;
        let cfg = Manifest::parse(json).unwrap().configs["t"].clone();
        let mut state = ModelState::new(&cfg);
        let opts = HlaOptions::<f32>::default().with_gamma(0.98);
        let mut rng = crate::util::rng::Rng::new(5);
        let mut buf = vec![0f32; 4];
        for head in state.layers.iter_mut().flatten() {
            for _ in 0..3 {
                rng.fill_normal(&mut buf, 1.0);
                let q = buf.clone();
                rng.fill_normal(&mut buf, 1.0);
                let k = buf.clone();
                rng.fill_normal(&mut buf, 1.0);
                head.step(&q, &k, &buf, &opts);
            }
        }
        let parts = state.to_components(&cfg).unwrap();
        assert_eq!(parts.len(), 5);
        assert_eq!(parts[0].shape, vec![2, 1, 2, 4, 4]);
        let mut back = ModelState::new(&cfg);
        back.load_components(&cfg, &parts).unwrap();
        for (a, b) in state.layers.iter().flatten().zip(back.layers.iter().flatten()) {
            assert_eq!(a.state_vec().unwrap(), b.state_vec().unwrap());
        }
        // a manifest that covers only part of the state must be rejected
        let mut partial = cfg.clone();
        partial.state_paths.truncate(2);
        assert!(state.to_components(&partial).is_err(), "lossy layout accepted");
        assert!(back.load_components(&partial, &parts).is_err(), "arity mismatch accepted");
    }

    #[test]
    fn component_lane_copies_are_surgical_and_bit_exact() {
        // two components, [L=2, B=3, rest] and [L=2, B=2, rest]: copy a
        // lane across differing batch widths and check bytes + neighbours
        let mut src = Tensor::zeros(&[2, 3, 4]);
        for (i, x) in src.data.iter_mut().enumerate() {
            *x = i as f32 * 0.5 + 0.1;
        }
        let mut dst = Tensor::zeros(&[2, 2, 4]);
        dst.data.fill(9.0);
        copy_component_lane(&src, 1, &mut dst, 0);
        for li in 0..2 {
            let s = (li * 3 + 1) * 4;
            let d = (li * 2) * 4;
            assert_eq!(&dst.data[d..d + 4], &src.data[s..s + 4], "layer {li}");
            // the other destination lane is untouched
            assert!(dst.data[d + 4..d + 8].iter().all(|&x| x == 9.0), "layer {li} neighbour");
        }
        // slice/splice round-trip through a [L, 1, rest] part
        let parts = slice_components(std::slice::from_ref(&src), 2);
        assert_eq!(parts[0].shape, vec![2, 1, 4]);
        let mut comps = vec![Tensor::zeros(&[2, 3, 4])];
        splice_components(&mut comps, 0, &parts);
        let back = slice_components(&comps, 0);
        assert_eq!(back[0].data, parts[0].data, "splice/slice round-trip");
        // zeroing is surgical too
        zero_component_lane(&mut src, 1);
        let lane1 = slice_components(std::slice::from_ref(&src), 1);
        assert!(lane1[0].data.iter().all(|&x| x == 0.0));
        let lane0 = slice_components(std::slice::from_ref(&src), 0);
        assert!(lane0[0].data.iter().all(|&x| x != 0.0));
    }

    #[test]
    fn mixer_state_sizes_ranked() {
        // linear < ahla == (P,m,E,n) < hla2 (has S) ; softmax grows
        let lin = MixerState::new("linear", 32);
        let ahla = MixerState::new("ahla", 32);
        let hla2 = MixerState::new("hla2", 32);
        assert!(lin.nbytes() < ahla.nbytes());
        assert!(ahla.nbytes() < hla2.nbytes());
        let mut sm = MixerState::new("softmax", 32);
        let opts = HlaOptions::<f32>::default();
        assert_eq!(sm.nbytes(), 0);
        let z = vec![0.1f32; 32];
        for _ in 0..10 {
            sm.step(&z, &z, &z, &opts);
        }
        assert_eq!(sm.nbytes(), 10 * 2 * 32 * 4);
    }
}
