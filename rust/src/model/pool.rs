//! Persistent worker-pool parallel decode (the CPU decode hot path).
//!
//! The per-token HLA state update — rank-1 outer-product accumulate plus a
//! couple of mat-vecs per head — is embarrassingly parallel across heads
//! and lanes (layers are sequential: layer i+1 reads layer i's residual).
//! [`DecodePool`] owns long-lived workers on one shared job channel, so a
//! decode step costs two channel hops per shard instead of a thread spawn
//! (contrast `hla::chunk::parallel_chunks`, which `thread::scope`s per
//! call — fine for one big prefill scan, ruinous per token).
//!
//! Two partitions of the work:
//! * [`RustModel::decode_step_pooled`] — one lane, heads fanned out within
//!   each layer (the serve/spec single-stream path).
//! * [`decode_steps_pooled`] — many lanes, each lane one shard running the
//!   full serial step (the batched path; lanes are fully independent).
//!
//! Exactness: every shard performs the *same floating-point operations in
//! the same order* as the serial loop it replaces, and shards write
//! disjoint output slices addressed by index — so threaded decode is
//! byte-identical to serial regardless of completion order (pinned by
//! `tests/decode_parallel_differential.rs`).  There is no reassociation
//! anywhere to document away.
//!
//! Failure: a panicking shard (e.g. the kernels' length asserts firing on
//! a corrupted state) is caught in the worker, which stays alive; the
//! caller gets a typed [`PoolError`] instead of a hang.  The lane whose
//! shard panicked is *poisoned* — some of its head states were moved into
//! the dead shard — so the caller must drop that lane (the fixture engine
//! aborts the request; the spec drafter discards the proposal).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::attention::KvCache;
use crate::model::{mixer_opts, rmsnorm, silu, MixerState, ModelState, RustModel};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A decode shard failed.  `WorkerPanicked` carries the shard's panic
/// message; `WorkerLost` means the pool's channels closed underneath us
/// (workers gone — only possible if the pool is being torn down).
#[derive(Debug, thiserror::Error)]
pub enum PoolError {
    #[error("decode worker panicked: {0}")]
    WorkerPanicked(String),
    #[error("decode worker pool lost (channel closed)")]
    WorkerLost,
}

/// Long-lived decode workers sharing one job channel.
///
/// `threads <= 1` builds a pool with *zero* workers: every pooled entry
/// point then runs the serial path inline, so `--decode-threads 1` is the
/// serial path by construction (not merely equal to it).
pub struct DecodePool {
    tx: Mutex<Option<Sender<Job>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl DecodePool {
    /// Spawn `threads` workers (0 or 1 → no workers, serial inline).
    /// `0 = auto` is resolved by callers via [`crate::util::auto_threads`]
    /// *before* this constructor, so the pool itself has no hidden policy.
    pub fn new(threads: usize) -> DecodePool {
        if threads <= 1 {
            return DecodePool { tx: Mutex::new(None), workers: vec![], threads: threads.max(1) };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("decode-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn decode worker")
            })
            .collect();
        DecodePool { tx: Mutex::new(Some(tx)), workers, threads }
    }

    /// Resolved worker count (1 = serial inline, no worker threads).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// True when work actually fans out to worker threads.
    pub fn is_parallel(&self) -> bool {
        !self.workers.is_empty()
    }

    fn submit(&self, job: Job) -> Result<(), PoolError> {
        let tx = self.tx.lock().unwrap();
        match tx.as_ref() {
            Some(tx) => tx.send(job).map_err(|_| PoolError::WorkerLost),
            None => Err(PoolError::WorkerLost),
        }
    }
}

impl Drop for DecodePool {
    fn drop(&mut self) {
        // close the channel so workers drain and exit, then join
        *self.tx.lock().unwrap() = None;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // hold the lock only while receiving, never while running the job
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => break,
        };
        job();
    }
}

/// Stringify a panic payload (the usual &str / String cases, then a
/// placeholder — the type information is gone by here).
fn panic_msg(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Cheap placeholder for a [`MixerState`] moved into a shard (an empty
/// KV-cache allocates nothing).  If the shard never sends the state back
/// (panic), the placeholder is what poisons the lane.
fn placeholder() -> MixerState {
    MixerState::Softmax(KvCache::new())
}

impl RustModel {
    /// One decode step with the per-layer head fan-out on `pool`.
    ///
    /// Byte-identical to [`RustModel::decode_step`]: each head shard runs
    /// the exact serial per-head op sequence and writes its own disjoint
    /// `heads_out` slice; layers stay sequential (the residual stream is a
    /// true dependency).  Head states are moved into shards and back, so
    /// on `Err` the lane is poisoned and must be dropped by the caller.
    pub fn decode_step_pooled(
        &self,
        state: &mut ModelState,
        token: u8,
        pool: &DecodePool,
    ) -> Result<Vec<f32>, PoolError> {
        if !pool.is_parallel() {
            return Ok(self.decode_step(state, token));
        }
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let dh = cfg.head_dim;
        let multi_query = cfg.multi_query;
        let scale = 1.0 / (dh as f32).sqrt();
        let opts = mixer_opts(cfg);
        let mut x = self.embed.row(token as usize).to_vec();
        let mut h = vec![0f32; d];
        for (li, layer) in self.layers.iter().enumerate() {
            rmsnorm(&x, &layer.norm1, &mut h);
            let q = Arc::new(layer.wq.t_matvec(&h));
            let k = Arc::new(layer.wk.t_matvec(&h));
            let v = Arc::new(layer.wv.t_matvec(&h));
            let (res_tx, res_rx) = channel::<(usize, Result<(MixerState, Vec<f32>), String>)>();
            for hi in 0..cfg.n_heads {
                let head = std::mem::replace(&mut state.layers[li][hi], placeholder());
                let (q, k, v) = (Arc::clone(&q), Arc::clone(&k), Arc::clone(&v));
                let res_tx = res_tx.clone();
                pool.submit(Box::new(move || {
                    let out = catch_unwind(AssertUnwindSafe(move || {
                        let mut head = head;
                        let kvh = if multi_query { 0 } else { hi };
                        let qh: Vec<f32> =
                            q[hi * dh..(hi + 1) * dh].iter().map(|&x| x * scale).collect();
                        let kh: Vec<f32> =
                            k[kvh * dh..(kvh + 1) * dh].iter().map(|&x| x * scale).collect();
                        let vh = &v[kvh * dh..(kvh + 1) * dh];
                        let o = head.step(&qh, &kh, vh, &opts);
                        (head, o)
                    }))
                    .map_err(panic_msg);
                    let _ = res_tx.send((hi, out));
                }))?;
            }
            drop(res_tx);
            let mut heads_out = vec![0f32; cfg.n_heads * dh];
            let mut first_err: Option<PoolError> = None;
            for _ in 0..cfg.n_heads {
                match res_rx.recv() {
                    Ok((hi, Ok((head, o)))) => {
                        state.layers[li][hi] = head;
                        heads_out[hi * dh..(hi + 1) * dh].copy_from_slice(&o);
                    }
                    Ok((_, Err(msg))) => {
                        first_err.get_or_insert(PoolError::WorkerPanicked(msg));
                    }
                    Err(_) => {
                        first_err.get_or_insert(PoolError::WorkerLost);
                        break;
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            let proj = layer.wo.t_matvec(&heads_out);
            for (xi, pi) in x.iter_mut().zip(&proj) {
                *xi += pi;
            }
            rmsnorm(&x, &layer.norm2, &mut h);
            let gate = layer.w_gate.t_matvec(&h);
            let up = layer.w_up.t_matvec(&h);
            let act: Vec<f32> = gate.iter().zip(&up).map(|(&g, &u)| silu(g) * u).collect();
            let down = layer.w_down.t_matvec(&act);
            for (xi, di) in x.iter_mut().zip(&down) {
                *xi += di;
            }
        }
        rmsnorm(&x.clone(), &self.norm_f, &mut x);
        Ok(self.embed.matvec(&x))
    }
}

/// One decode step for each of `lanes` independent (state, token) pairs,
/// lane-partitioned across the pool — each shard runs the plain serial
/// [`RustModel::decode_step`] on a lane it temporarily owns.  Returns the
/// per-lane logits in lane order.
///
/// Byte-identical to stepping each lane serially (it *is* the serial step
/// per lane; only the interleaving across lanes changes, and lanes share
/// no state).  On `Err`, lanes whose shard never reported are poisoned.
pub fn decode_steps_pooled(
    model: &Arc<RustModel>,
    lanes: &mut [(&mut ModelState, u8)],
    pool: &DecodePool,
) -> Result<Vec<Vec<f32>>, PoolError> {
    if !pool.is_parallel() || lanes.len() <= 1 {
        return Ok(lanes.iter_mut().map(|(st, tok)| model.decode_step(st, *tok)).collect());
    }
    let (res_tx, res_rx) = channel::<(usize, Result<(ModelState, Vec<f32>), String>)>();
    for (i, (st, tok)) in lanes.iter_mut().enumerate() {
        let owned = std::mem::replace(*st, ModelState { layers: vec![] });
        let model = Arc::clone(model);
        let tok = *tok;
        let res_tx = res_tx.clone();
        pool.submit(Box::new(move || {
            let out = catch_unwind(AssertUnwindSafe(move || {
                let mut owned = owned;
                let logits = model.decode_step(&mut owned, tok);
                (owned, logits)
            }))
            .map_err(panic_msg);
            let _ = res_tx.send((i, out));
        }))?;
    }
    drop(res_tx);
    let mut logits = vec![Vec::new(); lanes.len()];
    let mut first_err: Option<PoolError> = None;
    for _ in 0..lanes.len() {
        match res_rx.recv() {
            Ok((i, Ok((st, lg)))) => {
                *lanes[i].0 = st;
                logits[i] = lg;
            }
            Ok((_, Err(msg))) => {
                first_err.get_or_insert(PoolError::WorkerPanicked(msg));
            }
            Err(_) => {
                first_err.get_or_insert(PoolError::WorkerLost);
                break;
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(logits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures;

    #[test]
    fn serial_mode_pool_spawns_no_workers() {
        for t in [0, 1] {
            let pool = DecodePool::new(t);
            assert!(!pool.is_parallel());
            assert_eq!(pool.threads(), 1);
        }
        let pool = DecodePool::new(3);
        assert!(pool.is_parallel());
        assert_eq!(pool.threads(), 3);
    }

    #[test]
    fn pooled_step_matches_serial_bitwise() {
        let model = fixtures::build_model("hla2", &fixtures::ModelShape::default(), 1);
        let pool = DecodePool::new(4);
        let mut serial = crate::model::ModelState::new(&model.cfg);
        let mut pooled = crate::model::ModelState::new(&model.cfg);
        for tok in [3u8, 7, 1, 0, 12] {
            let a = model.decode_step(&mut serial, tok);
            let b = model.decode_step_pooled(&mut pooled, tok, &pool).unwrap();
            assert_eq!(a, b);
        }
        for (s, p) in serial.layers.iter().flatten().zip(pooled.layers.iter().flatten()) {
            assert_eq!(s.state_vec().unwrap(), p.state_vec().unwrap());
        }
    }

    #[test]
    fn pool_survives_a_panicking_job_and_keeps_serving() {
        let pool = DecodePool::new(2);
        let (tx, rx) = channel();
        let tx2 = tx.clone();
        pool.submit(Box::new(move || {
            let r = catch_unwind(|| panic!("shard down"));
            let _ = tx2.send(r.is_err());
        }))
        .unwrap();
        assert!(rx.recv().unwrap(), "panic was caught in-job");
        // the worker is still alive to take more work
        pool.submit(Box::new(move || {
            let _ = tx.send(true);
        }))
        .unwrap();
        assert!(rx.recv().unwrap());
    }
}
