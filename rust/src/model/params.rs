//! Parameter container + loading from the manifest's tree-flatten order.
//!
//! `aot.py` records `param_paths` like `"['layers'][0]['wq']"` in the exact
//! order the flat parameter tensors appear in every artifact signature; this
//! module parses those names so the Rust model binds each tensor to the
//! right weight regardless of tree layout changes.

use anyhow::{anyhow, bail, Result};

use crate::runtime::ModelCfg;
use crate::tensor::{Mat, Tensor};

/// One transformer layer's weights.
#[derive(Debug, Clone)]
pub struct Layer {
    pub norm1: Vec<f32>,
    pub wq: Mat<f32>,
    pub wk: Mat<f32>,
    pub wv: Mat<f32>,
    pub wo: Mat<f32>,
    pub norm2: Vec<f32>,
    pub w_gate: Mat<f32>,
    pub w_up: Mat<f32>,
    pub w_down: Mat<f32>,
}

/// The full model: config + weights (embedding is the tied LM head).
#[derive(Debug, Clone)]
pub struct RustModel {
    pub cfg: ModelCfg,
    pub embed: Mat<f32>,
    pub norm_f: Vec<f32>,
    pub layers: Vec<Layer>,
}

/// A parsed parameter path: layer index (None = top level) + field name.
fn parse_path(path: &str) -> Result<(Option<usize>, String)> {
    // formats: "['embed']", "['layers'][3]['wq']", "['norm_f']"
    let parts: Vec<&str> = path
        .split(['[', ']'])
        .filter(|s| !s.is_empty())
        .map(|s| s.trim_matches('\''))
        .collect();
    match parts.as_slice() {
        [field] => Ok((None, field.to_string())),
        ["layers", idx, field] => Ok((Some(idx.parse()?), field.to_string())),
        _ => bail!("unparseable param path {path:?}"),
    }
}

impl RustModel {
    /// Bind flat parameter tensors (artifact order) to model weights.
    pub fn from_tensors(cfg: &ModelCfg, tensors: &[Tensor]) -> Result<RustModel> {
        if tensors.len() != cfg.param_paths.len() {
            bail!("expected {} param tensors, got {}", cfg.param_paths.len(), tensors.len());
        }
        let mut embed = None;
        let mut norm_f = None;
        let mut layers: Vec<Option<Layer>> = (0..cfg.n_layers).map(|_| None).collect();
        let blank = |cfg: &ModelCfg| Layer {
            norm1: vec![],
            wq: Mat::zeros(0, 0),
            wk: Mat::zeros(0, 0),
            wv: Mat::zeros(0, 0),
            wo: Mat::zeros(0, 0),
            norm2: vec![],
            w_gate: Mat::zeros(cfg.d_model, 0),
            w_up: Mat::zeros(0, 0),
            w_down: Mat::zeros(0, 0),
        };
        for ((path, shape), tensor) in cfg.param_paths.iter().zip(tensors) {
            if &tensor.shape != shape {
                bail!("param {path}: manifest shape {shape:?} != tensor {:?}", tensor.shape);
            }
            let (layer_idx, field) = parse_path(path)?;
            match layer_idx {
                None => match field.as_str() {
                    "embed" => embed = Some(tensor.to_mat()),
                    "norm_f" => norm_f = Some(tensor.data.clone()),
                    other => bail!("unknown top-level param {other:?}"),
                },
                Some(li) => {
                    let slot = layers
                        .get_mut(li)
                        .ok_or_else(|| anyhow!("layer index {li} out of range"))?;
                    let layer = slot.get_or_insert_with(|| blank(cfg));
                    match field.as_str() {
                        "norm1" => layer.norm1 = tensor.data.clone(),
                        "norm2" => layer.norm2 = tensor.data.clone(),
                        "wq" => layer.wq = tensor.to_mat(),
                        "wk" => layer.wk = tensor.to_mat(),
                        "wv" => layer.wv = tensor.to_mat(),
                        "wo" => layer.wo = tensor.to_mat(),
                        "w_gate" => layer.w_gate = tensor.to_mat(),
                        "w_up" => layer.w_up = tensor.to_mat(),
                        "w_down" => layer.w_down = tensor.to_mat(),
                        other => bail!("unknown layer param {other:?}"),
                    }
                }
            }
        }
        Ok(RustModel {
            cfg: cfg.clone(),
            embed: embed.ok_or_else(|| anyhow!("missing embed"))?,
            norm_f: norm_f.ok_or_else(|| anyhow!("missing norm_f"))?,
            layers: layers
                .into_iter()
                .enumerate()
                .map(|(i, l)| l.ok_or_else(|| anyhow!("missing layer {i}")))
                .collect::<Result<_>>()?,
        })
    }

    pub fn n_params(&self) -> usize {
        let layer_n: usize = self
            .layers
            .iter()
            .map(|l| {
                l.norm1.len()
                    + l.norm2.len()
                    + l.wq.data.len()
                    + l.wk.data.len()
                    + l.wv.data.len()
                    + l.wo.data.len()
                    + l.w_gate.data.len()
                    + l.w_up.data.len()
                    + l.w_down.data.len()
            })
            .sum();
        self.embed.data.len() + self.norm_f.len() + layer_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_paths() {
        assert_eq!(parse_path("['embed']").unwrap(), (None, "embed".into()));
        assert_eq!(parse_path("['layers'][3]['wq']").unwrap(), (Some(3), "wq".into()));
        assert!(parse_path("['a'][1]['b'][2]").is_err());
    }
}
