//! Persisted perf trajectory: serialize an E-series bench run as a
//! schema-versioned `BENCH_<id>.json` at the repo root.
//!
//! Committing the file turns a bench run into a trajectory: every PR that
//! re-runs the bench diffs against the last committed numbers, so perf
//! regressions show up in review rather than in production.  The writer is
//! paired with [`validate`], which CI runs against the emitted file — a
//! report that drops a field or records a NaN fails the build, not the
//! reader six months later.
//!
//! Layout (schema `hla-bench/1`):
//!
//! ```json
//! {
//!   "schema": "hla-bench/1",
//!   "bench": "e8",
//!   "title": "serving stack",
//!   "created_unix_s": 1754550000,
//!   "cases": [
//!     {"name": "decode/base", "metrics": {"ns_per_token": 812.4}}
//!   ]
//! }
//! ```
//!
//! Numbers are f64 throughout (the substrate is `util::json`); metric keys
//! are free-form but stable per bench — renaming one breaks the trajectory
//! diff just like deleting it, so treat keys as part of the schema.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Schema tag every report carries; bump on layout changes.
pub const BENCH_SCHEMA: &str = "hla-bench/1";

/// One named measurement set within a report (a bench "case").
#[derive(Debug, Clone)]
pub struct BenchCase {
    pub name: String,
    /// ordered (key, value) metric pairs; values must be finite
    pub metrics: Vec<(String, f64)>,
}

/// A bench run headed for `BENCH_<id>.json` at the repo root.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// short bench id, e.g. `"e8"` — names the output file
    pub bench: String,
    /// one-line description of what the bench pins
    pub title: String,
    pub cases: Vec<BenchCase>,
}

impl BenchReport {
    pub fn new(bench: &str, title: &str) -> BenchReport {
        BenchReport { bench: bench.into(), title: title.into(), cases: Vec::new() }
    }

    /// Append one case.  Non-finite metric values are recorded as given —
    /// [`validate`] (and therefore [`write_repo_root`](Self::write_repo_root))
    /// rejects them, which is the point: a NaN should fail the bench run,
    /// not silently poison the trajectory.
    pub fn case(&mut self, name: &str, metrics: &[(&str, f64)]) -> &mut Self {
        self.cases.push(BenchCase {
            name: name.into(),
            metrics: metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
        self
    }

    pub fn to_json(&self) -> Json {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|c| {
                let metrics: Vec<(&str, Json)> =
                    c.metrics.iter().map(|(k, v)| (k.as_str(), Json::num(*v))).collect();
                Json::obj(vec![
                    ("name", Json::str(c.name.clone())),
                    ("metrics", Json::obj(metrics)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("bench", Json::str(self.bench.clone())),
            ("title", Json::str(self.title.clone())),
            ("created_unix_s", Json::num(created)),
            ("cases", Json::Arr(cases)),
        ])
    }

    /// Validate, then write `BENCH_<bench>.json` into the repo root
    /// (tmp-file + rename, so a crashed bench never leaves a torn report).
    /// `HLA_BENCH_DIR` overrides the destination directory — CI points it
    /// at a scratch dir, tests at a tempdir.
    pub fn write_repo_root(&self) -> Result<PathBuf> {
        let j = self.to_json();
        validate(&j).with_context(|| format!("bench {} produced an invalid report", self.bench))?;
        let dir = match std::env::var_os("HLA_BENCH_DIR") {
            Some(d) => PathBuf::from(d),
            // benches run with cwd = crate root; the repo root is one up
            None => Path::new(env!("CARGO_MANIFEST_DIR")).join(".."),
        };
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        let tmp = dir.join(format!("BENCH_{}.json.tmp", self.bench));
        std::fs::write(&tmp, format!("{j}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(path)
    }
}

/// Check a report against schema `hla-bench/1`.  Fails on a missing or
/// mistyped field, an empty case list, and any non-finite number — the
/// gate CI runs over every committed `BENCH_*.json`.
pub fn validate(j: &Json) -> Result<()> {
    let schema = j
        .get("schema")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing \"schema\""))?;
    if schema != BENCH_SCHEMA {
        bail!("schema {schema:?}, want {BENCH_SCHEMA:?}");
    }
    let bench =
        j.get("bench").and_then(Json::as_str).ok_or_else(|| anyhow!("missing \"bench\""))?;
    if bench.is_empty() {
        bail!("empty \"bench\" id");
    }
    j.get("title").and_then(Json::as_str).ok_or_else(|| anyhow!("missing \"title\""))?;
    let created = j
        .get("created_unix_s")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing \"created_unix_s\""))?;
    if !created.is_finite() || created < 0.0 {
        bail!("bad created_unix_s {created}");
    }
    let cases =
        j.get("cases").and_then(Json::as_arr).ok_or_else(|| anyhow!("missing \"cases\""))?;
    if cases.is_empty() {
        bail!("empty \"cases\" (a report with nothing measured)");
    }
    for (i, c) in cases.iter().enumerate() {
        let name = c
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("case {i}: missing \"name\""))?;
        let metrics = c
            .get("metrics")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("case {name:?}: missing \"metrics\""))?;
        if metrics.is_empty() {
            bail!("case {name:?}: empty \"metrics\"");
        }
        for (k, v) in metrics {
            let v = v
                .as_f64()
                .ok_or_else(|| anyhow!("case {name:?}: metric {k:?} is not a number"))?;
            if !v.is_finite() {
                bail!("case {name:?}: metric {k:?} is non-finite ({v})");
            }
        }
    }
    Ok(())
}

/// Load and validate a committed `BENCH_<id>.json`.
pub fn load(path: &Path) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
    validate(&j).with_context(|| format!("{} failed validation", path.display()))?;
    Ok(j)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> BenchReport {
        let mut r = BenchReport::new("e99", "report round-trip");
        r.case("decode/base", &[("ns_per_token", 812.4), ("tokens", 4096.0)]);
        r.case("decode/traced", &[("ns_per_token", 820.1), ("overhead_pct", 0.9)]);
        r
    }

    #[test]
    fn round_trips_and_validates() {
        let j = sample().to_json();
        validate(&j).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        validate(&j2).unwrap();
        assert_eq!(j2.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert_eq!(j2.get("bench").unwrap().as_str(), Some("e99"));
        let cases = j2.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(
            cases[0].path("metrics.ns_per_token").unwrap().as_f64(),
            Some(812.4)
        );
    }

    #[test]
    fn validate_rejects_missing_fields() {
        for drop in ["schema", "bench", "title", "created_unix_s", "cases"] {
            let j = sample().to_json();
            let Json::Obj(mut m) = j else { unreachable!() };
            m.remove(drop);
            assert!(validate(&Json::Obj(m)).is_err(), "surviving without {drop:?}");
        }
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        // wrong schema tag
        let mut r = sample().to_json();
        if let Json::Obj(m) = &mut r {
            m.insert("schema".into(), Json::str("hla-bench/0"));
        }
        assert!(validate(&r).is_err());
        // empty case list
        let mut r = sample().to_json();
        if let Json::Obj(m) = &mut r {
            m.insert("cases".into(), Json::Arr(vec![]));
        }
        assert!(validate(&r).is_err());
        // non-finite metric
        let mut rep = sample();
        rep.case("bad", &[("nan_metric", f64::NAN)]);
        assert!(validate(&rep.to_json()).is_err());
        let mut rep = sample();
        rep.case("bad", &[("inf_metric", f64::INFINITY)]);
        assert!(validate(&rep.to_json()).is_err());
    }

    #[test]
    fn write_respects_bench_dir_override() {
        let dir = std::env::temp_dir().join(format!("hla-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // serialize env mutation: tests in this module run on one thread
        // each but share the process env, so scope it tightly
        std::env::set_var("HLA_BENCH_DIR", &dir);
        let path = sample().write_repo_root().unwrap();
        std::env::remove_var("HLA_BENCH_DIR");
        assert_eq!(path, dir.join("BENCH_e99.json"));
        let j = load(&path).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("e99"));
        // tmp file never survives the rename
        assert!(!dir.join("BENCH_e99.json.tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
