//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations with mean/stddev/min, plus a black_box and table output via
//! `metrics::Table`.  Used by every `rust/benches/e*.rs` target
//! (`harness = false`, driven by `cargo bench`).
//!
//! [`report`] persists a run's results as a schema-versioned
//! `BENCH_<id>.json` at the repo root — the perf trajectory the
//! acceptance gates diff against (see `ROADMAP.md`).

pub mod report;

pub use report::BenchReport;

use std::hint::black_box as hint_black_box;
use std::time::Instant;

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    hint_black_box(x)
}

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    /// items/second at `items` work items per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean_s
    }
}

/// Benchmark a closure: `warmup` untimed runs then `iters` timed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / times.len() as f64;
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    Stats { iters, mean_s: mean, std_s: var.sqrt(), min_s: min }
}

/// Adaptive: pick an iteration count so total time ≈ `budget_s`, then bench.
pub fn bench_budget<F: FnMut()>(budget_s: f64, mut f: F) -> Stats {
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_secs_f64().max(1e-7);
    let iters = ((budget_s / one).round() as usize).clamp(3, 10_000);
    bench(1, iters, f)
}

/// Standard header printed by every experiment harness.
pub fn banner(id: &str, claim: &str) {
    println!("\n=== {id}: {claim} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let s = bench(1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(s.mean_s > 0.0);
        assert!(s.min_s <= s.mean_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn budget_adapts() {
        let s = bench_budget(0.02, || {
            std::thread::sleep(std::time::Duration::from_micros(500));
        });
        assert!(s.iters >= 3 && s.iters <= 100, "{}", s.iters);
    }
}
