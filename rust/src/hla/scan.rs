//! Generic Blelloch scans over any associative segment monoid (Thm 4.1,
//! Remark 4.2) — the parallel-training skeleton shared by second order,
//! AHLA and third order.
//!
//! `Monoid` captures the paper's segment algebra: an identity (the
//! zero-length segment E) and an associative `combine`.  Scans:
//! * [`inclusive_scan`] / [`exclusive_scan`] — serial O(n) reference.
//! * [`blelloch_exclusive`] — the up-sweep/down-sweep tree scan (O(n) work,
//!   O(log n) span) exactly as in Blelloch (1990), validated against the
//!   serial scans.
//! * [`chunked_scan`] in [`super::chunk`] builds the two-level intra-/
//!   inter-chunk strategy of §4.2 on top, with std::thread parallelism.

pub trait Monoid: Clone {
    /// The zero-length segment E (all-zero summaries, ρ = 1).
    fn identity_like(&self) -> Self;
    /// Segment concatenation: `self` (earlier, A) then `rhs` (later, B).
    fn combine(&self, rhs: &Self) -> Self;
}

/// Inclusive prefixes I_t = T_1 ⊕ … ⊕ T_t (serial reference).
pub fn inclusive_scan<M: Monoid>(leaves: &[M]) -> Vec<M> {
    let mut out = Vec::with_capacity(leaves.len());
    let mut acc: Option<M> = None;
    for leaf in leaves {
        let next = match &acc {
            None => leaf.clone(),
            Some(a) => a.combine(leaf),
        };
        out.push(next.clone());
        acc = Some(next);
    }
    out
}

/// Exclusive prefixes P_t = E ⊕ T_1 ⊕ … ⊕ T_{t-1} (Remark 4.2).
pub fn exclusive_scan<M: Monoid>(leaves: &[M]) -> Vec<M> {
    if leaves.is_empty() {
        return vec![];
    }
    let ident = leaves[0].identity_like();
    let mut out = Vec::with_capacity(leaves.len());
    let mut acc = ident;
    for leaf in leaves {
        out.push(acc.clone());
        acc = acc.combine(leaf);
    }
    out
}

/// Blelloch work-efficient exclusive scan (up-sweep + down-sweep).
///
/// Produces exactly `exclusive_scan`'s output for any associative monoid;
/// the tree reassociation is what Theorem 4.1 licenses.
pub fn blelloch_exclusive<M: Monoid>(leaves: &[M]) -> Vec<M> {
    let n = leaves.len();
    if n == 0 {
        return vec![];
    }
    let ident = leaves[0].identity_like();
    // pad to a power of two with identities
    let size = n.next_power_of_two();
    let mut tree: Vec<M> = Vec::with_capacity(size);
    tree.extend(leaves.iter().cloned());
    tree.resize(size, ident.clone());

    // up-sweep: tree[i + 2^k - 1] accumulates its segment
    let mut stride = 1;
    while stride < size {
        let mut i = stride * 2 - 1;
        while i < size {
            let left = tree[i - stride].clone();
            tree[i] = left.combine(&tree[i]);
            i += stride * 2;
        }
        stride *= 2;
    }

    // down-sweep
    tree[size - 1] = ident;
    let mut stride = size / 2;
    while stride >= 1 {
        let mut i = stride * 2 - 1;
        while i < size {
            let left = tree[i - stride].clone();
            tree[i - stride] = tree[i].clone();
            tree[i] = tree[i].combine(&left);
            i += stride * 2;
        }
        stride /= 2;
    }

    tree.truncate(n);
    tree
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deliberately *non-commutative* monoid (string concat) to make sure
    /// the scans preserve order.
    #[derive(Clone, Debug, PartialEq)]
    struct Cat(String);

    impl Monoid for Cat {
        fn identity_like(&self) -> Self {
            Cat(String::new())
        }
        fn combine(&self, rhs: &Self) -> Self {
            Cat(format!("{}{}", self.0, rhs.0))
        }
    }

    fn letters(n: usize) -> Vec<Cat> {
        (0..n).map(|i| Cat(((b'a' + (i % 26) as u8) as char).to_string())).collect()
    }

    #[test]
    fn exclusive_matches_definition() {
        let leaves = letters(5);
        let ex = exclusive_scan(&leaves);
        assert_eq!(ex[0].0, "");
        assert_eq!(ex[4].0, "abcd");
    }

    #[test]
    fn blelloch_equals_serial_exclusive() {
        for n in [1, 2, 3, 4, 5, 7, 8, 13, 16, 31, 64] {
            let leaves = letters(n);
            assert_eq!(blelloch_exclusive(&leaves), exclusive_scan(&leaves), "n={n}");
        }
    }

    #[test]
    fn inclusive_is_exclusive_plus_local() {
        let leaves = letters(9);
        let inc = inclusive_scan(&leaves);
        let ex = blelloch_exclusive(&leaves);
        for t in 0..9 {
            assert_eq!(inc[t], ex[t].combine(&leaves[t]), "Remark 4.2 at t={t}");
        }
    }
}
