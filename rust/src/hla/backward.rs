//! Reverse-mode algebra for masked second-order HLA (§4, "Backward for
//! gradients"): the vector–Jacobian adjoint of the forward recurrence,
//! computed by a reverse sweep with forward-state checkpointing at chunk
//! boundaries — gradients match the serial recurrence exactly (Theorem 4.1
//! + chain rule), verified here against central finite differences.
//!
//! Forward (monoid-consistent decayed step, `state2::Hla2State::step`):
//!
//!   G_t = γ(G_{t-1} + k_t k_tᵀ C_{t-1})      h_t = γ(h_{t-1} + k_t k_tᵀ m_{t-1})
//!   S_t = γS_{t-1} + k_t k_tᵀ                C_t = γC_{t-1} + q_t v_tᵀ
//!   m_t = γm_{t-1} + q_t
//!   o_t = q_tᵀ(S_t C_t − G_t)  [/ (q_tᵀ(S_t m_t − h_t) + ε) when normalized]
//!
//! The adjoint runs t = n..1 carrying cotangents (S̄, C̄, m̄, Ḡ, h̄) and
//! producing (q̄, k̄, v̄) per token.  Checkpointing: forward states are
//! stored every `ckpt` steps and recomputed within a segment, giving the
//! O(n/w) memory / O(n·w) recompute tradeoff of the paper's tile scheme.

use crate::tensor::{ops, Mat, Scalar};

use super::state2::Hla2State;
use super::{HlaOptions, NormMode};

/// Gradients of a scalar loss w.r.t. the inputs.
#[derive(Debug, Clone)]
pub struct Hla2Grads<T> {
    pub dq: Mat<T>,
    pub dk: Mat<T>,
    pub dv: Mat<T>,
}

/// Reverse-mode gradient of `sum(dout ⊙ hla2_serial(q, k, v))`.
///
/// `ckpt` is the checkpoint interval (forward states kept every `ckpt`
/// tokens; segment states recomputed during the reverse sweep).
/// Supports `NormMode::None` and `NormMode::Linear` (the paper's Eq. 3.4);
/// masked form only (the default operator).
pub fn hla2_backward<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    dout: &Mat<T>,
    opts: &HlaOptions<T>,
    ckpt: usize,
) -> Hla2Grads<T> {
    assert!(opts.masked, "backward implemented for the masked operator");
    assert!(
        matches!(opts.norm, NormMode::None | NormMode::Linear),
        "backward supports none/linear normalization"
    );
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let ckpt = ckpt.max(1);
    let gamma = opts.gamma;

    // forward: checkpoint the state every `ckpt` tokens (state *before*
    // token index c*ckpt is stored at checkpoint c)
    let mut checkpoints: Vec<Hla2State<T>> = Vec::with_capacity(n / ckpt + 1);
    let mut st = Hla2State::new(d, dv);
    for t in 0..n {
        if t % ckpt == 0 {
            checkpoints.push(st.clone());
        }
        st.step(q.row(t), k.row(t), v.row(t), gamma);
    }

    // cotangents of the carried state, initialized to zero at t = n
    let mut sb = Mat::<T>::zeros(d, d); // S̄
    let mut cb = Mat::<T>::zeros(d, dv); // C̄
    let mut mb = vec![T::ZERO; d]; // m̄
    let mut gb = Mat::<T>::zeros(d, dv); // Ḡ
    let mut hb = vec![T::ZERO; d]; // h̄
    let mut grads =
        Hla2Grads { dq: Mat::zeros(n, d), dk: Mat::zeros(n, d), dv: Mat::zeros(n, dv) };

    // reverse sweep over checkpointed segments
    let n_ck = checkpoints.len();
    for c in (0..n_ck).rev() {
        let lo = c * ckpt;
        let hi = ((c + 1) * ckpt).min(n);
        // recompute the forward states inside this segment: states[i] is the
        // *inclusive* state after token lo+i; pre[i] the state before it.
        let mut pre: Vec<Hla2State<T>> = Vec::with_capacity(hi - lo);
        let mut seg = checkpoints[c].clone();
        for t in lo..hi {
            pre.push(seg.clone());
            seg.step(q.row(t), k.row(t), v.row(t), gamma);
        }
        for t in (lo..hi).rev() {
            let prev = &pre[t - lo];
            // recompute the inclusive state at t from prev (cheap, rank-1)
            let mut cur = prev.clone();
            cur.step(q.row(t), k.row(t), v.row(t), gamma);
            let (qt, kt, vt) = (q.row(t), k.row(t), v.row(t));
            let go = dout.row(t); // ∂L/∂o_t

            // ---- output adjoint: o = u C − qᵀG (num), den = u m − qᵀh ----
            // u = qᵀS (+ λq)
            let mut u = cur.s.t_matvec(qt);
            if opts.lambda != T::ZERO {
                ops::axpy(opts.lambda, qt, &mut u);
            }
            let num: Vec<T> = {
                let mut x = cur.c.t_matvec(&u);
                let qg = cur.g.t_matvec(qt);
                for (a, b) in x.iter_mut().zip(&qg) {
                    *a = *a - *b;
                }
                x
            };
            let (go_num, den_adj): (Vec<T>, T) = match opts.norm {
                NormMode::None => (go.to_vec(), T::ZERO),
                NormMode::Linear => {
                    let den =
                        ops::dot(&u, &cur.m) - ops::dot(qt, &cur.h) + opts.eps;
                    // o = num/den ; n̄um = ḡo/den ; d̄en = −(ḡo·num)/den²
                    let inv = T::ONE / den;
                    let gnum: Vec<T> = go.iter().map(|&x| x * inv).collect();
                    let gden = -ops::dot(go, &num) * inv * inv;
                    (gnum, gden)
                }
                NormMode::Abs => unreachable!(),
            };
            // num = uᵀC − qᵀG:
            //   ū += C ḡnum ; C̄ += u ḡnumᵀ ; q̄ −= G ḡnum ; Ḡ −= q ḡnumᵀ
            let mut ubar = cur.c.matvec(&go_num);
            cb.add_outer(T::ONE, &u, &go_num);
            let g_gnum = cur.g.matvec(&go_num);
            for (dqi, gi) in grads.dq.row_mut(t).iter_mut().zip(&g_gnum) {
                *dqi = *dqi - *gi;
            }
            gb.add_outer(-T::ONE, qt, &go_num);
            // den = uᵀm − qᵀh (+ε):
            if den_adj != T::ZERO {
                ops::axpy(den_adj, &cur.m, &mut ubar);
                ops::axpy(den_adj, &u, &mut mb);
                ops::axpy(-den_adj, &cur.h, grads.dq.row_mut(t));
                ops::axpy(-den_adj, qt, &mut hb);
            }
            // u = Sᵀq (+λq):  S̄ += q ūᵀ ; q̄ += S ū (+ λū)
            sb.add_outer(T::ONE, qt, &ubar);
            let s_ubar = cur.s.matvec(&ubar);
            ops::axpy(T::ONE, &s_ubar, grads.dq.row_mut(t));
            if opts.lambda != T::ZERO {
                ops::axpy(opts.lambda, &ubar, grads.dq.row_mut(t));
            }

            // ---- step adjoint (reverse of Hla2State::step) ----
            // m_t = γ m_prev + q  :  q̄ += m̄ ; m̄_prev = γ m̄
            ops::axpy(T::ONE, &mb, grads.dq.row_mut(t));
            // C_t = γ C_prev + q vᵀ : q̄ += C̄ v ; v̄ += C̄ᵀ q ; C̄_prev = γC̄
            ops::axpy(T::ONE, &cb.matvec(vt), grads.dq.row_mut(t));
            ops::axpy(T::ONE, &cb.t_matvec(qt), grads.dv.row_mut(t));
            // S_t = γ S_prev + k kᵀ : k̄ += (S̄ + S̄ᵀ) k ; S̄_prev = γS̄
            let sk = sb.matvec(kt);
            let stk = sb.t_matvec(kt);
            ops::axpy(T::ONE, &sk, grads.dk.row_mut(t));
            ops::axpy(T::ONE, &stk, grads.dk.row_mut(t));
            // h_t = γ(h_prev + (kᵀ m_prev) k):
            //   k̄ += γ[(h̄·k) m_prev-term + (kᵀm_prev) h̄] ; m̄_prev += γ(h̄·k) k
            let hk = ops::dot(&hb, kt);
            let km_prev = ops::dot(kt, &prev.m);
            ops::axpy(gamma * hk, &prev.m, grads.dk.row_mut(t));
            ops::axpy(gamma * km_prev, &hb, grads.dk.row_mut(t));
            // G_t = γ(G_prev + k (kᵀ C_prev)):
            //   k̄ += γ[Ḡ (C_prevᵀk)-row + C_prev (Ḡᵀ... ] — with w = kᵀC_prev:
            //   k̄ += γ[Ḡ w + C_prev (Ḡᵀ k)] ; C̄_prev += γ k (Ḡᵀ k)ᵀ... careful:
            //   ∂/∂C_prev [k wᵀ]·Ḡ = k kᵀ Ḡ  (since w = C_prevᵀ k)
            let w = prev.c.t_matvec(kt); // kᵀ C_prev
            let gk = gb.t_matvec(kt); // Ḡᵀ k  [dv]
            ops::axpy(gamma, &gb.matvec(&w), grads.dk.row_mut(t));
            ops::axpy(gamma, &prev.c.matvec(&gk), grads.dk.row_mut(t));
            // carry cotangents to t-1 (all decayed by γ; G/h feed C/m)
            // C̄_prev = γC̄ + γ k gkᵀ ;  m̄_prev = γm̄ + γ hk k
            cb.scale(gamma);
            cb.add_outer(gamma, kt, &gk);
            ops::scale(gamma, &mut mb);
            ops::axpy(gamma * hk, kt, &mut mb);
            sb.scale(gamma);
            gb.scale(gamma);
            ops::scale(gamma, &mut hb);
        }
    }
    grads
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::state2::hla2_serial;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    /// scalar loss = Σ dout ⊙ forward(q,k,v)
    fn loss(q: &Mat<f64>, k: &Mat<f64>, v: &Mat<f64>, dout: &Mat<f64>, opts: &HlaOptions<f64>) -> f64 {
        let out = hla2_serial(q, k, v, opts);
        ops::dot(&out.data, &dout.data)
    }

    fn fd_check(opts: HlaOptions<f64>, ckpt: usize) {
        let mut rng = Rng::new(0xBAC);
        let (n, d, dv) = (10, 3, 4);
        let (q, k, v) = random(&mut rng, n, d, dv);
        let mut dout = Mat::<f64>::zeros(n, dv);
        for x in &mut dout.data {
            *x = rng.normal();
        }
        let grads = hla2_backward(&q, &k, &v, &dout, &opts, ckpt);
        let eps = 1e-6;
        let mut check = |mat: &Mat<f64>, grad: &Mat<f64>, which: &str| {
            for idx in [0usize, mat.data.len() / 2, mat.data.len() - 1] {
                let mut plus = mat.clone();
                plus.data[idx] += eps;
                let mut minus = mat.clone();
                minus.data[idx] -= eps;
                let (lp, lm) = match which {
                    "q" => (loss(&plus, &k, &v, &dout, &opts), loss(&minus, &k, &v, &dout, &opts)),
                    "k" => (loss(&q, &plus, &v, &dout, &opts), loss(&q, &minus, &v, &dout, &opts)),
                    _ => (loss(&q, &k, &plus, &dout, &opts), loss(&q, &k, &minus, &dout, &opts)),
                };
                let fd = (lp - lm) / (2.0 * eps);
                let an = grad.data[idx];
                let denom = 1.0f64.max(fd.abs()).max(an.abs());
                assert!(
                    (fd - an).abs() / denom < 1e-5,
                    "{which}[{idx}]: fd {fd} vs analytic {an} (opts {opts:?})"
                );
            }
        };
        check(&q, &grads.dq, "q");
        check(&k, &grads.dk, "k");
        check(&v, &grads.dv, "v");
    }

    #[test]
    fn gradcheck_unnormalized_gamma1() {
        fd_check(HlaOptions::default(), 4);
    }

    #[test]
    fn gradcheck_decayed() {
        fd_check(HlaOptions::default().with_gamma(0.9), 3);
    }

    #[test]
    fn gradcheck_normalized_linear() {
        fd_check(HlaOptions::default().with_norm(NormMode::Linear).with_gamma(0.95), 4);
    }

    #[test]
    fn gradcheck_with_ridge() {
        fd_check(HlaOptions::default().with_lambda(0.2), 5);
    }

    #[test]
    fn checkpoint_interval_does_not_change_gradients() {
        let mut rng = Rng::new(7);
        let (q, k, v) = random(&mut rng, 17, 3, 3);
        let mut dout = Mat::<f64>::zeros(17, 3);
        for x in &mut dout.data {
            *x = rng.normal();
        }
        let opts = HlaOptions::default().with_gamma(0.97);
        let g1 = hla2_backward(&q, &k, &v, &dout, &opts, 1);
        let g5 = hla2_backward(&q, &k, &v, &dout, &opts, 5);
        let g17 = hla2_backward(&q, &k, &v, &dout, &opts, 17);
        testing::assert_close(&g1.dq.data, &g5.dq.data, 1e-12, "ckpt dq").unwrap();
        testing::assert_close(&g1.dk.data, &g17.dk.data, 1e-12, "ckpt dk").unwrap();
        testing::assert_close(&g5.dv.data, &g17.dv.data, 1e-12, "ckpt dv").unwrap();
    }
}
