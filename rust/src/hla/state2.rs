//! Masked second-order HLA streaming state (Theorem 3.1 / Algorithm 1).
//!
//! State tuple `(S, C, m, G, h)` per head; `step` is the monoid-consistent
//! decayed online update (§3.1/§4.3 with DESIGN.md erratum #2: the carry —
//! including the cross-term's `C_{t-1}`/`m_{t-1}` — is attenuated by γ,
//! which is what the decayed semidirect product of §4.2 implies and what
//! makes scan ≡ serial hold for γ < 1).
//!
//! Per-token cost: O(d² + d·d_v) — two rank-1 updates, two mat-vecs —
//! independent of sequence length (bench E2 measures this).

use crate::tensor::{ops, Mat, Scalar};

use super::HlaOptions;

/// Second-order state (per head): S [d,d], C [d,dv], m [d], G [d,dv], h [d].
#[derive(Debug, Clone, PartialEq)]
pub struct Hla2State<T> {
    pub s: Mat<T>,
    pub c: Mat<T>,
    pub m: Vec<T>,
    pub g: Mat<T>,
    pub h: Vec<T>,
}

impl<T: Scalar> Hla2State<T> {
    pub fn new(d: usize, dv: usize) -> Self {
        Hla2State {
            s: Mat::zeros(d, d),
            c: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            g: Mat::zeros(d, dv),
            h: vec![T::ZERO; d],
        }
    }

    pub fn d(&self) -> usize {
        self.s.rows
    }

    pub fn dv(&self) -> usize {
        self.c.cols
    }

    /// Bytes of state per head (memory table, E6/E7).
    pub fn nbytes(&self) -> usize {
        std::mem::size_of::<T>()
            * (self.s.data.len() + self.c.data.len() + self.m.len() + self.g.data.len() + self.h.len())
    }

    /// One online update (the paper's §3.1 updates with decay).
    ///
    /// Order matters: G/h consume C_{t-1}/m_{t-1} *before* C/m absorb the
    /// token's deltas.
    ///
    /// Each decayed update is one fused pass (`add_outer_decay` /
    /// `decay_add_outer` / `scale_axpy` / `axpy_scale`) — bit-identical to
    /// the old scale-then-accumulate pairs (the kernels preserve the exact
    /// per-element rounding sequence, and multiplying by γ = 1 is exact),
    /// so serial ≡ scan ≡ threaded equalities all still hold to the bit.
    pub fn step(&mut self, q: &[T], k: &[T], v: &[T], gamma: T) {
        // kc = k^T C_{t-1},  km = k^T m_{t-1}
        let kc = self.c.t_matvec(k);
        let km = ops::dot(k, &self.m);
        // G <- g (G + k kc^T);  h <- g (h + km k)
        self.g.add_outer_decay(T::ONE, k, &kc, gamma);
        ops::axpy_scale(km, k, &mut self.h, gamma);
        // S <- g S + k k^T;  C <- g C + q v^T;  m <- g m + q
        self.s.decay_add_outer(gamma, T::ONE, k, k);
        self.c.decay_add_outer(gamma, T::ONE, q, v);
        ops::scale_axpy(gamma, T::ONE, q, &mut self.m);
    }

    /// Per-token output from the inclusive state (Theorem 3.1).
    pub fn output(&self, q: &[T], opts: &HlaOptions<T>) -> Vec<T> {
        // u = q^T S (+ λ q)
        let mut u = self.s.t_matvec(q);
        if opts.lambda != T::ZERO {
            ops::axpy(opts.lambda, q, &mut u);
        }
        let mut num = self.c.t_matvec(&u);
        let mut den = ops::dot(&u, &self.m);
        if opts.masked {
            let qg = self.g.t_matvec(q);
            for (n, g) in num.iter_mut().zip(&qg) {
                *n = *n - *g;
            }
            den = den - ops::dot(q, &self.h);
        }
        opts.norm.apply(&mut num, den, opts.eps);
        num
    }
}

/// Full-sequence serial reference: q, k are [n, d] rows; v is [n, dv].
pub fn hla2_serial<T: Scalar>(q: &Mat<T>, k: &Mat<T>, v: &Mat<T>, opts: &HlaOptions<T>) -> Mat<T> {
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    assert_eq!(k.rows, n);
    assert_eq!(v.rows, n);
    let mut st = Hla2State::new(d, dv);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
        let o = st.output(q.row(t), opts);
        out.row_mut(t).copy_from_slice(&o);
    }
    out
}

/// Materialized masked oracle (Theorem 3.1 right-hand side), γ = 1 only:
/// `o_t = row_t[((L∘QKᵀ)(L∘QKᵀ)ᵀ ∘ L) V]` — O(n²d) time, used by tests/E1.
pub fn hla2_quadratic<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE, "quadratic oracle requires gamma == 1");
    let n = q.rows;
    let dv = v.cols;
    // W = L ∘ (Q K^T)
    let mut w = q.matmul_t(k);
    for i in 0..n {
        for j in (i + 1)..n {
            w[(i, j)] = T::ZERO;
        }
    }
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        // row t of (W W^T) for columns j <= t  (or the prefix form when unmasked)
        let mut den = T::ZERO;
        let mut acc = vec![T::ZERO; dv];
        for j in 0..=t {
            let limit = if opts.masked { j.min(t) } else { t };
            let mut wgt = T::ZERO;
            for i in 0..=limit {
                wgt += w[(t, i)] * w_unmasked(k, q, j, i, opts.masked, &w);
            }
            if opts.lambda != T::ZERO {
                wgt += opts.lambda * ops::dot(q.row(t), q.row(j));
            }
            ops::axpy(wgt, v.row(j), &mut acc);
            den += wgt;
        }
        opts.norm.apply(&mut acc, den, opts.eps);
        out.row_mut(t).copy_from_slice(&acc);
    }
    out
}

#[inline]
fn w_unmasked<T: Scalar>(
    k: &Mat<T>,
    q: &Mat<T>,
    j: usize,
    i: usize,
    masked: bool,
    w: &Mat<T>,
) -> T {
    if masked {
        // W_{j,i} already causally masked
        w[(j, i)]
    } else {
        // prefix form uses the *unmasked* A_{j,i} = q_j . k_i
        ops::dot(q.row(j), k.row(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::NormMode;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random_qkv(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let scale = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, s: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * s;
            }
            m
        };
        (mk(rng, n, d, scale), mk(rng, n, d, scale), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn serial_matches_quadratic_masked() {
        testing::quick("hla2 serial==quadratic", 24, |rng, _| {
            let n = rng.range(1, 24);
            let d = rng.range(1, 8);
            let dv = rng.range(1, 8);
            let (q, k, v) = random_qkv(rng, n, d, dv);
            let opts = HlaOptions::default();
            let a = hla2_serial(&q, &k, &v, &opts);
            let b = hla2_quadratic(&q, &k, &v, &opts);
            testing::assert_close(&a.data, &b.data, 1e-10, "masked")
        });
    }

    #[test]
    fn serial_matches_quadratic_unmasked_and_ridge() {
        testing::quick("hla2 prefix/ridge", 16, |rng, _| {
            let (q, k, v) = random_qkv(rng, 17, 5, 4);
            let unm = HlaOptions::default().unmasked();
            testing::assert_close(
                &hla2_serial(&q, &k, &v, &unm).data,
                &hla2_quadratic(&q, &k, &v, &unm).data,
                1e-10,
                "prefix",
            )?;
            let ridge = HlaOptions::default().with_lambda(0.3);
            testing::assert_close(
                &hla2_serial(&q, &k, &v, &ridge).data,
                &hla2_quadratic(&q, &k, &v, &ridge).data,
                1e-10,
                "ridge",
            )
        });
    }

    #[test]
    fn normalization_modes() {
        let mut rng = Rng::new(9);
        let (q, k, v) = random_qkv(&mut rng, 12, 4, 4);
        for norm in [NormMode::Linear, NormMode::Abs] {
            let opts = HlaOptions::default().with_norm(norm);
            let a = hla2_serial(&q, &k, &v, &opts);
            let b = hla2_quadratic(&q, &k, &v, &opts);
            testing::assert_close(&a.data, &b.data, 1e-10, "norm").unwrap();
        }
    }

    #[test]
    fn strict_causality() {
        let mut rng = Rng::new(10);
        let (q, k, v) = random_qkv(&mut rng, 16, 4, 4);
        let (q2, k2, v2) = random_qkv(&mut rng, 16, 4, 4);
        let opts = HlaOptions::default().with_gamma(0.9);
        let base = hla2_serial(&q, &k, &v, &opts);
        // splice different future
        let t = 9;
        let splice = |a: &Mat<f64>, b: &Mat<f64>| {
            let mut m = a.clone();
            for i in (t + 1)..16 {
                m.row_mut(i).copy_from_slice(b.row(i));
            }
            m
        };
        let pert = hla2_serial(&splice(&q, &q2), &splice(&k, &k2), &splice(&v, &v2), &opts);
        for i in 0..=t {
            testing::assert_close(base.row(i), pert.row(i), 1e-12, "causal").unwrap();
        }
    }

    #[test]
    fn decay_bounds_state() {
        let mut rng = Rng::new(11);
        let (q, k, v) = random_qkv(&mut rng, 400, 4, 4);
        let mut grow = Hla2State::<f64>::new(4, 4);
        let mut decay = Hla2State::<f64>::new(4, 4);
        for t in 0..400 {
            grow.step(q.row(t), k.row(t), v.row(t), 1.0);
            decay.step(q.row(t), k.row(t), v.row(t), 0.9);
        }
        assert!(decay.s.frobenius_norm() < 0.2 * grow.s.frobenius_norm());
    }

    #[test]
    fn state_size_formula() {
        let st = Hla2State::<f32>::new(64, 64);
        // S + C + G : 3 * d*dv(=d) matrices, m + h : 2 * d vectors
        assert_eq!(st.nbytes(), 4 * (3 * 64 * 64 + 2 * 64));
    }
}
