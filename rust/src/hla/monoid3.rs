//! Third-order segment monoids (§7.3).
//!
//! * [`Seg3Paper`] — the paper's ⊗₃ (Eqs. 7.6–7.7, Algorithm 4) for the
//!   paper-literal Eq. (7.5) operator, with the segment maps
//!   `M^{KQP}`/`M^{KQm}` in **both** representations:
//!   - [`SegMap::Dense`]: the O(d³·d_v) tensor the paper prices in §7.3;
//!   - [`SegMap::Factored`]: the exact sum-of-rank-terms form
//!     `M_X[Z] = Σ_t (k_tᵀ Z k_t) k_t v_tᵀ`, O(|X|·(d + d_v)) storage.
//!   Bench E9 measures the dense-vs-factored composition/apply tradeoff.
//!
//! * [`Seg3Canon`] — the *canonical* third-order operator's monoid, which
//!   needs **no** segment maps at all: the cross terms close over fixed-size
//!   statistics (S^Q, R, r, N), so exact chunk composition costs O(d²·d_v).
//!   This is a strict improvement over §7.3's price and one of the repo's
//!   findings (γ = 1, matching Algorithm 4's stated regime).

use crate::tensor::{ops, Mat, Scalar};

use super::scan::Monoid;
use super::state3::{Hla3PaperState, Hla3State};
use super::HlaOptions;

// ---------------------------------------------------------------------------
// segment maps
// ---------------------------------------------------------------------------

/// A segment's linear map `Z ↦ Σ_t (k_tᵀ Z k_t) · k_t · w_tᵀ` where `w_t`
/// is `v_t` (numerator map) or the scalar 1 (denominator map, d_v = 1).
#[derive(Debug, Clone, PartialEq)]
pub enum SegMap<T> {
    /// Dense 4-tensor `[d, d, d, dv]`: `T[a,i,j,b] = Σ_t k_a k_i k_j v_b`.
    Dense { d: usize, dv: usize, data: Vec<T> },
    /// Exact factored form: the list of (k_t, w_t) rank terms.
    Factored { d: usize, dv: usize, terms: Vec<(Vec<T>, Vec<T>)> },
}

impl<T: Scalar> SegMap<T> {
    pub fn empty_dense(d: usize, dv: usize) -> Self {
        SegMap::Dense { d, dv, data: vec![T::ZERO; d * d * d * dv] }
    }

    pub fn empty_factored(d: usize, dv: usize) -> Self {
        SegMap::Factored { d, dv, terms: vec![] }
    }

    pub fn token(k: &[T], w: &[T], dense: bool) -> Self {
        let (d, dv) = (k.len(), w.len());
        if !dense {
            return SegMap::Factored { d, dv, terms: vec![(k.to_vec(), w.to_vec())] };
        }
        let mut data = vec![T::ZERO; d * d * d * dv];
        for a in 0..d {
            for i in 0..d {
                for j in 0..d {
                    let base = ((a * d + i) * d + j) * dv;
                    let kk = k[a] * k[i] * k[j];
                    for (b, &wb) in w.iter().enumerate() {
                        data[base + b] = kk * wb;
                    }
                }
            }
        }
        SegMap::Dense { d, dv, data }
    }

    /// Maps compose additively (Eq. 7.6).
    pub fn add(&mut self, other: &SegMap<T>) {
        match (self, other) {
            (SegMap::Dense { data: a, .. }, SegMap::Dense { data: b, .. }) => {
                ops::axpy(T::ONE, b, a);
            }
            (SegMap::Factored { terms: a, .. }, SegMap::Factored { terms: b, .. }) => {
                a.extend(b.iter().cloned());
            }
            _ => panic!("SegMap representation mismatch"),
        }
    }

    /// Apply to a fixed matrix Z: `M[Z] ∈ R^{d×dv}`.
    pub fn apply(&self, z: &Mat<T>) -> Mat<T> {
        match self {
            SegMap::Dense { d, dv, data } => {
                let mut out = Mat::zeros(*d, *dv);
                for a in 0..*d {
                    for i in 0..*d {
                        for j in 0..*d {
                            let zij = z[(i, j)];
                            if zij == T::ZERO {
                                continue;
                            }
                            let base = ((a * d + i) * d + j) * dv;
                            for b in 0..*dv {
                                out[(a, b)] += data[base + b] * zij;
                            }
                        }
                    }
                }
                out
            }
            SegMap::Factored { d, dv, terms } => {
                let mut out = Mat::zeros(*d, *dv);
                for (k, w) in terms {
                    // (k^T Z k) k w^T
                    let zk = z.matvec(k);
                    let alpha = ops::dot(k, &zk);
                    out.add_outer(alpha, k, w);
                }
                out
            }
        }
    }

    pub fn nbytes(&self) -> usize {
        match self {
            SegMap::Dense { data, .. } => data.len() * std::mem::size_of::<T>(),
            SegMap::Factored { terms, .. } => terms
                .iter()
                .map(|(k, w)| (k.len() + w.len()) * std::mem::size_of::<T>())
                .sum(),
        }
    }
}

// ---------------------------------------------------------------------------
// paper ⊗₃ (Eqs. 7.6–7.7)
// ---------------------------------------------------------------------------

/// Paper third-order segment: moments + corrected state + cross statistics
/// + the two segment maps.
#[derive(Debug, Clone)]
pub struct Seg3Paper<T> {
    pub sk: Mat<T>,
    pub sq: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub f: Mat<T>,
    pub eta: Vec<T>,
    pub r_qp: Mat<T>,
    pub r_qm: Vec<T>,
    pub u_kq: Mat<T>,
    pub map_p: SegMap<T>,
    pub map_m: SegMap<T>,
}

impl<T: Scalar> Seg3Paper<T> {
    pub fn empty(d: usize, dv: usize, dense: bool) -> Self {
        Seg3Paper {
            sk: Mat::zeros(d, d),
            sq: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            f: Mat::zeros(d, dv),
            eta: vec![T::ZERO; d],
            r_qp: Mat::zeros(d, dv),
            r_qm: vec![T::ZERO; d],
            u_kq: Mat::zeros(d, d),
            map_p: if dense { SegMap::empty_dense(d, dv) } else { SegMap::empty_factored(d, dv) },
            map_m: if dense { SegMap::empty_dense(d, 1) } else { SegMap::empty_factored(d, 1) },
        }
    }

    /// Algorithm 4 step 2: the single-token segment.
    pub fn token(q: &[T], k: &[T], v: &[T], dense: bool) -> Self {
        let (d, dv) = (q.len(), v.len());
        let mut s = Seg3Paper::empty(d, dv, dense);
        let kq = ops::dot(k, q);
        s.sk.add_outer(T::ONE, k, k);
        s.sq.add_outer(T::ONE, q, q);
        s.p.add_outer(T::ONE, k, v);
        s.m.copy_from_slice(k);
        // F = D^K D^Q D^P = kq^2 k v^T ; eta = kq^2 k
        s.f.add_outer(kq * kq, k, v);
        ops::axpy(kq * kq, k, &mut s.eta);
        // R^{QP} = kq q v^T ; r^{Qm} = kq q ; U^{KQ} = kq k q^T
        s.r_qp.add_outer(kq, q, v);
        ops::axpy(kq, q, &mut s.r_qm);
        s.u_kq.add_outer(kq, k, q);
        s.map_p = SegMap::token(k, v, dense);
        s.map_m = SegMap::token(k, &[T::ONE], dense);
        s
    }

    pub fn as_state(&self) -> Hla3PaperState<T> {
        Hla3PaperState {
            sk: self.sk.clone(),
            sq: self.sq.clone(),
            p: self.p.clone(),
            m: self.m.clone(),
            f: self.f.clone(),
            eta: self.eta.clone(),
        }
    }
}

impl<T: Scalar> Monoid for Seg3Paper<T> {
    fn identity_like(&self) -> Self {
        let dense = matches!(self.map_p, SegMap::Dense { .. });
        Seg3Paper::empty(self.sk.rows, self.p.cols, dense)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let (a, b) = (self, rhs);
        // F_AB = F_A + F_B + S_A^K R_B^{QP} + M_B^{KQP}[S_A^Q] + U_B^{KQ} P_A
        let mut f = a.f.clone();
        f.add_scaled(T::ONE, &b.f);
        f.add_scaled(T::ONE, &a.sk.matmul(&b.r_qp));
        f.add_scaled(T::ONE, &b.map_p.apply(&a.sq));
        f.add_scaled(T::ONE, &b.u_kq.matmul(&a.p));
        // eta analogous
        let mut eta = a.eta.clone();
        ops::axpy(T::ONE, &b.eta, &mut eta);
        ops::axpy(T::ONE, &a.sk.matvec(&b.r_qm), &mut eta);
        let m_eta = b.map_m.apply(&a.sq); // [d, 1]
        ops::axpy(T::ONE, &m_eta.data, &mut eta);
        ops::axpy(T::ONE, &b.u_kq.matvec(&a.m), &mut eta);
        // additive pieces (Eq. 7.6)
        let add_mat = |x: &Mat<T>, y: &Mat<T>| {
            let mut z = x.clone();
            z.add_scaled(T::ONE, y);
            z
        };
        let mut m = a.m.clone();
        ops::axpy(T::ONE, &b.m, &mut m);
        let mut r_qm = a.r_qm.clone();
        ops::axpy(T::ONE, &b.r_qm, &mut r_qm);
        let mut map_p = a.map_p.clone();
        map_p.add(&b.map_p);
        let mut map_m = a.map_m.clone();
        map_m.add(&b.map_m);
        Seg3Paper {
            sk: add_mat(&a.sk, &b.sk),
            sq: add_mat(&a.sq, &b.sq),
            p: add_mat(&a.p, &b.p),
            m,
            f,
            eta,
            r_qp: add_mat(&a.r_qp, &b.r_qp),
            r_qm,
            u_kq: add_mat(&a.u_kq, &b.u_kq),
            map_p,
            map_m,
        }
    }
}

/// Algorithm 4: chunk-parallel paper third order via exclusive scan + local
/// inclusion (γ = 1).  `dense` picks the segment-map representation.
pub fn hla3_paper_scan<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
    dense: bool,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE, "Algorithm 4 is stated for gamma == 1");
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<Seg3Paper<T>> =
        (0..n).map(|t| Seg3Paper::token(q.row(t), k.row(t), v.row(t), dense)).collect();
    let prefixes = super::scan::blelloch_exclusive(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let st = prefixes[t].combine(&leaves[t]).as_state();
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

// ---------------------------------------------------------------------------
// canonical third-order monoid — no segment maps needed
// ---------------------------------------------------------------------------

/// Canonical third-order segment: the cross terms of
/// `F_t = Σ_u (S_u q_u)(q_uᵀ P_u)ᵀ` close over fixed-size statistics:
///
///   R_X = Σ_u q_u (q_uᵀ P^loc_u)ᵀ     [d, dv]
///   r_X = Σ_u (q_uᵀ m^loc_u) q_u      [d]
///   N_X = Σ_u (S^loc_u q_u) q_uᵀ      [d, d]
///
/// with composition (derived in DESIGN.md):
///   F_AB = F_A + F_B + S_A S^Q_B P_A + S_A R_B + N_B P_A
///   R_AB = R_A + R_B + S^Q_B P_A,   N_AB = N_A + N_B + S_A S^Q_B
#[derive(Debug, Clone, PartialEq)]
pub struct Seg3Canon<T> {
    pub s: Mat<T>,
    pub sq: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub f: Mat<T>,
    pub eta: Vec<T>,
    pub r: Mat<T>,
    pub rv: Vec<T>,
    pub nmat: Mat<T>,
}

impl<T: Scalar> Seg3Canon<T> {
    pub fn empty(d: usize, dv: usize) -> Self {
        Seg3Canon {
            s: Mat::zeros(d, d),
            sq: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            f: Mat::zeros(d, dv),
            eta: vec![T::ZERO; d],
            r: Mat::zeros(d, dv),
            rv: vec![T::ZERO; d],
            nmat: Mat::zeros(d, d),
        }
    }

    pub fn token(q: &[T], k: &[T], v: &[T]) -> Self {
        let (d, dv) = (q.len(), v.len());
        let mut s = Seg3Canon::empty(d, dv);
        let kq = ops::dot(k, q);
        s.s.add_outer(T::ONE, k, k);
        s.p.add_outer(T::ONE, k, v);
        s.m.copy_from_slice(k);
        s.sq.add_outer(T::ONE, q, q);
        // local inclusive: S_u q_u = kq k ; q_u^T P_u = kq v ; q_u^T m_u = kq
        s.f.add_outer(kq * kq, k, v);
        ops::axpy(kq * kq, k, &mut s.eta);
        s.r.add_outer(kq, q, v);
        ops::axpy(kq, q, &mut s.rv);
        s.nmat.add_outer(kq, k, q);
        s
    }

    pub fn as_state(&self) -> Hla3State<T> {
        Hla3State {
            s: self.s.clone(),
            p: self.p.clone(),
            m: self.m.clone(),
            f: self.f.clone(),
            eta: self.eta.clone(),
        }
    }

    pub fn nbytes(&self) -> usize {
        std::mem::size_of::<T>()
            * (3 * self.s.data.len() + 2 * self.p.data.len() + self.f.data.len() + 3 * self.m.len())
    }
}

impl<T: Scalar> Monoid for Seg3Canon<T> {
    fn identity_like(&self) -> Self {
        Seg3Canon::empty(self.s.rows, self.p.cols)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let (a, b) = (self, rhs);
        let add = |x: &Mat<T>, y: &Mat<T>| {
            let mut z = x.clone();
            z.add_scaled(T::ONE, y);
            z
        };
        // F_AB = F_A + F_B + S_A S^Q_B P_A + S_A R_B + N_B P_A
        let mut f = add(&a.f, &b.f);
        let s_sq = a.s.matmul(&b.sq);
        f.add_scaled(T::ONE, &s_sq.matmul(&a.p));
        f.add_scaled(T::ONE, &a.s.matmul(&b.r));
        f.add_scaled(T::ONE, &b.nmat.matmul(&a.p));
        // eta_AB = eta_A + eta_B + S_A S^Q_B m_A + S_A r_B + N_B m_A
        let mut eta = a.eta.clone();
        ops::axpy(T::ONE, &b.eta, &mut eta);
        ops::axpy(T::ONE, &s_sq.matvec(&a.m), &mut eta);
        ops::axpy(T::ONE, &a.s.matvec(&b.rv), &mut eta);
        ops::axpy(T::ONE, &b.nmat.matvec(&a.m), &mut eta);
        // R_AB = R_A + R_B + S^Q_B P_A ; r likewise
        let mut r = add(&a.r, &b.r);
        r.add_scaled(T::ONE, &b.sq.matmul(&a.p));
        let mut rv = a.rv.clone();
        ops::axpy(T::ONE, &b.rv, &mut rv);
        ops::axpy(T::ONE, &b.sq.matvec(&a.m), &mut rv);
        // N_AB = N_A + N_B + S_A S^Q_B
        let mut nmat = add(&a.nmat, &b.nmat);
        nmat.add_scaled(T::ONE, &s_sq);
        let mut m = a.m.clone();
        ops::axpy(T::ONE, &b.m, &mut m);
        Seg3Canon {
            s: add(&a.s, &b.s),
            sq: add(&a.sq, &b.sq),
            p: add(&a.p, &b.p),
            m,
            f,
            eta,
            r,
            rv,
            nmat,
        }
    }
}

// ---------------------------------------------------------------------------
// decayed canonical third-order monoid — Seg3Canon generalized to γ < 1
// ---------------------------------------------------------------------------

/// Decayed canonical third-order segment: [`Seg3Canon`] extended to γ ≤ 1
/// so the serving prefill scan covers decayed third-order lanes too (a
/// repo finding; the paper states Algorithm 4 for γ = 1 only).
///
/// Invariants over a segment X of length L with 1-based positions j
/// (derived from [`Hla3State::step`]'s scale-then-add recurrence; "loc"
/// means accumulated within X from zero state):
///
///   S, P, m, F, η — the usual decayed moments / corrected state
///   SQ̃_X = Σ_u γ^{j_u} q_u q_uᵀ          (decay-weighted query moment)
///   R̃_X  = Σ_u q_u (q_uᵀ P^loc_u)ᵀ       (suffix-undecayed cross stats)
///   r̃_X  = Σ_u (q_uᵀ m^loc_u) q_u
///   Ñ_X  = Σ_u (S^loc_u q_u) q_uᵀ
///   ρ_X  = γ^L
///
/// Composition (A then B; exact for concatenation, hence associative):
///
///   F_AB  = ρ_B F_A + F_B + ρ_B (S_A SQ̃_B P_A + S_A R̃_B + Ñ_B P_A)
///   η_AB  = ρ_B η_A + η_B + ρ_B (S_A SQ̃_B m_A + S_A r̃_B + Ñ_B m_A)
///   R̃_AB = R̃_A + R̃_B + SQ̃_B P_A        (r̃, Ñ analogous)
///   SQ̃_AB = SQ̃_A + ρ_A SQ̃_B
///
/// At γ = 1 every ρ is 1, SQ̃ = S^Q and this is exactly [`Seg3Canon`].
#[derive(Debug, Clone, PartialEq)]
pub struct Seg3Decay<T> {
    pub s: Mat<T>,
    pub sq: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub f: Mat<T>,
    pub eta: Vec<T>,
    pub r: Mat<T>,
    pub rv: Vec<T>,
    pub nmat: Mat<T>,
    pub rho: T,
}

impl<T: Scalar> Seg3Decay<T> {
    pub fn empty(d: usize, dv: usize) -> Self {
        Seg3Decay {
            s: Mat::zeros(d, d),
            sq: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            f: Mat::zeros(d, dv),
            eta: vec![T::ZERO; d],
            r: Mat::zeros(d, dv),
            rv: vec![T::ZERO; d],
            nmat: Mat::zeros(d, d),
            rho: T::ONE,
        }
    }

    /// Single-token segment (j = 1, so SQ̃ carries one γ).
    pub fn token(q: &[T], k: &[T], v: &[T], gamma: T) -> Self {
        let (d, dv) = (q.len(), v.len());
        let mut s = Seg3Decay::empty(d, dv);
        let kq = ops::dot(k, q);
        s.s.add_outer(T::ONE, k, k);
        s.p.add_outer(T::ONE, k, v);
        s.m.copy_from_slice(k);
        s.sq.add_outer(gamma, q, q);
        s.f.add_outer(kq * kq, k, v);
        ops::axpy(kq * kq, k, &mut s.eta);
        s.r.add_outer(kq, q, v);
        ops::axpy(kq, q, &mut s.rv);
        s.nmat.add_outer(kq, k, q);
        s.rho = gamma;
        s
    }

    /// Embed a streaming state as a scan segment (resume case; see
    /// [`super::monoid2::Seg2::from_state`]).  The history's SQ̃/R̃/r̃/Ñ
    /// and ρ are set to 0 and 1 — exact while the embedding stays the
    /// left operand of every `combine`, which scan prefixes always do.
    pub fn from_state(st: &Hla3State<T>) -> Self {
        let (d, dv) = (st.s.rows, st.p.cols);
        let mut seg = Seg3Decay::empty(d, dv);
        seg.s = st.s.clone();
        seg.p = st.p.clone();
        seg.m = st.m.clone();
        seg.f = st.f.clone();
        seg.eta = st.eta.clone();
        seg
    }

    pub fn as_state(&self) -> Hla3State<T> {
        Hla3State {
            s: self.s.clone(),
            p: self.p.clone(),
            m: self.m.clone(),
            f: self.f.clone(),
            eta: self.eta.clone(),
        }
    }
}

impl<T: Scalar> Monoid for Seg3Decay<T> {
    fn identity_like(&self) -> Self {
        Seg3Decay::empty(self.s.rows, self.p.cols)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let (a, b) = (self, rhs);
        let (ra, rb) = (a.rho, b.rho);
        let s_sq = a.s.matmul(&b.sq); // S_A SQ̃_B
        // F_AB = ρ_B F_A + F_B + ρ_B (S_A SQ̃_B P_A + S_A R̃_B + Ñ_B P_A)
        let mut f = a.f.clone();
        f.scale(rb);
        f.add_scaled(T::ONE, &b.f);
        f.add_scaled(rb, &s_sq.matmul(&a.p));
        f.add_scaled(rb, &a.s.matmul(&b.r));
        f.add_scaled(rb, &b.nmat.matmul(&a.p));
        // η analogous
        let mut eta: Vec<T> = a.eta.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &b.eta, &mut eta);
        ops::axpy(rb, &s_sq.matvec(&a.m), &mut eta);
        ops::axpy(rb, &a.s.matvec(&b.rv), &mut eta);
        ops::axpy(rb, &b.nmat.matvec(&a.m), &mut eta);
        // cross statistics (suffix-undecayed weights)
        let mut r = a.r.clone();
        r.add_scaled(T::ONE, &b.r);
        r.add_scaled(T::ONE, &b.sq.matmul(&a.p));
        let mut rv = a.rv.clone();
        ops::axpy(T::ONE, &b.rv, &mut rv);
        ops::axpy(T::ONE, &b.sq.matvec(&a.m), &mut rv);
        let mut nmat = a.nmat.clone();
        nmat.add_scaled(T::ONE, &b.nmat);
        nmat.add_scaled(T::ONE, &s_sq);
        let mut sq = a.sq.clone();
        sq.add_scaled(ra, &b.sq);
        // decayed moments
        let mut s = a.s.clone();
        s.scale(rb);
        s.add_scaled(T::ONE, &b.s);
        let mut p = a.p.clone();
        p.scale(rb);
        p.add_scaled(T::ONE, &b.p);
        let mut m: Vec<T> = a.m.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &b.m, &mut m);
        Seg3Decay { s, sq, p, m, f, eta, r, rv, nmat, rho: ra * rb }
    }
}

/// Decayed canonical third order via exclusive Blelloch scan + local
/// inclusion — exact for any γ ∈ (0, 1].
pub fn hla3_decay_scan<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<Seg3Decay<T>> =
        (0..n).map(|t| Seg3Decay::token(q.row(t), k.row(t), v.row(t), opts.gamma)).collect();
    let prefixes = super::scan::blelloch_exclusive(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let st = prefixes[t].combine(&leaves[t]).as_state();
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

/// Canonical third order via exclusive Blelloch scan (γ = 1): the exact
/// chunk-parallel algorithm *without* O(d³ d_v) segment maps.
pub fn hla3_canon_scan<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE);
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<Seg3Canon<T>> =
        (0..n).map(|t| Seg3Canon::token(q.row(t), k.row(t), v.row(t))).collect();
    let prefixes = super::scan::blelloch_exclusive(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let st = prefixes[t].combine(&leaves[t]).as_state();
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::state3::{hla3_paper_serial, hla3_serial};
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn paper_scan_matches_serial_thm72() {
        testing::quick("hla3 paper scan==serial (Thm 7.2)", 10, |rng, _| {
            let n = rng.range(1, 14);
            let (q, k, v) = random(rng, n, 3, 4);
            let opts = HlaOptions::default();
            let serial = hla3_paper_serial(&q, &k, &v, &opts);
            for dense in [false, true] {
                let scan = hla3_paper_scan(&q, &k, &v, &opts, dense);
                testing::assert_close(&serial.data, &scan.data, 1e-9, "paper scan")?;
            }
            Ok(())
        });
    }

    #[test]
    fn dense_and_factored_maps_agree() {
        let mut rng = Rng::new(20);
        let (_q, k, v) = random(&mut rng, 6, 3, 3);
        let mut z = Mat::<f64>::zeros(3, 3);
        for x in &mut z.data {
            *x = rng.normal();
        }
        let mut dense = SegMap::empty_dense(3, 3);
        let mut fact = SegMap::empty_factored(3, 3);
        for t in 0..6 {
            dense.add(&SegMap::token(k.row(t), v.row(t), true));
            fact.add(&SegMap::token(k.row(t), v.row(t), false));
        }
        let a = dense.apply(&z);
        let b = fact.apply(&z);
        testing::assert_close(&a.data, &b.data, 1e-11, "maps").unwrap();
        // the cost asymmetry the paper prices in §7.3:
        assert_eq!(dense.nbytes(), 8 * 3 * 3 * 3 * 3);
        assert_eq!(fact.nbytes(), 8 * 6 * (3 + 3));
    }

    #[test]
    fn canon_scan_matches_serial() {
        testing::quick("hla3 canon scan==serial", 12, |rng, _| {
            let n = rng.range(1, 20);
            let (q, k, v) = random(rng, n, 4, 4);
            let opts = HlaOptions::default();
            let serial = hla3_serial(&q, &k, &v, &opts);
            let scan = hla3_canon_scan(&q, &k, &v, &opts);
            testing::assert_close(&serial.data, &scan.data, 1e-9, "canon scan")
        });
    }

    #[test]
    fn canon_monoid_associative() {
        testing::quick("seg3 canon associativity", 16, |rng, _| {
            let seg = |rng: &mut Rng| {
                let len = rng.range(1, 4);
                let (q, k, v) = random(rng, len, 3, 3);
                (0..len)
                    .map(|t| Seg3Canon::<f64>::token(q.row(t), k.row(t), v.row(t)))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap()
            };
            let (a, b, c) = (seg(rng), seg(rng), seg(rng));
            let l = a.combine(&b).combine(&c);
            let r = a.combine(&b.combine(&c));
            testing::assert_close(&l.f.data, &r.f.data, 1e-10, "F")?;
            testing::assert_close(&l.r.data, &r.r.data, 1e-10, "R")?;
            testing::assert_close(&l.nmat.data, &r.nmat.data, 1e-10, "N")
        });
    }

    #[test]
    fn decay_scan_matches_serial_all_gammas() {
        testing::quick("hla3 decay scan==serial", 12, |rng, _| {
            let n = rng.range(1, 24);
            let (q, k, v) = random(rng, n, 4, 4);
            for gamma in [1.0, 0.9, 0.98] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let serial = hla3_serial(&q, &k, &v, &opts);
                let scan = hla3_decay_scan(&q, &k, &v, &opts);
                testing::assert_close(&serial.data, &scan.data, 1e-9, &format!("g={gamma}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn decay_monoid_associative() {
        testing::quick("seg3 decay associativity", 16, |rng, _| {
            let seg = |rng: &mut Rng| {
                let len = rng.range(1, 4);
                let (q, k, v) = random(rng, len, 3, 3);
                (0..len)
                    .map(|t| Seg3Decay::<f64>::token(q.row(t), k.row(t), v.row(t), 0.9))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap()
            };
            let (a, b, c) = (seg(rng), seg(rng), seg(rng));
            let l = a.combine(&b).combine(&c);
            let r = a.combine(&b.combine(&c));
            testing::assert_close(&l.f.data, &r.f.data, 1e-10, "F")?;
            testing::assert_close(&l.eta, &r.eta, 1e-10, "eta")?;
            testing::assert_close(&l.r.data, &r.r.data, 1e-10, "R")?;
            testing::assert_close(&l.rv, &r.rv, 1e-10, "r")?;
            testing::assert_close(&l.nmat.data, &r.nmat.data, 1e-10, "N")?;
            testing::assert_close(&l.sq.data, &r.sq.data, 1e-10, "SQ")?;
            if (l.rho - r.rho).abs() > 1e-12 {
                return Err("rho".into());
            }
            Ok(())
        });
    }

    #[test]
    fn decay_monoid_reduces_to_canon_at_gamma_one() {
        let mut rng = Rng::new(21);
        let (q, k, v) = random(&mut rng, 7, 3, 4);
        let dec = (0..7)
            .map(|t| Seg3Decay::<f64>::token(q.row(t), k.row(t), v.row(t), 1.0))
            .reduce(|a, b| a.combine(&b))
            .unwrap();
        let can = (0..7)
            .map(|t| Seg3Canon::<f64>::token(q.row(t), k.row(t), v.row(t)))
            .reduce(|a, b| a.combine(&b))
            .unwrap();
        testing::assert_close(&dec.f.data, &can.f.data, 1e-11, "F").unwrap();
        testing::assert_close(&dec.eta, &can.eta, 1e-11, "eta").unwrap();
        testing::assert_close(&dec.sq.data, &can.sq.data, 1e-11, "SQ").unwrap();
        testing::assert_close(&dec.r.data, &can.r.data, 1e-11, "R").unwrap();
        testing::assert_close(&dec.nmat.data, &can.nmat.data, 1e-11, "N").unwrap();
        assert_eq!(dec.rho, 1.0);
    }

    #[test]
    fn canon_segment_constant_size_vs_paper_maps() {
        // §7.3: paper segment maps are O(d^3 dv); canonical segments are O(d^2).
        let d = 8;
        let canon = Seg3Canon::<f64>::token(&vec![1.0; d], &vec![1.0; d], &vec![1.0; d]);
        let paper_dense = Seg3Paper::<f64>::token(&vec![1.0; d], &vec![1.0; d], &vec![1.0; d], true);
        assert!(canon.nbytes() < paper_dense.map_p.nbytes() / 8);
    }
}
