//! Packed symmetric storage for the key moment S (§5.2): only the upper
//! triangle (d(d+1)/2 entries) is stored, halving state bandwidth without
//! changing the algebra.  Bench E12 measures the tradeoff.

use crate::tensor::{ops, Mat, Scalar};

/// Symmetric d×d matrix stored as the upper triangle, row-major:
/// index(i, j) for i <= j is `i*d - i(i-1)/2 + (j - i)`.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedSym<T> {
    pub d: usize,
    pub data: Vec<T>,
}

impl<T: Scalar> PackedSym<T> {
    pub fn zeros(d: usize) -> Self {
        PackedSym { d, data: vec![T::ZERO; d * (d + 1) / 2] }
    }

    #[inline]
    fn idx(&self, i: usize, j: usize) -> usize {
        let (i, j) = if i <= j { (i, j) } else { (j, i) };
        i * (2 * self.d - i + 1) / 2 + (j - i)
    }

    pub fn get(&self, i: usize, j: usize) -> T {
        self.data[self.idx(i, j)]
    }

    /// S += k kᵀ (the §3.1 rank-1 update), touching only the triangle.
    pub fn add_outer_self(&mut self, k: &[T]) {
        debug_assert_eq!(k.len(), self.d);
        let d = self.d;
        let mut off = 0;
        for i in 0..d {
            let ki = k[i];
            let row = &mut self.data[off..off + (d - i)];
            // row holds S[i, i..d]
            for (r, &kj) in row.iter_mut().zip(&k[i..]) {
                *r += ki * kj;
            }
            off += d - i;
        }
    }

    pub fn scale(&mut self, alpha: T) {
        ops::scale(alpha, &mut self.data);
    }

    /// y = S x (symmetric mat-vec over the packed triangle).
    pub fn matvec(&self, x: &[T]) -> Vec<T> {
        let d = self.d;
        let mut y = vec![T::ZERO; d];
        let mut off = 0;
        for i in 0..d {
            let row = &self.data[off..off + (d - i)];
            // diagonal
            y[i] += row[0] * x[i];
            // off-diagonal contributes to both y[i] and y[j]
            for (dj, &s) in row.iter().enumerate().skip(1) {
                let j = i + dj;
                y[i] += s * x[j];
                y[j] += s * x[i];
            }
            off += d - i;
        }
        y
    }

    pub fn to_dense(&self) -> Mat<T> {
        let mut m = Mat::zeros(self.d, self.d);
        for i in 0..self.d {
            for j in 0..self.d {
                m[(i, j)] = self.get(i, j);
            }
        }
        m
    }

    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<T>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    #[test]
    fn packed_matches_dense() {
        testing::quick("packed S == dense S", 16, |rng, _| {
            let d = rng.range(1, 12);
            let mut packed = PackedSym::<f64>::zeros(d);
            let mut dense = Mat::<f64>::zeros(d, d);
            for _ in 0..5 {
                let k: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
                packed.add_outer_self(&k);
                dense.add_outer(1.0, &k, &k);
                packed.scale(0.95);
                dense.scale(0.95);
            }
            testing::assert_close(&packed.to_dense().data, &dense.data, 1e-12, "dense")?;
            let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            testing::assert_close(&packed.matvec(&x), &dense.matvec(&x), 1e-12, "matvec")
        });
    }

    #[test]
    fn storage_is_half() {
        let p = PackedSym::<f32>::zeros(64);
        assert_eq!(p.nbytes(), 4 * 64 * 65 / 2);
        assert!(p.nbytes() < 4 * 64 * 64 * 3 / 5);
    }
}
