//! The paper's algebra in pure Rust: streaming states, associative
//! (semidirect-product) monoids, Blelloch scans and the chunk-parallel
//! driver, all generic over `f32`/`f64`.
//!
//! This is both (a) the reference/verification substrate for the AOT HLO
//! path and (b) the engine behind the CPU baselines and the paper
//! experiment harnesses (benches E1–E5, E9, E12).
//!
//! Module map (paper section in parens):
//! * [`state2`]  — masked second-order streaming state (Thm 3.1, Alg 1, §4.3)
//! * [`monoid2`] — (decayed) semidirect product ⊕ (Eq 4.1) + S-tilde correction
//! * [`ahla`]    — asymmetric variant streaming + monoid (§6, Thm 6.1, Eq 6.2)
//! * [`state3`]  — third order: canonical rank-1 form and the paper-literal
//!                 Eq. 7.5 recurrence (Alg 3)
//! * [`monoid3`] — paper's ⊗₃ with segment maps, dense *and* factored (Alg 4,
//!                 Thm 7.2) + the cheap canonical third-order monoid and its
//!                 decayed generalization (`Seg3Decay`, any γ — serving
//!                 prefill uses it)
//! * [`scan`]    — generic exclusive/inclusive Blelloch scan over any monoid
//!                 (Thm 4.1, Rmk 4.2), serial and multi-threaded chunked
//! * [`chunk`]   — two-level intra-/inter-chunk parallel driver (§4.2, Fig 1C),
//!                 incl. the non-identity-initial-segment form (resume)
//! * [`packed`]  — packed symmetric storage for S (§5.2)

pub mod ahla;
pub mod backward;
pub mod chunk;
pub mod monoid2;
pub mod monoid3;
pub mod packed;
pub mod scan;
pub mod state2;
pub mod state3;

use crate::tensor::Scalar;

/// How (and whether) to normalize operator outputs (§3, Eqs. 3.2/3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormMode {
    /// Unnormalized — the paper's default operator.
    None,
    /// Divide by `den + eps` (Eq. 3.2/3.4 verbatim).
    Linear,
    /// Divide by `|den| + eps` (sign-safe; used by the LM configs).
    Abs,
}

impl NormMode {
    pub fn apply<T: Scalar>(self, num: &mut [T], den: T, eps: T) {
        match self {
            NormMode::None => {}
            NormMode::Linear => {
                let inv = T::ONE / (den + eps);
                for x in num {
                    *x = *x * inv;
                }
            }
            NormMode::Abs => {
                let inv = T::ONE / (den.abs_() + eps);
                for x in num {
                    *x = *x * inv;
                }
            }
        }
    }

    pub fn parse(s: &str) -> Option<NormMode> {
        match s {
            "none" => Some(NormMode::None),
            "linear" => Some(NormMode::Linear),
            "abs" => Some(NormMode::Abs),
            _ => None,
        }
    }
}

/// Operator options shared by every HLA variant.
#[derive(Debug, Clone, Copy)]
pub struct HlaOptions<T> {
    /// Exponential decay γ ∈ (0, 1] (§4.3).
    pub gamma: T,
    /// Ridge λ (Algorithm 1's `S_eff = S + λI`); second order only.
    pub lambda: T,
    pub norm: NormMode,
    pub eps: T,
    /// `false` selects the prefix ("unmasked") Eq. 3.1 operator.
    pub masked: bool,
}

impl<T: Scalar> Default for HlaOptions<T> {
    fn default() -> Self {
        HlaOptions {
            gamma: T::ONE,
            lambda: T::ZERO,
            norm: NormMode::None,
            eps: T::from_f64(1e-6),
            masked: true,
        }
    }
}

impl<T: Scalar> HlaOptions<T> {
    pub fn with_gamma(mut self, gamma: f64) -> Self {
        self.gamma = T::from_f64(gamma);
        self
    }

    pub fn with_lambda(mut self, lambda: f64) -> Self {
        self.lambda = T::from_f64(lambda);
        self
    }

    pub fn with_norm(mut self, norm: NormMode) -> Self {
        self.norm = norm;
        self
    }

    pub fn unmasked(mut self) -> Self {
        self.masked = false;
        self
    }
}
