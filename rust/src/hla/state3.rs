//! Third-order HLA streaming (§7).
//!
//! Two operators (see DESIGN.md erratum #4):
//!
//! * [`Hla3State`] — the **canonical** strictly causal masked W-product
//!   `(((W Wᵀ)∘L) W)∘L V`, which streams with the rank-1 recurrence
//!   `F_t = γ F + (S_t q_t)(q_tᵀ P_t)ᵀ`.  Cheaper than the paper's form:
//!   state (S, P, m, F, η), cost O(d² + d·d_v)/token.
//! * [`Hla3PaperState`] — the paper-literal Eq. (7.5)/Algorithm 3 corrected
//!   state (S^K, S^Q, P, m, F, η).  Its chunk scan (Algorithm 4 / Thm 7.2)
//!   lives in [`super::monoid3`].

use crate::tensor::{ops, Mat, Scalar};

use super::HlaOptions;

/// Canonical third-order state.
#[derive(Debug, Clone, PartialEq)]
pub struct Hla3State<T> {
    pub s: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub f: Mat<T>,
    pub eta: Vec<T>,
}

impl<T: Scalar> Hla3State<T> {
    pub fn new(d: usize, dv: usize) -> Self {
        Hla3State {
            s: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            f: Mat::zeros(d, dv),
            eta: vec![T::ZERO; d],
        }
    }

    pub fn nbytes(&self) -> usize {
        std::mem::size_of::<T>()
            * (self.s.data.len()
                + self.p.data.len()
                + self.m.len()
                + self.f.data.len()
                + self.eta.len())
    }

    /// Fused decayed kernels, bit-identical to the old scale-then-accumulate
    /// form (see `Hla2State::step`).  F/η's decay moves from before the
    /// moment reads to their own fused updates — safe because nothing reads
    /// F/η in between.
    pub fn step(&mut self, q: &[T], k: &[T], v: &[T], gamma: T) {
        self.s.decay_add_outer(gamma, T::ONE, k, k);
        self.p.decay_add_outer(gamma, T::ONE, k, v);
        ops::scale_axpy(gamma, T::ONE, k, &mut self.m);
        let sq = self.s.matvec(q); // S_t q_t
        let qp = self.p.t_matvec(q); // q_t^T P_t
        let qm = ops::dot(q, &self.m); // q_t^T m_t
        self.f.decay_add_outer(gamma, T::ONE, &sq, &qp);
        ops::scale_axpy(gamma, qm, &sq, &mut self.eta);
    }

    pub fn output(&self, q: &[T], opts: &HlaOptions<T>) -> Vec<T> {
        let mut num = self.f.t_matvec(q);
        let den = ops::dot(q, &self.eta);
        opts.norm.apply(&mut num, den, opts.eps);
        num
    }
}

/// Full-sequence canonical third order.
pub fn hla3_serial<T: Scalar>(q: &Mat<T>, k: &Mat<T>, v: &Mat<T>, opts: &HlaOptions<T>) -> Mat<T> {
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let mut st = Hla3State::new(d, dv);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

/// Materialized canonical oracle `(((W Wᵀ)∘L) W)∘L V` (γ = 1).
pub fn hla3_quadratic<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE);
    let n = q.rows;
    let mut w = q.matmul_t(k);
    for i in 0..n {
        for j in (i + 1)..n {
            w[(i, j)] = T::ZERO;
        }
    }
    let mut wwt = w.matmul_t(&w);
    for i in 0..n {
        for j in (i + 1)..n {
            wwt[(i, j)] = T::ZERO;
        }
    }
    let t3 = wwt.matmul(&w);
    let mut out = Mat::zeros(n, v.cols);
    for t in 0..n {
        let mut acc = vec![T::ZERO; v.cols];
        let mut den = T::ZERO;
        for j in 0..=t {
            ops::axpy(t3[(t, j)], v.row(j), &mut acc);
            den += t3[(t, j)];
        }
        opts.norm.apply(&mut acc, den, opts.eps);
        out.row_mut(t).copy_from_slice(&acc);
    }
    out
}

/// Paper-literal Eq. (7.5) corrected state (Algorithm 3 semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct Hla3PaperState<T> {
    pub sk: Mat<T>,
    pub sq: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub f: Mat<T>,
    pub eta: Vec<T>,
}

impl<T: Scalar> Hla3PaperState<T> {
    pub fn new(d: usize, dv: usize) -> Self {
        Hla3PaperState {
            sk: Mat::zeros(d, d),
            sq: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            f: Mat::zeros(d, dv),
            eta: vec![T::ZERO; d],
        }
    }

    /// Eq. (7.5) with monoid-consistent decay (carry attenuated by γ,
    /// including inside the cross terms).  The four cross terms reduce to
    /// rank-1 updates — see `python/compile/kernels/ref.py` for the algebra.
    pub fn step(&mut self, q: &[T], k: &[T], v: &[T], gamma: T) {
        if gamma != T::ONE {
            self.sk.scale(gamma);
            self.sq.scale(gamma);
            self.p.scale(gamma);
            ops::scale(gamma, &mut self.m);
            self.f.scale(gamma);
            ops::scale(gamma, &mut self.eta);
        }
        let kq = ops::dot(k, q);
        let sk_q = self.sk.matvec(q); // S_{t-1}^K q
        let sq_k = self.sq.matvec(k); // S_{t-1}^Q k
        let k_sq_k = ops::dot(k, &sq_k);
        let qp = self.p.t_matvec(q); // q^T P_{t-1}
        let qm = ops::dot(q, &self.m);
        // F += (S^K q)(kq v)^T + k(k_sq_k v)^T + k(kq q^T P)^T + k(kq^2 v)^T
        let kq_v: Vec<T> = v.iter().map(|&x| x * kq).collect();
        self.f.add_outer(T::ONE, &sk_q, &kq_v);
        let mut inner: Vec<T> = v.iter().map(|&x| x * (k_sq_k + kq * kq)).collect();
        for (a, b) in inner.iter_mut().zip(&qp) {
            *a += kq * *b;
        }
        self.f.add_outer(T::ONE, k, &inner);
        // eta += kq S^K q + (k_sq_k + kq qm + kq^2) k
        ops::axpy(kq, &sk_q, &mut self.eta);
        ops::axpy(k_sq_k + kq * qm + kq * kq, k, &mut self.eta);
        // moments
        self.sk.add_outer(T::ONE, k, k);
        self.sq.add_outer(T::ONE, q, q);
        self.p.add_outer(T::ONE, k, v);
        ops::axpy(T::ONE, k, &mut self.m);
    }

    pub fn output(&self, q: &[T], opts: &HlaOptions<T>) -> Vec<T> {
        let mut num = self.f.t_matvec(q);
        let den = ops::dot(q, &self.eta);
        opts.norm.apply(&mut num, den, opts.eps);
        num
    }
}

/// Full-sequence paper-literal third order (Algorithm 3).
pub fn hla3_paper_serial<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let mut st = Hla3PaperState::new(d, dv);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

/// The paper's G-form (Theorem 7.1 cross-summaries), direct from the
/// definitions — O(d³)/token, used only to check F-form consistency.
pub fn hla3_paper_gform<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE);
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let mut sk = Mat::<T>::zeros(d, d);
    let mut sq = Mat::<T>::zeros(d, d);
    let mut p = Mat::<T>::zeros(d, dv);
    let mut m = vec![T::ZERO; d];
    let mut g1 = Mat::<T>::zeros(d, dv);
    let mut g2 = Mat::<T>::zeros(d, dv);
    let mut g3 = Mat::<T>::zeros(d, dv);
    let mut h1 = vec![T::ZERO; d];
    let mut h2 = vec![T::ZERO; d];
    let mut h3 = vec![T::ZERO; d];
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let (qt, kt, vt) = (q.row(t), k.row(t), v.row(t));
        // G1 += kk^T S^Q_{t-1} P_{t-1}, etc.
        let sqp = sq.matmul(&p);
        let k_sqp = sqp.t_matvec(kt);
        g1.add_outer(T::ONE, kt, &k_sqp);
        let sqm = sq.matvec(&m);
        ops::axpy(ops::dot(kt, &sqm), kt, &mut h1);
        let sk_q = sk.matvec(qt);
        let qp = p.t_matvec(qt);
        g2.add_outer(T::ONE, &sk_q, &qp);
        ops::axpy(ops::dot(qt, &m), &sk_q, &mut h2);
        let sq_k = sq.matvec(kt);
        let sk_sq_k = sk.matvec(&sq_k);
        g3.add_outer(T::ONE, &sk_sq_k, vt);
        ops::axpy(T::ONE, &sk_sq_k, &mut h3);
        // moments
        sk.add_outer(T::ONE, kt, kt);
        sq.add_outer(T::ONE, qt, qt);
        p.add_outer(T::ONE, kt, vt);
        ops::axpy(T::ONE, kt, &mut m);
        // num = q^T (S^K S^Q P - G1 - G2 - G3)
        let skq = sk.t_matvec(qt); // q^T S^K
        let skq_sq = sq.t_matvec(&skq); // q^T S^K S^Q
        let mut num = p.t_matvec(&skq_sq);
        for (i, x) in num.iter_mut().enumerate() {
            *x = *x
                - ops::dot(qt, &col(&g1, i))
                - ops::dot(qt, &col(&g2, i))
                - ops::dot(qt, &col(&g3, i));
        }
        let den = ops::dot(&skq_sq, &m)
            - ops::dot(qt, &h1)
            - ops::dot(qt, &h2)
            - ops::dot(qt, &h3);
        let mut o = num;
        opts.norm.apply(&mut o, den, opts.eps);
        out.row_mut(t).copy_from_slice(&o);
    }
    out
}

fn col<T: Scalar>(m: &Mat<T>, j: usize) -> Vec<T> {
    (0..m.rows).map(|i| m[(i, j)]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn canonical_matches_quadratic() {
        testing::quick("hla3 canonical==quadratic", 16, |rng, _| {
            let n = rng.range(1, 20);
            let (q, k, v) = random(rng, n, 4, 4);
            let opts = HlaOptions::default();
            testing::assert_close(
                &hla3_serial(&q, &k, &v, &opts).data,
                &hla3_quadratic(&q, &k, &v, &opts).data,
                1e-9,
                "canonical",
            )
        });
    }

    #[test]
    fn paper_fform_matches_gform() {
        testing::quick("hla3 paper F==G (Thm 7.1 consistency)", 12, |rng, _| {
            let n = rng.range(1, 16);
            let (q, k, v) = random(rng, n, 3, 4);
            let opts = HlaOptions::default();
            testing::assert_close(
                &hla3_paper_serial(&q, &k, &v, &opts).data,
                &hla3_paper_gform(&q, &k, &v, &opts).data,
                1e-9,
                "paper-form",
            )
        });
    }

    #[test]
    fn paper_form_differs_from_canonical() {
        let mut rng = Rng::new(13);
        let (q, k, v) = random(&mut rng, 12, 4, 4);
        let opts = HlaOptions::default();
        let paper = hla3_paper_serial(&q, &k, &v, &opts);
        let canon = hla3_serial(&q, &k, &v, &opts);
        assert!(paper.max_abs_diff(&canon) > 1e-9, "erratum #4: operators differ");
        // but they agree on the first token
        testing::assert_close(paper.row(0), canon.row(0), 1e-10, "t=0").unwrap();
    }

    #[test]
    fn both_forms_are_causal() {
        let mut rng = Rng::new(14);
        let (q, k, v) = random(&mut rng, 14, 3, 3);
        let (q2, k2, v2) = random(&mut rng, 14, 3, 3);
        let opts = HlaOptions::default().with_gamma(0.9);
        let t = 6usize;
        let splice = |a: &Mat<f64>, b: &Mat<f64>| {
            let mut m = a.clone();
            for i in (t + 1)..14 {
                m.row_mut(i).copy_from_slice(b.row(i));
            }
            m
        };
        for f in [hla3_serial::<f64>, hla3_paper_serial::<f64>] {
            let base = f(&q, &k, &v, &opts);
            let pert = f(&splice(&q, &q2), &splice(&k, &k2), &splice(&v, &v2), &opts);
            for i in 0..=t {
                testing::assert_close(base.row(i), pert.row(i), 1e-12, "causal").unwrap();
            }
        }
    }

    #[test]
    fn canonical_state_smaller_than_paper_state() {
        // canonical drops the S^Q moment: (S,P,F) + (m,eta) vs paper's
        // (S^K,S^Q,P,F) + (m,eta)
        let canon = Hla3State::<f32>::new(64, 64);
        assert_eq!(canon.nbytes(), 4 * (3 * 64 * 64 + 2 * 64));
        let paper = Hla3PaperState::<f32>::new(64, 64);
        let paper_bytes = 4
            * (paper.sk.data.len()
                + paper.sq.data.len()
                + paper.p.data.len()
                + paper.m.len()
                + paper.f.data.len()
                + paper.eta.len());
        assert_eq!(paper_bytes, 4 * (4 * 64 * 64 + 2 * 64));
        assert!(canon.nbytes() < paper_bytes);
    }
}
