//! Two-level chunk-parallel driver (§4.2, Figure 1C): intra-chunk scans in
//! parallel worker threads, an exclusive inter-chunk scan over chunk
//! summaries, then per-token merge — the training-time execution skeleton
//! shared by second order, AHLA and (γ=1) third order.

use crate::tensor::{Mat, Scalar};

use super::ahla::SegA;
use super::monoid2::Seg2;
use super::scan::{blelloch_exclusive, inclusive_scan, Monoid};
use super::HlaOptions;

/// Generic two-level chunked scan.
///
/// * `leaves`   — one monoid element per token.
/// * `chunk`    — chunk width w.
/// * `threads`  — worker threads for the intra-chunk phase (≥ 1).
/// * `emit(t, inclusive_state)` — called for every token with its inclusive
///   prefix state, in order within each chunk (chunks may emit in parallel,
///   so `emit` receives a per-chunk output row instead of locking).
pub fn chunked_scan<M, T, F>(
    leaves: &[M],
    chunk: usize,
    threads: usize,
    dv: usize,
    emit: F,
) -> Mat<T>
where
    M: Monoid + Send + Sync,
    T: Scalar + Send + Sync,
    F: Fn(usize, &M, &mut [T]) + Send + Sync,
{
    chunked_scan_from(None, leaves, chunk, threads, dv, emit)
}

/// [`chunked_scan`] with a *non-identity initial segment* — the resume
/// case: a lane restored from a `SessionSnapshot` re-enters the scan as
/// the segment to the left of every leaf (Remark 4.2 with P_0 = init
/// instead of E).  `init` is always the **left** operand of `combine`, so
/// a state-only embedding (e.g. [`Seg2::from_state`]) whose auxiliary
/// fields are unknowable is still exact: `combine` only folds a left
/// argument's aux fields into result fields that no downstream output
/// reads when the result itself stays a left operand.
pub fn chunked_scan_from<M, T, F>(
    init: Option<&M>,
    leaves: &[M],
    chunk: usize,
    threads: usize,
    dv: usize,
    emit: F,
) -> Mat<T>
where
    M: Monoid + Send + Sync,
    T: Scalar + Send + Sync,
    F: Fn(usize, &M, &mut [T]) + Send + Sync,
{
    let n = leaves.len();
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);

    // phase 1: per-chunk summaries (parallel)
    let mut summaries: Vec<Option<M>> = vec![None; nc];
    {
        let summaries_slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(summaries_slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut acc = leaves[lo].clone();
            for leaf in &leaves[lo + 1..hi] {
                acc = acc.combine(leaf);
            }
            **slot = Some(acc);
        });
    }
    let summaries: Vec<M> = summaries.into_iter().map(|s| s.unwrap()).collect();

    // phase 2: exclusive scan over the B_c chunk summaries, then fold the
    // initial segment in on the left (init ⊕ P_c stays a left operand)
    let carries = blelloch_exclusive(&summaries);
    let carries: Vec<M> = match init {
        Some(i) => carries.iter().map(|c| i.combine(c)).collect(),
        None => carries,
    };

    // phase 3: intra-chunk inclusive scans + merge + emit (parallel)
    {
        let rows: Vec<(usize, &mut [T])> = {
            // split `out` into per-chunk row bands
            let mut bands = Vec::with_capacity(nc);
            let mut rest = out.data.as_mut_slice();
            for c in 0..nc {
                let lo = c * chunk;
                let hi = (lo + chunk).min(n);
                let (band, tail) = rest.split_at_mut((hi - lo) * dv);
                bands.push((c, band));
                rest = tail;
            }
            bands
        };
        parallel_chunks(rows, threads, |_, (c, band)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let local = inclusive_scan(&leaves[lo..hi]);
            for (i, loc) in local.iter().enumerate() {
                let merged = carries[c].combine(loc);
                let row = &mut band[i * dv..(i + 1) * dv];
                emit(lo + i, &merged, row);
            }
        });
    }
    out
}

/// Run `f(index, item)` over items on up to `threads` scoped threads.
/// (Shared with [`crate::prefill`], whose per-head scans reuse this
/// partitioning for chunk summaries and per-chunk recurrences.)
pub(crate) fn parallel_chunks<I, F>(items: Vec<I>, threads: usize, f: F)
where
    I: Send,
    F: Fn(usize, &mut I) + Send + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || items.len() <= 1 {
        for (i, mut item) in items.into_iter().enumerate() {
            f(i, &mut item);
        }
        return;
    }
    let mut indexed: Vec<(usize, I)> = items.into_iter().enumerate().collect();
    let per = indexed.len().div_ceil(threads);
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = indexed.as_mut_slice();
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (batch, tail) = rest.split_at_mut(take);
            rest = tail;
            scope.spawn(move || {
                for (i, item) in batch.iter_mut() {
                    f(*i, item);
                }
            });
        }
    });
}

/// Chunk-parallel masked second-order HLA (outputs identical to serial).
///
/// Hot-path layout (rust/DESIGN.md §Perf): chunk summaries are built by
/// *serial rank-1 stepping* (not per-token monoid combines, which cost an
/// O(d³) matmul + five matrix clones per token), the exclusive Blelloch
/// scan runs over the B_c summaries only, and each chunk then serial-steps
/// from its carried-in state.  ~20× faster than the naive monoid
/// materialization at d=32 while producing bit-identical activations.
pub fn hla2_chunked<T: Scalar + Send + Sync>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
    chunk: usize,
    threads: usize,
) -> Mat<T> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);

    // phase 1: chunk summaries via serial stepping (rank-1 updates only)
    let mut summaries: Vec<Option<Seg2<T>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut st = crate::hla::state2::Hla2State::new(d, dv);
            let mut stp = Mat::zeros(d, d); // plain S-tilde
            let mut rho = T::ONE;
            for t in lo..hi {
                st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                stp.add_outer(T::ONE, k.row(t), k.row(t));
                rho = rho * opts.gamma;
            }
            **slot = Some(Seg2 { s: st.s, c: st.c, m: st.m, g: st.g, h: st.h, st: stp, rho });
        });
    }
    let summaries: Vec<Seg2<T>> = summaries.into_iter().map(|s| s.unwrap()).collect();

    // phase 2: exclusive scan across the B_c chunk summaries
    let carries = blelloch_exclusive(&summaries);

    // phase 3: per-chunk serial recurrence from the carried-in state
    {
        let mut bands = Vec::with_capacity(nc);
        let mut rest = out.data.as_mut_slice();
        for c in 0..nc {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let (band, tail) = rest.split_at_mut((hi - lo) * dv);
            bands.push((c, band));
            rest = tail;
        }
        parallel_chunks(bands, threads, |_, (c, band)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut st = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let o = st.output(q.row(t), opts);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
        });
    }
    out
}

/// Chunk-parallel AHLA (same hot-path layout as [`hla2_chunked`]).
pub fn ahla_chunked<T: Scalar + Send + Sync>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
    chunk: usize,
    threads: usize,
) -> Mat<T> {
    let n = q.rows;
    let (d, dv) = (q.cols, v.cols);
    let mut out = Mat::zeros(n, dv);
    if n == 0 {
        return out;
    }
    let nc = n.div_ceil(chunk);
    let mut summaries: Vec<Option<SegA<T>>> = vec![None; nc];
    {
        let slots: Vec<_> = summaries.iter_mut().collect();
        parallel_chunks(slots, threads, |c, slot| {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut st = crate::hla::ahla::AhlaState::new(d, dv);
            let mut r = Mat::zeros(d, d); // plain R^KQ
            let mut rho = T::ONE;
            for t in lo..hi {
                st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                r.add_outer(T::ONE, k.row(t), q.row(t));
                rho = rho * opts.gamma;
            }
            **slot = Some(SegA { r, p: st.p, m: st.m, e: st.e, n: st.n, rho });
        });
    }
    let summaries: Vec<SegA<T>> = summaries.into_iter().map(|s| s.unwrap()).collect();
    let carries = blelloch_exclusive(&summaries);
    {
        let mut bands = Vec::with_capacity(nc);
        let mut rest = out.data.as_mut_slice();
        for c in 0..nc {
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let (band, tail) = rest.split_at_mut((hi - lo) * dv);
            bands.push((c, band));
            rest = tail;
        }
        parallel_chunks(bands, threads, |_, (c, band)| {
            let c = *c;
            let lo = c * chunk;
            let hi = (lo + chunk).min(n);
            let mut st = carries[c].as_state();
            for (i, t) in (lo..hi).enumerate() {
                st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                let o = st.output(q.row(t), opts);
                band[i * dv..(i + 1) * dv].copy_from_slice(&o);
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::ahla::ahla_serial;
    use crate::hla::state2::hla2_serial;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn chunked_matches_serial_all_widths() {
        testing::quick("chunked==serial (Fig 1C)", 12, |rng, _| {
            let n = rng.range(1, 70);
            let (q, k, v) = random(rng, n, 4, 4);
            for gamma in [1.0, 0.92] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let want = hla2_serial(&q, &k, &v, &opts);
                for chunk in [1, 3, 8, 64] {
                    for threads in [1, 4] {
                        let got = hla2_chunked(&q, &k, &v, &opts, chunk, threads);
                        testing::assert_close(
                            &want.data,
                            &got.data,
                            1e-10,
                            &format!("w={chunk} th={threads}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ahla_chunked_matches_serial() {
        testing::quick("ahla chunked==serial", 8, |rng, _| {
            let n = rng.range(1, 50);
            let (q, k, v) = random(rng, n, 3, 5);
            let opts = HlaOptions::default().with_gamma(0.9);
            let want = ahla_serial(&q, &k, &v, &opts);
            let got = ahla_chunked(&q, &k, &v, &opts, 8, 3);
            testing::assert_close(&want.data, &got.data, 1e-10, "ahla chunked")
        });
    }

    // -- chunked_scan_from: non-identity initial segment (the resume case) --
    //
    // Each property builds a random "history", embeds it as the scan's
    // initial segment two ways (the true segment with correct auxiliary
    // fields, and the state-only embedding a SessionSnapshot restore can
    // afford), and checks both against the serial recurrence stepped from
    // the history's state — over chunk widths 1, non-divisors, and w > n.

    const WIDTHS: [usize; 4] = [1, 3, 8, 64];

    #[test]
    fn scan_from_init_matches_serial_seg2() {
        testing::quick("seg2 init scan==serial (resume)", 10, |rng, _| {
            let n = rng.range(1, 40);
            let hist = rng.range(1, 12);
            let (d, dv) = (3, 4);
            for gamma in [1.0, 0.9] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let (hq, hk, hv) = random(rng, hist, d, dv);
                let (q, k, v) = random(rng, n, d, dv);
                // serial reference from the history's state
                let mut st = crate::hla::state2::Hla2State::<f64>::new(d, dv);
                for t in 0..hist {
                    st.step(hq.row(t), hk.row(t), hv.row(t), opts.gamma);
                }
                let mut want = Mat::zeros(n, dv);
                {
                    let mut s = st.clone();
                    for t in 0..n {
                        s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                        want.row_mut(t).copy_from_slice(&s.output(q.row(t), &opts));
                    }
                }
                let true_seg = (0..hist)
                    .map(|t| Seg2::<f64>::token(hq.row(t), hk.row(t), hv.row(t), opts.gamma))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap();
                let embed = Seg2::from_state(&st);
                let leaves: Vec<Seg2<f64>> = (0..n)
                    .map(|t| Seg2::token(q.row(t), k.row(t), v.row(t), opts.gamma))
                    .collect();
                for init in [&true_seg, &embed] {
                    for w in WIDTHS {
                        for threads in [1, 3] {
                            let got = chunked_scan_from(Some(init), &leaves, w, threads, dv, |t, seg, row| {
                                row.copy_from_slice(&seg.as_state().output(q.row(t), &opts));
                            });
                            testing::assert_close(
                                &want.data,
                                &got.data,
                                1e-10,
                                &format!("seg2 g={gamma} w={w} th={threads}"),
                            )?;
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scan_from_init_matches_serial_sega() {
        testing::quick("segA init scan==serial (resume)", 10, |rng, _| {
            let n = rng.range(1, 40);
            let hist = rng.range(1, 12);
            let (d, dv) = (3, 3);
            for gamma in [1.0, 0.85] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let (hq, hk, hv) = random(rng, hist, d, dv);
                let (q, k, v) = random(rng, n, d, dv);
                let mut st = crate::hla::ahla::AhlaState::<f64>::new(d, dv);
                for t in 0..hist {
                    st.step(hq.row(t), hk.row(t), hv.row(t), opts.gamma);
                }
                let mut want = Mat::zeros(n, dv);
                {
                    let mut s = st.clone();
                    for t in 0..n {
                        s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                        want.row_mut(t).copy_from_slice(&s.output(q.row(t), &opts));
                    }
                }
                let true_seg = (0..hist)
                    .map(|t| SegA::<f64>::token(hq.row(t), hk.row(t), hv.row(t), opts.gamma))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap();
                let embed = SegA::from_state(&st);
                let leaves: Vec<SegA<f64>> = (0..n)
                    .map(|t| SegA::token(q.row(t), k.row(t), v.row(t), opts.gamma))
                    .collect();
                for init in [&true_seg, &embed] {
                    for w in WIDTHS {
                        let got = chunked_scan_from(Some(init), &leaves, w, 3, dv, |t, seg, row| {
                            row.copy_from_slice(&seg.as_state().output(q.row(t), &opts));
                        });
                        testing::assert_close(
                            &want.data,
                            &got.data,
                            1e-10,
                            &format!("segA g={gamma} w={w}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn scan_from_init_matches_serial_seg3() {
        use crate::hla::monoid3::Seg3Decay;
        use crate::hla::state3::Hla3State;
        testing::quick("seg3 init scan==serial (resume)", 8, |rng, _| {
            let n = rng.range(1, 32);
            let hist = rng.range(1, 10);
            let (d, dv) = (3, 3);
            for gamma in [1.0, 0.9] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let (hq, hk, hv) = random(rng, hist, d, dv);
                let (q, k, v) = random(rng, n, d, dv);
                let mut st = Hla3State::<f64>::new(d, dv);
                for t in 0..hist {
                    st.step(hq.row(t), hk.row(t), hv.row(t), opts.gamma);
                }
                let mut want = Mat::zeros(n, dv);
                {
                    let mut s = st.clone();
                    for t in 0..n {
                        s.step(q.row(t), k.row(t), v.row(t), opts.gamma);
                        want.row_mut(t).copy_from_slice(&s.output(q.row(t), &opts));
                    }
                }
                let true_seg = (0..hist)
                    .map(|t| Seg3Decay::<f64>::token(hq.row(t), hk.row(t), hv.row(t), opts.gamma))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap();
                let embed = Seg3Decay::from_state(&st);
                let leaves: Vec<Seg3Decay<f64>> = (0..n)
                    .map(|t| Seg3Decay::token(q.row(t), k.row(t), v.row(t), opts.gamma))
                    .collect();
                for init in [&true_seg, &embed] {
                    for w in WIDTHS {
                        let got = chunked_scan_from(Some(init), &leaves, w, 3, dv, |t, seg, row| {
                            row.copy_from_slice(&seg.as_state().output(q.row(t), &opts));
                        });
                        testing::assert_close(
                            &want.data,
                            &got.data,
                            1e-9,
                            &format!("seg3 g={gamma} w={w}"),
                        )?;
                    }
                }
            }
            Ok(())
        });
    }
}
