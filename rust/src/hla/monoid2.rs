//! Second-order segment monoid: the masked (decayed) semidirect product of
//! §4.1–4.2, with the S-tilde correction (DESIGN.md erratum #2) that makes
//! the decayed operator associative *and* consistent with the serial
//! recurrence:
//!
//!   S_AB  = ρ_B S_A + S_B            C, m analogous
//!   G_AB  = ρ_B G_A + G_B + S̃_B (ρ_B C_A)
//!   h_AB  = ρ_B h_A + h_B + S̃_B (ρ_B m_A)
//!   S̃_AB = S̃_A + S̃_B               (plain, undecayed key moment)
//!   ρ_AB  = ρ_A ρ_B
//!
//! At γ = 1, S̃ = S and this is the paper's Eq. (4.1) verbatim.

use crate::tensor::{ops, Mat, Scalar};

use super::scan::Monoid;
use super::state2::Hla2State;
use super::HlaOptions;

/// Segment summary for masked second-order HLA.
#[derive(Debug, Clone, PartialEq)]
pub struct Seg2<T> {
    pub s: Mat<T>,
    pub c: Mat<T>,
    pub m: Vec<T>,
    pub g: Mat<T>,
    pub h: Vec<T>,
    /// Plain (undecayed) key moment S̃ used in the cross terms.
    pub st: Mat<T>,
    /// Segment attenuation ρ = γ^len.
    pub rho: T,
}

impl<T: Scalar> Seg2<T> {
    pub fn empty(d: usize, dv: usize) -> Self {
        Seg2 {
            s: Mat::zeros(d, d),
            c: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            g: Mat::zeros(d, dv),
            h: vec![T::ZERO; d],
            st: Mat::zeros(d, d),
            rho: T::ONE,
        }
    }

    /// Single-token segment T_t (G = h = 0; ρ = γ).
    pub fn token(q: &[T], k: &[T], v: &[T], gamma: T) -> Self {
        let (d, dv) = (q.len(), v.len());
        let mut seg = Seg2::empty(d, dv);
        seg.s.add_outer(T::ONE, k, k);
        seg.st = seg.s.clone();
        seg.c.add_outer(T::ONE, q, v);
        seg.m.copy_from_slice(q);
        seg.rho = gamma;
        seg
    }

    /// Embed a streaming state as a scan segment — the resume case: a lane
    /// restored from a `SessionSnapshot` becomes the non-identity initial
    /// segment of the prompt scan (Remark 4.2 with P_0 ≠ E).
    ///
    /// The history's plain S̃ moment and ρ are unknowable from the state
    /// tuple, so they are set to 0 and 1.  That is exact **as long as the
    /// embedding stays the left operand of every `combine`**: `combine`
    /// reads its left argument's `st`/`rho` only additively into result
    /// fields that no output consumes while the result itself stays a left
    /// operand (which prefixes in an exclusive scan always do).
    pub fn from_state(st: &Hla2State<T>) -> Self {
        Seg2 {
            s: st.s.clone(),
            c: st.c.clone(),
            m: st.m.clone(),
            g: st.g.clone(),
            h: st.h.clone(),
            st: Mat::zeros(st.d(), st.d()),
            rho: T::ONE,
        }
    }

    /// View the segment (interpreted as the prefix 1..t) as a state tuple.
    pub fn as_state(&self) -> Hla2State<T> {
        Hla2State {
            s: self.s.clone(),
            c: self.c.clone(),
            m: self.m.clone(),
            g: self.g.clone(),
            h: self.h.clone(),
        }
    }
}

impl<T: Scalar> Monoid for Seg2<T> {
    fn identity_like(&self) -> Self {
        Seg2::empty(self.s.rows, self.c.cols)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let a = self;
        let b = rhs;
        let rb = b.rho;
        // G = ρ_B G_A + G_B + S̃_B (ρ_B C_A)
        let mut g = a.g.clone();
        g.scale(rb);
        g.add_scaled(T::ONE, &b.g);
        let mut ca = a.c.clone();
        ca.scale(rb);
        g.add_scaled(T::ONE, &b.st.matmul(&ca));
        // h = ρ_B h_A + h_B + S̃_B (ρ_B m_A)
        let mut h: Vec<T> = a.h.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &b.h, &mut h);
        let ma: Vec<T> = a.m.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &b.st.matvec(&ma), &mut h);
        // additive decayed moments
        let mut s = a.s.clone();
        s.scale(rb);
        s.add_scaled(T::ONE, &b.s);
        let mut c = ca; // ρ_B C_A already
        c.add_scaled(T::ONE, &b.c);
        let mut m = ma;
        ops::axpy(T::ONE, &b.m, &mut m);
        // plain S̃ adds undecayed
        let mut st = a.st.clone();
        st.add_scaled(T::ONE, &b.st);
        Seg2 { s, c, m, g, h, st, rho: a.rho * b.rho }
    }
}

/// Full-sequence outputs via an inclusive token-level scan (Fig 1C route).
pub fn hla2_scan<T: Scalar>(q: &Mat<T>, k: &Mat<T>, v: &Mat<T>, opts: &HlaOptions<T>) -> Mat<T> {
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<Seg2<T>> =
        (0..n).map(|t| Seg2::token(q.row(t), k.row(t), v.row(t), opts.gamma)).collect();
    let states = super::scan::inclusive_scan(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let o = states[t].as_state().output(q.row(t), opts);
        out.row_mut(t).copy_from_slice(&o);
    }
    out
}

/// Same outputs via *exclusive Blelloch scan + local inclusion* — the
/// paper's Algorithm 1 statement (Remark 4.2), exercising the tree scan.
pub fn hla2_blelloch<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<Seg2<T>> =
        (0..n).map(|t| Seg2::token(q.row(t), k.row(t), v.row(t), opts.gamma)).collect();
    let prefixes = super::scan::blelloch_exclusive(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let inclusive = prefixes[t].combine(&leaves[t]);
        let o = inclusive.as_state().output(q.row(t), opts);
        out.row_mut(t).copy_from_slice(&o);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::state2::hla2_serial;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn associativity_random_segments() {
        testing::quick("seg2 associativity", 32, |rng, _| {
            let d = rng.range(1, 6);
            let dv = rng.range(1, 6);
            let gamma = if rng.bool(0.5) { 1.0 } else { 0.8 };
            let seg = |rng: &mut Rng| {
                let len = rng.range(1, 4);
                let (q, k, v) = random(rng, len, d, dv);
                (0..len)
                    .map(|t| Seg2::<f64>::token(q.row(t), k.row(t), v.row(t), gamma))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap()
            };
            let (a, b, c) = (seg(rng), seg(rng), seg(rng));
            let left = a.combine(&b).combine(&c);
            let right = a.combine(&b.combine(&c));
            testing::assert_close(&left.g.data, &right.g.data, 1e-11, "G assoc")?;
            testing::assert_close(&left.s.data, &right.s.data, 1e-11, "S assoc")?;
            testing::assert_close(&left.h, &right.h, 1e-11, "h assoc")?;
            if (left.rho - right.rho).abs() > 1e-12 {
                return Err("rho".into());
            }
            Ok(())
        });
    }

    #[test]
    fn scan_matches_serial() {
        testing::quick("hla2 scan==serial (Thm 4.1)", 20, |rng, _| {
            let n = rng.range(1, 33);
            let (q, k, v) = random(rng, n, 4, 5);
            for gamma in [1.0, 0.9] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let serial = hla2_serial(&q, &k, &v, &opts);
                let scan = hla2_scan(&q, &k, &v, &opts);
                testing::assert_close(&serial.data, &scan.data, 1e-10, "incl scan")?;
                let tree = hla2_blelloch(&q, &k, &v, &opts);
                testing::assert_close(&serial.data, &tree.data, 1e-10, "blelloch")?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_token_combine_equals_step() {
        let mut rng = Rng::new(3);
        let (q, k, v) = random(&mut rng, 2, 3, 3);
        let gamma = 0.95;
        // state route
        let mut st = Hla2State::<f64>::new(3, 3);
        st.step(q.row(0), k.row(0), v.row(0), gamma);
        st.step(q.row(1), k.row(1), v.row(1), gamma);
        // monoid route
        let t0 = Seg2::token(q.row(0), k.row(0), v.row(0), gamma);
        let t1 = Seg2::token(q.row(1), k.row(1), v.row(1), gamma);
        let both = t0.combine(&t1).as_state();
        testing::assert_close(&st.g.data, &both.g.data, 1e-12, "g").unwrap();
        testing::assert_close(&st.s.data, &both.s.data, 1e-12, "s").unwrap();
        testing::assert_close(&st.m, &both.m, 1e-12, "m").unwrap();
    }
}
