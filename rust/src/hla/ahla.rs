//! Asymmetric HLA (§6): streaming state (Theorem 6.1 / Algorithm 2) and the
//! chunk-scan monoid (Eq. 6.2) with the plain-R correction (DESIGN.md
//! erratum #3: R^{KQ} must compose *undecayed* for the decayed operator to
//! match Algorithm 2; at γ = 1 both conventions coincide).

use crate::tensor::{ops, Mat, Scalar};

use super::scan::Monoid;
use super::HlaOptions;

/// AHLA state (per head): P [d,dv], m [d], E [d,dv], n [d].
#[derive(Debug, Clone, PartialEq)]
pub struct AhlaState<T> {
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub e: Mat<T>,
    pub n: Vec<T>,
}

impl<T: Scalar> AhlaState<T> {
    pub fn new(d: usize, dv: usize) -> Self {
        AhlaState {
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            e: Mat::zeros(d, dv),
            n: vec![T::ZERO; d],
        }
    }

    pub fn nbytes(&self) -> usize {
        std::mem::size_of::<T>()
            * (self.p.data.len() + self.m.len() + self.e.data.len() + self.n.len())
    }

    /// Algorithm 2's update: P/m first, then E/n with the inclusive P/m.
    ///
    /// Fused decayed kernels, bit-identical to the old scale-then-accumulate
    /// pairs (see `Hla2State::step`).
    pub fn step(&mut self, q: &[T], k: &[T], v: &[T], gamma: T) {
        self.p.decay_add_outer(gamma, T::ONE, k, v);
        ops::scale_axpy(gamma, T::ONE, k, &mut self.m);
        let r = self.p.t_matvec(q); // q^T P_t
        let s = ops::dot(q, &self.m); // q^T m_t
        self.e.decay_add_outer(gamma, T::ONE, k, &r);
        ops::scale_axpy(gamma, s, k, &mut self.n);
    }

    pub fn output(&self, q: &[T], opts: &HlaOptions<T>) -> Vec<T> {
        let mut num = self.e.t_matvec(q);
        let den = ops::dot(q, &self.n);
        opts.norm.apply(&mut num, den, opts.eps);
        num
    }
}

/// Full-sequence serial AHLA.
pub fn ahla_serial<T: Scalar>(q: &Mat<T>, k: &Mat<T>, v: &Mat<T>, opts: &HlaOptions<T>) -> Mat<T> {
    let (n, d, dv) = (q.rows, q.cols, v.cols);
    let mut st = AhlaState::new(d, dv);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        st.step(q.row(t), k.row(t), v.row(t), opts.gamma);
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

/// Materialized oracle (Eq. 6.1): ((A A) ∘ L) V with A = L ∘ QKᵀ, γ = 1.
pub fn ahla_quadratic<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    assert_eq!(opts.gamma, T::ONE, "quadratic oracle requires gamma == 1");
    let n = q.rows;
    let mut a = q.matmul_t(k);
    for i in 0..n {
        for j in (i + 1)..n {
            a[(i, j)] = T::ZERO;
        }
    }
    let aa = a.matmul(&a);
    let mut out = Mat::zeros(n, v.cols);
    for t in 0..n {
        let mut acc = vec![T::ZERO; v.cols];
        let mut den = T::ZERO;
        for j in 0..=t {
            ops::axpy(aa[(t, j)], v.row(j), &mut acc);
            den += aa[(t, j)];
        }
        opts.norm.apply(&mut acc, den, opts.eps);
        out.row_mut(t).copy_from_slice(&acc);
    }
    out
}

/// AHLA segment summary: (R̃, P, m, E, n, ρ) — R̃ composes undecayed.
#[derive(Debug, Clone, PartialEq)]
pub struct SegA<T> {
    pub r: Mat<T>,
    pub p: Mat<T>,
    pub m: Vec<T>,
    pub e: Mat<T>,
    pub n: Vec<T>,
    pub rho: T,
}

impl<T: Scalar> SegA<T> {
    pub fn empty(d: usize, dv: usize) -> Self {
        SegA {
            r: Mat::zeros(d, d),
            p: Mat::zeros(d, dv),
            m: vec![T::ZERO; d],
            e: Mat::zeros(d, dv),
            n: vec![T::ZERO; d],
            rho: T::ONE,
        }
    }

    /// Single-token segment: E uses the token's own inclusive P (= k vᵀ).
    pub fn token(q: &[T], k: &[T], v: &[T], gamma: T) -> Self {
        let (d, dv) = (q.len(), v.len());
        let mut seg = SegA::empty(d, dv);
        seg.r.add_outer(T::ONE, k, q);
        seg.p.add_outer(T::ONE, k, v);
        seg.m.copy_from_slice(k);
        let qk = ops::dot(q, k);
        let scaled_v: Vec<T> = v.iter().map(|&x| x * qk).collect();
        seg.e.add_outer(T::ONE, k, &scaled_v);
        for (ni, &ki) in seg.n.iter_mut().zip(k) {
            *ni = qk * ki;
        }
        seg.rho = gamma;
        seg
    }

    /// Embed a streaming state as a scan segment (resume case; see
    /// [`super::monoid2::Seg2::from_state`]).  The history's plain R̃ and ρ
    /// are set to 0 and 1 — exact while the embedding stays the left
    /// operand of every `combine`, which scan prefixes always do.
    pub fn from_state(st: &AhlaState<T>) -> Self {
        SegA {
            r: Mat::zeros(st.p.rows, st.p.rows),
            p: st.p.clone(),
            m: st.m.clone(),
            e: st.e.clone(),
            n: st.n.clone(),
            rho: T::ONE,
        }
    }

    pub fn as_state(&self) -> AhlaState<T> {
        AhlaState { p: self.p.clone(), m: self.m.clone(), e: self.e.clone(), n: self.n.clone() }
    }
}

impl<T: Scalar> Monoid for SegA<T> {
    fn identity_like(&self) -> Self {
        SegA::empty(self.r.rows, self.p.cols)
    }

    fn combine(&self, rhs: &Self) -> Self {
        let (a, b) = (self, rhs);
        let rb = b.rho;
        let mut pa = a.p.clone();
        pa.scale(rb);
        let ma: Vec<T> = a.m.iter().map(|&x| x * rb).collect();
        // E = ρ_B E_A + E_B + R̃_B (ρ_B P_A)
        let mut e = a.e.clone();
        e.scale(rb);
        e.add_scaled(T::ONE, &b.e);
        e.add_scaled(T::ONE, &b.r.matmul(&pa));
        // n = ρ_B n_A + n_B + R̃_B (ρ_B m_A)
        let mut n: Vec<T> = a.n.iter().map(|&x| x * rb).collect();
        ops::axpy(T::ONE, &b.n, &mut n);
        ops::axpy(T::ONE, &b.r.matvec(&ma), &mut n);
        // moments
        let mut p = pa;
        p.add_scaled(T::ONE, &b.p);
        let mut m = ma;
        ops::axpy(T::ONE, &b.m, &mut m);
        let mut r = a.r.clone();
        r.add_scaled(T::ONE, &b.r);
        SegA { r, p, m, e, n, rho: a.rho * b.rho }
    }
}

/// Full-sequence outputs via the exclusive Blelloch scan + local inclusion.
pub fn ahla_blelloch<T: Scalar>(
    q: &Mat<T>,
    k: &Mat<T>,
    v: &Mat<T>,
    opts: &HlaOptions<T>,
) -> Mat<T> {
    let (n, dv) = (q.rows, v.cols);
    let leaves: Vec<SegA<T>> =
        (0..n).map(|t| SegA::token(q.row(t), k.row(t), v.row(t), opts.gamma)).collect();
    let prefixes = super::scan::blelloch_exclusive(&leaves);
    let mut out = Mat::zeros(n, dv);
    for t in 0..n {
        let st = prefixes[t].combine(&leaves[t]).as_state();
        out.row_mut(t).copy_from_slice(&st.output(q.row(t), opts));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hla::state2::hla2_serial;
    use crate::testing;
    use crate::util::rng::Rng;

    fn random(rng: &mut Rng, n: usize, d: usize, dv: usize) -> (Mat<f64>, Mat<f64>, Mat<f64>) {
        let s = 1.0 / (d as f64).sqrt();
        let mk = |rng: &mut Rng, r: usize, c: usize, sc: f64| {
            let mut m = Mat::zeros(r, c);
            for x in &mut m.data {
                *x = rng.normal() * sc;
            }
            m
        };
        (mk(rng, n, d, s), mk(rng, n, d, s), mk(rng, n, dv, 1.0))
    }

    #[test]
    fn serial_matches_quadratic() {
        testing::quick("ahla serial==quadratic (Thm 6.1)", 20, |rng, _| {
            let n = rng.range(1, 24);
            let (q, k, v) = random(rng, n, 4, 4);
            let opts = HlaOptions::default();
            let a = ahla_serial(&q, &k, &v, &opts);
            let b = ahla_quadratic(&q, &k, &v, &opts);
            testing::assert_close(&a.data, &b.data, 1e-10, "ahla")
        });
    }

    #[test]
    fn scan_matches_serial_with_decay() {
        testing::quick("ahla scan==serial (Eq 6.2)", 20, |rng, _| {
            let n = rng.range(1, 33);
            let (q, k, v) = random(rng, n, 3, 5);
            for gamma in [1.0, 0.85] {
                let opts = HlaOptions::default().with_gamma(gamma);
                let serial = ahla_serial(&q, &k, &v, &opts);
                let tree = ahla_blelloch(&q, &k, &v, &opts);
                testing::assert_close(&serial.data, &tree.data, 1e-10, "scan")?;
            }
            Ok(())
        });
    }

    #[test]
    fn monoid_associative() {
        testing::quick("segA associativity", 24, |rng, _| {
            let seg = |rng: &mut Rng| {
                let len = rng.range(1, 4);
                let (q, k, v) = random(rng, len, 3, 3);
                (0..len)
                    .map(|t| SegA::<f64>::token(q.row(t), k.row(t), v.row(t), 0.9))
                    .reduce(|a, b| a.combine(&b))
                    .unwrap()
            };
            let (a, b, c) = (seg(rng), seg(rng), seg(rng));
            let l = a.combine(&b).combine(&c);
            let r = a.combine(&b.combine(&c));
            testing::assert_close(&l.e.data, &r.e.data, 1e-11, "E")?;
            testing::assert_close(&l.n, &r.n, 1e-11, "n")
        });
    }

    #[test]
    fn differs_from_symmetric_second_order() {
        let mut rng = Rng::new(12);
        let (q, k, v) = random(&mut rng, 12, 4, 4);
        let opts = HlaOptions::default();
        let asym = ahla_serial(&q, &k, &v, &opts);
        let sym = hla2_serial(&q, &k, &v, &opts);
        assert!(asym.max_abs_diff(&sym) > 1e-8, "AHLA should differ from AAᵀV (§6.3)");
    }

    #[test]
    fn state_cost_is_first_order_sized() {
        // §6.1 cost note: AHLA's streaming state is O(d dv + d), like
        // first-order linear attention (no d x d metric).
        let st = AhlaState::<f32>::new(64, 64);
        assert_eq!(st.nbytes(), 4 * (2 * 64 * 64 + 2 * 64));
    }
}
