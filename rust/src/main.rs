//! `hla` binary entrypoint — see `cli::USAGE`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = hla::cli::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
