//! Cluster mode: a standalone front-end process routing the line-JSON
//! client protocol across N independent `hla serve` replicas, with
//! wire-level session migration and mid-stream failover.
//!
//! The pieces:
//!
//! - [`registry`] — the front-end's fleet view: liveness, load, strikes,
//!   and the identity each replica announced at registration.
//! - [`frontend`] — the router itself: policy placement (shared
//!   [`PolicyCore`](crate::coordinator::router::PolicyCore) with the
//!   in-process router), generation relay with token-prefix suppression
//!   on replay, the session desk of CRC-framed snapshots, fleet-wide
//!   stats fan-out, and drain.
//! - [`health`] — the probe loop: 3 strikes to death (with desk
//!   rebalance), exponential-backoff revival through the full register
//!   handshake.
//! - [`replica`] — the artifact-free fixture engine behind
//!   `hla serve --fixture true`, the replica the cluster tests and
//!   `e19_cluster` bench actually run.
//! - [`stats`] — the router's own metrics plane (relay latency, router-
//!   added overhead, failover tallies), surfaced as the `"router"`
//!   section of the stats fan-out reply.
//! - [`events`] — the structured cluster event log: an in-memory ring
//!   (queryable as `{"events": N}`) plus an optional JSONL journal
//!   recording register/strike/dead/revived/failover/attach/detach/drain
//!   in order.
//!
//! Why this is cheap at all: HLA decode state is constant-size per
//! sequence (Theorem 3.1), so "move a conversation" is a few-KB snapshot
//! frame over the control plane — not an O(context) KV-cache transfer.
//! `benches/e19_cluster.rs` quantifies exactly that gap; the wire
//! contract lives in `docs/PROTOCOL.md` ("Control plane").

pub mod events;
pub mod frontend;
pub mod health;
pub mod registry;
pub mod replica;
pub mod stats;

pub use events::{Event, EventKind, EventLog};
pub use frontend::{serve_frontend, Frontend, FrontendCfg};
pub use health::spawn_health;
pub use registry::{Replica, ReplicaRegistry};
pub use replica::{
    fixture_identity, spawn_fixture_engine, spawn_fixture_engine_pooled,
    spawn_fixture_engine_traced,
};
pub use stats::RouterStats;
