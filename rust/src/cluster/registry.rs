//! Replica registry: the front-end's view of its fleet.
//!
//! One [`Replica`] per `hla serve` process, holding liveness, the
//! front-end-maintained in-flight count (the load input to
//! [`crate::coordinator::router::PolicyCore::pick`]), the health-check
//! strike count, and the identity learned from the `register` control
//! verb.  Everything is atomics + one small mutex so relay threads, the
//! health checker, and the accept loop share it without contention.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// One replica process as seen from the front-end.
pub struct Replica {
    /// `host:port` of the replica's line-JSON listener.
    pub addr: String,
    alive: AtomicBool,
    /// Requests this front-end currently has relaying to the replica.
    in_flight: AtomicUsize,
    /// Consecutive failed health probes (reset on any success).
    strikes: AtomicUsize,
    /// In-flight count the replica itself reported on its last health
    /// reply (includes load from other front-ends; informational).
    reported_in_flight: AtomicU64,
    /// Config name from `register` (empty until registered).
    cfg_name: Mutex<String>,
    /// State-layout fingerprint from `register` (0 until registered).
    fingerprint: AtomicU64,
    /// Sessions moved onto / off this replica by this front-end.
    pub attaches: AtomicU64,
    pub detaches: AtomicU64,
}

impl Replica {
    fn new(addr: &str) -> Replica {
        Replica {
            addr: addr.to_string(),
            // replicas start dead; `register` is what brings one up
            alive: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            strikes: AtomicUsize::new(0),
            reported_in_flight: AtomicU64::new(0),
            cfg_name: Mutex::new(String::new()),
            fingerprint: AtomicU64::new(0),
            attaches: AtomicU64::new(0),
            detaches: AtomicU64::new(0),
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    pub fn mark_alive(&self) {
        self.strikes.store(0, Ordering::Relaxed);
        self.alive.store(true, Ordering::Relaxed);
    }

    pub fn mark_dead(&self) {
        self.alive.store(false, Ordering::Relaxed);
    }

    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Bracket a relayed request (load accounting for least-loaded).
    pub fn begin_request(&self) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    pub fn end_request(&self) {
        // saturating: a racing mark_dead/mark_alive cycle must not wrap
        let _ = self.in_flight.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(1))
        });
    }

    /// Record one failed health probe; returns the strike count so far.
    pub fn strike(&self) -> usize {
        self.strikes.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn clear_strikes(&self) {
        self.strikes.store(0, Ordering::Relaxed);
    }

    pub fn strikes(&self) -> usize {
        self.strikes.load(Ordering::Relaxed)
    }

    pub fn set_reported_in_flight(&self, n: u64) {
        self.reported_in_flight.store(n, Ordering::Relaxed);
    }

    pub fn reported_in_flight(&self) -> u64 {
        self.reported_in_flight.load(Ordering::Relaxed)
    }

    /// Store the identity a `register` round-trip returned.
    pub fn set_identity(&self, cfg_name: &str, fingerprint: u64) {
        *self.cfg_name.lock().unwrap() = cfg_name.to_string();
        self.fingerprint.store(fingerprint, Ordering::Relaxed);
    }

    pub fn cfg_name(&self) -> String {
        self.cfg_name.lock().unwrap().clone()
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint.load(Ordering::Relaxed)
    }
}

/// The fleet: index-stable (the policy core's replica indices point into
/// this vec for the front-end's whole lifetime; death flips a flag, it
/// never removes an entry).
pub struct ReplicaRegistry {
    pub replicas: Vec<Replica>,
}

impl ReplicaRegistry {
    pub fn new(addrs: &[String]) -> ReplicaRegistry {
        ReplicaRegistry { replicas: addrs.iter().map(|a| Replica::new(a)).collect() }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_alive()).count()
    }

    /// Indices of live replicas (stats fan-out, rebalance targets).
    pub fn alive_indices(&self) -> Vec<usize> {
        (0..self.replicas.len()).filter(|&i| self.replicas[i].is_alive()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_load_accounting() {
        let reg = ReplicaRegistry::new(&["a:1".into(), "b:2".into()]);
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.alive_count(), 0, "replicas start dead until registered");
        let r = &reg.replicas[0];
        r.set_identity("tiny", 0xDEAD);
        r.mark_alive();
        assert!(r.is_alive());
        assert_eq!(r.cfg_name(), "tiny");
        assert_eq!(r.fingerprint(), 0xDEAD);
        assert_eq!(reg.alive_indices(), vec![0]);

        r.begin_request();
        r.begin_request();
        assert_eq!(r.in_flight(), 2);
        r.end_request();
        r.end_request();
        r.end_request(); // over-release must not wrap
        assert_eq!(r.in_flight(), 0);

        assert_eq!(r.strike(), 1);
        assert_eq!(r.strike(), 2);
        r.mark_dead();
        assert!(!r.is_alive());
        r.mark_alive();
        assert_eq!(r.strikes(), 0, "revival clears strikes");
    }
}
